//! Quickstart: train the paper's §5.1 logistic-regression objective with
//! Gossip-PGA on an 8-node ring and compare against Parallel & Gossip SGD.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Expected output: three loss curves on the same iteration grid; Gossip-PGA
//! hugs the Parallel-SGD curve while Gossip SGD lags (the transient stage),
//! and the simulated wall-clock (alpha-beta model calibrated to the paper's
//! Table 17 cluster) shows PGA cheaper than Parallel per iteration.

use std::sync::Arc;

use gossip_pga::algorithms::{AlgorithmKind, SlowMoParams};
use gossip_pga::comm::{BackendKind, Compression};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::CostModel;
use gossip_pga::eventsim::Regime;
use gossip_pga::harness::Table;
use gossip_pga::metrics::{smooth, transient_stage_scaled};
use gossip_pga::optim::LrSchedule;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let n = 20; // beta = 0.967 on the ring — sparse enough to see the gap
    let steps = 600;
    let h = 16;
    let seed = 42;
    let topo = Topology::ring(n);
    println!(
        "# quickstart: {n}-node ring (beta = {:.4}), non-iid logistic regression, H = {h}\n",
        topo.beta()
    );

    let rt = Arc::new(Runtime::load_default()?);
    let mut histories = Vec::new();
    for algo in [AlgorithmKind::Parallel, AlgorithmKind::Gossip, AlgorithmKind::GossipPga] {
        let (workload, init) = logreg_workload(rt.clone(), n, 2000, true, seed)?;
        let opts = TrainerOptions {
            algorithm: algo,
            topology: Topology::ring(n),
            period: h,
            aga_init_period: 4,
            aga_warmup: 50,
            lr: LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 },
            momentum: 0.0,
            nesterov: false,
            seed,
            slowmo: SlowMoParams::default(),
            cost: CostModel::calibrated_resnet50(),
            cost_dim: 25_500_000, // bill comms as if this were ResNet-50
            node_costs: None,
            log_every: 25,
            threads: 1,
            stealing: false,
            pin: false,
            pipeline_depth: 1,
            regime: Regime::Bsp,
            max_staleness: 0,
            backend: BackendKind::Shared,
            compression: Compression::None,
            round_timeout: 0.0,
            listen: "127.0.0.1:0".to_string(),
        };
        let mut trainer = Trainer::new(workload, init, opts)?;
        let hist = trainer.run(steps, algo.display())?;
        println!(
            "{:<14} final loss {:.5}  sim time {:.2} h",
            algo.display(),
            hist.final_loss(),
            hist.final_sim_hours()
        );
        histories.push(hist);
    }

    println!("\nloss curves (every 25 iterations):");
    let mut t = Table::new(&["iter", "Parallel", "Gossip", "Gossip-PGA"]);
    for i in 0..histories[0].records.len() {
        t.rowv(vec![
            histories[0].records[i].step.to_string(),
            format!("{:.5}", histories[0].records[i].loss),
            format!("{:.5}", histories[1].records[i].loss),
            format!("{:.5}", histories[2].records[i].loss),
        ]);
    }
    t.print();

    let par = histories[0].losses();
    for (name, hist) in [("Gossip SGD", &histories[1]), ("Gossip-PGA", &histories[2])] {
        let ts = transient_stage_scaled(&smooth(&hist.losses(), 3), &par, 0.05)
            .map(|i| (histories[0].records[i].step + 1).to_string())
            .unwrap_or_else(|| "> budget".into());
        println!("{name:<12} transient stage ~ {ts} iterations (5%-of-progress band vs Parallel)");
    }
    Ok(())
}
