//! Gossip-AGA demo: watch the adaptive period grow as the loss falls
//! (Algorithm 2), and compare against fixed-H Gossip-PGA on the same
//! simulated-time axis.
//!
//!     make artifacts && cargo run --release --example adaptive_period

use std::sync::Arc;

use gossip_pga::algorithms::{AlgorithmKind, CommAction, SlowMoParams};
use gossip_pga::comm::{BackendKind, Compression};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::CostModel;
use gossip_pga::eventsim::Regime;
use gossip_pga::harness::Table;
use gossip_pga::optim::LrSchedule;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn opts(algo: AlgorithmKind, n: usize, seed: u64) -> TrainerOptions {
    TrainerOptions {
        algorithm: algo,
        topology: Topology::ring(n),
        period: 6,
        aga_init_period: 4,
        aga_warmup: 40,
        lr: LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 },
        momentum: 0.0,
        nesterov: false,
        seed,
        slowmo: SlowMoParams::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 50,
        threads: 1,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn main() -> anyhow::Result<()> {
    let n = 12;
    let steps = 900;
    let seed = 7;
    let rt = Arc::new(Runtime::load_default()?);

    // --- Gossip-AGA with a sync trace -------------------------------------
    let (workload, init) = logreg_workload(rt.clone(), n, 2000, true, seed)?;
    let mut aga = Trainer::new(workload, init, opts(AlgorithmKind::GossipAga, n, seed))?;
    println!("# Gossip-AGA on a {n}-node ring: global syncs and the adaptive period\n");
    let mut t = Table::new(&["sync at iter", "mean loss", "next period H"]);
    let mut syncs = 0usize;
    for k in 0..steps {
        let action = aga.step_once()?;
        if action == CommAction::GlobalAverage {
            syncs += 1;
            t.rowv(vec![
                k.to_string(),
                format!("{:.5}", aga.mean_loss()),
                aga.current_period().to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\n{} global averages over {steps} iterations ({:.1}% of iterations), final H = {}",
        syncs,
        100.0 * syncs as f64 / steps as f64,
        aga.current_period()
    );

    // --- fixed-H PGA comparison on the simulated clock --------------------
    let (workload, init) = logreg_workload(rt.clone(), n, 2000, true, seed)?;
    let mut pga = Trainer::new(workload, init, opts(AlgorithmKind::GossipPga, n, seed))?;
    let hist_pga = pga.run(steps, "pga")?;
    println!(
        "\nfixed-H PGA (H=6):  final loss {:.5}, sim time {:.2} h",
        hist_pga.final_loss(),
        hist_pga.final_sim_hours()
    );
    println!(
        "Gossip-AGA:         final loss {:.5}, sim time {:.2} h",
        aga.mean_loss(),
        aga.sim_seconds() / 3600.0
    );
    println!(
        "\nAGA reaches comparable loss while syncing less often late in\n\
         training — the paper's Table 7/11 runtime advantage."
    );
    Ok(())
}
