//! Topology explorer: beta, C_beta, D_beta, consensus regime and the
//! theoretical transient-stage orders (paper Tables 2-3) for every built-in
//! topology across cluster sizes.
//!
//!     cargo run --release --example topology_explorer [-- n1 n2 ...]

use gossip_pga::harness::Table;
use gossip_pga::topology::{spectral, Topology};

fn main() -> anyhow::Result<()> {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let sizes = if args.is_empty() { vec![16, 32, 64] } else { args };
    let h = 16;

    for &n in &sizes {
        println!("\n== n = {n}, H = {h} ==");
        let mut t = Table::new(&[
            "topology",
            "|N_i|",
            "beta",
            "1-beta",
            "C_beta",
            "D_beta",
            "regime",
            "PGA transient (non-iid)",
            "Gossip transient (non-iid)",
        ]);
        for name in ["ring", "grid", "star", "expo", "one-peer-expo", "full"] {
            let topo = Topology::from_name(name, n)?;
            let beta = topo.beta();
            t.rowv(vec![
                name.to_string(),
                topo.max_degree_incl_self().to_string(),
                format!("{beta:.5}"),
                format!("{:.2e}", 1.0 - beta),
                format!("{:.2}", spectral::c_beta(beta, h)),
                format!("{:.2}", spectral::d_beta(beta, h)),
                match spectral::regime(beta, h) {
                    spectral::ConsensusRegime::GlobalAveragingDominates => "global-avg",
                    spectral::ConsensusRegime::GossipDominates => "gossip",
                }
                .to_string(),
                format!("{:.2e}", spectral::transient::pga_noniid(n, beta, h)),
                format!("{:.2e}", spectral::transient::gossip_noniid(n, beta)),
            ]);
        }
        t.print();
    }
    println!(
        "\nReading the last two columns: Gossip-PGA's transient stage stays\n\
         bounded by H even as 1-beta -> 0 (ring at large n), while Gossip\n\
         SGD's blows up as 1/(1-beta)^4 — the paper's Table 2."
    );
    Ok(())
}
