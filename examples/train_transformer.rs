//! End-to-end driver (DESIGN.md §5, EXPERIMENTS.md §E2E): train a ~12M-param
//! causal-LM transformer across n simulated nodes with Gossip-PGA, logging
//! the loss curve. Proves all three layers compose: the JAX/Pallas-authored
//! grad graph (AOT HLO) executes under the rust coordinator's gossip +
//! periodic-global-averaging schedule with no Python on the training path.
//!
//!     make artifacts && cargo run --release --example train_transformer
//!
//! Flags: --nodes N --steps S --tag tiny|e2e --algo pga|gossip|... --h H
//!        --threads T --overlap true --out csv_path
//!
//! The synthetic corpus is an order-1 Markov chain with entropy floor
//! ~ln(4)+noise (= the best achievable loss); watching the loss fall from
//! ln(vocab) ~ 8.3 toward ~2 is the learning signal.

use std::sync::Arc;

use gossip_pga::algorithms::{AlgorithmKind, SlowMoParams};
use gossip_pga::comm::{BackendKind, Compression};
use gossip_pga::coordinator::{lm_eval_loss, lm_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::CostModel;
use gossip_pga::eventsim::Regime;
use gossip_pga::optim::LrSchedule;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn flag(args: &[String], name: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == &format!("--{name}"))
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = flag(&args, "nodes", "4").parse()?;
    let steps: usize = flag(&args, "steps", "200").parse()?;
    let tag = flag(&args, "tag", "e2e");
    let algo = AlgorithmKind::from_name(&flag(&args, "algo", "pga"))?;
    let h: usize = flag(&args, "h", "6").parse()?;
    let threads: usize = flag(&args, "threads", "1").parse()?;
    let overlap: bool = flag(&args, "overlap", "false").parse()?;
    let out = flag(&args, "out", "target/e2e_loss.csv");
    let lr: f64 = flag(&args, "lr", "0.1").parse()?;
    let momentum: f64 = flag(&args, "momentum", "0.9").parse()?;
    let seed = 1234;

    let topo = Topology::one_peer_expo(n);
    let rt = Arc::new(Runtime::load_default()?);
    let (workload, init) = lm_workload(rt, &tag, seed)?;
    let d = workload.flat_dim();
    println!(
        "# e2e transformer: config '{tag}' ({:.1}M params), {n} nodes on one-peer expo \
         (beta_eff = {:.3}), {} H = {h}, {steps} steps",
        d as f64 / 1e6,
        topo.beta(),
        algo.display()
    );

    let opts = TrainerOptions {
        algorithm: algo,
        topology: topo,
        period: h,
        aga_init_period: 4,
        aga_warmup: 40,
        // Plain-SGD-friendly schedule: short warmup then gentle decay.
        lr: LrSchedule::WarmupMilestones {
            lr,
            warmup: 20,
            milestones: vec![steps / 2, steps * 3 / 4],
            factor: 0.3,
        },
        momentum,
        nesterov: momentum > 0.0,
        seed,
        slowmo: SlowMoParams::default(),
        // Bill communication as if this were BERT-Large on the paper's
        // cluster (Table 17 calibration).
        cost: CostModel::calibrated_bert(),
        cost_dim: 330_000_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 1,
        threads,
        regime: if overlap { Regime::Overlap } else { Regime::Bsp },
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    };
    let mut trainer = Trainer::new(workload, init, opts)?;

    let wall0 = std::time::Instant::now();
    let mut hist = gossip_pga::metrics::History::new(format!("{}-{tag}", algo.name()));
    for k in 0..steps {
        trainer.step_once()?;
        let loss = trainer.mean_loss();
        // Overlap note: comm_stats() counts completed (drained) actions, so
        // with --overlap the traffic columns lag the sim clock by the one
        // in-flight gossip round; Trainer::run drains before logging and
        // has no such offset. Acceptable for this example's coarse curve.
        let comm = trainer.comm_stats();
        hist.push(gossip_pga::metrics::Record {
            step: k,
            loss,
            consensus: 0.0, // O(n d) to compute; skipped at 12M params
            lr: 0.0,
            sim_seconds: trainer.sim_seconds(),
            comm_scalars: comm.scalars_sent,
            comm_msgs: comm.msgs,
            sim_min_seconds: trainer.sim_seconds_min(),
            straggler_slack: trainer.straggler_slack(),
            barrier_wait: comm.barrier_wait,
            stale_max: 0,
            stale_mean: 0.0,
            link_util: 0.0,
            peer_drops: trainer.peer_drops(),
            row_renorms: trainer.row_renorms(),
        });
        if k % 10 == 0 || k + 1 == steps {
            println!(
                "step {k:>4}  loss {loss:.4}  sim_t {:.2} h  wall {:.0}s",
                trainer.sim_seconds() / 3600.0,
                wall0.elapsed().as_secs_f64()
            );
        }
    }
    trainer.drain()?; // overlap mode: complete the in-flight mix before eval
    let eval = lm_eval_loss(&trainer, 8, seed)?;
    hist.write_csv(std::path::Path::new(&out))?;
    println!(
        "\n# done: train loss {:.4} -> {:.4} | eval loss {:?} | sim {:.2} h | wall {:.1} min | csv {}",
        hist.records.first().map(|r| r.loss).unwrap_or(f64::NAN),
        hist.final_loss(),
        eval,
        hist.final_sim_hours(),
        wall0.elapsed().as_secs_f64() / 60.0,
        out
    );
    Ok(())
}
