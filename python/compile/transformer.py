"""L2: causal-LM transformer — the BERT substitute (Table 11 / Fig 3).

A pre-norm decoder-only transformer over a flat f32[D] parameter vector,
following the repo-wide AOT contract: grad_fn(flat, tokens) -> (loss, grad).
The flat layout is static (python-int offsets), so slicing lowers to plain
HLO slices and the whole step fuses into one module.

Configs (see CONFIGS): `tiny` for benches/tests, `e2e` (~12M params) for the
end-to-end example, `bert100m` provided for scale parity with the paper
(compile-only in CI — CPU budget).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


CONFIGS = {
    "tiny": TransformerConfig(vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256, seq_len=32),
    "e2e": TransformerConfig(vocab=1024, d_model=384, n_layers=6, n_heads=6, d_ff=1536, seq_len=64),
    "bert100m": TransformerConfig(vocab=8192, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq_len=128),
}


class TransformerLayout:
    """Static flat-parameter layout: list of (name, shape, offset)."""

    def __init__(self, cfg: TransformerConfig):
        self.cfg = cfg
        d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
        entries = [("embed", (v, d)), ("pos", (cfg.seq_len, d))]
        for layer in range(cfg.n_layers):
            p = f"l{layer}."
            entries += [
                (p + "ln1_g", (d,)),
                (p + "ln1_b", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "ln2_g", (d,)),
                (p + "ln2_b", (d,)),
                (p + "w1", (d, ff)),
                (p + "b1", (ff,)),
                (p + "w2", (ff, d)),
                (p + "b2", (d,)),
            ]
        # Untied output head: tying halves params but starves the early
        # bigram-learning signal on plain SGD (the embedding must serve
        # both roles); an untied head escapes the uniform plateau much
        # faster, which matters for the CPU-budget e2e run.
        entries += [("lnf_g", (d,)), ("lnf_b", (d,)), ("head", (d, v))]
        self.entries = []
        off = 0
        for name, shape in entries:
            size = math.prod(shape)
            self.entries.append((name, shape, off))
            off += size
        self.dim = off
        self._index = {name: (shape, off) for name, shape, off in self.entries}

    def get(self, flat: jax.Array, name: str) -> jax.Array:
        shape, off = self._index[name]
        return flat[off : off + math.prod(shape)].reshape(shape)

    def init(self, key: jax.Array) -> jax.Array:
        """Scaled-normal init, flat vector."""
        cfg = self.cfg
        parts = []
        for name, shape, _ in self.entries:
            key, sub = jax.random.split(key)
            if name.endswith(("_g",)):
                parts.append(jnp.ones(shape))
            elif name.endswith(("_b", "b1", "b2")) or name == "pos":
                if name == "pos":
                    parts.append(0.01 * jax.random.normal(sub, shape))
                else:
                    parts.append(jnp.zeros(shape))
            else:
                fan_in = shape[0]
                scale = 1.0 / math.sqrt(fan_in)
                # GPT-2-style depth scaling on residual-out projections.
                if name.endswith(("wo", "w2")):
                    scale /= math.sqrt(2.0 * cfg.n_layers)
                parts.append(scale * jax.random.normal(sub, shape))
        return jnp.concatenate([p.reshape(-1) for p in parts]).astype(jnp.float32)


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + eps) + b


def _attention(x, layout: TransformerLayout, flat, prefix: str):
    cfg = layout.cfg
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def proj(name):
        return (x @ layout.get(flat, prefix + name)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    q, k, v = proj("wq"), proj("wk"), proj("wv")
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ layout.get(flat, prefix + "wo")


def _mlp_block(x, layout: TransformerLayout, flat, prefix: str):
    from .kernels import ref

    w1, b1 = layout.get(flat, prefix + "w1"), layout.get(flat, prefix + "b1")
    w2, b2 = layout.get(flat, prefix + "w2"), layout.get(flat, prefix + "b2")
    h = ref.gelu_tanh(x @ w1 + b1)
    return h @ w2 + b2


def forward(flat: jax.Array, tokens: jax.Array, layout: TransformerLayout) -> jax.Array:
    """Logits (b, s, vocab) for input tokens (b, s) int32."""
    cfg = layout.cfg
    x = layout.get(flat, "embed")[tokens] + layout.get(flat, "pos")[None, : tokens.shape[1]]
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        x = x + _attention(
            _layer_norm(x, layout.get(flat, p + "ln1_g"), layout.get(flat, p + "ln1_b")),
            layout,
            flat,
            p,
        )
        x = x + _mlp_block(
            _layer_norm(x, layout.get(flat, p + "ln2_g"), layout.get(flat, p + "ln2_b")),
            layout,
            flat,
            p,
        )
    x = _layer_norm(x, layout.get(flat, "lnf_g"), layout.get(flat, "lnf_b"))
    return x @ layout.get(flat, "head")


def lm_loss(flat: jax.Array, batch: jax.Array, layout: TransformerLayout) -> jax.Array:
    """Next-token cross entropy. batch: (b, s+1) int32; predicts batch[:,1:]."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(flat, inputs, layout)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def lm_grad(flat: jax.Array, batch: jax.Array, layout: TransformerLayout):
    """(loss[1], grad[D]) — the AOT contract for the LM."""
    loss, grad = jax.value_and_grad(lm_loss)(flat, batch, layout)
    return jnp.reshape(loss, (1,)), grad
