"""AOT pipeline: lower every L2 graph to HLO *text* + write the manifest.

Interchange format is HLO text, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` rust crate) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--full]

Emits one .hlo.txt per executable variant plus manifest.json describing
shapes, dtypes and flat-parameter dims — the rust runtime loads executables
strictly through the manifest (rust/src/runtime/registry.rs).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import transformer as T
from .kernels import fused_update, gossip_mix


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "artifacts": []}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, arg_specs, *, model, kind, flat_dim, inputs, outputs, meta=None):
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        self.manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "model": model,
                "kind": kind,
                "flat_dim": flat_dim,
                "inputs": inputs,
                "outputs": outputs,
                "meta": meta or {},
            }
        )
        print(f"  wrote {path} ({len(text)} chars)")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  wrote {path} ({len(self.manifest['artifacts'])} artifacts)")


def emit_logreg(em: Emitter, d: int = 10, m: int = 32):
    """Paper §5.1 convex experiments. Pallas fused loss+grad inside."""
    name = f"logreg_grad_d{d}_m{m}"
    em.emit(
        name,
        M.logreg_grad,
        (_spec((d,)), _spec((m, d)), _spec((m,))),
        model="logreg",
        kind="grad",
        flat_dim=d,
        inputs=[_io("w", (d,)), _io("x", (m, d)), _io("y", (m,))],
        outputs=[_io("loss", (1,)), _io("grad", (d,))],
        meta={"batch": m},
    )
    em.emit(
        f"logreg_step_d{d}_m{m}",
        M.logreg_fused_step,
        (_spec((d,)), _spec((m, d)), _spec((m,)), _spec(())),
        model="logreg",
        kind="fused_step",
        flat_dim=d,
        inputs=[_io("w", (d,)), _io("x", (m, d)), _io("y", (m,)), _io("lr", ())],
        outputs=[_io("new_w", (d,)), _io("loss", (1,))],
        meta={"batch": m},
    )


def emit_mlp(em: Emitter, in_dim=32, hidden=128, classes=10, m=64, eval_m=256):
    """Image-classification substitute (Tables 7/9/10/15/16)."""
    layout = M.MlpLayout(in_dim, hidden, classes)
    tag = f"in{in_dim}_h{hidden}_c{classes}"

    def grad_fn(flat, x, y):
        return M.mlp_grad(flat, x, y, layout, use_pallas=True)

    em.emit(
        f"mlp_grad_{tag}_m{m}",
        grad_fn,
        (_spec((layout.dim,)), _spec((m, in_dim)), _spec((m,), jnp.int32)),
        model="mlp",
        kind="grad",
        flat_dim=layout.dim,
        inputs=[_io("flat", (layout.dim,)), _io("x", (m, in_dim)), _io("y", (m,), "i32")],
        outputs=[_io("loss", (1,)), _io("grad", (layout.dim,))],
        meta={"batch": m, "in_dim": in_dim, "hidden": hidden, "classes": classes},
    )

    def eval_fn(flat, x, y):
        return (M.mlp_accuracy(flat, x, y, layout),)

    em.emit(
        f"mlp_eval_{tag}_m{eval_m}",
        eval_fn,
        (_spec((layout.dim,)), _spec((eval_m, in_dim)), _spec((eval_m,), jnp.int32)),
        model="mlp",
        kind="eval",
        flat_dim=layout.dim,
        inputs=[_io("flat", (layout.dim,)), _io("x", (eval_m, in_dim)), _io("y", (eval_m,), "i32")],
        outputs=[_io("accuracy", (1,))],
        meta={"batch": eval_m, "in_dim": in_dim, "hidden": hidden, "classes": classes},
    )


def emit_transformer(em: Emitter, cfg_name: str, batch: int):
    """BERT substitute (Table 11 / Fig 3) + the e2e example model."""
    cfg = T.CONFIGS[cfg_name]
    layout = T.TransformerLayout(cfg)
    s1 = cfg.seq_len + 1

    def grad_fn(flat, tokens):
        return T.lm_grad(flat, tokens, layout)

    em.emit(
        f"transformer_grad_{cfg_name}_b{batch}",
        grad_fn,
        (_spec((layout.dim,)), _spec((batch, s1), jnp.int32)),
        model="transformer",
        kind="grad",
        flat_dim=layout.dim,
        inputs=[_io("flat", (layout.dim,)), _io("tokens", (batch, s1), "i32")],
        outputs=[_io("loss", (1,)), _io("grad", (layout.dim,))],
        meta={
            "config": cfg_name,
            "batch": batch,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
        },
    )

    def loss_fn(flat, tokens):
        return (jnp.reshape(T.lm_loss(flat, tokens, layout), (1,)),)

    em.emit(
        f"transformer_loss_{cfg_name}_b{batch}",
        loss_fn,
        (_spec((layout.dim,)), _spec((batch, s1), jnp.int32)),
        model="transformer",
        kind="eval",
        flat_dim=layout.dim,
        inputs=[_io("flat", (layout.dim,)), _io("tokens", (batch, s1), "i32")],
        outputs=[_io("loss", (1,))],
        meta={"config": cfg_name, "batch": batch},
    )


def emit_mix(em: Emitter, k: int, d: int):
    """Gossip-mix executable (validation + demo of the L1 mixing kernel)."""

    def fn(w, stack):
        return (gossip_mix.gossip_mix(w, stack),)

    em.emit(
        f"gossip_mix_k{k}_d{d}",
        fn,
        (_spec((k,)), _spec((k, d))),
        model="mix",
        kind="mix",
        flat_dim=d,
        inputs=[_io("weights", (k,)), _io("stack", (k, d))],
        outputs=[_io("mixed", (d,))],
        meta={"k": k},
    )


def emit_fused_update(em: Emitter, k: int, d: int):
    def fn(w, stack, g, lr):
        return (fused_update.fused_update_mix(w, stack, g, lr),)

    em.emit(
        f"fused_update_k{k}_d{d}",
        fn,
        (_spec((k,)), _spec((k, d)), _spec((d,)), _spec(())),
        model="mix",
        kind="fused_update",
        flat_dim=d,
        inputs=[_io("weights", (k,)), _io("stack", (k, d)), _io("grad", (d,)), _io("lr", ())],
        outputs=[_io("mixed", (d,))],
        meta={"k": k},
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also emit the 100M-param config (compile-only)")
    args = ap.parse_args()

    em = Emitter(args.out_dir)
    print("[aot] logreg")
    emit_logreg(em, d=10, m=32)
    print("[aot] mlp classifier")
    emit_mlp(em)
    print("[aot] transformer tiny")
    emit_transformer(em, "tiny", batch=8)
    print("[aot] transformer e2e")
    emit_transformer(em, "e2e", batch=8)
    if args.full:
        print("[aot] transformer bert100m (compile-only target)")
        emit_transformer(em, "bert100m", batch=2)
    print("[aot] gossip mix kernels")
    for k in (2, 3, 5):
        emit_mix(em, k, 10)
    emit_mix(em, 3, 4096)
    emit_fused_update(em, 3, 10)
    em.finish()


if __name__ == "__main__":
    main()
