"""L2: JAX compute graphs for the Gossip-PGA training path.

Every model exposes the same AOT contract (DESIGN.md §1):

    grad_fn(flat_params f32[D], *batch) -> (loss f32[1], grad f32[D])

The rust coordinator (L3) owns optimizers and communication schedules; L2 is
pure loss+gradient. A fused variant (SGD update folded into the HLO) is also
emitted for the §Perf L2-fusion ablation.

Models:
  * logreg      — paper §5.1 convex experiments; forward+grad is the fused
                  Pallas kernel (kernels.logistic), no autodiff involved.
  * mlp         — classifier used as the image-classification substitute
                  (Tables 7/9/10/15/16); hidden layer is the Pallas fused
                  dense+GELU kernel with its custom VJP.
  * transformer — causal LM substitute for BERT (Table 11/Fig 3) lives in
                  transformer.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import logistic as logistic_kernel
from .kernels import mlp as mlp_kernel

# ----------------------------------------------------------------------------
# Logistic regression (paper §5.1)
# ----------------------------------------------------------------------------


def logreg_grad(w: jax.Array, x: jax.Array, y: jax.Array):
    """(loss[1], grad[d]) via the fused Pallas kernel."""
    loss, grad = logistic_kernel.logistic_loss_grad(w, x, y)
    return loss, grad


def logreg_fused_step(w: jax.Array, x: jax.Array, y: jax.Array, lr: jax.Array):
    """SGD step folded into the graph: (new_w[d], loss[1]). §Perf ablation."""
    loss, grad = logistic_kernel.logistic_loss_grad(w, x, y)
    return w - lr * grad, loss


# ----------------------------------------------------------------------------
# MLP classifier (image-classification substitute)
# ----------------------------------------------------------------------------


class MlpLayout:
    """Flat-parameter layout for the 2-layer MLP classifier.

    Parameters, in flat order:
      w1 (in_dim, hidden), b1 (hidden,), w2 (hidden, classes), b2 (classes,)
    """

    def __init__(self, in_dim: int, hidden: int, classes: int):
        self.in_dim, self.hidden, self.classes = in_dim, hidden, classes
        self.shapes = [
            ("w1", (in_dim, hidden)),
            ("b1", (hidden,)),
            ("w2", (hidden, classes)),
            ("b2", (classes,)),
        ]
        self.offsets = {}
        off = 0
        for name, shape in self.shapes:
            size = 1
            for s in shape:
                size *= s
            self.offsets[name] = (off, shape)
            off += size
        self.dim = off

    def unflatten(self, flat: jax.Array):
        out = {}
        for name, (off, shape) in self.offsets.items():
            size = 1
            for s in shape:
                size *= s
            out[name] = flat[off : off + size].reshape(shape)
        return out

    def init(self, key: jax.Array) -> jax.Array:
        k1, k2 = jax.random.split(key)
        w1 = jax.random.normal(k1, (self.in_dim, self.hidden)) * (1.0 / jnp.sqrt(self.in_dim))
        w2 = jax.random.normal(k2, (self.hidden, self.classes)) * (1.0 / jnp.sqrt(self.hidden))
        return jnp.concatenate(
            [
                w1.reshape(-1),
                jnp.zeros(self.hidden),
                w2.reshape(-1),
                jnp.zeros(self.classes),
            ]
        ).astype(jnp.float32)


def mlp_loss(flat: jax.Array, x: jax.Array, y: jax.Array, layout: MlpLayout, *, use_pallas: bool = True):
    """Softmax cross-entropy of the 2-layer MLP. y: (m,) int32 class ids."""
    p = layout.unflatten(flat)
    if use_pallas:
        h = mlp_kernel.dense_gelu(x, p["w1"], p["b1"])
    else:
        from .kernels import ref

        h = ref.dense_gelu(x, p["w1"], p["b1"])
    logits = h @ p["w2"] + p["b2"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)
    return jnp.mean(nll)


def mlp_grad(flat: jax.Array, x: jax.Array, y: jax.Array, layout: MlpLayout, *, use_pallas: bool = True):
    """(loss[1], grad[D]) for the MLP classifier."""
    loss, grad = jax.value_and_grad(mlp_loss)(flat, x, y, layout, use_pallas=use_pallas)
    return jnp.reshape(loss, (1,)), grad


def mlp_accuracy(flat: jax.Array, x: jax.Array, y: jax.Array, layout: MlpLayout):
    """Top-1 accuracy (evaluation artifact for the Table 7 suite)."""
    p = layout.unflatten(flat)
    from .kernels import ref

    h = ref.dense_gelu(x, p["w1"], p["b1"])
    logits = h @ p["w2"] + p["b2"]
    return jnp.reshape(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)), (1,))
