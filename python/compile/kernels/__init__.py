"""L1: Pallas kernels for the Gossip-PGA compute hot-spots.

Every kernel here has a pure-jnp oracle in ref.py and is verified against it
by python/tests/test_kernels.py (hypothesis sweeps) before any artifact is
emitted. All kernels run interpret=True — see DESIGN.md §Hardware-Adaptation.
"""

from . import fused_update, gossip_mix, logistic, mlp, ref  # noqa: F401
