"""Pallas kernel: fused dense + GELU, with a custom VJP for training.

The MLP/transformer feed-forward blocks spend their time in
out = gelu(x @ W + b). The GPU version round-trips the pre-activation z
through HBM between the matmul and the activation; on TPU we tile the output
into MXU-shaped (BLOCK_M, BLOCK_N) blocks with the full K dimension resident,
apply GELU in VMEM, and never materialize z.

Autodiff: pallas_call has no general AD rule, so the forward is wrapped in a
jax.custom_vjp whose backward pass is a (tested) closed-form jnp graph. The
pytest suite checks both the forward against ref.dense_gelu and the VJP
against jax.grad of the reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _dense_gelu_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]  # (BLOCK_M, K)
    w = w_ref[...]  # (K, BLOCK_N)
    b = b_ref[...]  # (BLOCK_N,)
    z = x @ w + b  # MXU tile
    o_ref[...] = ref.gelu_tanh(z)


def _pallas_forward(x, w, b, block_m, block_n):
    m, k = x.shape
    _, n = w.shape
    bm, bn = min(block_m, m), min(block_n, n)
    rm, rn = (-m) % bm, (-n) % bn
    xp = jnp.pad(x, ((0, rm), (0, 0))) if rm else x
    wp = jnp.pad(w, ((0, 0), (0, rn))) if rn else w
    bp = jnp.pad(b, ((0, rn),)) if rn else b
    out = pl.pallas_call(
        _dense_gelu_kernel,
        grid=((m + rm) // bm, (n + rn) // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + rm, n + rn), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def dense_gelu(x, w, b, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N):
    """Fused gelu(x @ w + b) with Pallas forward. Matches ref.dense_gelu."""
    return _pallas_forward(x, w, b, block_m, block_n)


def _fwd(x, w, b, block_m, block_n):
    out = _pallas_forward(x, w, b, block_m, block_n)
    return out, (x, w, b)


def _gelu_tanh_deriv(z):
    c = ref.SQRT_2_OVER_PI
    inner = c * (z + 0.044715 * z**3)
    t = jnp.tanh(inner)
    dinner = c * (1.0 + 3 * 0.044715 * z**2)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * dinner


def _bwd(block_m, block_n, res, g):
    x, w, b = res
    z = x @ w + b  # recompute (rematerialization beats saving z in HBM)
    dz = g * _gelu_tanh_deriv(z)
    dx = dz @ w.T
    dw = x.T @ dz
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense_gelu.defvjp(_fwd, _bwd)


def vmem_bytes(block_m: int, block_n: int, k: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (for §Perf)."""
    x_tile = block_m * k * dtype_bytes
    w_tile = k * block_n * dtype_bytes
    out_tile = block_m * block_n * dtype_bytes
    return 2 * (x_tile + w_tile) + out_tile + block_n * dtype_bytes


def mxu_flops(m: int, k: int, n: int) -> int:
    """MXU FLOP count per forward call for roofline estimates."""
    return 2 * m * k * n
