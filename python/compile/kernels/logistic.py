"""Pallas kernel: fused logistic loss + analytic gradient (paper §5.1).

The logistic-regression experiments (Figs. 1, 4-7) evaluate, per node per
iteration,

    loss = (1/M) sum_m ln(1 + exp(-y_m h_m^T w)),
    grad = -(1/M) X^T (y * sigmoid(-y Xw)).

A naive XLA graph materializes the (M,) logits in HBM twice (forward +
backward). The fused kernel streams X in (BLOCK_M, d) tiles: each grid step
computes its tile's logits in VMEM, folds them straight into running loss and
grad accumulators that live in the (revisited) output tiles. Two matvecs per
tile — Xw and X^T r — are the MXU work; the accumulators never leave VMEM
until the launch finishes.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; the grid is executed
sequentially, which makes the accumulate-into-output pattern exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128-row tiles: MXU-aligned on real hardware, and small enough that the
# (BLOCK_M, d) tile + accumulators fit VMEM for any d used in the paper's
# convex experiments (d = 10).
DEFAULT_BLOCK_M = 128


def _logreg_kernel(x_ref, y_ref, w_ref, loss_ref, grad_ref, *, inv_m: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        grad_ref[...] = jnp.zeros_like(grad_ref)

    x = x_ref[...]  # (BLOCK_M, d)
    y = y_ref[...]  # (BLOCK_M,)
    w = w_ref[...]  # (d,)
    z = x @ w  # MXU matvec
    margin = y * z
    # Numerically stable ln(1 + exp(-margin)).
    loss_tile = jnp.sum(jnp.logaddexp(0.0, -margin))
    residual = y * jax.nn.sigmoid(-margin)  # (BLOCK_M,)
    grad_tile = -(x.T @ residual)  # MXU matvec, (d,)
    loss_ref[...] += inv_m * loss_tile
    grad_ref[...] += inv_m * grad_tile


@functools.partial(jax.jit, static_argnames=("block_m",))
def logistic_loss_grad(
    w: jax.Array, x: jax.Array, y: jax.Array, *, block_m: int = DEFAULT_BLOCK_M
):
    """Fused loss+grad. Matches ref.logistic_loss_grad.

    Args:
      w: (d,) parameters.
      x: (m, d) features; m is padded internally to a multiple of block_m.
      y: (m,) labels in {-1, +1}.
    Returns:
      (loss (1,), grad (d,)) — loss is a length-1 vector (scalar outputs are
      awkward as Pallas refs); callers squeeze it.
    """
    m, d = x.shape
    bm = min(block_m, m)
    rem = (-m) % bm
    if rem:
        # Padding rows get y=+1, x=0 => margin 0 => ln 2 loss contribution;
        # cancel exactly by weighting padded rows with 0 via y=0 trick:
        # y=0 => margin=0 => logaddexp(0,0)=ln2 as well. Instead pad y with 0
        # and x with 0, then subtract the known ln2*rem/M? Simpler: pad and
        # mask with an explicit validity column is overkill for tests — pad
        # with duplicated first row and correct by scaling is wrong. We pad
        # x with zeros and y with zeros: margin = 0, sigmoid(-0)=0.5, and the
        # grad contribution is -x^T(y*0.5) = 0 (x rows are zero). The loss
        # contribution is ln(2) per padded row, which we subtract below.
        x = jnp.pad(x, ((0, rem), (0, 0)))
        y = jnp.pad(y, ((0, rem),))
    mp = m + rem
    inv_m = 1.0 / m
    loss, grad = pl.pallas_call(
        functools.partial(_logreg_kernel, inv_m=inv_m),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # revisited accumulator
            pl.BlockSpec((d,), lambda i: (0,)),  # revisited accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((d,), x.dtype),
        ],
        interpret=True,
    )(x, y, w)
    if rem:
        loss = loss - jnp.log(2.0) * rem * inv_m
    return loss, grad


def vmem_bytes(block_m: int, d: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (for §Perf)."""
    x_tile = block_m * d * dtype_bytes
    vectors = (2 * block_m + 2 * d + 1) * dtype_bytes
    return 2 * x_tile + vectors  # x2: double-buffered X stream


def mxu_flops(m: int, d: int) -> int:
    """MXU FLOP count per call (two matvecs) for roofline estimates."""
    return 2 * (2 * m * d)
