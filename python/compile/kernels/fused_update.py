"""Pallas kernel: fused local SGD update + gossip mix (one HBM pass).

Algorithm 1 performs, on every non-sync iteration,

    x_i^{k+1/2} = x_i^k - gamma * g_i          (local update)
    x_i^{k+1}   = sum_{j in N_i} w_ij x_j^{k+1/2}   (gossip)

Neighbors exchange *updated* half-step parameters, so on the receiving node
only the self row still needs its gradient applied. Running the update and
the mix as separate ops costs two full HBM round-trips over d; this kernel
fuses them: each (k, BLOCK_D) tile of the neighbor stack is loaded once, the
self row is corrected by -gamma*g in VMEM, and the weighted reduction is
written straight out.

Row convention: stack[0] is the self (pre-update) row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 2048


def _fused_kernel(w_ref, lr_ref, x_ref, g_ref, o_ref):
    w = w_ref[...]  # (k, 1)
    lr = lr_ref[0]
    x = x_ref[...]  # (k, BLOCK_D)
    g = g_ref[...]  # (BLOCK_D,) self gradient tile
    x = x.at[0, :].add(-lr * g)
    o_ref[...] = jnp.sum(w * x, axis=0)


@functools.partial(jax.jit, static_argnames=("block_d",))
def fused_update_mix(
    weights: jax.Array,
    stack: jax.Array,
    self_grad: jax.Array,
    lr: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
) -> jax.Array:
    """Fused update+mix. Matches ref.fused_update_mix.

    Args:
      weights: (k,) gossip weights, index 0 = self.
      stack: (k, d) neighbor params; row 0 = self params *before* the update.
      self_grad: (d,) gradient at the self params.
      lr: scalar learning rate.
    Returns:
      (d,) next iterate x_i^{k+1}.
    """
    k, d = stack.shape
    bd = min(block_d, d)
    rem = (-d) % bd
    if rem:
        stack = jnp.pad(stack, ((0, 0), (0, rem)))
        self_grad = jnp.pad(self_grad, ((0, rem),))
    dp = d + rem
    out = pl.pallas_call(
        _fused_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k, bd), lambda i: (0, i)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), stack.dtype),
        interpret=True,
    )(weights.reshape(k, 1), jnp.reshape(lr, (1,)).astype(stack.dtype), stack, self_grad)
    return out[:d]
