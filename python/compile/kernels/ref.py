"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each Pallas kernel is checked against
its oracle by pytest (with hypothesis sweeps over shapes/seeds) at build time,
before any HLO artifact is trusted on the rust training path.

All oracles are written in the most obvious way possible — no tiling, no
fusion — so that a mismatch always indicts the kernel, not the reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654


def logistic_loss_grad(w: jax.Array, x: jax.Array, y: jax.Array):
    """Mean logistic loss and its gradient (paper §5.1 objective).

    f(w) = (1/M) sum_m ln(1 + exp(-y_m * h_m^T w)),  y in {-1, +1}.

    Args:
      w: (d,) parameter vector.
      x: (m, d) feature matrix.
      y: (m,) labels in {-1, +1}.
    Returns:
      (loss scalar, grad (d,)).
    """
    z = x @ w
    margin = y * z
    loss = jnp.mean(jnp.logaddexp(0.0, -margin))
    # d/dw ln(1+exp(-m)) = -y * sigmoid(-m) * h
    s = jax.nn.sigmoid(-margin)
    grad = -(x.T @ (y * s)) / x.shape[0]
    return loss, grad


def gossip_mix(weights: jax.Array, stack: jax.Array) -> jax.Array:
    """Weighted neighborhood average: out = sum_j weights[j] * stack[j].

    This is the gossip communication step x_i <- sum_{j in N_i} w_ij x_j
    (Algorithm 1, gossip branch) over the node's own neighborhood, with the
    neighbor parameter vectors stacked row-wise.

    Args:
      weights: (k,) the row of W restricted to the neighborhood.
      stack: (k, d) neighbor parameter vectors (self included).
    Returns:
      (d,) mixed parameter vector.
    """
    return jnp.einsum("k,kd->d", weights, stack)


def fused_update_mix(
    weights: jax.Array,
    stack: jax.Array,
    self_grad: jax.Array,
    lr: jax.Array,
) -> jax.Array:
    """Fused local-SGD-update + gossip-mix for the self row.

    Neighbors broadcast *already updated* parameters x_j^{k+1/2}; only the
    self row (row 0 by convention) still needs its update applied:

        out = w_0 * (stack[0] - lr * self_grad) + sum_{j>=1} w_j * stack[j]
    """
    updated = stack.at[0].add(-lr * self_grad)
    return jnp.einsum("k,kd->d", weights, updated)


def gelu_tanh(z: jax.Array) -> jax.Array:
    """Tanh-approximated GELU (the variant the fused dense kernel uses)."""
    return 0.5 * z * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (z + 0.044715 * z**3)))


def dense_gelu(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused dense layer oracle: gelu(x @ w + b).

    Args:
      x: (m, k) activations.
      w: (k, n) weights.
      b: (n,) bias.
    Returns:
      (m, n).
    """
    return gelu_tanh(x @ w + b)
