"""Pallas kernel: gossip mixing x_i <- sum_{j in N_i} w_ij x_j.

The gossip step of Algorithm 1 is an HBM-bandwidth-bound weighted reduction
over the k neighbor parameter vectors. TPU mapping (see DESIGN.md
§Hardware-Adaptation): the (k, d) neighbor stack is tiled along d with
BlockSpec((k, BLOCK_D)); each grid step pulls one k×BLOCK_D tile into VMEM,
reduces it against the (k,) weight row (resident for the whole launch), and
writes one BLOCK_D output tile. No MXU work — the roofline is HBM bandwidth,
so the only tunable is BLOCK_D (VMEM footprint vs. grid overhead).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU numbers are estimated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile width along d. With k <= 8 neighbors this keeps the resident
# stack tile at k * 2048 * 4B <= 64 KiB — far under a 16 MiB VMEM budget,
# leaving room for double buffering of the HBM->VMEM stream.
DEFAULT_BLOCK_D = 2048


def _mix_kernel(w_ref, x_ref, o_ref):
    """One output tile: o[bd] = sum_k w[k] * x[k, bd]."""
    w = w_ref[...]  # (k, 1), VMEM-resident across the grid
    x = x_ref[...]  # (k, BLOCK_D)
    o_ref[...] = jnp.sum(w * x, axis=0)


@functools.partial(jax.jit, static_argnames=("block_d",))
def gossip_mix(weights: jax.Array, stack: jax.Array, *, block_d: int = DEFAULT_BLOCK_D) -> jax.Array:
    """Weighted neighborhood average via the Pallas kernel.

    Args:
      weights: (k,) gossip weights (the W row restricted to the neighborhood).
      stack: (k, d) neighbor parameter vectors, row 0 = self.
      block_d: tile width along d.
    Returns:
      (d,) mixed parameter vector. Matches ref.gossip_mix.
    """
    k, d = stack.shape
    bd = min(block_d, d)
    # Pad d up to a multiple of the tile so BlockSpec tiling is exact.
    rem = (-d) % bd
    padded = jnp.pad(stack, ((0, 0), (0, rem))) if rem else stack
    dp = d + rem
    out = pl.pallas_call(
        _mix_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((k, 1), lambda i: (0, 0)),  # weights: whole, every step
            pl.BlockSpec((k, bd), lambda i: (0, i)),  # stream stack tiles
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), stack.dtype),
        interpret=True,
    )(weights.reshape(k, 1), padded)
    return out[:d]


def vmem_bytes(k: int, block_d: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (for §Perf)."""
    stack_tile = k * block_d * dtype_bytes
    out_tile = block_d * dtype_bytes
    weights = k * dtype_bytes
    return 2 * stack_tile + out_tile + weights  # x2: double buffering
