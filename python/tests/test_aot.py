"""AOT emission: HLO text parses (has HloModule header, ENTRY, tuple root),
manifest is valid JSON with consistent shapes."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import transformer as T

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    em = aot.Emitter(str(out))
    aot.emit_logreg(em, d=4, m=8)
    aot.emit_mix(em, 3, 16)
    em.finish()
    return out, em.manifest


def test_hlo_text_shape(emitted):
    out, manifest = emitted
    for art in manifest["artifacts"]:
        text = (out / art["file"]).read_text()
        assert text.startswith("HloModule"), art["name"]
        assert "ENTRY" in text
        # return_tuple=True => root is a tuple
        assert "tuple(" in text or "ROOT" in text


def test_manifest_valid_json(emitted):
    out, _ = emitted
    data = json.loads((out / "manifest.json").read_text())
    assert data["version"] == 1
    names = [a["name"] for a in data["artifacts"]]
    assert len(names) == len(set(names)), "artifact names must be unique"
    for art in data["artifacts"]:
        assert art["kind"] in {"grad", "fused_step", "mix", "fused_update", "eval"}
        for io in art["inputs"] + art["outputs"]:
            assert io["dtype"] in {"f32", "i32"}
            assert all(isinstance(s, int) and s > 0 for s in io["shape"]) or io["shape"] == []


def test_grad_artifact_io_consistency(emitted):
    _, manifest = emitted
    grads = [a for a in manifest["artifacts"] if a["kind"] == "grad"]
    assert grads
    for art in grads:
        # contract: outputs are (loss[1], grad[flat_dim])
        assert art["outputs"][0]["shape"] == [1]
        assert art["outputs"][1]["shape"] == [art["flat_dim"]]


def test_to_hlo_text_roundtrip_simple():
    """Sanity: the lowering helper produces text XLA's parser accepts
    (checked indirectly via structure; rust integration tests do the real
    load+execute round trip)."""

    def fn(a, b):
        return (a @ b + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_transformer_tiny_lowering():
    """The LM grad graph lowers (no data-dependent shapes snuck in)."""
    cfg = T.CONFIGS["tiny"]
    layout = T.TransformerLayout(cfg)

    def grad_fn(flat, tokens):
        return T.lm_grad(flat, tokens, layout)

    specs = (
        jax.ShapeDtypeStruct((layout.dim,), jnp.float32),
        jax.ShapeDtypeStruct((2, cfg.seq_len + 1), jnp.int32),
    )
    text = aot.to_hlo_text(jax.jit(grad_fn).lower(*specs))
    assert text.startswith("HloModule")
    assert f"f32[{layout.dim}]" in text
