"""L2 model graphs: gradients vs numerical/autodiff checks, shape contracts,
and training-sanity (loss decreases under plain SGD)."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model as M
from compile import transformer as T
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed=0):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------------------
# logreg
# ----------------------------------------------------------------------------


def test_logreg_grad_numeric():
    """Kernel-computed gradient vs central finite differences."""
    r = _rng(0)
    d, m = 6, 40
    w = jnp.asarray(r.normal(size=d), jnp.float32)
    x = jnp.asarray(r.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=m), jnp.float32)
    _, grad = M.logreg_grad(w, x, y)
    eps = 1e-3
    for i in range(d):
        e = jnp.zeros(d).at[i].set(eps)
        lp, _ = M.logreg_grad(w + e, x, y)
        lm, _ = M.logreg_grad(w - e, x, y)
        fd = (float(lp[0]) - float(lm[0])) / (2 * eps)
        assert abs(fd - float(grad[i])) < 5e-3, f"coord {i}: fd={fd} grad={float(grad[i])}"


def test_logreg_fused_step_is_sgd():
    r = _rng(1)
    d, m = 10, 32
    w = jnp.asarray(r.normal(size=d), jnp.float32)
    x = jnp.asarray(r.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=m), jnp.float32)
    lr = jnp.float32(0.3)
    new_w, loss = M.logreg_fused_step(w, x, y, lr)
    loss2, grad = M.logreg_grad(w, x, y)
    assert_allclose(np.asarray(new_w), np.asarray(w - lr * grad), rtol=1e-5, atol=1e-6)
    assert_allclose(float(loss[0]), float(loss2[0]), rtol=1e-6)


def test_logreg_sgd_decreases_loss():
    r = _rng(2)
    d, m = 10, 256
    w_star = r.normal(size=d)
    x = r.normal(size=(m, d))
    y = np.where(r.random(m) <= 1.0 / (1.0 + np.exp(-x @ w_star)), 1.0, -1.0)
    w = jnp.zeros(d, jnp.float32)
    xj, yj = jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)
    losses = []
    for _ in range(50):
        loss, grad = M.logreg_grad(w, xj, yj)
        losses.append(float(loss[0]))
        w = w - 0.5 * grad
    assert losses[-1] < 0.6 * losses[0]


# ----------------------------------------------------------------------------
# mlp classifier
# ----------------------------------------------------------------------------


def test_mlp_layout_roundtrip():
    layout = M.MlpLayout(8, 16, 4)
    flat = layout.init(jax.random.PRNGKey(0))
    assert flat.shape == (layout.dim,)
    p = layout.unflatten(flat)
    assert p["w1"].shape == (8, 16)
    assert p["b2"].shape == (4,)
    # Round-trip: reassembling in layout order reproduces the flat vector.
    re = jnp.concatenate([p[name].reshape(-1) for name, _ in layout.shapes])
    assert_allclose(np.asarray(re), np.asarray(flat))


def test_mlp_grad_pallas_vs_pure():
    """Pallas hidden layer and pure-jnp hidden layer agree on loss+grad."""
    layout = M.MlpLayout(8, 16, 4)
    flat = layout.init(jax.random.PRNGKey(1))
    r = _rng(3)
    x = jnp.asarray(r.normal(size=(32, 8)), jnp.float32)
    y = jnp.asarray(r.integers(0, 4, size=32), jnp.int32)
    l1, g1 = M.mlp_grad(flat, x, y, layout, use_pallas=True)
    l2, g2 = M.mlp_grad(flat, x, y, layout, use_pallas=False)
    assert_allclose(float(l1[0]), float(l2[0]), rtol=1e-5)
    assert_allclose(np.asarray(g1), np.asarray(g2), rtol=5e-4, atol=5e-5)


def test_mlp_sgd_learns_separable():
    layout = M.MlpLayout(4, 32, 2)
    flat = layout.init(jax.random.PRNGKey(2))
    r = _rng(4)
    x = r.normal(size=(256, 4)).astype(np.float32)
    y = (x[:, 0] + x[:, 1] > 0).astype(np.int32)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    for _ in range(60):
        _, g = M.mlp_grad(flat, xj, yj, layout, use_pallas=False)
        flat = flat - 0.5 * g
    acc = float(M.mlp_accuracy(flat, xj, yj, layout)[0])
    assert acc > 0.9, acc


# ----------------------------------------------------------------------------
# transformer LM
# ----------------------------------------------------------------------------


def test_transformer_layout_dim():
    cfg = T.CONFIGS["tiny"]
    layout = T.TransformerLayout(cfg)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    per_layer = 2 * d + 4 * d * d + 2 * d + d * ff + ff + ff * d + d
    expect = v * d + cfg.seq_len * d + cfg.n_layers * per_layer + 2 * d + d * v
    assert layout.dim == expect


def test_transformer_grad_contract():
    layout = T.TransformerLayout(T.CONFIGS["tiny"])
    flat = layout.init(jax.random.PRNGKey(0))
    r = _rng(5)
    batch = jnp.asarray(r.integers(0, 256, size=(2, 33)), jnp.int32)
    loss, grad = T.lm_grad(flat, batch, layout)
    assert loss.shape == (1,)
    assert grad.shape == (layout.dim,)
    # fresh init => loss close to ln(vocab)
    assert abs(float(loss[0]) - np.log(256)) < 1.0


def test_transformer_sgd_memorizes():
    """A tiny model must overfit one repeated sequence quickly."""
    layout = T.TransformerLayout(T.CONFIGS["tiny"])
    flat = layout.init(jax.random.PRNGKey(3))
    r = _rng(6)
    seq = r.integers(0, 256, size=33)
    batch = jnp.asarray(np.stack([seq] * 2), jnp.int32)
    first = None
    for _ in range(30):
        loss, grad = T.lm_grad(flat, batch, layout)
        if first is None:
            first = float(loss[0])
        flat = flat - 0.5 * grad
    assert float(loss[0]) < 0.5 * first


def test_transformer_causality():
    """Changing a future token must not change past logits."""
    layout = T.TransformerLayout(T.CONFIGS["tiny"])
    flat = layout.init(jax.random.PRNGKey(4))
    r = _rng(7)
    toks = r.integers(0, 256, size=(1, 32))
    t2 = toks.copy()
    t2[0, -1] = (t2[0, -1] + 1) % 256
    l1 = T.forward(flat, jnp.asarray(toks, jnp.int32), layout)
    l2 = T.forward(flat, jnp.asarray(t2, jnp.int32), layout)
    assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), rtol=1e-5, atol=1e-5)


def test_e2e_config_size():
    """The e2e config is in the documented ~10-15M band; bert100m ~90-110M."""
    e2e = T.TransformerLayout(T.CONFIGS["e2e"]).dim
    assert 8e6 < e2e < 2e7, e2e
    big = T.TransformerLayout(T.CONFIGS["bert100m"]).dim
    assert 8e7 < big < 1.3e8, big
