"""Pallas kernels vs pure-jnp oracles (ref.py) — the core L1 signal.

hypothesis sweeps shapes and seeds; assert_allclose against ref.py per the
repo testing policy (DESIGN.md §6).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fused_update, gossip_mix, logistic, mlp, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------------------
# logistic: fused loss + grad
# ----------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_logistic_matches_ref(m, d, seed):
    r = _rng(seed)
    w = jnp.asarray(r.normal(size=d), jnp.float32)
    x = jnp.asarray(r.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=m), jnp.float32)
    loss_k, grad_k = logistic.logistic_loss_grad(w, x, y)
    loss_r, grad_r = ref.logistic_loss_grad(w, x, y)
    assert_allclose(float(loss_k[0]), float(loss_r), rtol=2e-5, atol=2e-6)
    assert_allclose(np.asarray(grad_k), np.asarray(grad_r), rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("block_m", [8, 32, 128])
def test_logistic_block_size_invariant(block_m):
    """Tiling must not change the numbers (tile-boundary correctness)."""
    r = _rng(7)
    w = jnp.asarray(r.normal(size=10), jnp.float32)
    x = jnp.asarray(r.normal(size=(100, 10)), jnp.float32)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=100), jnp.float32)
    loss_r, grad_r = ref.logistic_loss_grad(w, x, y)
    loss_k, grad_k = logistic.logistic_loss_grad(w, x, y, block_m=block_m)
    assert_allclose(float(loss_k[0]), float(loss_r), rtol=2e-5)
    assert_allclose(np.asarray(grad_k), np.asarray(grad_r), rtol=2e-5, atol=2e-6)


def test_logistic_grad_matches_autodiff():
    """Analytic in-kernel gradient vs jax.grad of the scalar loss."""
    r = _rng(3)
    w = jnp.asarray(r.normal(size=10), jnp.float32)
    x = jnp.asarray(r.normal(size=(64, 10)), jnp.float32)
    y = jnp.asarray(r.choice([-1.0, 1.0], size=64), jnp.float32)
    auto = jax.grad(lambda w_: ref.logistic_loss_grad(w_, x, y)[0])(w)
    _, grad_k = logistic.logistic_loss_grad(w, x, y)
    assert_allclose(np.asarray(grad_k), np.asarray(auto), rtol=2e-5, atol=2e-6)


# ----------------------------------------------------------------------------
# gossip_mix
# ----------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    k=st.integers(min_value=1, max_value=8),
    d=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gossip_mix_matches_ref(k, d, seed):
    r = _rng(seed)
    w = r.random(k)
    w = jnp.asarray(w / w.sum(), jnp.float32)  # stochastic row
    stack = jnp.asarray(r.normal(size=(k, d)), jnp.float32)
    out = gossip_mix.gossip_mix(w, stack, block_d=256)
    assert_allclose(np.asarray(out), np.asarray(ref.gossip_mix(w, stack)), rtol=2e-5, atol=1e-5)


def test_gossip_mix_preserves_mean():
    """With uniform weights the mix is the exact average (consensus op)."""
    r = _rng(11)
    k, d = 4, 1000
    stack = jnp.asarray(r.normal(size=(k, d)), jnp.float32)
    w = jnp.full((k,), 1.0 / k, jnp.float32)
    out = gossip_mix.gossip_mix(w, stack)
    assert_allclose(np.asarray(out), np.asarray(stack.mean(0)), rtol=2e-5, atol=1e-5)


def test_gossip_mix_identity_weight():
    """w = e_0 must return the self row untouched (W = I => Local SGD)."""
    r = _rng(13)
    stack = jnp.asarray(r.normal(size=(3, 257)), jnp.float32)
    w = jnp.asarray([1.0, 0.0, 0.0], jnp.float32)
    out = gossip_mix.gossip_mix(w, stack, block_d=64)
    assert_allclose(np.asarray(out), np.asarray(stack[0]), rtol=1e-6)


# ----------------------------------------------------------------------------
# fused_update
# ----------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    k=st.integers(min_value=1, max_value=6),
    d=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_update_matches_ref(k, d, seed):
    r = _rng(seed)
    w = r.random(k)
    w = jnp.asarray(w / w.sum(), jnp.float32)
    stack = jnp.asarray(r.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(r.normal(size=d), jnp.float32)
    lr = jnp.float32(0.1)
    out = fused_update.fused_update_mix(w, stack, g, lr, block_d=512)
    expect = ref.fused_update_mix(w, stack, g, lr)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-5)


def test_fused_update_equals_separate_ops():
    """Fusion must equal update-then-mix done as two unfused steps."""
    r = _rng(5)
    k, d = 3, 100
    w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
    stack = jnp.asarray(r.normal(size=(k, d)), jnp.float32)
    g = jnp.asarray(r.normal(size=d), jnp.float32)
    lr = jnp.float32(0.2)
    updated = stack.at[0].add(-lr * g)
    expect = ref.gossip_mix(w, updated)
    out = fused_update.fused_update_mix(w, stack, g, lr)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# mlp fused dense+gelu (+ custom VJP)
# ----------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=200),
    k=st.integers(min_value=1, max_value=48),
    n=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dense_gelu_matches_ref(m, k, n, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(r.normal(size=(k, n)) / np.sqrt(k), jnp.float32)
    b = jnp.asarray(r.normal(size=n), jnp.float32)
    out = mlp.dense_gelu(x, w, b, 64, 64)
    assert_allclose(np.asarray(out), np.asarray(ref.dense_gelu(x, w, b)), rtol=3e-5, atol=3e-6)


def test_dense_gelu_vjp_matches_autodiff():
    """Custom VJP (pallas fwd + closed-form bwd) vs jax.grad of the oracle."""
    r = _rng(17)
    x = jnp.asarray(r.normal(size=(16, 8)), jnp.float32)
    w = jnp.asarray(r.normal(size=(8, 12)) / np.sqrt(8), jnp.float32)
    b = jnp.asarray(r.normal(size=12), jnp.float32)

    def loss_kernel(x, w, b):
        return jnp.sum(mlp.dense_gelu(x, w, b) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.dense_gelu(x, w, b) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(e), rtol=3e-4, atol=3e-5)


def test_vmem_estimates_positive():
    """§Perf helpers are sane: footprints are positive and monotone in tiles."""
    assert gossip_mix.vmem_bytes(3, 2048) > gossip_mix.vmem_bytes(3, 256)
    assert logistic.vmem_bytes(128, 10) > 0
    assert mlp.vmem_bytes(128, 128, 64) > mlp.vmem_bytes(32, 32, 64)
    assert logistic.mxu_flops(100, 10) == 2 * 2 * 100 * 10
