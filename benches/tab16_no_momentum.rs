//! Table 16 (Appendix F.3): the suite without momentum — plain SGD
//! optimizer, matching the paper's non-accelerated theory exactly.
//!
//!     cargo bench --bench tab16_no_momentum

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let steps = step_scale(600);
    println!("# Table 16: plain SGD (no momentum), n = {n}, {steps} steps\n");

    let mut t = Table::new(&["Method", "Acc.%"]);
    for (label, algo) in [
        ("Parallel SGD", AlgorithmKind::Parallel),
        ("Gossip SGD", AlgorithmKind::Gossip),
        ("Gossip-PGA", AlgorithmKind::GossipPga),
    ] {
        let mut spec = RunSpec::image(algo, Topology::one_peer_expo(n), 6, steps);
        spec.momentum = 0.0; // Table 16's point: drop the acceleration
        let r = run_image(rt.clone(), &spec, 2048)?;
        t.rowv(vec![label.to_string(), format!("{:.2}", r.accuracy * 100.0)]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Table 16): ordering preserved without\n\
         momentum — Parallel >= PGA > Gossip."
    );
    Ok(())
}
