//! Table 9: Gossip-PGA vs Gossip SGD on the *static ring* topology (the
//! setting the theory is stated for, as opposed to the dynamic one-peer
//! graph used in the other deep runs).
//!
//!     cargo bench --bench tab9_ring_static

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let steps = step_scale(600);
    let h = 6;
    println!("# Table 9: static ring, n = {n} (beta = {:.4}), {steps} steps\n", Topology::ring(n).beta());

    let mut t = Table::new(&["Method", "Steps", "Acc.%", "Sim hrs"]);
    for (label, algo) in [("Gossip SGD", AlgorithmKind::Gossip), ("Gossip-PGA", AlgorithmKind::GossipPga)] {
        let spec = RunSpec::image(algo, Topology::ring(n), h, steps);
        let r = run_image(rt.clone(), &spec, 2048)?;
        t.rowv(vec![
            label.to_string(),
            steps.to_string(),
            format!("{:.2}", r.accuracy * 100.0),
            format!("{:.2}", r.sim_hours),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Table 9): PGA achieves higher accuracy than\n\
         Gossip on the static ring at slightly more simulated time."
    );
    Ok(())
}
