//! Table 7 (+ Figure 2's endpoints): the full image-classification method
//! suite — Parallel, Local (1x/3x), Gossip (1x/2x), OSGP (overlap-modeled),
//! Gossip-PGA, Gossip-AGA — accuracy, simulated training time, and
//! time-to-target.
//!
//! Substitution (DESIGN.md): ImageNet/ResNet-50 -> Gaussian-cluster
//! classification/MLP; communication billed at ResNet-50's d = 25.5M via
//! the Table 17-calibrated alpha-beta model. OSGP's update rule in a
//! synchronous simulator equals Gossip SGD; its overlap only changes the
//! clock, so its time column uses max(compute, comm) per iteration.
//!
//!     cargo bench --bench tab7_image_suite

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::costmodel::{AlgoCost, CostModel};
use gossip_pga::harness::suite::{run_image, step_scale, ImageResult, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let base = step_scale(600);
    let h = 6; // paper's period for Local SGD and Gossip-PGA
    println!("# Table 7: method suite on the image substitute, n = {n}, H = {h}, base {base} steps\n");

    struct Row {
        label: String,
        result: ImageResult,
        osgp_hours: Option<f64>,
        steps: usize,
    }

    let cost = CostModel::calibrated_resnet50();
    let d = 25_500_000;
    let mut rows: Vec<Row> = Vec::new();
    let runs: Vec<(&str, AlgorithmKind, usize, bool)> = vec![
        ("Parallel SGD", AlgorithmKind::Parallel, base, false),
        ("Local SGD", AlgorithmKind::Local, base, false),
        ("Local SGD x3", AlgorithmKind::Local, base * 3, false),
        ("Gossip SGD", AlgorithmKind::Gossip, base, false),
        ("Gossip SGD x2", AlgorithmKind::Gossip, base * 2, false),
        ("OSGP", AlgorithmKind::Gossip, base, true),
        ("OSGP x2", AlgorithmKind::Gossip, base * 2, true),
        ("Gossip-PGA", AlgorithmKind::GossipPga, base, false),
        ("Gossip-AGA", AlgorithmKind::GossipAga, base, false),
    ];
    for (label, algo, steps, overlap) in runs {
        let mut spec = RunSpec::image(algo, Topology::one_peer_expo(n), h, steps);
        spec.seed = 42 + overlap as u64; // OSGP rows: distinct stochastic run
        let result = run_image(rt.clone(), &spec, 2048)?;
        let osgp_hours = overlap.then(|| {
            // Overlap: per-iteration time = max(compute, comm) + amortized
            // nothing else; recompute the clock analytically.
            let topo = Topology::one_peer_expo(n);
            let per = cost.compute.max(cost.per_iter(AlgoCost::Gossip, &topo, d, h));
            steps as f64 * per / 3600.0
        });
        result
            .history
            .write_csv(std::path::Path::new(&format!(
                "target/bench_out/tab7_{}.csv",
                label.replace([' ', '/'], "_")
            )))
            .ok();
        rows.push(Row { label: label.to_string(), result, osgp_hours, steps });
    }

    // Target accuracy: 99% of Parallel SGD's final accuracy (the paper's
    // "76%" line scaled to this workload).
    let target_acc = rows[0].result.accuracy * 0.99;
    // time-to-target needs the accuracy *curve*; we approximate with the
    // loss curve's first crossing of the loss value at which the parallel
    // run reached the target accuracy (loss is monotone enough here).
    let target_loss = rows[0]
        .result
        .history
        .records
        .last()
        .map(|r| r.loss * 1.02)
        .unwrap_or(f64::NAN);

    let mut t = Table::new(&["Method", "Steps", "Acc.%", "Sim hrs", "Steps/hrs to target"]);
    for row in &rows {
        let hours = row.osgp_hours.unwrap_or(row.result.sim_hours);
        let to_target = row
            .result
            .history
            .first_step_below(target_loss)
            .map(|r| {
                let frac_hours = hours * (r.step + 1) as f64 / row.steps as f64;
                format!("{}/{:.2}", r.step + 1, frac_hours)
            })
            .unwrap_or_else(|| "N.A.".into());
        t.rowv(vec![
            row.label.clone(),
            row.steps.to_string(),
            format!("{:.2}", row.result.accuracy * 100.0),
            format!("{hours:.2}"),
            to_target,
        ]);
    }
    t.print();
    println!(
        "\n(target = 99% of Parallel's accuracy, i.e. {:.2}%)\n\
         Expected shape (paper Table 7): PGA/AGA match Parallel's accuracy at\n\
         ~0.65-0.75x its time; Local and Gossip 1x degrade accuracy; their 2x/3x\n\
         variants recover it only by exceeding Parallel's total time.",
        target_acc * 100.0
    );
    Ok(())
}
