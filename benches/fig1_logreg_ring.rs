//! Figure 1: Gossip-PGA vs Gossip vs Parallel SGD on non-iid logistic
//! regression over the ring topology, n in {20, 50, 100} (paper §5.1).
//!
//! Paper shape to reproduce: all three share the asymptotic rate, but the
//! transient stage of Gossip SGD grows dramatically with n (1 - beta =
//! O(1/n^2) on the ring) while Gossip-PGA's stays controlled by H = 16.
//!
//!     cargo bench --bench fig1_logreg_ring

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_logreg, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::metrics::{smooth, transient_stage_scaled};
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let steps = step_scale(1000);
    let h = 16;
    println!("# Figure 1: logistic regression, ring, non-iid, H = {h}, {steps} iters\n");

    for &n in &[20usize, 50, 100] {
        let topo = Topology::ring(n);
        let beta = topo.beta();
        println!("== n = {n} (beta = {beta:.4}) ==");
        let algos = [AlgorithmKind::Parallel, AlgorithmKind::Gossip, AlgorithmKind::GossipPga];
        let mut hists = Vec::new();
        for algo in algos {
            let spec = RunSpec::logreg(algo, Topology::ring(n), h, true, steps);
            let hist = run_logreg(rt.clone(), &spec, 8000 / n)?;
            hist.write_csv(std::path::Path::new(&format!(
                "target/bench_out/fig1_n{n}_{}.csv",
                algo.name()
            )))?;
            hists.push(hist);
        }
        let mut t = Table::new(&["iter", "Parallel", "Gossip", "Gossip-PGA"]);
        let stride = (hists[0].records.len() / 10).max(1);
        for i in (0..hists[0].records.len()).step_by(stride) {
            t.rowv(vec![
                hists[0].records[i].step.to_string(),
                format!("{:.5}", hists[0].records[i].loss),
                format!("{:.5}", hists[1].records[i].loss),
                format!("{:.5}", hists[2].records[i].loss),
            ]);
        }
        t.print();
        // Transient stages vs Parallel SGD (Fig. 1 caption's definition).
        let par = smooth(&hists[0].losses(), 5);
        for (name, hh) in [("Gossip SGD", &hists[1]), ("Gossip-PGA", &hists[2])] {
            let cand = smooth(&hh.losses(), 5);
            let ts = transient_stage_scaled(&cand, &par, 0.05)
                .map(|i| format!("~{}", hists[0].records[i].step))
                .unwrap_or_else(|| "beyond canvas".into());
            println!("{name:<12} transient stage: {ts} iterations");
        }
        println!();
    }
    println!(
        "Expected shape (paper Fig. 1): Gossip-PGA's transient stage roughly\n\
         constant in n; Gossip SGD's explodes as n grows (beta -> 1)."
    );
    Ok(())
}
