//! Ablation (extension beyond the paper, per its §2: quantization /
//! sparsification "can be added to our methods"): gossip-message
//! compression under Gossip-PGA on the §5.1 convex problem.
//!
//! Rows: identity / int8 / top-10% (+ error feedback). Reports final loss,
//! deviation from the uncompressed run, and wire traffic per gossip round.
//!
//!     cargo bench --bench abl_compression

use std::sync::Arc;

use gossip_pga::compress::{Codec, ErrorFeedback, Identity, Int8, TopK};
use gossip_pga::coordinator::mixer::Mixer;
use gossip_pga::coordinator::{logreg_workload, Workload};
use gossip_pga::exec::WorkerPool;
use gossip_pga::harness::suite::step_scale;
use gossip_pga::harness::Table;
use gossip_pga::model::logreg_layout;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::{lit_f32, Runtime};
use gossip_pga::topology::Topology;

/// A hand-rolled PGA loop with compressed gossip (the Trainer always mixes
/// exactly; this bench owns the mixing to inject codecs).
fn run(
    rt: Arc<Runtime>,
    codec_for: &mut dyn FnMut(usize) -> Box<dyn FnMut(&[f32]) -> (Vec<f32>, usize)>,
    steps: usize,
    n: usize,
    h: usize,
) -> anyhow::Result<(f64, u64)> {
    let (workload, init) = logreg_workload(rt, n, 512, true, 7)?;
    let (data, grad) = match &workload {
        Workload::LogReg { data, grad } => (data, grad),
        _ => unreachable!(),
    };
    let d = grad.flat_dim();
    let topo = Topology::ring(n);
    let mut mixer = Mixer::new(&topo, d);
    let pool = WorkerPool::new(1); // this bench's loop is single-threaded
    let mut params = ParamMatrix::broadcast(n, &init);
    let _ = logreg_layout(d);
    let mut rngs: Vec<Rng> = (0..n).map(|i| Rng::new(7).split(i as u64)).collect();
    let mut gbuf = vec![0.0f32; d];
    let (mut x, mut y) = (Vec::new(), Vec::new());
    let mut codecs: Vec<Box<dyn FnMut(&[f32]) -> (Vec<f32>, usize)>> =
        (0..n).map(|i| codec_for(i)).collect();
    let mut wire_bytes = 0u64;
    let mut last_loss = 0.0f64;
    let batch = grad.spec.meta_usize("batch").unwrap_or(32);
    for k in 0..steps {
        last_loss = 0.0;
        for i in 0..n {
            data.sample_batch(i, batch, &mut rngs[i], &mut x, &mut y);
            let lits = vec![
                lit_f32(&x, &grad.spec.inputs[1].shape)?,
                lit_f32(&y, &grad.spec.inputs[2].shape)?,
            ];
            let loss = grad.call_into(params.row(i), lits, &mut gbuf)?;
            last_loss += loss as f64 / n as f64;
            for (p, g) in params.row_mut(i).iter_mut().zip(&gbuf) {
                *p -= 0.2 * g;
            }
        }
        if (k + 1) % h == 0 {
            // exact global average
            mixer.global_average(&mut params, &pool)?;
        } else {
            mixer.gossip_with(&mut params, &pool, |j, xj| {
                let (dense, bytes) = codecs[j](xj);
                wire_bytes += bytes as u64;
                dense
            })?;
        }
    }
    Ok((last_loss, wire_bytes))
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let steps = step_scale(400);
    let (n, h) = (12usize, 8usize);
    println!("# Ablation: compressed gossip under Gossip-PGA (ring n = {n}, H = {h}, {steps} steps)\n");

    let mut t = Table::new(&["codec", "final loss", "wire bytes/round/node", "vs identity"]);
    let mut baseline = f64::NAN;
    type CodecFactory<'a> = (&'a str, Box<dyn FnMut(usize) -> Box<dyn FnMut(&[f32]) -> (Vec<f32>, usize)>>);
    let d_hint = 10usize;
    let factories: Vec<CodecFactory> = vec![
        (
            "identity",
            Box::new(|_i| {
                Box::new(move |x: &[f32]| {
                    let c = Identity.compress(x);
                    (c.dense, c.wire_bytes)
                })
            }),
        ),
        (
            "int8",
            Box::new(|_i| {
                Box::new(move |x: &[f32]| {
                    let c = Int8::default().compress(x);
                    (c.dense, c.wire_bytes)
                })
            }),
        ),
        (
            "top-30% + EF",
            Box::new(move |_i| {
                let mut ef = ErrorFeedback::new(TopK { frac: 0.3 }, d_hint);
                Box::new(move |x: &[f32]| {
                    let c = ef.compress(x);
                    (c.dense, c.wire_bytes)
                })
            }),
        ),
    ];
    let total_rounds = (steps - steps / h) as u64 * n as u64;
    for (name, mut factory) in factories {
        let (loss, wire) = run(rt.clone(), &mut *factory, steps, n, h)?;
        if baseline.is_nan() {
            baseline = loss;
        }
        t.rowv(vec![
            name.to_string(),
            format!("{loss:.5}"),
            format!("{:.1}", wire as f64 / total_rounds.max(1) as f64),
            format!("{:+.5}", loss - baseline),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape: int8 indistinguishable from identity at 4x less\n\
         traffic; aggressive top-k costs some loss unless error feedback\n\
         reinjects the residual (it does here)."
    );
    Ok(())
}
