//! §Perf harness: micro-benchmarks of every hot-path component, used for
//! the before/after log in EXPERIMENTS.md §Perf.
//!
//!   * axpy + gossip mix (the L3 inner loop) at deep-learning d
//!   * global average
//!   * task dispatch: per-step scoped spawn vs the persistent pool — the
//!     pooled-vs-scoped headline (why `exec::WorkerPool` exists)
//!   * in-proc ring all-reduce (threaded bus)
//!   * PJRT grad execution + literal round-trip per model
//!   * a full coordinator step (logreg, n = 32)
//!   * sequential vs pooled coordinator step (n = 16) — the scaling
//!     headline; also asserts both runs end bit-identical
//!   * overlap (double-buffered async gossip) vs BSP at the same thread
//!     count — the async-gossip headline; asserts bit-identical finals
//!   * regime dispatch: BSP vs event-driven async at max_staleness 0 and 2
//!     — strict async asserts bit-identical params + clocks vs BSP;
//!     relaxed async asserts a no-worse simulated critical path
//!   * virtual population sweep scaling: per-row wall time + peak RSS
//!     across a virtual-n sweep (10^3 → 10^5)
//!   * transport plane: tcp (real loopback sockets) vs bus (in-proc
//!     channels) vs shared (fused mix) gossip + global average at the
//!     same pool size — all three bit-identical
//!   * mix kernel: blocked/vectorized `mix_row_src` vs the naive scalar
//!     reference at deep-learning d — asserts bit-equal outputs in-bench
//!   * core pinning: the same pooled gossip on a pinned vs unpinned
//!     worker pool — asserts bit-equal finals
//!   * gossip pipelining: depth {1, 2, 4} chained async rounds vs the
//!     synchronous sequence — asserts bit-equal finals + clocks
//!   * overlap on the wire: bus + tcp async gossip (epoch-tagged frames)
//!     at depth {1, 2, 4} vs the same burst run BSP — asserts bit-equal
//!     finals, equal clocks and zero dropped frames
//!   * tracing overhead: the same gossip burst with the obs trace plane
//!     disarmed vs armed (`--trace`), on the shared and bus backends —
//!     asserts bit-equal finals in-bench (probes observe, never perturb)
//!
//! The sweep and transport rows land in BENCH_7.json; the kernel, pinning
//! and pipelining rows land in BENCH_8.json; the overlap-on-the-wire rows
//! land in BENCH_9.json; the tracing-overhead rows land in BENCH_10.json.
//! All are anchored at CARGO_MANIFEST_DIR (not the CWD — `cargo bench`
//! runs from wherever).
//!
//!     cargo bench --bench perf_hotpath

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::collective::{bus, ring_all_reduce, run_nodes};
use gossip_pga::comm::{BackendKind, BusBackend, CommBackend, Compression, SharedBackend, TcpBackend};
use gossip_pga::jsonio::{self, Json};
use gossip_pga::coordinator::mixer::{axpy, mix_row_src, mix_row_src_scalar, Mixer};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::harness::{fmt_duration, measure, Table};
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::{lit_f32, lit_i32, GradFn, Runtime};
use gossip_pga::topology::Topology;

fn random_matrix(rng: &mut Rng, n: usize, d: usize) -> ParamMatrix {
    ParamMatrix::random(rng, n, d, 1.0)
}

fn trainer_opts(n: usize, threads: usize, regime: Regime) -> TrainerOptions {
    TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::ring(n),
        period: 6,
        aga_init_period: 4,
        aga_warmup: 10,
        lr: LrSchedule::Const { lr: 0.1 },
        momentum: 0.0,
        nesterov: false,
        seed: 3,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 1000,
        threads,
        regime,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

/// BENCH_9 helper: the same comm-only burst, synchronous (BSP) then
/// overlapped at depth {1, 2, 4}, on one message-passing wire. Issue keeps
/// the ring at most `depth` deep (finish the oldest round when full), then
/// a full FIFO drain ends the burst — the k·H-boundary discipline. Every
/// run covers the same total round count from the same start, so all
/// finals must be bit-identical to the synchronous reference (asserted
/// in-bench; the rows record that the assert held).
#[allow(clippy::too_many_arguments)]
fn overlap_wire_burst<W: gossip_pga::collective::Wire>(
    t: &mut Table,
    rows: &mut Vec<Json>,
    backend: &str,
    mk: impl Fn(usize) -> anyhow::Result<gossip_pga::comm::BusCore<W>>,
    init: &ParamMatrix,
    pool: &WorkerPool,
    burst: usize,
    warmup: usize,
    iters: usize,
) -> anyhow::Result<()> {
    use std::collections::VecDeque;
    let (n, dd) = (init.n(), init.d());
    let mut push_row = |mode: &str, depth: usize, s: &gossip_pga::harness::Stats| {
        rows.push(jsonio::obj(vec![
            ("backend", Json::Str(backend.into())),
            ("mode", Json::Str(mode.into())),
            ("depth", Json::Num(depth as f64)),
            ("rounds", Json::Num(burst as f64)),
            ("n", Json::Num(n as f64)),
            ("d", Json::Num(dd as f64)),
            ("mean_seconds", Json::Num(s.mean)),
            ("p95_seconds", Json::Num(s.p95)),
            ("bit_equal", Json::Bool(true)),
        ]));
    };
    let mut sync_b = mk(1)?;
    let mut p_sync = init.clone();
    let s_sync = measure(warmup, iters, || {
        for _ in 0..burst {
            sync_b.gossip(&mut p_sync, pool).unwrap();
        }
    });
    t.rowv(vec![
        format!("overlap wire, {backend} bsp"),
        format!("one-peer-expo n = {n}, d = {dd}, {burst} rounds/burst"),
        fmt_duration(s_sync.mean),
        fmt_duration(s_sync.p95),
        format!("{:.1} rounds/s", burst as f64 / s_sync.mean),
    ]);
    push_row("bsp", 1, &s_sync);
    for depth in [1usize, 2, 4] {
        let mut b = mk(depth)?;
        let mut p = init.clone();
        let s = measure(warmup, iters, || {
            let mut handles = VecDeque::new();
            for _ in 0..burst {
                if !b.pipeline_ready() {
                    let oldest = handles.pop_front().unwrap();
                    b.finish(&mut p, oldest).unwrap();
                }
                let pend = unsafe { b.gossip_async(&p, pool).unwrap() }
                    .expect("uncompressed wire backends overlap");
                handles.push_back(pend);
            }
            while let Some(h) = handles.pop_front() {
                b.finish(&mut p, h).unwrap();
            }
        });
        assert_eq!(
            b.gossip_clock(),
            sync_b.gossip_clock(),
            "{backend} depth {depth}: overlapped run covered a different round count"
        );
        assert_eq!(p, p_sync, "{backend} depth {depth}: overlapped rounds diverged from BSP");
        assert_eq!(
            b.total().stale_frames_dropped,
            0,
            "{backend} depth {depth}: a clean overlapped run dropped frames"
        );
        t.rowv(vec![
            format!("overlap wire, {backend} depth {depth}"),
            format!("one-peer-expo n = {n}, d = {dd}, {burst} rounds/burst"),
            fmt_duration(s.mean),
            fmt_duration(s.p95),
            format!("{:.2}x vs bsp", s_sync.mean / s.mean),
        ]);
        push_row("overlap", depth, &s);
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("# §Perf hot-path microbenchmarks\n");
    let mut t = Table::new(&["component", "config", "mean", "p95", "throughput"]);

    let fast = std::env::var("GOSSIP_PGA_FAST").is_ok();
    let mut transport_rows: Vec<Json> = Vec::new();

    // --- BENCH_7 part 1: virtual population sweep scaling -------------------
    // The population plane's memory-scaling claim, measured: per-row wall
    // time and peak RSS across a virtual-n sweep (surrogate plane, seeded
    // churn, a few iterations each). Runs FIRST so VmHWM — a process-wide
    // high-water mark — is not polluted by the deep-learning-d sections
    // below. `GOSSIP_PGA_FAST=1` drops the 10^5 flagship row.
    let population_rows = {
        use gossip_pga::population::{run_sweep, ChurnScript, SweepSpec};

        /// Linux VmHWM (peak resident set) in bytes; None off-Linux.
        fn peak_rss_bytes() -> Option<u64> {
            let status = std::fs::read_to_string("/proc/self/status").ok()?;
            let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
            let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
            Some(kb * 1024)
        }

        let sizes: &[usize] =
            if fast { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
        let mut rows = Vec::new();
        for &vn in sizes {
            let mut spec = SweepSpec::massive_n(vn, 4, 11);
            spec.log_points = 2;
            spec.churn = ChurnScript::seeded(5, &spec.topo, 2, 2.0)?.events;
            let mut report = None;
            let s = measure(0, 1, || {
                report = Some(run_sweep(&spec).unwrap());
            });
            let report = report.unwrap();
            let rss = peak_rss_bytes();
            let last = report.curve.last().copied();
            t.rowv(vec![
                "population sweep (surrogate)".into(),
                format!("virtual n = {vn}, 4 iters, churn"),
                fmt_duration(s.mean),
                fmt_duration(s.p95),
                format!(
                    "{} links, {} peak slots, RSS {}",
                    report.num_links,
                    report.peak_live_slots,
                    rss.map_or("n/a".into(), |b| format!(
                        "{:.2} GiB",
                        b as f64 / (1u64 << 30) as f64
                    )),
                ),
            ]);
            rows.push(jsonio::obj(vec![
                ("n", Json::Num(vn as f64)),
                ("wall_seconds", Json::Num(s.mean)),
                ("sim_seconds", Json::Num(last.map_or(0.0, |c| c.time))),
                ("msgs", Json::Num(last.map_or(0, |c| c.msgs) as f64)),
                ("num_links", Json::Num(report.num_links as f64)),
                ("peak_live_slots", Json::Num(report.peak_live_slots as f64)),
                ("peak_dense_scalars", Json::Num(report.peak_dense_scalars as f64)),
                ("peak_rss_bytes", rss.map_or(Json::Null, |b| Json::Num(b as f64))),
            ]));
        }
        rows
    };

    // --- axpy ------------------------------------------------------------
    let d = 12_235_776; // e2e transformer flat dim
    let mut rng = Rng::new(1);
    let x = rng.normal_vec(d, 1.0);
    let mut out = vec![0.0f32; d];
    let s = measure(3, 20, || axpy(0.5, &x, &mut out));
    t.rowv(vec![
        "axpy (mix inner loop)".into(),
        format!("d = {d}"),
        fmt_duration(s.mean),
        fmt_duration(s.p95),
        format!("{:.1} GB/s", (d * 8) as f64 / s.mean / 1e9),
    ]);

    // --- task dispatch: scoped spawn vs persistent pool --------------------
    // The pooled-vs-scoped row pair: identical tiny jobs (the small-d
    // regime where PR 1's per-step spawn/join cost dominated), dispatched
    // through std::thread::scope vs the parked pool.
    let threads_avail = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let dispatch_t = threads_avail.clamp(2, 8);
    let work: Vec<f32> = rng.normal_vec(1 << 14, 1.0);
    let s_scoped = measure(10, 300, || {
        std::thread::scope(|s| {
            for _ in 0..dispatch_t {
                let w = &work;
                s.spawn(move || std::hint::black_box(w.iter().sum::<f32>()));
            }
        });
    });
    let pool = WorkerPool::new(dispatch_t);
    let s_pooled = measure(10, 300, || {
        pool.run(
            (0..dispatch_t)
                .map(|_| {
                    let w = &work;
                    move || {
                        std::hint::black_box(w.iter().sum::<f32>());
                        Ok(())
                    }
                })
                .collect::<Vec<_>>(),
        )
        .unwrap();
    });
    t.rowv(vec![
        "task dispatch, scoped spawn".into(),
        format!("{dispatch_t} jobs x 16k f32"),
        fmt_duration(s_scoped.mean),
        fmt_duration(s_scoped.p95),
        format!("{:.0} batches/s", 1.0 / s_scoped.mean),
    ]);
    t.rowv(vec![
        "task dispatch, pooled".into(),
        format!("{dispatch_t} jobs x 16k f32"),
        fmt_duration(s_pooled.mean),
        fmt_duration(s_pooled.p95),
        format!("{:.0} batches/s", 1.0 / s_pooled.mean),
    ]);
    t.rowv(vec![
        "  -> pooled vs scoped".into(),
        format!("{dispatch_t} threads"),
        format!("{:.2}x", s_scoped.mean / s_pooled.mean),
        "-".into(),
        "(persistent pool, no spawn/join)".into(),
    ]);

    // --- gossip mix, ring n=16 -------------------------------------------
    for (dd, label) in [(1_000_000usize, "d = 1M"), (12_235_776, "d = 12.2M (e2e)")] {
        let topo = Topology::ring(16);
        let mut params = random_matrix(&mut rng, 16, dd);
        let mut mixer = Mixer::new(&topo, dd);
        for threads in [1usize, threads_avail] {
            let mix_pool = WorkerPool::new(threads);
            let s = measure(2, 10, || mixer.gossip(&mut params, &mix_pool).unwrap());
            t.rowv(vec![
                format!("gossip mix (ring, n=16, t={threads})"),
                label.into(),
                fmt_duration(s.mean),
                fmt_duration(s.p95),
                format!("{:.1} GB/s", (16 * 3 * dd * 4) as f64 / s.mean / 1e9),
            ]);
        }
        let seq_pool = WorkerPool::new(1);
        let s = measure(2, 10, || mixer.global_average(&mut params, &seq_pool).unwrap());
        t.rowv(vec![
            "global average (n=16)".into(),
            label.into(),
            fmt_duration(s.mean),
            fmt_duration(s.p95),
            format!("{:.1} GB/s", (16 * 2 * dd * 4) as f64 / s.mean / 1e9),
        ]);
    }

    // --- threaded ring all-reduce -----------------------------------------
    let dd = 1_000_000;
    let s = measure(1, 5, || {
        let eps = bus(8);
        run_nodes(eps, move |mut ep| {
            let mut x = vec![1.0f32; dd];
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(())
        })
        .unwrap();
    });
    t.rowv(vec![
        "bus ring all-reduce".into(),
        "n = 8, d = 1M".into(),
        fmt_duration(s.mean),
        fmt_duration(s.p95),
        format!("{:.1} GB/s agg", (8 * 2 * dd * 4) as f64 / s.mean / 1e9),
    ]);

    // --- BENCH_7 part 2: tcp vs bus vs shared transport ---------------------
    // The price of real message passing relative to the in-proc fused mix,
    // at the same pool size: shared (fused), bus (mpsc channels), tcp (real
    // loopback sockets, framed streams). The final matrices must agree
    // bit-for-bit across all three (the unified-plane equivalence contract).
    {
        let n = 16;
        let dd = 1_000_000usize;
        let topo = Topology::ring(n);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), n);
        let mut p_shared = random_matrix(&mut rng, n, dd);
        let mut p_bus = p_shared.clone();
        let mut p_tcp = p_shared.clone();
        let mut shared =
            SharedBackend::new(&topo, dd, &costs, 25_500_000, Compression::None);
        let mut busb =
            BusBackend::new(&topo, dd, &costs, 25_500_000, Compression::None, true);
        let mut tcpb = TcpBackend::new_loopback(
            &topo, dd, &costs, 25_500_000, Compression::None, true, "127.0.0.1:0",
        )?;
        let comm_pool = WorkerPool::new(threads_avail.clamp(2, 8));
        let s_shared = measure(2, 10, || {
            shared.gossip(&mut p_shared, &comm_pool).unwrap();
        });
        let s_bus = measure(2, 10, || {
            busb.gossip(&mut p_bus, &comm_pool).unwrap();
        });
        let s_tcp = measure(2, 10, || {
            tcpb.gossip(&mut p_tcp, &comm_pool).unwrap();
        });
        assert_eq!(
            shared.gossip_clock(),
            busb.gossip_clock(),
            "backends ran different round counts"
        );
        assert_eq!(
            shared.gossip_clock(),
            tcpb.gossip_clock(),
            "tcp ran a different round count"
        );
        assert_eq!(p_shared, p_bus, "bus gossip diverged from shared gossip");
        assert_eq!(p_shared, p_tcp, "tcp gossip diverged from shared gossip");
        t.rowv(vec![
            "gossip, shared backend".into(),
            format!("ring n = {n}, d = 1M"),
            fmt_duration(s_shared.mean),
            fmt_duration(s_shared.p95),
            format!("{:.1} GB/s", (n * 3 * dd * 4) as f64 / s_shared.mean / 1e9),
        ]);
        t.rowv(vec![
            "gossip, bus backend".into(),
            format!("ring n = {n}, d = 1M"),
            fmt_duration(s_bus.mean),
            fmt_duration(s_bus.p95),
            format!("{:.1} GB/s", (n * 3 * dd * 4) as f64 / s_bus.mean / 1e9),
        ]);
        t.rowv(vec![
            "gossip, tcp backend".into(),
            format!("ring n = {n}, d = 1M, loopback sockets"),
            fmt_duration(s_tcp.mean),
            fmt_duration(s_tcp.p95),
            format!("{:.1} GB/s", (n * 3 * dd * 4) as f64 / s_tcp.mean / 1e9),
        ]);
        t.rowv(vec![
            "  -> bus vs shared".into(),
            "real send/recv + copies".into(),
            format!("{:.2}x slower", s_bus.mean / s_shared.mean),
            "-".into(),
            "(params bit-identical)".into(),
        ]);
        t.rowv(vec![
            "  -> tcp vs bus".into(),
            "kernel socket + framing".into(),
            format!("{:.2}x slower", s_tcp.mean / s_bus.mean),
            "-".into(),
            "(params bit-identical)".into(),
        ]);
        let s_shared_avg = measure(1, 5, || {
            shared.global_average(&mut p_shared, &comm_pool).unwrap();
        });
        let s_bus_avg = measure(1, 5, || {
            busb.global_average(&mut p_bus, &comm_pool).unwrap();
        });
        let s_tcp_avg = measure(1, 5, || {
            tcpb.global_average(&mut p_tcp, &comm_pool).unwrap();
        });
        assert_eq!(p_shared, p_bus, "bus global average diverged from shared");
        assert_eq!(p_shared, p_tcp, "tcp global average diverged from shared");
        t.rowv(vec![
            "global average, shared backend".into(),
            format!("n = {n}, d = 1M"),
            fmt_duration(s_shared_avg.mean),
            fmt_duration(s_shared_avg.p95),
            format!("{:.1} GB/s", (n * 2 * dd * 4) as f64 / s_shared_avg.mean / 1e9),
        ]);
        t.rowv(vec![
            "global average, bus backend".into(),
            format!("n = {n}, d = 1M, chunked exchange"),
            fmt_duration(s_bus_avg.mean),
            fmt_duration(s_bus_avg.p95),
            format!("{:.1} GB/s", (n * 2 * dd * 4) as f64 / s_bus_avg.mean / 1e9),
        ]);
        t.rowv(vec![
            "global average, tcp backend".into(),
            format!("n = {n}, d = 1M, chunked over sockets"),
            fmt_duration(s_tcp_avg.mean),
            fmt_duration(s_tcp_avg.p95),
            format!("{:.1} GB/s", (n * 2 * dd * 4) as f64 / s_tcp_avg.mean / 1e9),
        ]);
        let mut push = |op: &str, backend: &str, s: &gossip_pga::harness::Stats| {
            transport_rows.push(jsonio::obj(vec![
                ("op", Json::Str(op.into())),
                ("backend", Json::Str(backend.into())),
                ("n", Json::Num(n as f64)),
                ("d", Json::Num(dd as f64)),
                ("wall_seconds", Json::Num(s.mean)),
                ("p95_seconds", Json::Num(s.p95)),
                ("bit_identical", Json::Bool(true)),
            ]));
        };
        push("gossip", "shared", &s_shared);
        push("gossip", "bus", &s_bus);
        push("gossip", "tcp", &s_tcp);
        push("global_average", "shared", &s_shared_avg);
        push("global_average", "bus", &s_bus_avg);
        push("global_average", "tcp", &s_tcp_avg);
    }

    // BENCH_7: anchored at the manifest dir so the artifact lands in the
    // repo root no matter where `cargo bench` is launched from (the BENCH_6
    // CWD-relative write is why no trajectory was ever committed).
    {
        let doc = jsonio::obj(vec![
            ("bench", Json::Str("transport_and_population".into())),
            ("fast", Json::Bool(fast)),
            ("transport_rows", Json::Arr(std::mem::take(&mut transport_rows))),
            ("population_rows", Json::Arr(population_rows)),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_7.json");
        std::fs::write(&path, doc.dump() + "\n")?;
        println!("wrote {}", path.display());
    }

    // --- BENCH_8 part 1: blocked/vectorized kernel vs scalar reference ------
    // The §Kernel tentpole row pair: the shipping `mix_row_src` (fused
    // 1/2/3-neighbor lanes + MIX_BLOCK-blocked general arm) against the
    // naive reference it must reproduce bit for bit. deg 2/3 hit the fused
    // arms (one-peer / ring rows), deg 8 the blocked arm (grid-ish).
    let mut kernel_rows: Vec<Json> = Vec::new();
    {
        let dd = 1_000_000usize;
        let nsrc = 9;
        let src = rng.normal_vec(nsrc * dd, 1.0);
        for deg in [2usize, 3, 8] {
            let row: Vec<(usize, f32)> =
                (0..deg).map(|j| (j, 1.0 / (deg as f32 + 1.0))).collect();
            let srow = |j: usize| &src[j * dd..(j + 1) * dd];
            let mut out_blocked = vec![0.0f32; dd];
            let mut out_scalar = vec![0.0f32; dd];
            let s_blocked = measure(3, 20, || mix_row_src(&row, srow, &mut out_blocked));
            let s_scalar =
                measure(3, 20, || mix_row_src_scalar(&row, srow, &mut out_scalar));
            assert!(
                out_blocked.iter().zip(&out_scalar).all(|(a, b)| a.to_bits() == b.to_bits()),
                "deg {deg}: blocked kernel diverged from the scalar reference"
            );
            t.rowv(vec![
                format!("mix row, blocked kernel (deg {deg})"),
                "d = 1M".into(),
                fmt_duration(s_blocked.mean),
                fmt_duration(s_blocked.p95),
                format!("{:.1} GB/s", ((deg + 1) * dd * 4) as f64 / s_blocked.mean / 1e9),
            ]);
            t.rowv(vec![
                format!("mix row, scalar reference (deg {deg})"),
                "d = 1M".into(),
                fmt_duration(s_scalar.mean),
                fmt_duration(s_scalar.p95),
                format!("{:.2}x vs blocked", s_scalar.mean / s_blocked.mean),
            ]);
            for (kernel, s) in [("blocked", &s_blocked), ("scalar", &s_scalar)] {
                kernel_rows.push(jsonio::obj(vec![
                    ("kernel", Json::Str(kernel.into())),
                    ("d", Json::Num(dd as f64)),
                    ("deg", Json::Num(deg as f64)),
                    ("mean_seconds", Json::Num(s.mean)),
                    ("p95_seconds", Json::Num(s.p95)),
                    ("bit_equal", Json::Bool(true)),
                ]));
            }
        }
    }

    // --- BENCH_8 part 2: pinned vs unpinned worker pool ---------------------
    // The same pooled gossip mix on two pools that differ only in core
    // affinity. Bits must be identical (pinning is pure placement); the
    // wall-clock delta is what `--pin` buys on this box.
    let mut pin_rows: Vec<Json> = Vec::new();
    {
        let n = 16;
        let dd = 1_000_000usize;
        let topo = Topology::ring(n);
        let pin_t = threads_avail.clamp(2, 8);
        let mut p_plain = random_matrix(&mut rng, n, dd);
        let mut p_pinned = p_plain.clone();
        let mut mixer_plain = Mixer::new(&topo, dd);
        let mut mixer_pinned = Mixer::new(&topo, dd);
        let plain_pool = WorkerPool::with_options(pin_t, false, false);
        let pinned_pool = WorkerPool::with_options(pin_t, false, true);
        let s_plain =
            measure(2, 10, || mixer_plain.gossip(&mut p_plain, &plain_pool).unwrap());
        let s_pinned =
            measure(2, 10, || mixer_pinned.gossip(&mut p_pinned, &pinned_pool).unwrap());
        assert_eq!(
            mixer_plain.gossip_clock, mixer_pinned.gossip_clock,
            "pin benches ran different round counts"
        );
        assert_eq!(p_plain, p_pinned, "pinning changed the gossip bits");
        t.rowv(vec![
            format!("gossip mix, unpinned pool (t={pin_t})"),
            "ring n = 16, d = 1M".into(),
            fmt_duration(s_plain.mean),
            fmt_duration(s_plain.p95),
            format!("{:.1} GB/s", (n * 3 * dd * 4) as f64 / s_plain.mean / 1e9),
        ]);
        t.rowv(vec![
            format!("gossip mix, pinned pool (t={pin_t})"),
            "ring n = 16, d = 1M".into(),
            fmt_duration(s_pinned.mean),
            fmt_duration(s_pinned.p95),
            format!("{:.2}x vs unpinned", s_pinned.mean / s_plain.mean),
        ]);
        for (pinned, s) in [(false, &s_plain), (true, &s_pinned)] {
            pin_rows.push(jsonio::obj(vec![
                ("pinned", Json::Bool(pinned)),
                ("threads", Json::Num(pin_t as f64)),
                ("n", Json::Num(n as f64)),
                ("d", Json::Num(dd as f64)),
                ("mean_seconds", Json::Num(s.mean)),
                ("p95_seconds", Json::Num(s.p95)),
                ("bit_equal", Json::Bool(true)),
            ]));
        }
    }

    // --- BENCH_8 part 3: depth-k gossip pipelining --------------------------
    // A burst of chained comm-only rounds per iteration: issue keeps the
    // ring at most `depth` deep (finish the oldest round when full), then a
    // full FIFO drain at the end of the burst — exactly the k·H-boundary
    // discipline. Every depth runs the same total round count from the
    // same start, so all finals must be bit-identical to the synchronous
    // mixer's.
    let mut pipeline_rows: Vec<Json> = Vec::new();
    {
        use std::collections::VecDeque;
        let n = 16;
        let dd = 1_000_000usize;
        let burst = 8usize;
        let (warmup, iters) = (1usize, 5);
        let topo = Topology::one_peer_expo(n);
        let pipe_pool = WorkerPool::new(threads_avail.clamp(2, 8));
        let init = random_matrix(&mut rng, n, dd);
        let mut p_sync = init.clone();
        let mut sync_mixer = Mixer::new(&topo, dd);
        for _ in 0..(warmup + iters) * burst {
            sync_mixer.gossip(&mut p_sync, &pipe_pool)?;
        }
        for depth in [1usize, 2, 4] {
            let mut p = init.clone();
            let mut mixer = Mixer::with_depth(&topo, dd, depth);
            let s = measure(warmup, iters, || {
                let mut handles = VecDeque::new();
                for _ in 0..burst {
                    if !mixer.pipeline_ready() {
                        let oldest = handles.pop_front().unwrap();
                        mixer.finish_gossip(&mut p, oldest).unwrap();
                    }
                    handles.push_back(unsafe { mixer.gossip_async(&p, &pipe_pool).unwrap() });
                }
                while let Some(h) = handles.pop_front() {
                    mixer.finish_gossip(&mut p, h).unwrap();
                }
            });
            assert_eq!(
                mixer.gossip_clock, sync_mixer.gossip_clock,
                "depth {depth}: pipeline ran a different round count"
            );
            assert_eq!(p, p_sync, "depth {depth}: pipelined rounds diverged from sync");
            t.rowv(vec![
                format!("gossip pipeline, depth {depth}"),
                format!("one-peer-expo n = {n}, d = 1M, {burst} rounds/burst"),
                fmt_duration(s.mean),
                fmt_duration(s.p95),
                format!("{:.1} rounds/s", burst as f64 / s.mean),
            ]);
            pipeline_rows.push(jsonio::obj(vec![
                ("depth", Json::Num(depth as f64)),
                ("rounds", Json::Num(burst as f64)),
                ("n", Json::Num(n as f64)),
                ("d", Json::Num(dd as f64)),
                ("mean_seconds", Json::Num(s.mean)),
                ("p95_seconds", Json::Num(s.p95)),
                ("bit_equal", Json::Bool(true)),
            ]));
        }
    }

    // BENCH_8: the kernel / pinning / pipelining rows, same anchoring as
    // BENCH_7. Written before the PJRT sections so artifact-free boxes
    // still emit it.
    {
        let doc = jsonio::obj(vec![
            ("bench", Json::Str("hotpath_kernel_pin_pipeline".into())),
            ("fast", Json::Bool(fast)),
            ("kernel_rows", Json::Arr(std::mem::take(&mut kernel_rows))),
            ("pin_rows", Json::Arr(std::mem::take(&mut pin_rows))),
            ("pipeline_rows", Json::Arr(std::mem::take(&mut pipeline_rows))),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_8.json");
        std::fs::write(&path, doc.dump() + "\n")?;
        println!("wrote {}", path.display());
    }

    // --- BENCH_9: overlap on the wire — bus + tcp async gossip vs BSP -------
    // The ISSUE 9 headline rows: the message-passing backends running the
    // same comm-only burst synchronously and overlapped at depth {1, 2, 4}.
    // The overlapped runs must stay bit-identical to BSP at the drain and
    // drop zero frames (epoch hygiene on a clean run); the wall-clock
    // ratio is what `--overlap --pipeline-depth K` buys once round t's
    // receive+mix hides behind round t+1's sends.
    let mut overlap_rows: Vec<Json> = Vec::new();
    {
        let n = 16;
        let dd = if fast { 250_000usize } else { 1_000_000 };
        let burst = 8usize;
        let (warmup, iters) = (1usize, 5);
        let topo = Topology::one_peer_expo(n);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), n);
        let wire_pool = WorkerPool::new(threads_avail.clamp(2, 8));
        let init = random_matrix(&mut rng, n, dd);
        overlap_wire_burst(
            &mut t,
            &mut overlap_rows,
            "bus",
            |depth| {
                Ok(BusBackend::with_depth(
                    &topo,
                    dd,
                    &costs,
                    25_500_000,
                    Compression::None,
                    false,
                    depth,
                ))
            },
            &init,
            &wire_pool,
            burst,
            warmup,
            iters,
        )?;
        overlap_wire_burst(
            &mut t,
            &mut overlap_rows,
            "tcp",
            |depth| {
                TcpBackend::new_loopback_with_depth(
                    &topo,
                    dd,
                    &costs,
                    25_500_000,
                    Compression::None,
                    false,
                    "127.0.0.1:0",
                    depth,
                )
            },
            &init,
            &wire_pool,
            burst,
            warmup,
            iters,
        )?;
    }

    // BENCH_9: the overlap-on-the-wire rows, same anchoring as BENCH_7/8,
    // written before the PJRT sections so artifact-free boxes still emit it.
    {
        let doc = jsonio::obj(vec![
            ("bench", Json::Str("overlap_wire".into())),
            ("fast", Json::Bool(fast)),
            ("overlap_rows", Json::Arr(std::mem::take(&mut overlap_rows))),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_9.json");
        std::fs::write(&path, doc.dump() + "\n")?;
        println!("wrote {}", path.display());
    }

    // --- BENCH_10: tracing overhead — the obs plane disarmed vs armed -------
    // The ISSUE 10 headline rows: the same synchronous gossip burst with
    // tracing off (every probe one relaxed atomic load) and on (spans into
    // the per-thread ring). The traced finals must stay bit-identical to
    // the untraced ones — probes read and annotate, never touch the
    // arithmetic — and the wall-clock ratio is what `--trace` costs.
    let mut tracing_rows: Vec<Json> = Vec::new();
    {
        let n = 16;
        let dd = if fast { 250_000usize } else { 1_000_000 };
        let burst = 8usize;
        let (warmup, iters) = (1usize, 5);
        let topo = Topology::one_peer_expo(n);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), n);
        let obs_pool = WorkerPool::new(threads_avail.clamp(2, 8));
        let init = random_matrix(&mut rng, n, dd);
        for backend_name in ["shared", "bus"] {
            let mk = || -> Box<dyn CommBackend> {
                match backend_name {
                    "shared" => Box::new(SharedBackend::new(
                        &topo,
                        dd,
                        &costs,
                        25_500_000,
                        Compression::None,
                    )),
                    _ => Box::new(BusBackend::new(
                        &topo,
                        dd,
                        &costs,
                        25_500_000,
                        Compression::None,
                        false,
                    )),
                }
            };
            assert!(!gossip_pga::obs::enabled(), "trace plane left armed");
            let mut plain_b = mk();
            let mut p_plain = init.clone();
            let s_plain = measure(warmup, iters, || {
                for _ in 0..burst {
                    plain_b.gossip(&mut p_plain, &obs_pool).unwrap();
                }
            });
            let mut traced_b = mk();
            let mut p_traced = init.clone();
            gossip_pga::obs::start(1 << 16);
            let s_traced = measure(warmup, iters, || {
                for _ in 0..burst {
                    traced_b.gossip(&mut p_traced, &obs_pool).unwrap();
                }
            });
            let data = gossip_pga::obs::stop_and_collect();
            assert_eq!(
                traced_b.gossip_clock(),
                plain_b.gossip_clock(),
                "{backend_name}: traced run covered a different round count"
            );
            assert_eq!(p_traced, p_plain, "{backend_name}: tracing perturbed the gossip bits");
            let spans = data.total_spans();
            assert_eq!(
                spans,
                (warmup + iters) * burst,
                "{backend_name}: one span per traced gossip round"
            );
            t.rowv(vec![
                format!("gossip burst, untraced ({backend_name})"),
                format!("one-peer-expo n = {n}, d = {dd}, {burst} rounds/burst"),
                fmt_duration(s_plain.mean),
                fmt_duration(s_plain.p95),
                format!("{:.1} rounds/s", burst as f64 / s_plain.mean),
            ]);
            t.rowv(vec![
                format!("gossip burst, traced ({backend_name})"),
                format!("one-peer-expo n = {n}, d = {dd}, {burst} rounds/burst"),
                fmt_duration(s_traced.mean),
                fmt_duration(s_traced.p95),
                format!("{:.3}x vs untraced", s_traced.mean / s_plain.mean),
            ]);
            for (traced, s) in [(false, &s_plain), (true, &s_traced)] {
                tracing_rows.push(jsonio::obj(vec![
                    ("backend", Json::Str(backend_name.into())),
                    ("traced", Json::Bool(traced)),
                    ("rounds", Json::Num(burst as f64)),
                    ("n", Json::Num(n as f64)),
                    ("d", Json::Num(dd as f64)),
                    ("mean_seconds", Json::Num(s.mean)),
                    ("p95_seconds", Json::Num(s.p95)),
                    ("spans", Json::Num(if traced { spans as f64 } else { 0.0 })),
                    ("bit_equal", Json::Bool(true)),
                ]));
            }
        }
    }

    // BENCH_10: the tracing-overhead rows, same anchoring as BENCH_7/8/9,
    // written before the PJRT sections so artifact-free boxes still emit it.
    {
        let doc = jsonio::obj(vec![
            ("bench", Json::Str("obs_trace".into())),
            ("fast", Json::Bool(fast)),
            ("tracing_rows", Json::Arr(std::mem::take(&mut tracing_rows))),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_10.json");
        std::fs::write(&path, doc.dump() + "\n")?;
        println!("wrote {}", path.display());
    }

    // --- PJRT grad exec ----------------------------------------------------
    let rt = Arc::new(Runtime::load_default()?);
    for (model, tag) in [("logreg", None), ("mlp", None), ("transformer", Some("tiny"))] {
        let spec = rt.manifest.find(model, "grad", tag)?.clone();
        let g = GradFn::new(rt.clone(), &spec.name)?;
        let dflat = spec.flat_dim;
        let params = vec![0.01f32; dflat];
        let mut grad = vec![0.0f32; dflat];
        let mk_batch = || -> Vec<xla::Literal> {
            spec.inputs[1..]
                .iter()
                .map(|io| {
                    let n: usize = io.shape.iter().product();
                    match io.dtype {
                        gossip_pga::runtime::Dtype::F32 => lit_f32(&vec![0.1; n], &io.shape).unwrap(),
                        gossip_pga::runtime::Dtype::I32 => lit_i32(&vec![1; n], &io.shape).unwrap(),
                    }
                })
                .collect()
        };
        let s = measure(3, 15, || {
            g.call_into(&params, mk_batch(), &mut grad).unwrap();
        });
        t.rowv(vec![
            format!("PJRT grad exec ({model})"),
            format!("flat_dim = {dflat}"),
            fmt_duration(s.mean),
            fmt_duration(s.p95),
            format!("{:.0} exec/s", 1.0 / s.mean),
        ]);
    }

    // --- full coordinator step --------------------------------------------
    let n = 32;
    let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 3)?;
    let mut trainer = Trainer::new(workload, init, trainer_opts(n, 1, Regime::Bsp))?;
    let s = measure(5, 50, || {
        trainer.step_once().unwrap();
    });
    t.rowv(vec![
        "coordinator step (logreg)".into(),
        format!("n = {n}, PGA H=6"),
        fmt_duration(s.mean),
        fmt_duration(s.p95),
        format!("{:.0} worker-execs/s", n as f64 / s.mean),
    ]);

    // --- sequential vs pooled coordinator step -----------------------------
    // Same seed, same step count: the throughput ratio is the parallel
    // speedup, and the final parameters must agree bit-for-bit.
    let n = 16;
    let threads = threads_avail.min(n).max(2);
    let (workload_seq, init_seq) = logreg_workload(rt.clone(), n, 256, true, 3)?;
    let (workload_thr, init_thr) = logreg_workload(rt.clone(), n, 256, true, 3)?;
    let mut seq = Trainer::new(workload_seq, init_seq, trainer_opts(n, 1, Regime::Bsp))?;
    let mut thr = Trainer::new(workload_thr, init_thr, trainer_opts(n, threads, Regime::Bsp))?;
    let s_seq = measure(5, 50, || {
        seq.step_once().unwrap();
    });
    let s_thr = measure(5, 50, || {
        thr.step_once().unwrap();
    });
    for i in 0..n {
        assert_eq!(
            seq.worker_params(i),
            thr.worker_params(i),
            "pooled run diverged from sequential at worker {i}"
        );
    }
    t.rowv(vec![
        "coordinator step, sequential".into(),
        format!("n = {n}, PGA H=6, threads=1"),
        fmt_duration(s_seq.mean),
        fmt_duration(s_seq.p95),
        format!("{:.0} worker-execs/s", n as f64 / s_seq.mean),
    ]);
    t.rowv(vec![
        "coordinator step, pooled".into(),
        format!("n = {n}, PGA H=6, threads={threads}"),
        fmt_duration(s_thr.mean),
        fmt_duration(s_thr.p95),
        format!("{:.0} worker-execs/s", n as f64 / s_thr.mean),
    ]);
    t.rowv(vec![
        "  -> pooled speedup".into(),
        format!("{threads} threads"),
        format!("{:.2}x", s_seq.mean / s_thr.mean),
        "-".into(),
        "(params bit-identical)".into(),
    ]);

    // --- overlap (double-buffered async gossip) vs BSP ---------------------
    // Same thread count, same seed: overlap hides the round-t mix behind
    // round t+1's sampling phase. Both trainers take the same number of
    // steps; after a final drain their parameters must agree bit-for-bit
    // (the schedule-equivalence contract).
    let (workload_bsp, init_bsp) = logreg_workload(rt.clone(), n, 256, true, 3)?;
    let (workload_ovl, init_ovl) = logreg_workload(rt.clone(), n, 256, true, 3)?;
    let mut bsp = Trainer::new(workload_bsp, init_bsp, trainer_opts(n, threads, Regime::Bsp))?;
    let mut ovl = Trainer::new(workload_ovl, init_ovl, trainer_opts(n, threads, Regime::Overlap))?;
    let s_bsp = measure(5, 60, || {
        bsp.step_once().unwrap();
    });
    let s_ovl = measure(5, 60, || {
        ovl.step_once().unwrap();
    });
    ovl.drain().unwrap();
    for i in 0..n {
        assert_eq!(
            bsp.worker_params(i),
            ovl.worker_params(i),
            "overlap run diverged from BSP at worker {i}"
        );
    }
    t.rowv(vec![
        "coordinator step, BSP".into(),
        format!("n = {n}, PGA H=6, threads={threads}"),
        fmt_duration(s_bsp.mean),
        fmt_duration(s_bsp.p95),
        format!("{:.0} worker-execs/s", n as f64 / s_bsp.mean),
    ]);
    t.rowv(vec![
        "coordinator step, overlap".into(),
        format!("n = {n}, PGA H=6, threads={threads}, async gossip"),
        fmt_duration(s_ovl.mean),
        fmt_duration(s_ovl.p95),
        format!("{:.0} worker-execs/s", n as f64 / s_ovl.mean),
    ]);
    t.rowv(vec![
        "  -> overlap vs BSP".into(),
        format!("{threads} threads"),
        format!("{:.2}x", s_bsp.mean / s_ovl.mean),
        "-".into(),
        "(params bit-identical after drain)".into(),
    ]);

    // --- regime dispatch: BSP vs overlap vs event-driven async --------------
    // Three step loops over the same workload and seed. Strict async
    // (max_staleness = 0) must reproduce the BSP trainer bit-exactly —
    // parameters AND virtual clocks (the eventsim anchor) — while relaxed
    // async (max_staleness = 2) is the AD-PSGD regime proper: bounded-
    // stale mixing, per-link billing, smaller simulated critical path.
    {
        let (w_bsp, i_bsp) = logreg_workload(rt.clone(), n, 256, true, 3)?;
        let (w_strict, i_strict) = logreg_workload(rt.clone(), n, 256, true, 3)?;
        let (w_relaxed, i_relaxed) = logreg_workload(rt.clone(), n, 256, true, 3)?;
        let mut bsp = Trainer::new(w_bsp, i_bsp, trainer_opts(n, threads, Regime::Bsp))?;
        let mut strict =
            Trainer::new(w_strict, i_strict, trainer_opts(n, threads, Regime::Async))?;
        let mut relaxed_opts = trainer_opts(n, threads, Regime::Async);
        relaxed_opts.max_staleness = 2;
        let mut relaxed = Trainer::new(w_relaxed, i_relaxed, relaxed_opts)?;
        let s_bsp = measure(5, 50, || {
            bsp.step_once().unwrap();
        });
        let s_strict = measure(5, 50, || {
            strict.step_once().unwrap();
        });
        let s_relaxed = measure(5, 50, || {
            relaxed.step_once().unwrap();
        });
        for i in 0..n {
            assert_eq!(
                bsp.worker_params(i),
                strict.worker_params(i),
                "strict async diverged from BSP at worker {i}"
            );
        }
        assert_eq!(
            bsp.sim_seconds(),
            strict.sim_seconds(),
            "strict async must reproduce the barrier-billed clock bit-exactly"
        );
        assert!(
            relaxed.sim_seconds() <= bsp.sim_seconds(),
            "relaxed async sim time {} exceeded BSP's {}",
            relaxed.sim_seconds(),
            bsp.sim_seconds()
        );
        t.rowv(vec![
            "coordinator step, regime=bsp".into(),
            format!("n = {n}, PGA H=6, threads={threads}"),
            fmt_duration(s_bsp.mean),
            fmt_duration(s_bsp.p95),
            format!("{:.0} worker-execs/s", n as f64 / s_bsp.mean),
        ]);
        t.rowv(vec![
            "coordinator step, regime=async s=0".into(),
            format!("n = {n}, lockstep waves"),
            fmt_duration(s_strict.mean),
            fmt_duration(s_strict.p95),
            format!("{:.0} worker-execs/s", n as f64 / s_strict.mean),
        ]);
        t.rowv(vec![
            "coordinator step, regime=async s=2".into(),
            format!("n = {n}, event-driven"),
            fmt_duration(s_relaxed.mean),
            fmt_duration(s_relaxed.p95),
            format!("{:.0} worker-execs/s", n as f64 / s_relaxed.mean),
        ]);
        t.rowv(vec![
            "  -> async s=0 vs bsp".into(),
            "dispatch overhead of the event plane".into(),
            format!("{:.2}x", s_strict.mean / s_bsp.mean),
            "-".into(),
            "(params + clocks bit-identical)".into(),
        ]);
        t.rowv(vec![
            "  -> async s=2 sim-time".into(),
            "per-link billing".into(),
            format!("{:.2}x of bsp", relaxed.sim_seconds() / bsp.sim_seconds()),
            "-".into(),
            "(hides comm behind compute)".into(),
        ]);
    }

    // --- work-stealing vs static sharding under a 4x straggler ---------------
    // A simulated straggler (node 2: 4x compute + latency in the cost
    // table) only bends the virtual clocks, so stealing's job here is the
    // REAL wall-clock: over-split chunks let idle threads drain the queue
    // while an unlucky thread grinds. Both runs must end bit-identical to
    // each other AND carry identical virtual clocks (billing is
    // pool-independent).
    {
        let straggler =
            NodeCosts::homogeneous(CostModel::calibrated_resnet50(), n).with_straggler(2, 4.0)?;
        let mk = |stealing: bool| -> anyhow::Result<Trainer> {
            let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 3)?;
            let mut opts = trainer_opts(n, threads, Regime::Bsp);
            opts.stealing = stealing;
            opts.node_costs = Some(straggler.clone());
            Trainer::new(workload, init, opts)
        };
        let mut stat = mk(false)?;
        let mut steal = mk(true)?;
        let s_static = measure(5, 50, || {
            stat.step_once().unwrap();
        });
        let s_steal = measure(5, 50, || {
            steal.step_once().unwrap();
        });
        for i in 0..n {
            assert_eq!(
                stat.worker_params(i),
                steal.worker_params(i),
                "stealing run diverged from static sharding at worker {i}"
            );
        }
        assert_eq!(
            stat.sim_seconds(),
            steal.sim_seconds(),
            "virtual clocks must not depend on the chunking policy"
        );
        assert!(stat.straggler_slack() > 0.0, "the seeded straggler must open clock slack");
        t.rowv(vec![
            "coordinator step, static shards".into(),
            format!("n = {n}, 4x straggler, threads={threads}"),
            fmt_duration(s_static.mean),
            fmt_duration(s_static.p95),
            format!("{:.0} worker-execs/s", n as f64 / s_static.mean),
        ]);
        t.rowv(vec![
            "coordinator step, work stealing".into(),
            format!("n = {n}, 4x straggler, threads={threads}"),
            fmt_duration(s_steal.mean),
            fmt_duration(s_steal.p95),
            format!("{:.0} worker-execs/s", n as f64 / s_steal.mean),
        ]);
        t.rowv(vec![
            "  -> stealing vs static".into(),
            format!("{threads} threads, grain 4"),
            format!("{:.2}x", s_static.mean / s_steal.mean),
            "-".into(),
            "(params + clocks bit-identical)".into(),
        ]);
    }

    t.print();
    Ok(())
}
