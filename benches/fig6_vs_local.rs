//! Figure 6 (Appendix F.2): Gossip-PGA vs Local SGD vs Parallel SGD over
//! the exponential graph, grid and ring topologies (non-iid, H = 16).
//!
//! Paper shape: Gossip-PGA always converges faster than Local SGD (the
//! extra gossip communication between syncs contracts consensus); on the
//! exponential graph (smallest beta) PGA is nearly indistinguishable from
//! Parallel SGD.
//!
//!     cargo bench --bench fig6_vs_local

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_logreg, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::metrics::{smooth, transient_stage_scaled};
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let steps = step_scale(1000);
    let n = 36;
    let h = 16;
    println!("# Figure 6: Gossip-PGA vs Local SGD, non-iid, n = {n}, H = {h}\n");

    let mut summary =
        Table::new(&["topology", "beta", "final Local", "final PGA", "Local transient", "PGA transient"]);
    for name in ["expo", "grid", "ring"] {
        let beta = Topology::from_name(name, n)?.beta();
        let mut curves = Vec::new();
        for algo in [AlgorithmKind::Parallel, AlgorithmKind::Local, AlgorithmKind::GossipPga] {
            let spec = RunSpec::logreg(algo, Topology::from_name(name, n)?, h, true, steps);
            let hist = run_logreg(rt.clone(), &spec, 8000 / n)?;
            hist.write_csv(std::path::Path::new(&format!(
                "target/bench_out/fig6_{name}_{}.csv",
                algo.name()
            )))?;
            curves.push(hist);
        }
        let par = smooth(&curves[0].losses(), 5);
        let ts = |hh: &gossip_pga::metrics::History| {
            transient_stage_scaled(&smooth(&hh.losses(), 5), &par, 0.05)
                .map(|i| format!("~{}", curves[0].records[i].step))
                .unwrap_or_else(|| "beyond canvas".into())
        };
        summary.rowv(vec![
            name.to_string(),
            format!("{beta:.4}"),
            format!("{:.5}", curves[1].final_loss()),
            format!("{:.5}", curves[2].final_loss()),
            ts(&curves[1]),
            ts(&curves[2]),
        ]);
    }
    summary.print();
    println!(
        "\nExpected shape (paper Fig. 6 / Table 3): PGA <= Local everywhere;\n\
         the advantage is largest on the best-connected (expo) graph, where\n\
         C_beta -> 1 while Local SGD still pays H."
    );
    Ok(())
}
