//! Figure 5 (Appendix F.2): Gossip-PGA vs Gossip SGD across topologies of
//! decreasing connectivity — exponential graph, grid, ring — at fixed n.
//!
//! Paper shape: the sparser the topology (beta -> 1), the more evident
//! Gossip-PGA's advantage over Gossip SGD.
//!
//!     cargo bench --bench fig5_topologies

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_logreg, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::metrics::{smooth, transient_stage_scaled};
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let steps = step_scale(1000);
    let n = 36;
    let h = 16;
    println!("# Figure 5: non-iid logistic regression, n = {n}, H = {h}, topology sweep\n");

    let mut summary =
        Table::new(&["topology", "beta", "final Gossip", "final PGA", "Gossip transient", "PGA transient"]);
    for name in ["expo", "grid", "ring"] {
        let topo = Topology::from_name(name, n)?;
        let beta = topo.beta();
        let mut curves = Vec::new();
        for algo in [AlgorithmKind::Parallel, AlgorithmKind::Gossip, AlgorithmKind::GossipPga] {
            let spec = RunSpec::logreg(algo, Topology::from_name(name, n)?, h, true, steps);
            let hist = run_logreg(rt.clone(), &spec, 8000 / n)?;
            hist.write_csv(std::path::Path::new(&format!(
                "target/bench_out/fig5_{name}_{}.csv",
                algo.name()
            )))?;
            curves.push(hist);
        }
        let par = smooth(&curves[0].losses(), 5);
        let ts = |hh: &gossip_pga::metrics::History| {
            transient_stage_scaled(&smooth(&hh.losses(), 5), &par, 0.05)
                .map(|i| format!("~{}", curves[0].records[i].step))
                .unwrap_or_else(|| "beyond canvas".into())
        };
        summary.rowv(vec![
            name.to_string(),
            format!("{beta:.4}"),
            format!("{:.5}", curves[1].final_loss()),
            format!("{:.5}", curves[2].final_loss()),
            ts(&curves[1]),
            ts(&curves[2]),
        ]);
    }
    summary.print();
    println!(
        "\nExpected shape (paper Fig. 5): on expo, PGA ~ Gossip; on the ring\n\
         the gap is largest (beta closest to 1)."
    );
    Ok(())
}
