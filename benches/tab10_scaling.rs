//! Table 10: scaling study — Parallel / Gossip / Gossip-PGA at n in
//! {4, 8, 16, 32} nodes; final accuracy and simulated hours.
//!
//! Paper shape: near-linear time speedup for all methods as n doubles (the
//! per-node batch is fixed so steps-to-budget halves); Gossip degrades
//! accuracy at n = 32 while PGA holds Parallel-level accuracy.
//!
//!     cargo bench --bench tab10_scaling

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let budget = step_scale(4800); // total sample budget: steps(n) = budget / n
    println!("# Table 10: scaling (fixed total sample budget = {budget} worker-steps)\n");

    let mut t = Table::new(&["Method", "4 nodes", "8 nodes", "16 nodes", "32 nodes"]);
    for (label, algo) in [
        ("Parallel SGD", AlgorithmKind::Parallel),
        ("Gossip SGD", AlgorithmKind::Gossip),
        ("Gossip-PGA", AlgorithmKind::GossipPga),
    ] {
        let mut cells = vec![label.to_string()];
        for &n in &[4usize, 8, 16, 32] {
            let steps = budget / n;
            let spec = RunSpec::image(algo, Topology::one_peer_expo(n), 6, steps);
            let r = run_image(rt.clone(), &spec, 2048)?;
            cells.push(format!("{:.1}/{:.2}", r.accuracy * 100.0, r.sim_hours));
        }
        t.rowv(cells);
    }
    t.print();
    println!(
        "\nCell format: accuracy% / simulated hours (paper Table 10 format).\n\
         Expected shape: hours roughly halve per doubling for every method;\n\
         Gossip's accuracy sags at 32 nodes, PGA's does not."
    );
    Ok(())
}
