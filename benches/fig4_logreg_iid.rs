//! Figure 4 (Appendix F.2): same as Figure 1 but with iid data.
//!
//! Paper shape: Gossip-PGA still beats Gossip SGD, but the transient-stage
//! gap is *smaller* than in the non-iid case (b^2 = 0 removes the
//! (1-beta)^-4 term — Table 2's first column vs second).
//!
//!     cargo bench --bench fig4_logreg_iid

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_logreg, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::metrics::{smooth, transient_stage_scaled};
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let steps = step_scale(1000);
    let h = 16;
    println!("# Figure 4: logistic regression, ring, iid, H = {h}, {steps} iters\n");

    let mut summary = Table::new(&["n", "beta", "Gossip transient", "PGA transient"]);
    for &n in &[20usize, 50, 100] {
        let beta = Topology::ring(n).beta();
        let mut curves = Vec::new();
        for algo in [AlgorithmKind::Parallel, AlgorithmKind::Gossip, AlgorithmKind::GossipPga] {
            let spec = RunSpec::logreg(algo, Topology::ring(n), h, false, steps);
            let hist = run_logreg(rt.clone(), &spec, 8000 / n)?;
            hist.write_csv(std::path::Path::new(&format!(
                "target/bench_out/fig4_n{n}_{}.csv",
                algo.name()
            )))?;
            curves.push(hist);
        }
        let par = smooth(&curves[0].losses(), 5);
        let ts = |h: &gossip_pga::metrics::History| {
            transient_stage_scaled(&smooth(&h.losses(), 5), &par, 0.05)
                .map(|i| format!("~{}", curves[0].records[i].step))
                .unwrap_or_else(|| "beyond canvas".into())
        };
        summary.rowv(vec![n.to_string(), format!("{beta:.4}"), ts(&curves[1]), ts(&curves[2])]);
    }
    summary.print();
    println!(
        "\nExpected shape (paper Fig. 4 / Table 2): both transients shorter than\n\
         the non-iid run (fig1), and the Gossip-vs-PGA gap narrower."
    );
    Ok(())
}
