//! Tables 5, 12, 13, 14: transient *time* = transient iterations x per-
//! iteration communication time, on grid/ring topologies, iid/non-iid, with
//! H = sqrt(n) (Appendix D.2).
//!
//! Uses the paper's own alpha-beta model, calibrated to its Table 17
//! measurements, with the measured beta of each topology.
//!
//!     cargo bench --bench tab5_transient_time

use gossip_pga::costmodel::{AlgoCost, CostModel};
use gossip_pga::harness::{fmt_duration, Table};
use gossip_pga::topology::spectral::transient;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let model = CostModel::calibrated_resnet50();
    let d = 25_500_000; // ResNet-50
    println!(
        "# Tables 5/12/13/14: transient time, H = sqrt(n), d = 25.5M\n\
         # (alpha = {:.2e} s, theta = {:.2e} s/scalar — Table 17 calibration)\n",
        model.alpha, model.theta
    );

    for (table, topo_name, non_iid) in [
        ("Table 5  (grid, non-iid)", "grid", true),
        ("Table 12 (grid, iid)", "grid", false),
        ("Table 13 (ring, non-iid)", "ring", true),
        ("Table 14 (ring, iid)", "ring", false),
    ] {
        println!("== {table} ==");
        let mut t = Table::new(&[
            "n",
            "H",
            "beta",
            "Gossip trans. iter",
            "PGA trans. iter",
            "Gossip comm/iter",
            "PGA comm/iter",
            "Gossip trans. time",
            "PGA trans. time",
            "PGA wins?",
        ]);
        for &n in &[16usize, 36, 64, 100] {
            let topo = Topology::from_name(topo_name, n)?;
            let beta = topo.beta();
            let h = (n as f64).sqrt().round() as usize;
            let (g_it, p_it) = if non_iid {
                (transient::gossip_noniid(n, beta), transient::pga_noniid(n, beta, h))
            } else {
                (transient::gossip_iid(n, beta), transient::pga_iid(n, beta, h))
            };
            let g_comm = model.per_iter(AlgoCost::Gossip, &topo, d, h);
            let p_comm = model.per_iter(AlgoCost::GossipPga, &topo, d, h);
            let g_time = g_it * g_comm;
            let p_time = p_it * p_comm;
            t.rowv(vec![
                n.to_string(),
                h.to_string(),
                format!("{beta:.4}"),
                format!("{g_it:.2e}"),
                format!("{p_it:.2e}"),
                fmt_duration(g_comm),
                fmt_duration(p_comm),
                fmt_duration(g_time),
                fmt_duration(p_time),
                (p_time <= g_time).to_string(),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "Expected shape (paper App. D.2): although PGA pays more per iteration\n\
         (amortized all-reduce), its transient time is orders of magnitude\n\
         shorter — O(n^5.5) vs O(n^7)-O(n^11) depending on the scenario."
    );
    Ok(())
}
