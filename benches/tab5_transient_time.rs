//! Tables 5, 12, 13, 14: transient *time* = transient iterations x per-
//! iteration communication time, on grid/ring topologies, iid/non-iid, with
//! H = sqrt(n) (Appendix D.2).
//!
//! Uses the paper's own alpha-beta model, calibrated to its Table 17
//! measurements, with the measured beta of each topology. Since the
//! virtual-time refactor the per-action times come from the same
//! [`VirtualClocks`] engine the trainer bills (via
//! [`NodeCosts::gossip_critical`] / [`NodeCosts::all_reduce_critical`] —
//! one-round critical paths), not a parallel copy of the formulas; on the
//! homogeneous table used here the values are bit-identical to the old
//! scalar `CostModel` arithmetic (asserted below), so every printed number
//! is unchanged. A final section shows the same accounting under a 4x
//! straggler — the heterogeneous regime the scalar model could not express.
//!
//!     cargo bench --bench tab5_transient_time

use gossip_pga::costmodel::{AlgoCost, CostModel, NodeCosts};
use gossip_pga::harness::{fmt_duration, Table};
use gossip_pga::topology::spectral::transient;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let model = CostModel::calibrated_resnet50();
    let d = 25_500_000; // ResNet-50
    println!(
        "# Tables 5/12/13/14: transient time, H = sqrt(n), d = 25.5M\n\
         # (alpha = {:.2e} s, theta = {:.2e} s/scalar — Table 17 calibration;\n\
         #  per-action times from the VirtualClocks engine, homogeneous table)\n",
        model.alpha, model.theta
    );

    for (table, topo_name, non_iid) in [
        ("Table 5  (grid, non-iid)", "grid", true),
        ("Table 12 (grid, iid)", "grid", false),
        ("Table 13 (ring, non-iid)", "ring", true),
        ("Table 14 (ring, iid)", "ring", false),
    ] {
        println!("== {table} ==");
        let mut t = Table::new(&[
            "n",
            "H",
            "beta",
            "Gossip trans. iter",
            "PGA trans. iter",
            "Gossip comm/iter",
            "PGA comm/iter",
            "Gossip trans. time",
            "PGA trans. time",
            "PGA wins?",
        ]);
        for &n in &[16usize, 36, 64, 100] {
            let topo = Topology::from_name(topo_name, n)?;
            let beta = topo.beta();
            let h = (n as f64).sqrt().round() as usize;
            let (g_it, p_it) = if non_iid {
                (transient::gossip_noniid(n, beta), transient::pga_noniid(n, beta, h))
            } else {
                (transient::gossip_iid(n, beta), transient::pga_iid(n, beta, h))
            };
            // Per-iteration comm from the clock engine: one-round critical
            // paths, amortized exactly like CostModel::per_iter.
            let costs = NodeCosts::homogeneous(model, n);
            let gossip = costs.gossip_critical(&topo, d);
            let allreduce = costs.all_reduce_critical(&topo, d);
            let g_comm = gossip;
            let p_comm = gossip + allreduce / h as f64;
            // The homogeneous regression anchor: the clock-derived values
            // ARE the scalar model's, bit for bit.
            assert_eq!(g_comm, model.per_iter(AlgoCost::Gossip, &topo, d, h));
            assert_eq!(p_comm, model.per_iter(AlgoCost::GossipPga, &topo, d, h));
            let g_time = g_it * g_comm;
            let p_time = p_it * p_comm;
            t.rowv(vec![
                n.to_string(),
                h.to_string(),
                format!("{beta:.4}"),
                format!("{g_it:.2e}"),
                format!("{p_it:.2e}"),
                fmt_duration(g_comm),
                fmt_duration(p_comm),
                fmt_duration(g_time),
                fmt_duration(p_time),
                (p_time <= g_time).to_string(),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "Expected shape (paper App. D.2): although PGA pays more per iteration\n\
         (amortized all-reduce), its transient time is orders of magnitude\n\
         shorter — O(n^5.5) vs O(n^7)-O(n^11) depending on the scenario.\n"
    );

    // --- heterogeneous coda: the same accounting under a 4x straggler ------
    println!("== Straggler coda: per-iteration comm under node 0 at 4x (compute+latency) ==");
    let mut t = Table::new(&[
        "topology",
        "n",
        "Gossip/iter (hom -> slow)",
        "All-Reduce/iter (hom -> slow)",
        "Gossip degr.",
        "All-Reduce degr.",
    ]);
    for (name, n) in [("ring", 36usize), ("one-peer-expo", 32)] {
        let topo = Topology::from_name(name, n)?;
        let hom = NodeCosts::homogeneous(model, n);
        let slow = hom.clone().with_straggler(0, 4.0)?;
        let g0 = hom.gossip_critical(&topo, d);
        let g1 = slow.gossip_critical(&topo, d);
        let a0 = hom.all_reduce_critical(&topo, d);
        let a1 = slow.all_reduce_critical(&topo, d);
        t.rowv(vec![
            name.to_string(),
            n.to_string(),
            format!("{} -> {}", fmt_duration(g0), fmt_duration(g1)),
            format!("{} -> {}", fmt_duration(a0), fmt_duration(a1)),
            format!("{:.2}x", g1 / g0),
            format!("{:.2}x", a1 / a0),
        ]);
    }
    t.print();
    println!(
        "\nAll-Reduce pays the straggler's latency n times per round, gossip\n\
         pays it once — the n*alpha term of §3.4 is exactly what a slow node\n\
         amplifies (see benches/tab17_comm_overhead.rs for the asserted gate)."
    );
    Ok(())
}
