//! Tables 2, 3, 4, 6: transient-stage orders and convergence-rate bounds
//! evaluated at *measured* beta for real topologies (Appendix D).
//!
//! Purely analytic — this bench regenerates the paper's theory tables from
//! the implemented formulas and verifies the claimed dominance relations.
//!
//!     cargo bench --bench tab2_3_transient_theory

use gossip_pga::harness::Table;
use gossip_pga::topology::spectral::{self, transient, RateParams};
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let h = 16;

    println!("# Table 2: transient-stage orders, Gossip SGD vs Gossip-PGA (H = {h})\n");
    let mut t2 = Table::new(&[
        "topology/n",
        "beta",
        "regime",
        "Gossip iid",
        "Gossip non-iid",
        "PGA iid",
        "PGA non-iid",
        "PGA shorter?",
    ]);
    for (name, n) in [("grid", 36), ("grid", 100), ("ring", 36), ("ring", 100)] {
        let topo = Topology::from_name(name, n)?;
        let beta = topo.beta();
        let g_iid = transient::gossip_iid(n, beta);
        let g_non = transient::gossip_noniid(n, beta);
        let p_iid = transient::pga_iid(n, beta, h);
        let p_non = transient::pga_noniid(n, beta, h);
        t2.rowv(vec![
            format!("{name}/{n}"),
            format!("{beta:.4}"),
            format!("{:?}", spectral::regime(beta, h)),
            format!("{g_iid:.2e}"),
            format!("{g_non:.2e}"),
            format!("{p_iid:.2e}"),
            format!("{p_non:.2e}"),
            (p_iid <= g_iid && p_non <= g_non).to_string(),
        ]);
    }
    t2.print();

    println!("\n# Table 3: transient-stage orders, Local SGD vs Gossip-PGA (H = {h})\n");
    let mut t3 = Table::new(&[
        "topology/n",
        "beta",
        "Local iid",
        "Local non-iid",
        "PGA iid",
        "PGA non-iid",
        "PGA shorter?",
    ]);
    for (name, n) in [("expo", 36), ("grid", 36), ("ring", 36)] {
        let topo = Topology::from_name(name, n)?;
        let beta = topo.beta();
        let l_iid = transient::local_iid(n, h);
        let l_non = transient::local_noniid(n, h);
        let p_iid = transient::pga_iid(n, beta, h);
        let p_non = transient::pga_noniid(n, beta, h);
        t3.rowv(vec![
            format!("{name}/{n}"),
            format!("{beta:.4}"),
            format!("{l_iid:.2e}"),
            format!("{l_non:.2e}"),
            format!("{p_iid:.2e}"),
            format!("{p_non:.2e}"),
            (p_iid <= l_iid && p_non <= l_non).to_string(),
        ]);
    }
    t3.print();

    println!("\n# Tables 4/6: rate bounds at measured beta (sigma = 1, b = 1, n = 36)\n");
    let mut t4 = Table::new(&["topology", "beta", "bound @ T=1e4", "bound @ T=1e6", "transient boundary"]);
    for name in ["expo", "grid", "ring"] {
        let topo = Topology::from_name(name, 36)?;
        let p = RateParams { n: 36, beta: topo.beta(), h, sigma: 1.0, b: 1.0 };
        t4.rowv(vec![
            name.to_string(),
            format!("{:.4}", p.beta),
            format!("{:.4e}", p.bound(1e4)),
            format!("{:.4e}", p.bound(1e6)),
            format!("{:.2e}", p.transient_boundary()),
        ]);
    }
    t4.print();
    println!(
        "\nAll 'PGA shorter?' cells must read true — that is Tables 2-3's claim\n\
         (C_beta < min{{1/(1-beta), H}} makes PGA dominate both baselines)."
    );
    Ok(())
}
