//! Figure 7 (Appendix F.2): Gossip-PGA vs Local SGD on the grid topology
//! with growing averaging periods H in {16, 32, 64} (non-iid).
//!
//! Paper shape: the larger H, the bigger Gossip-PGA's advantage — Local
//! SGD's transient grows as H^4 while PGA's is damped by C_beta^2 H^2.
//!
//!     cargo bench --bench fig7_period_sweep

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_logreg, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let steps = step_scale(1200);
    let n = 36;
    println!("# Figure 7: PGA vs Local SGD on the grid, H sweep, non-iid, n = {n}\n");

    let mut t = Table::new(&["H", "final Parallel", "final Local", "final PGA", "Local-PGA gap"]);
    for &h in &[16usize, 32, 64] {
        let mut finals = Vec::new();
        for algo in [AlgorithmKind::Parallel, AlgorithmKind::Local, AlgorithmKind::GossipPga] {
            let spec = RunSpec::logreg(algo, Topology::grid(n), h, true, steps);
            let hist = run_logreg(rt.clone(), &spec, 8000 / n)?;
            hist.write_csv(std::path::Path::new(&format!(
                "target/bench_out/fig7_h{h}_{}.csv",
                algo.name()
            )))?;
            finals.push(hist.final_loss());
        }
        t.rowv(vec![
            h.to_string(),
            format!("{:.5}", finals[0]),
            format!("{:.5}", finals[1]),
            format!("{:.5}", finals[2]),
            format!("{:+.5}", finals[1] - finals[2]),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Fig. 7): the Local-PGA gap widens as H grows."
    );
    Ok(())
}
