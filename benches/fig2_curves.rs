//! Figures 2 and 8: iteration-wise AND (simulated-)runtime-wise convergence
//! curves of the image suite — the data behind the paper's ImageNet plots.
//! Writes per-method CSVs and prints the curves on a common grid.
//!
//!     cargo bench --bench fig2_curves

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::metrics::History;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let steps = step_scale(600);
    println!("# Figures 2/8: loss vs iteration and loss vs simulated time, n = {n}\n");

    let algos = [
        AlgorithmKind::Parallel,
        AlgorithmKind::Local,
        AlgorithmKind::Gossip,
        AlgorithmKind::GossipPga,
        AlgorithmKind::GossipAga,
    ];
    let mut hists: Vec<History> = Vec::new();
    for algo in algos {
        let spec = RunSpec::image(algo, Topology::one_peer_expo(n), 6, steps);
        let r = run_image(rt.clone(), &spec, 2048)?;
        r.history
            .write_csv(std::path::Path::new(&format!("target/bench_out/fig2_{}.csv", algo.name())))?;
        hists.push(r.history);
    }

    println!("== iteration-wise (Fig. 2 left) ==");
    let mut t = Table::new(&["iter", "Parallel", "Local", "Gossip", "PGA", "AGA"]);
    let stride = (hists[0].records.len() / 12).max(1);
    for i in (0..hists[0].records.len()).step_by(stride) {
        let mut row = vec![hists[0].records[i].step.to_string()];
        for h in &hists {
            row.push(format!("{:.4}", h.records[i].loss));
        }
        t.rowv(row);
    }
    t.print();

    println!("\n== runtime-wise (Fig. 2 right; simulated hours at each logged step) ==");
    let mut t = Table::new(&["method", "25% time", "50% time", "75% time", "100% time", "final loss"]);
    for h in &hists {
        let total = h.records.last().map(|r| r.sim_seconds).unwrap_or(0.0);
        let loss_at = |frac: f64| {
            h.records
                .iter()
                .find(|r| r.sim_seconds >= frac * total)
                .map(|r| format!("{:.4}", r.loss))
                .unwrap_or_else(|| "-".into())
        };
        t.rowv(vec![
            h.label.clone(),
            loss_at(0.25),
            loss_at(0.5),
            loss_at(0.75),
            loss_at(1.0),
            format!("{:.4}", h.final_loss()),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Fig. 2): iteration-wise PGA/AGA track Parallel;\n\
         runtime-wise they reach any given loss earliest (cheaper comms)."
    );
    Ok(())
}
