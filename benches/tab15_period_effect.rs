//! Table 15: effect of the averaging period — Gossip-PGA with H in
//! {3, 6, 12, 24, 48} vs the Parallel and Gossip endpoints.
//!
//! Paper shape: accuracy degrades gracefully as H grows; even H = 48
//! (2% of iterations averaging globally) beats plain Gossip SGD.
//!
//!     cargo bench --bench tab15_period_effect

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let steps = step_scale(600);
    println!("# Table 15: averaging-period sweep, n = {n}, {steps} steps\n");

    let mut t = Table::new(&["Method", "H", "% iters with global avg", "Acc.%"]);
    {
        let spec = RunSpec::image(AlgorithmKind::Parallel, Topology::one_peer_expo(n), 1, steps);
        let r = run_image(rt.clone(), &spec, 2048)?;
        t.rowv(vec!["Parallel SGD".into(), "-".into(), "100".into(), format!("{:.2}", r.accuracy * 100.0)]);
    }
    {
        let spec = RunSpec::image(AlgorithmKind::Gossip, Topology::one_peer_expo(n), 1, steps);
        let r = run_image(rt.clone(), &spec, 2048)?;
        t.rowv(vec!["Gossip SGD".into(), "-".into(), "0".into(), format!("{:.2}", r.accuracy * 100.0)]);
    }
    for &h in &[3usize, 6, 12, 24, 48] {
        let spec = RunSpec::image(AlgorithmKind::GossipPga, Topology::one_peer_expo(n), h, steps);
        let r = run_image(rt.clone(), &spec, 2048)?;
        t.rowv(vec![
            "Gossip-PGA".into(),
            h.to_string(),
            format!("{:.1}", 100.0 / h as f64),
            format!("{:.2}", r.accuracy * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Table 15): accuracy ~flat for H <= 12, mild\n\
         decay to H = 48, all PGA rows >= plain Gossip."
    );
    Ok(())
}
