//! Table 17 (Appendix H): communication overhead of one gossip round vs one
//! ring all-reduce — model predictions AND measured traffic/time on the
//! in-proc collective substrate.
//!
//!     cargo bench --bench tab17_comm_overhead

use gossip_pga::collective::{bus, gossip_exchange, ring_all_reduce, run_nodes};
use gossip_pga::costmodel::CostModel;
use gossip_pga::harness::{fmt_duration, Table};
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    // --- model side: reproduce the paper's Table 17 numbers --------------
    println!("# Table 17 (model): per-iteration comm time, Table 17 calibration\n");
    let mut t = Table::new(&["Model", "No comm", "All-Reduce", "Gossip (one-peer)"]);
    for (name, model, d, n) in [
        ("ResNet-50", CostModel::calibrated_resnet50(), 25_500_000usize, 32usize),
        ("BERT-Large", CostModel::calibrated_bert(), 330_000_000, 8),
    ] {
        let topo = Topology::one_peer_expo(n);
        t.rowv(vec![
            name.to_string(),
            fmt_duration(model.compute),
            format!("{} (+{})", fmt_duration(model.compute + model.all_reduce(n, d)), fmt_duration(model.all_reduce(n, d))),
            format!("{} (+{})", fmt_duration(model.compute + model.gossip(&topo, d)), fmt_duration(model.gossip(&topo, d))),
        ]);
    }
    t.print();
    println!("(paper: ResNet-50 424(278) / 296(150) ms; BERT 1913.8(1468.8) / 1011.5(566.5) ms)\n");

    // --- measured side: the in-proc substrate ----------------------------
    println!("# Table 17 (measured): in-proc bus, d = 1M floats, n = 8\n");
    let n = 8;
    let d = 1_000_000;
    let mut t2 = Table::new(&["Primitive", "Wall time", "Scalars sent/node", "Model prediction (2d(n-1)/n vs 3d)"]);

    // ring all-reduce
    let t0 = std::time::Instant::now();
    let eps = bus(n);
    let sent = run_nodes(eps, move |mut ep| {
        let mut x = vec![1.0f32; d];
        ring_all_reduce(&mut ep, &mut x)?;
        Ok(ep.scalars_sent)
    })?;
    let ar_time = t0.elapsed().as_secs_f64();
    t2.rowv(vec![
        "ring all-reduce".into(),
        fmt_duration(ar_time),
        sent[0].to_string(),
        format!("{}", 2 * d * (n - 1) / n),
    ]);

    // one ring-gossip round
    let topo = Topology::ring(n);
    let t0 = std::time::Instant::now();
    let eps = bus(n);
    let sent = run_nodes(eps, move |mut ep| {
        let rank = ep.rank;
        let x = vec![1.0f32; d];
        let row = topo.weight_row(rank, 0);
        let outn: Vec<usize> =
            topo.in_neighbors(rank, 0).into_iter().filter(|&j| j != rank).collect();
        gossip_exchange(&mut ep, &x, &row, &outn)?;
        Ok(ep.scalars_sent)
    })?;
    let g_time = t0.elapsed().as_secs_f64();
    t2.rowv(vec![
        "ring gossip round".into(),
        fmt_duration(g_time),
        sent[0].to_string(),
        format!("{}", 2 * d),
    ]);
    t2.print();
    println!(
        "\nExpected shape: all-reduce moves ~2d scalars per node in 2(n-1)\n\
         latency-bound steps; one gossip round moves 2d (ring) in a single\n\
         step — the latency gap is what the paper's Table 17 measures."
    );
    Ok(())
}
