//! Table 17 (Appendix H) on the unified CommPlane: communication overhead
//! of gossip vs global averaging — the paper's alpha-beta *model*
//! predictions next to traffic *measured* by running the same schedule on
//! both [`CommBackend`]s.
//!
//! Three sections:
//!   1. the model table (calibrated ResNet-50 / BERT-Large rows, §3.4);
//!   2. a schedule replay — Gossip-PGA actions driven over the
//!      `SharedBackend` (predicted counts) and the `BusBackend` (endpoint-
//!      measured counts): the columns must agree exactly, and the
//!      parameter trajectories must be bit-identical (asserted — this is
//!      the accounting gate `scripts/verify.sh --fast` runs);
//!   3. raw-substrate microbenches (ring all-reduce / one gossip round on
//!      the threaded bus) for the latency-vs-bandwidth shape.
//!
//!     cargo bench --bench tab17_comm_overhead          # full scale
//!     GOSSIP_PGA_FAST=1 cargo bench --bench tab17_comm_overhead
//!
//! Needs no AOT artifacts: the replay drives the backends directly.

use gossip_pga::algorithms::{schedule_for, AlgorithmKind, CommAction};
use gossip_pga::collective::{bus, gossip_exchange, ring_all_reduce, run_nodes};
use gossip_pga::comm::{schedule_traffic, BusBackend, CommBackend, Compression, SharedBackend};
use gossip_pga::costmodel::{BarrierScope, CostModel, NodeCosts, VirtualClocks};
use gossip_pga::exec::WorkerPool;
use gossip_pga::harness::{fmt_duration, Table};
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::topology::Topology;

fn fast() -> bool {
    std::env::var("GOSSIP_PGA_FAST").is_ok()
}

fn main() -> anyhow::Result<()> {
    // --- 1. model side: reproduce the paper's Table 17 numbers ------------
    println!("# Table 17 (model): per-iteration comm time, Table 17 calibration\n");
    let mut t = Table::new(&["Model", "No comm", "All-Reduce", "Gossip (one-peer)"]);
    for (name, model, d, n) in [
        ("ResNet-50", CostModel::calibrated_resnet50(), 25_500_000usize, 32usize),
        ("BERT-Large", CostModel::calibrated_bert(), 330_000_000, 8),
    ] {
        let topo = Topology::one_peer_expo(n);
        t.rowv(vec![
            name.to_string(),
            fmt_duration(model.compute),
            format!(
                "{} (+{})",
                fmt_duration(model.compute + model.all_reduce(n, d)),
                fmt_duration(model.all_reduce(n, d))
            ),
            format!(
                "{} (+{})",
                fmt_duration(model.compute + model.gossip(&topo, d)),
                fmt_duration(model.gossip(&topo, d))
            ),
        ]);
    }
    t.print();
    println!("(paper: ResNet-50 424(278) / 296(150) ms; BERT 1913.8(1468.8) / 1011.5(566.5) ms)\n");

    // --- 2. unified plane: predicted vs measured, same schedule ------------
    let n = 8usize;
    let d = if fast() { 10_000 } else { 250_000 };
    let steps = if fast() { 8 } else { 24 };
    let h = 4usize;
    let cost = CostModel::calibrated_resnet50();
    println!(
        "# Unified CommPlane: Gossip-PGA schedule (H = {h}, {steps} steps) replayed on both\n\
         # backends — ring and one-peer-expo, n = {n}, d = {d}\n"
    );
    let mut t2 = Table::new(&[
        "Topology",
        "Backend",
        "Wall",
        "Msgs",
        "Scalars",
        "Analytic scalars",
        "Comm sim time",
    ]);
    for topo in [Topology::ring(n), Topology::one_peer_expo(n)] {
        // The action sequence is schedule-owned; replay it identically on
        // both planes and derive the analytic counts alongside.
        let mut results = Vec::new();
        let mut analytic = (0u64, 0u64);
        for backend_name in ["shared", "bus"] {
            let costs = NodeCosts::homogeneous(cost, n);
            let mut backend: Box<dyn CommBackend> = match backend_name {
                "shared" => {
                    Box::new(SharedBackend::new(&topo, d, &costs, 25_500_000, Compression::None))
                }
                _ => Box::new(BusBackend::new(
                    &topo,
                    d,
                    &costs,
                    25_500_000,
                    Compression::None,
                    true,
                )),
            };
            let pool = WorkerPool::new(4);
            let mut params = ParamMatrix::random(&mut Rng::new(7), n, d, 1.0);
            let mut schedule = schedule_for(AlgorithmKind::GossipPga, h, 4, 10)?;
            let mut actions = Vec::new();
            let t0 = std::time::Instant::now();
            for k in 0..steps {
                let action = schedule.action(k, 1.0);
                match action {
                    CommAction::Gossip => {
                        backend.gossip(&mut params, &pool)?;
                    }
                    CommAction::GlobalAverage => {
                        backend.global_average(&mut params, &pool)?;
                    }
                    CommAction::None => {}
                }
                actions.push(action);
            }
            let wall = t0.elapsed().as_secs_f64();
            // One definition of "analytic": the same helper the test suite
            // checks against (comm::schedule_traffic).
            let expect = schedule_traffic(&topo, d, &actions);
            let total = backend.total();
            assert_eq!(
                (total.scalars_sent, total.msgs),
                expect,
                "{backend_name} backend accounting drifted from the analytic schedule counts"
            );
            analytic = expect;
            results.push((backend_name, wall, total, params));
            t2.rowv(vec![
                format!("{:?}", topo.kind),
                backend_name.to_string(),
                fmt_duration(wall),
                total.msgs.to_string(),
                total.scalars_sent.to_string(),
                expect.0.to_string(),
                fmt_duration(total.sim_seconds),
            ]);
        }
        // The equivalence contract: identical trajectories, identical
        // traffic, on the time-varying graph as much as the static one.
        let (_, _, shared_total, shared_params) = &results[0];
        let (_, _, bus_total, bus_params) = &results[1];
        assert_eq!(
            shared_params, bus_params,
            "{:?}: bus trajectory diverged from shared",
            topo.kind
        );
        assert_eq!(shared_total.scalars_sent, bus_total.scalars_sent);
        assert_eq!(shared_total.msgs, bus_total.msgs);
        assert_eq!(shared_total.scalars_sent, analytic.0);
    }
    t2.print();
    println!(
        "\nPredicted (shared) and measured (bus) traffic agree by construction;\n\
         the *sim time* columns differ — the shared backend bills the paper's\n\
         |N_i| theta d + alpha / 2 theta d + n alpha formulas while the bus\n\
         charges alpha-beta per actual message on the critical path. That gap\n\
         is the Table 17 story.\n"
    );

    // --- 2.5 straggler accounting gate --------------------------------------
    // A seeded 4x straggler (node 3: compute + latency) replayed through
    // the VirtualClocks billing for Gossip / Gossip-PGA / All-Reduce
    // schedules. All-Reduce pays the straggler's alpha n times per round
    // while gossip pays it once, so gossip's critical path must degrade
    // strictly less — asserted, like the traffic equalities above, so the
    // straggler story cannot silently rot.
    {
        let n = 8usize;
        let sd = if fast() { 2_000 } else { 50_000 };
        let ssteps = if fast() { 8 } else { 24 };
        let topo = Topology::one_peer_expo(n);
        let hom = NodeCosts::homogeneous(cost, n);
        let slow = hom.clone().with_straggler(3, 4.0)?;
        let critical = |algo: AlgorithmKind, costs: &NodeCosts| -> anyhow::Result<f64> {
            let mut backend =
                SharedBackend::new(&topo, sd, costs, 25_500_000, Compression::None);
            let pool = WorkerPool::new(1);
            let mut params = ParamMatrix::random(&mut Rng::new(7), n, sd, 1.0);
            let mut schedule = schedule_for(algo, h, 4, 10)?;
            let mut clocks = VirtualClocks::new(&topo);
            let no_comm = vec![0.0; n];
            for k in 0..ssteps {
                match schedule.action(k, 1.0) {
                    CommAction::Gossip => {
                        let c = backend.gossip(&mut params, &pool)?;
                        clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
                    }
                    CommAction::GlobalAverage => {
                        let c = backend.global_average(&mut params, &pool)?;
                        clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
                    }
                    CommAction::None => {
                        clocks.advance(&costs.compute, &no_comm, BarrierScope::None);
                    }
                }
            }
            Ok(clocks.max_seconds())
        };
        println!("# Straggler gate: node 3 at 4x (compute+latency), one-peer-expo n = {n}\n");
        let mut t25 = Table::new(&[
            "Algorithm",
            "Critical path (hom)",
            "Critical path (straggler)",
            "Degradation",
        ]);
        let mut ratios = Vec::new();
        for algo in [AlgorithmKind::Gossip, AlgorithmKind::GossipPga, AlgorithmKind::Parallel] {
            let base = critical(algo, &hom)?;
            let degraded = critical(algo, &slow)?;
            let ratio = degraded / base;
            ratios.push((algo, ratio));
            t25.rowv(vec![
                format!("{algo:?}"),
                fmt_duration(base),
                fmt_duration(degraded),
                format!("{ratio:.3}x"),
            ]);
        }
        t25.print();
        let get = |want: AlgorithmKind| {
            ratios.iter().find(|(a, _)| *a == want).expect("computed above").1
        };
        let (rg, rp, rar) =
            (get(AlgorithmKind::Gossip), get(AlgorithmKind::GossipPga), get(AlgorithmKind::Parallel));
        assert!(
            rg < rar,
            "straggler gate: gossip degraded {rg:.3}x, not less than all-reduce's {rar:.3}x"
        );
        assert!(
            rp < rar,
            "straggler gate: gossip-pga degraded {rp:.3}x, not less than all-reduce's {rar:.3}x"
        );
        println!(
            "\nGossip {rg:.3}x / Gossip-PGA {rp:.3}x / All-Reduce {rar:.3}x — the n*alpha\n\
             latency term (§3.4) is what a slow node amplifies; gossip's\n\
             neighborhood barrier localizes it.\n"
        );
    }

    // --- 2.75 event-plane gate: async vs neighborhood-barrier billing ------
    // The per-link overlap the ROADMAP's event-billing item asks for,
    // asserted: under seeded multi-stragglers (0:4x, 3:2x) the
    // event-driven async regime's critical path must come in BELOW the
    // neighborhood-barrier bill of the same gossip schedule — the barrier
    // plane exposes every transfer, the event plane only pays for
    // violated staleness bounds. Also gates the strict-mode anchor: at
    // max_staleness = 0 the event plane reproduces the barrier bill
    // bit-exactly.
    {
        use gossip_pga::eventsim::AsyncGossip;
        let n = 8usize;
        let sd = if fast() { 2_000 } else { 50_000 };
        let ssteps = if fast() { 12 } else { 32 };
        let topo = Topology::ring(n);
        let slow = NodeCosts::homogeneous(cost, n)
            .with_straggler(0, 4.0)?
            .with_straggler(3, 2.0)?;
        let pool = WorkerPool::new(2);
        // Synthetic local update: pure in (node, iter) — the gate is about
        // clocks, but the payload plumbing runs for real.
        let fake = |params: &mut ParamMatrix, batch: &[(usize, usize)]| -> anyhow::Result<()> {
            for &(node, iter) in batch {
                let mut r = Rng::new(0xAB ^ ((node as u64) << 32) ^ iter as u64);
                for x in params.row_mut(node) {
                    *x = 0.95 * *x + 0.05 * r.normal() as f32;
                }
            }
            Ok(())
        };
        let event_critical = |staleness: usize| -> anyhow::Result<f64> {
            let mut params = ParamMatrix::random(&mut Rng::new(7), n, sd, 1.0);
            let mut engine = AsyncGossip::new(
                &topo,
                &slow,
                sd,
                25_500_000,
                staleness,
                AlgorithmKind::Gossip,
                usize::MAX,
                &params,
            )?;
            let mut backend = SharedBackend::new(&topo, sd, &slow, 25_500_000, Compression::None);
            let mut clocks = VirtualClocks::new(&topo);
            let mut step = fake;
            let mut sync = |_k: usize, _p: &mut ParamMatrix| -> anyhow::Result<()> { Ok(()) };
            engine.run_until(
                ssteps,
                &mut params,
                &mut backend,
                &pool,
                &mut clocks,
                &slow,
                &mut step,
                &mut sync,
            )?;
            Ok(clocks.max_seconds())
        };
        let barrier_critical = {
            let mut backend = SharedBackend::new(&topo, sd, &slow, 25_500_000, Compression::None);
            let mut params = ParamMatrix::random(&mut Rng::new(7), n, sd, 1.0);
            let mut clocks = VirtualClocks::new(&topo);
            for k in 0..ssteps {
                let batch: Vec<(usize, usize)> = (0..n).map(|i| (i, k)).collect();
                fake(&mut params, &batch)?;
                let c = backend.gossip(&mut params, &pool)?;
                clocks.advance(&slow.compute, &c.node_seconds, c.barrier);
            }
            clocks.max_seconds()
        };
        let strict = event_critical(0)?;
        let relaxed = event_critical(2)?;
        println!(
            "# Event-plane gate (ring n = {n}, stragglers 0:4x + 3:2x, {ssteps} gossip steps):\n\
             #   neighborhood barrier {barrier_critical:>10.3}s\n\
             #   async s=0 (strict)   {strict:>10.3}s  (must be bit-equal)\n\
             #   async s=2            {relaxed:>10.3}s  (must be smaller)\n"
        );
        assert_eq!(
            strict, barrier_critical,
            "event-plane gate: strict mode drifted from the barrier bill"
        );
        assert!(
            relaxed < barrier_critical,
            "event-plane gate: async critical path {relaxed} not below the barrier bill {barrier_critical}"
        );
    }

    // --- 3. raw substrate: measured wall time of the two primitives -------
    println!("# Raw substrate (threaded bus): d = {d} floats, n = {n}\n");
    let mut t3 = Table::new(&[
        "Primitive",
        "Wall time",
        "Scalars sent/node",
        "Model prediction (2d(n-1)/n vs 2d)",
    ]);

    // ring all-reduce
    let t0 = std::time::Instant::now();
    let eps = bus(n);
    let sent = run_nodes(eps, move |mut ep| {
        let mut x = vec![1.0f32; d];
        ring_all_reduce(&mut ep, &mut x)?;
        Ok(ep.scalars_sent)
    })?;
    let ar_time = t0.elapsed().as_secs_f64();
    t3.rowv(vec![
        "ring all-reduce".into(),
        fmt_duration(ar_time),
        sent[0].to_string(),
        format!("{}", 2 * d * (n - 1) / n),
    ]);

    // one ring-gossip round
    let topo = Topology::ring(n);
    let t0 = std::time::Instant::now();
    let eps = bus(n);
    let sent = run_nodes(eps, move |mut ep| {
        let rank = ep.rank;
        let x = vec![1.0f32; d];
        let row = topo.weight_row(rank, 0);
        let outn = topo.out_neighbors(rank, 0);
        gossip_exchange(&mut ep, &x, &row, &outn)?;
        Ok(ep.scalars_sent)
    })?;
    let g_time = t0.elapsed().as_secs_f64();
    t3.rowv(vec![
        "ring gossip round".into(),
        fmt_duration(g_time),
        sent[0].to_string(),
        format!("{}", 2 * d),
    ]);
    t3.print();
    println!(
        "\nExpected shape: all-reduce moves ~2d scalars per node in 2(n-1)\n\
         latency-bound steps; one gossip round moves 2d (ring) in a single\n\
         step — the latency gap is what the paper's Table 17 measures."
    );
    println!("\ntab17 accounting gate: OK");
    Ok(())
}
