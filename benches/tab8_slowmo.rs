//! Table 8: Gossip-PGA vs SlowMo (slow-momentum outer update) with
//! H in {6, 48}.
//!
//! Paper shape: slow momentum helps at large H (it smooths long independent
//! excursions) but can hurt at small H — i.e. the PGA-vs-SlowMo ordering
//! flips between H = 6 and H = 48.
//!
//!     cargo bench --bench tab8_slowmo

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let steps = step_scale(600);
    println!("# Table 8: Gossip-PGA vs SlowMo, n = {n}, {steps} steps\n");

    let mut t = Table::new(&["Period", "Gossip-PGA acc.%", "SlowMo acc.%"]);
    for &h in &[6usize, 48] {
        let mut accs = Vec::new();
        for algo in [AlgorithmKind::GossipPga, AlgorithmKind::SlowMo] {
            let spec = RunSpec::image(algo, Topology::one_peer_expo(n), h, steps);
            let r = run_image(rt.clone(), &spec, 2048)?;
            accs.push(r.accuracy);
        }
        t.rowv(vec![
            format!("H = {h}"),
            format!("{:.2}", accs[0] * 100.0),
            format!("{:.2}", accs[1] * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Table 8): PGA >= SlowMo at H = 6; SlowMo\n\
         catches up (or wins) at H = 48."
    );
    Ok(())
}
