//! Table 11 + Figure 3: the language-modeling method suite — Parallel,
//! Local (1x/3x), Gossip (1x/2x), Gossip-PGA, Gossip-AGA — final training
//! loss and simulated runtime.
//!
//! Substitution (DESIGN.md): BERT-Large/Wikipedia -> a small causal-LM
//! transformer over a Markov-chain corpus; communication billed at
//! BERT-Large's d = 330M via the Table 17-calibrated alpha-beta model.
//!
//!     cargo bench --bench tab11_bert_suite

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_lm, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 8; // the paper's BERT runs use 8 nodes
    let base = step_scale(400);
    let h = 6;
    println!("# Table 11: LM suite (transformer 'tiny' on Markov corpus), n = {n}, H = {h}\n");

    let runs: Vec<(&str, AlgorithmKind, usize)> = vec![
        ("Parallel SGD", AlgorithmKind::Parallel, base),
        ("Local SGD", AlgorithmKind::Local, base),
        ("Local SGD x3", AlgorithmKind::Local, base * 3),
        ("Gossip SGD", AlgorithmKind::Gossip, base),
        ("Gossip SGD x2", AlgorithmKind::Gossip, base * 2),
        ("Gossip-PGA", AlgorithmKind::GossipPga, base),
        ("Gossip-AGA", AlgorithmKind::GossipAga, base),
    ];

    let mut t = Table::new(&["Method", "Steps", "Final train loss", "Eval loss", "Sim hrs"]);
    for (label, algo, steps) in runs {
        let spec = RunSpec::lm(algo, Topology::one_peer_expo(n), h, steps);
        let r = run_lm(rt.clone(), &spec, "tiny")?;
        r.history
            .write_csv(std::path::Path::new(&format!(
                "target/bench_out/tab11_{}.csv",
                label.replace([' ', '/'], "_")
            )))
            .ok();
        t.rowv(vec![
            label.to_string(),
            steps.to_string(),
            format!("{:.4}", r.history.final_loss()),
            format!("{:.4}", r.eval_loss),
            format!("{:.2}", r.sim_hours),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Table 11 / Fig. 3): PGA/AGA reach Parallel's\n\
         loss at a fraction of its simulated time; Local/Gossip 1x plateau\n\
         higher, and their extended runs exceed Parallel's total time."
    );
    Ok(())
}
