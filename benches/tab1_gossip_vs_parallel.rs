//! Table 1: Gossip SGD (ring / one-peer expo, 1x and 2x epochs) vs Parallel
//! SGD — accuracy and wall-clock time on the ImageNet substitute.
//!
//! Paper shape: Gossip finishes its epochs faster (cheaper comms) but loses
//! accuracy; doubling its budget recovers accuracy at MORE total time than
//! Parallel SGD. (That motivates PGA — see tab7.)
//!
//!     cargo bench --bench tab1_gossip_vs_parallel

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::harness::suite::{run_image, step_scale, RunSpec};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load_default()?);
    let n = 32;
    let base = step_scale(600);
    println!("# Table 1: Gossip vs Parallel, n = {n} (image substitute; time = alpha-beta\n\
              # model calibrated to the paper's Table 17 ResNet-50 cluster)\n");

    let rows: Vec<(&str, AlgorithmKind, Topology, usize)> = vec![
        ("Parallel SGD", AlgorithmKind::Parallel, Topology::one_peer_expo(n), base),
        ("Gossip SGD (ring)", AlgorithmKind::Gossip, Topology::ring(n), base),
        ("Gossip SGD (expo)", AlgorithmKind::Gossip, Topology::one_peer_expo(n), base),
        ("Gossip SGD (ring) x2", AlgorithmKind::Gossip, Topology::ring(n), base * 2),
        ("Gossip SGD (expo) x2", AlgorithmKind::Gossip, Topology::one_peer_expo(n), base * 2),
    ];

    let mut t = Table::new(&["Method", "Steps", "Acc.%", "Sim time (hrs)"]);
    for (label, algo, topo, steps) in rows {
        let spec = RunSpec::image(algo, topo, 6, steps);
        let r = run_image(rt.clone(), &spec, 2048)?;
        t.rowv(vec![
            label.to_string(),
            steps.to_string(),
            format!("{:.2}", r.accuracy * 100.0),
            format!("{:.2}", r.sim_hours),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Table 1): Gossip 1x faster but less accurate;\n\
         Gossip 2x matches accuracy at more total time than Parallel."
    );
    Ok(())
}
