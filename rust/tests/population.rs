//! The virtual population plane, end to end (no AOT artifacts — the
//! suites drive the engine and the sweep driver directly):
//!
//! * **(a) full-materialization anchor** — with every node materialized
//!   and no churn, the pooled-storage engine reproduces the per-link
//!   storage engine (the PR 5 shape) bit-exactly — params, per-node
//!   clocks, event trace, traffic totals — on BOTH CommPlane backends;
//! * **(b) plane equivalence** — a dense virtual population schedules the
//!   exact same event sequence as the materialized engine under the same
//!   costs (payload content never feeds back into timing), so the
//!   population plane's clocks/traffic are the engine's, not a model of
//!   them;
//! * **(c) churn property** — randomized seeded crash/rejoin/flaky
//!   scripts replay bit-exactly (PROPTEST_CASES-controlled);
//! * **(d) sweep replay** — a full `run_sweep` with churn + regions +
//!   stragglers is a pure function of its `SweepSpec`;
//! * **(e) massive-n smoke + audit** — the flagship one-peer-expo sweep
//!   (n = 10^5; `GOSSIP_PGA_FAST=1` trims to 10^4) completes with the
//!   allocation audit clean: no dense n x n spectral work, no per-edge
//!   dense payload copies.

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::comm::{BusBackend, CommBackend, CommStats, Compression, SharedBackend};
use gossip_pga::costmodel::{CostModel, NodeCosts, RegionMap, VirtualClocks};
use gossip_pga::eventsim::{AsyncGossip, TraceEv, VirtualConfig};
use gossip_pga::exec::WorkerPool;
use gossip_pga::params::ParamMatrix;
use gossip_pga::population::{run_sweep, ChurnScript, SweepSpec};
use gossip_pga::proptest;
use gossip_pga::rng::Rng;
use gossip_pga::topology::{BetaReport, Topology};

const COST_DIM: usize = 25_500_000;

/// Deterministic synthetic local update — pure in `(node, iter)`.
fn fake_step(params: &mut ParamMatrix, batch: &[(usize, usize)]) -> anyhow::Result<()> {
    for &(node, iter) in batch {
        let mut r = Rng::new(0xBEEF ^ ((node as u64) << 32) ^ iter as u64);
        for x in params.row_mut(node) {
            *x = 0.9 * *x + 0.1 * r.normal() as f32;
        }
    }
    Ok(())
}

fn mk_backend(kind: &str, topo: &Topology, d: usize, costs: &NodeCosts) -> Box<dyn CommBackend> {
    match kind {
        "shared" => Box::new(SharedBackend::new(topo, d, costs, COST_DIM, Compression::None)),
        _ => Box::new(BusBackend::new(topo, d, costs, COST_DIM, Compression::None, true)),
    }
}

#[allow(clippy::type_complexity)]
fn run_materialized(
    backend_kind: &str,
    intern: bool,
    topo: &Topology,
    costs: &NodeCosts,
    d: usize,
    steps: usize,
) -> (ParamMatrix, Vec<f64>, Vec<TraceEv>, CommStats) {
    let mut params = ParamMatrix::random(&mut Rng::new(17), topo.n, d, 1.0);
    let mut engine = AsyncGossip::new_with_storage(
        topo, costs, d, COST_DIM, 2, AlgorithmKind::GossipPga, 4, &params, intern,
    )
    .unwrap();
    engine.enable_trace();
    let mut backend = mk_backend(backend_kind, topo, d, costs);
    let pool = WorkerPool::new(2);
    let mut clocks = VirtualClocks::new(topo);
    let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
    let mut sync = |_k: usize, _p: &mut ParamMatrix| -> anyhow::Result<()> { Ok(()) };
    engine
        .run_until(steps, &mut params, backend.as_mut(), &pool, &mut clocks, costs, &mut step, &mut sync)
        .unwrap();
    let trace = engine.trace().unwrap().to_vec();
    (params, clocks.seconds().to_vec(), trace, backend.total())
}

#[test]
fn fully_materialized_runs_match_the_per_link_storage_shape_on_both_backends() {
    // (a) The PR 5 anchor: interned (pooled) payload storage vs the old
    // one-slot-per-link shape — same bits everywhere that matters.
    let topo = Topology::one_peer_expo(8);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 8)
        .with_straggler(1, 3.0)
        .unwrap();
    for backend_kind in ["shared", "bus"] {
        let pooled = run_materialized(backend_kind, true, &topo, &costs, 13, 11);
        let per_link = run_materialized(backend_kind, false, &topo, &costs, 13, 11);
        assert_eq!(pooled.0, per_link.0, "{backend_kind}: params diverged");
        assert_eq!(
            pooled.1.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            per_link.1.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            "{backend_kind}: clocks diverged"
        );
        assert_eq!(pooled.2, per_link.2, "{backend_kind}: event order diverged");
        assert_eq!(pooled.3, per_link.3, "{backend_kind}: traffic diverged");
    }
}

#[test]
fn virtual_plane_schedules_the_same_events_as_the_materialized_engine() {
    // (b) Payload content never feeds back into event timing, so a dense
    // virtual population under the same costs replays the materialized
    // engine's schedule event for event. cost_dim = d makes the two
    // traffic accountings directly comparable (the materialized backend
    // bills real payload scalars; the virtual plane bills cost_dim).
    let topo = Topology::one_peer_expo(8);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 8)
        .with_straggler(2, 3.0)
        .unwrap();
    let d = 6;
    let steps = 11;

    let mut params = ParamMatrix::random(&mut Rng::new(17), 8, d, 1.0);
    let mut mat = AsyncGossip::new(&topo, &costs, d, d, 2, AlgorithmKind::Gossip, usize::MAX, &params)
        .unwrap();
    mat.enable_trace();
    let mut backend = SharedBackend::new(&topo, d, &costs, d, Compression::None);
    let pool = WorkerPool::new(1);
    let mut mat_clocks = VirtualClocks::new(&topo);
    let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
    let mut sync = |_k: usize, _p: &mut ParamMatrix| -> anyhow::Result<()> { Ok(()) };
    mat.run_until(steps, &mut params, &mut backend, &pool, &mut mat_clocks, &costs, &mut step, &mut sync)
        .unwrap();

    let cfg = VirtualConfig { dim: d, seed: 23, churn: Vec::new(), regions: None };
    let mut virt =
        AsyncGossip::new_virtual(&topo, &costs, d, 2, AlgorithmKind::Gossip, usize::MAX, cfg)
            .unwrap();
    virt.enable_trace();
    let mut virt_clocks = VirtualClocks::flat(8);
    virt.run_virtual_until(steps, &mut virt_clocks).unwrap();

    assert_eq!(mat.trace().unwrap(), virt.trace().unwrap(), "event schedules diverged");
    assert_eq!(
        mat_clocks.seconds().iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
        virt_clocks.seconds().iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
        "per-node clocks diverged"
    );
    assert_eq!(mat.histogram(), virt.histogram(), "staleness accounting diverged");
    let (mt, vt) = (backend.total(), virt.virt_stats());
    assert_eq!((mt.scalars_sent, mt.msgs), (vt.scalars_sent, vt.msgs));
    assert_eq!(mt.sim_seconds.to_bits(), vt.sim_seconds.to_bits());
}

#[test]
fn seeded_churn_scripts_replay_bit_exactly() {
    // (c) Property: any seeded crash/rejoin/flaky script, surrogate or
    // dense, replays to identical traces, clocks, traffic, and state when
    // driven with the same chunking.
    proptest::check("seeded churn replays bit-exactly", |rng| {
        let n = 4 + rng.below(9) as usize;
        let topo = if rng.below(2) == 0 { Topology::ring(n) } else { Topology::one_peer_expo(n) };
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), n);
        let script = ChurnScript::seeded(rng.next_u64(), &topo, 1 + rng.below(4) as usize, 3.0)
            .map_err(|e| e.to_string())?;
        let dim = if rng.below(2) == 0 { 0 } else { 3 };
        let seed = rng.next_u64();
        let steps = 6 + rng.below(7) as usize;
        let mut run = || {
            let cfg = VirtualConfig { dim, seed, churn: script.events.clone(), regions: None };
            let mut eng = AsyncGossip::new_virtual(
                &topo, &costs, 1_000_000, 2, AlgorithmKind::GossipPga, 4, cfg,
            )
            .unwrap();
            eng.enable_trace();
            let mut clocks = VirtualClocks::flat(n);
            for t in [steps / 2, steps] {
                eng.run_virtual_until(t, &mut clocks).unwrap();
            }
            let means = eng.virt_means().map(|m| m.to_vec());
            let state = eng.virt_dense().map(|p| p.as_slice().to_vec());
            (
                eng.trace().unwrap().to_vec(),
                clocks.seconds().iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                eng.virt_stats(),
                eng.churn_counts(),
                means.map(|m| m.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
                state.map(|s| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
            )
        };
        let a = run();
        let b = run();
        proptest::ensure(a == b, format!("replay diverged on {:?} n={n} dim={dim}", topo.kind))
    });
}

#[test]
fn sweep_reports_are_a_pure_function_of_the_spec() {
    // (d) The full driver — churn, regions, stragglers, curve sampling,
    // transient detection — replays to an identical report.
    let mut spec = SweepSpec::massive_n(24, 16, 9);
    spec.log_points = 4;
    spec.stragglers = vec![(3, 2.5)];
    spec.regions = Some(RegionMap::tiers(24, 3, 1.0, 5.0).unwrap());
    spec.churn = ChurnScript::seeded(5, &spec.topo, 2, 4.0).unwrap().events;
    let a = run_sweep(&spec).unwrap();
    let b = run_sweep(&spec).unwrap();
    assert_eq!(a, b, "sweep must be replayable from its spec");
    assert_eq!(a.curve.len(), 4);
    assert!(a.surrogate);
}

#[test]
fn massive_population_sweep_is_bounded_and_audited() {
    // (e) The flagship scale: a one-peer-expo population with seeded
    // churn completes, and the allocation audit holds — the dense
    // spectral path is skipped (no n x n), and surrogate mode never
    // materializes a dense payload (no per-edge d-vectors).
    let n: usize = if std::env::var("GOSSIP_PGA_FAST").is_ok() { 10_000 } else { 100_000 };
    let mut spec = SweepSpec::massive_n(n, 2, 7);
    spec.log_points = 1;
    spec.churn = ChurnScript::seeded(3, &spec.topo, 2, 1.0).unwrap().events;
    let report = run_sweep(&spec).unwrap();
    assert_eq!(report.n, n);
    assert!(report.surrogate);
    assert!(matches!(report.beta, BetaReport::Skipped { .. }), "beta must skip the dense path");
    assert_eq!(report.peak_dense_scalars, 0, "surrogate mode allocated dense payloads");
    assert!(
        report.peak_live_slots <= report.num_links,
        "pool grew past the per-link bound: {} slots for {} links",
        report.peak_live_slots,
        report.num_links
    );
    let last = report.curve.last().unwrap();
    assert_eq!(last.step, 2);
    assert!(last.time > 0.0 && last.scalars > 0 && last.msgs > 0);
    assert!(last.consensus.is_finite());
}
