//! The per-node virtual-time plane, end to end:
//!
//! * **Homogeneous regression anchor** — with one cost triple on every
//!   node, the critical path reproduces the pre-refactor scalar `SimClock`
//!   accumulation bit-exactly on BOTH CommPlane backends (every existing
//!   `sim_seconds` table is unchanged by construction); with uniform
//!   per-node traffic, so does every individual clock;
//! * **straggler scenarios** — a `--straggler`-style table bends only the
//!   clocks (trajectories stay bit-identical), gossip's critical path
//!   degrades less than All-Reduce's, and the slack / barrier-wait
//!   breakdown is visible in `CommStats` and the History columns;
//! * **checkpoint v4** — a heterogeneous run checkpointed mid-run resumes
//!   with bit-exact per-node clocks in a fresh trainer; pre-v4 snapshots
//!   (clocks absent) resume on the uniform scalar axis.
//!
//! The schedule-replay tests drive the backends + clocks directly and need
//! no AOT artifacts; the trainer-level tests at the bottom need
//! `make artifacts` like the other integration suites.

use std::sync::Arc;

use gossip_pga::algorithms::{schedule_for, AlgorithmKind, CommAction, SlowMoParams};
use gossip_pga::comm::{BackendKind, BusBackend, CommBackend, Compression, SharedBackend, TcpBackend};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{BarrierScope, CostModel, NodeCosts, SimClock, VirtualClocks};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

/// One schedule-replay scenario: drive a backend + a fresh
/// [`VirtualClocks`] with every charge exactly the way the trainer does.
struct ReplaySpec<'a> {
    algo: AlgorithmKind,
    kind: BackendKind,
    topo: &'a Topology,
    costs: &'a NodeCosts,
    d: usize,
    cost_dim: usize,
    steps: usize,
    h: usize,
}

impl ReplaySpec<'_> {
    /// Returns (clocks, scalar clock fed node-0's compute + the aggregate
    /// stats — the pre-refactor accumulation, meaningful when node 0
    /// carries the homogeneous costs).
    fn run(&self) -> (VirtualClocks, SimClock) {
        let (topo, costs, d) = (self.topo, self.costs, self.d);
        let n = topo.n;
        let mut backend: Box<dyn CommBackend> = match self.kind {
            BackendKind::Shared => {
                Box::new(SharedBackend::new(topo, d, costs, self.cost_dim, Compression::None))
            }
            BackendKind::Bus => {
                Box::new(BusBackend::new(topo, d, costs, self.cost_dim, Compression::None, true))
            }
            BackendKind::Tcp => Box::new(
                TcpBackend::new_loopback(
                    topo,
                    d,
                    costs,
                    self.cost_dim,
                    Compression::None,
                    true,
                    "127.0.0.1:0",
                )
                .unwrap(),
            ),
        };
        let pool = WorkerPool::new(2);
        let mut params = ParamMatrix::random(&mut Rng::new(11), n, d, 1.0);
        let mut schedule = schedule_for(self.algo, self.h, 2, 4).unwrap();
        let mut clocks = VirtualClocks::new(topo);
        let mut scalar = SimClock::default();
        let no_comm = vec![0.0; n];
        for k in 0..self.steps {
            match schedule.action(k, 1.0) {
                CommAction::Gossip => {
                    let charge = backend.gossip(&mut params, &pool).unwrap();
                    clocks.advance(&costs.compute, &charge.node_seconds, charge.barrier);
                    scalar.advance(costs.compute[0] + charge.stats.sim_seconds);
                }
                CommAction::GlobalAverage => {
                    let charge = backend.global_average(&mut params, &pool).unwrap();
                    clocks.advance(&costs.compute, &charge.node_seconds, charge.barrier);
                    scalar.advance(costs.compute[0] + charge.stats.sim_seconds);
                }
                CommAction::None => {
                    clocks.advance(&costs.compute, &no_comm, BarrierScope::None);
                    scalar.advance(costs.compute[0] + 0.0);
                }
            }
        }
        (clocks, scalar)
    }
}

/// [`ReplaySpec`] for the Gossip-PGA schedule at `cost_dim == d` (the
/// homogeneous anchors).
fn replay(
    kind: BackendKind,
    topo: &Topology,
    costs: &NodeCosts,
    d: usize,
    steps: usize,
    h: usize,
) -> (VirtualClocks, SimClock) {
    ReplaySpec { algo: AlgorithmKind::GossipPga, kind, topo, costs, d, cost_dim: d, steps, h }
        .run()
}

#[test]
fn homogeneous_clocks_reproduce_the_scalar_sim_clock_on_both_backends() {
    // The acceptance anchor: `scalar` in `replay` accumulates exactly what
    // the pre-virtual-time trainer's SimClock did (compute + the action's
    // aggregate sim_seconds, one fused addition per step). With d chosen
    // divisible by n the bus's chunk exchange is perfectly even, so BOTH
    // planes stay lockstep and every per-node clock equals the scalar
    // clock to the bit, static and time-varying graphs alike.
    let base = CostModel::calibrated_resnet50();
    for topo in [Topology::ring(5), Topology::one_peer_expo(8), Topology::grid(9)] {
        let costs = NodeCosts::homogeneous(base, topo.n);
        for kind in [BackendKind::Shared, BackendKind::Bus] {
            let (clocks, scalar) = replay(kind, &topo, &costs, 720, 14, 3);
            for (i, &s) in clocks.seconds().iter().enumerate() {
                assert_eq!(
                    s, scalar.seconds,
                    "{kind:?}/{:?}: node {i} clock drifted from the scalar clock",
                    topo.kind
                );
            }
            assert_eq!(clocks.max_seconds(), scalar.seconds, "{kind:?}/{:?}", topo.kind);
            assert_eq!(clocks.slack(), 0.0, "{kind:?}/{:?}", topo.kind);
            assert_eq!(clocks.total_wait(), 0.0, "{kind:?}/{:?}", topo.kind);
        }
    }
}

#[test]
fn homogeneous_critical_path_matches_scalar_even_with_uneven_bus_chunks() {
    // d % n != 0: the bus's chunked global average ships slightly more
    // from the big-chunk ranks, so per-node clocks legitimately spread —
    // real traffic asymmetry the scalar clock could never express. The
    // CRITICAL PATH (what `sim_seconds` reports) still equals the scalar
    // accumulation bit-exactly: the scalar clock always billed each
    // action's busiest node.
    let base = CostModel::calibrated_resnet50();
    for topo in [Topology::ring(5), Topology::one_peer_expo(8)] {
        let costs = NodeCosts::homogeneous(base, topo.n);
        for kind in [BackendKind::Shared, BackendKind::Bus] {
            let (clocks, scalar) = replay(kind, &topo, &costs, 13, 14, 3);
            assert_eq!(clocks.max_seconds(), scalar.seconds, "{kind:?}/{:?}", topo.kind);
        }
        // The shared plane bills the analytic formulas, so it stays
        // lockstep even at uneven d.
        let (clocks, _) = replay(BackendKind::Shared, &topo, &costs, 13, 14, 3);
        assert_eq!(clocks.slack(), 0.0, "{:?}", topo.kind);
    }
}

#[test]
fn straggler_critical_path_degrades_gossip_less_than_all_reduce() {
    // The tab17-style gate in miniature: replay the same schedule shapes
    // under a 4x straggler (compute + latency) and compare each
    // algorithm's critical-path degradation ratio. All-Reduce pays the
    // straggler's latency n times per round; gossip pays it once.
    let base = CostModel::calibrated_resnet50();
    let topo = Topology::one_peer_expo(8);
    let n = topo.n;
    let hom = NodeCosts::homogeneous(base, n);
    let slow = hom.clone().with_straggler(3, 4.0).unwrap();
    let d = 64;
    let steps = 16;
    let ratio = |algo: AlgorithmKind| -> f64 {
        let run = |costs: &NodeCosts| -> f64 {
            // Bill communication at ResNet-50 scale (the Table 17 regime
            // the gate's margins are sized for).
            let spec = ReplaySpec {
                algo,
                kind: BackendKind::Shared,
                topo: &topo,
                costs,
                d,
                cost_dim: 25_500_000,
                steps,
                h: 4,
            };
            spec.run().0.max_seconds()
        };
        run(&slow) / run(&hom)
    };
    let r_gossip = ratio(AlgorithmKind::Gossip);
    let r_pga = ratio(AlgorithmKind::GossipPga);
    let r_parallel = ratio(AlgorithmKind::Parallel);
    assert!(
        r_gossip < r_parallel,
        "gossip degraded {r_gossip:.3}x vs all-reduce {r_parallel:.3}x"
    );
    assert!(
        r_pga < r_parallel,
        "gossip-pga degraded {r_pga:.3}x vs all-reduce {r_parallel:.3}x"
    );
    // And everyone degrades: the straggler is on every critical path.
    assert!(r_gossip > 1.0 && r_pga > 1.0 && r_parallel > 1.0);
}

// ---------------------------------------------------------------------------
// Trainer-level (needs the AOT artifacts, like the integration tests).
// ---------------------------------------------------------------------------

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_default().expect("run `make artifacts` first"))
}

fn opts(n: usize, threads: usize, costs: Option<NodeCosts>) -> TrainerOptions {
    TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::one_peer_expo(n),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.9,
        nesterov: true,
        seed: 31,
        slowmo: SlowMoParams::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: costs,
        log_every: 5,
        threads,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn trainer(rt: &Arc<Runtime>, n: usize, threads: usize, costs: Option<NodeCosts>) -> Trainer {
    let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 31).unwrap();
    Trainer::new(workload, init, opts(n, threads, costs)).unwrap()
}

fn straggler_costs(n: usize) -> NodeCosts {
    NodeCosts::homogeneous(CostModel::calibrated_resnet50(), n)
        .with_straggler(2, 4.0)
        .unwrap()
}

#[test]
fn straggler_bends_clocks_but_not_the_trajectory() {
    let rt = runtime();
    let n = 4;
    let mut hom = trainer(&rt, n, 2, None);
    let mut slow = trainer(&rt, n, 2, Some(straggler_costs(n)));
    for _ in 0..13 {
        hom.step_once().unwrap();
        slow.step_once().unwrap();
    }
    for i in 0..n {
        assert_eq!(
            hom.worker_params(i),
            slow.worker_params(i),
            "cost tables must never touch the parameter bits (worker {i})"
        );
    }
    // Homogeneous: lockstep clocks, no slack, no waits.
    assert_eq!(hom.straggler_slack(), 0.0);
    assert_eq!(hom.barrier_wait_seconds(), 0.0);
    assert_eq!(hom.sim_seconds(), hom.sim_seconds_min());
    // Straggled: longer critical path, open slack, real barrier waits —
    // and the node-2 clock IS the critical path.
    assert!(slow.sim_seconds() > hom.sim_seconds());
    assert!(slow.straggler_slack() > 0.0);
    assert!(slow.barrier_wait_seconds() > 0.0);
    assert_eq!(slow.node_sim_seconds()[2], slow.sim_seconds());
    assert_eq!(slow.comm_stats().barrier_wait, slow.barrier_wait_seconds());
    // Traffic accounting is cost-table-independent.
    let (a, b) = (hom.comm_stats(), slow.comm_stats());
    assert_eq!((a.scalars_sent, a.msgs), (b.scalars_sent, b.msgs));
}

#[test]
fn history_columns_expose_slack_and_barrier_wait() {
    let rt = runtime();
    let n = 4;
    let mut slow = trainer(&rt, n, 1, Some(straggler_costs(n)));
    let hist = slow.run(9, "straggled").unwrap();
    let last = hist.records.last().unwrap();
    assert!(last.sim_seconds >= last.sim_min_seconds);
    assert!(last.barrier_wait > 0.0, "straggled run must log barrier waits");
    let csv = hist.to_csv();
    // The PR-4 column block is stable; the PR-5 async columns append.
    assert!(csv
        .lines()
        .next()
        .unwrap()
        .contains("sim_min_seconds,straggler_slack,barrier_wait"));
    // PR-10 moved the header onto the metrics::COLUMNS registry and
    // appended the counter columns, so the async block is no longer last.
    assert!(csv.lines().next().unwrap().contains("stale_max,stale_mean,link_util"));
    let json = hist.to_json().dump();
    assert!(json.contains("\"straggler_slack\""));
    assert!(json.contains("\"barrier_wait\""));
}

#[test]
fn checkpoint_mid_run_resume_keeps_per_node_clocks_bit_exact() {
    // Heterogeneous run, checkpoint at step 9 (mid one-peer period), keep
    // running vs restore into a FRESH trainer on a different thread count:
    // parameters AND every per-node clock/wait must agree to the bit.
    let rt = runtime();
    let n = 4;
    let costs = straggler_costs(n);
    let mut a = trainer(&rt, n, 1, Some(costs.clone()));
    for _ in 0..9 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    let cs = ck.clocks.as_ref().expect("v4 checkpoints carry per-node clocks");
    assert_eq!(cs.seconds.len(), n);
    assert_eq!(cs.seconds, a.node_sim_seconds(), "snapshot must be the live clocks");
    for _ in 0..9 {
        a.step_once().unwrap();
    }

    let mut b = trainer(&rt, n, 3, Some(costs));
    b.restore(&ck).unwrap();
    assert_eq!(b.node_sim_seconds(), &cs.seconds[..]);
    assert_eq!(b.barrier_wait_seconds(), ck.comm.unwrap().barrier_wait);
    for _ in 0..9 {
        b.step_once().unwrap();
    }
    for i in 0..n {
        assert_eq!(a.worker_params(i), b.worker_params(i), "worker {i}");
        assert_eq!(
            a.node_sim_seconds()[i],
            b.node_sim_seconds()[i],
            "node {i} clock diverged across the resume"
        );
    }
    assert_eq!(a.sim_seconds(), b.sim_seconds());
    assert_eq!(a.straggler_slack(), b.straggler_slack());
    assert_eq!(a.barrier_wait_seconds(), b.barrier_wait_seconds());
}

#[test]
fn pre_v4_checkpoints_resume_on_the_uniform_scalar_axis() {
    // A snapshot without the clocks block (v1/v2/v3 files) must restore
    // every node to the scalar sim_seconds with zeroed wait accounts.
    let rt = runtime();
    let n = 4;
    let mut a = trainer(&rt, n, 1, Some(straggler_costs(n)));
    for _ in 0..7 {
        a.step_once().unwrap();
    }
    let mut ck = a.checkpoint().unwrap();
    ck.clocks = None; // simulate a pre-v4 file
    let mut b = trainer(&rt, n, 1, Some(straggler_costs(n)));
    b.restore(&ck).unwrap();
    assert_eq!(b.sim_seconds(), ck.sim_seconds);
    assert_eq!(b.sim_seconds_min(), ck.sim_seconds, "uniform resume");
    assert_eq!(b.barrier_wait_seconds(), 0.0, "pre-v4 waits restart at zero");
}
