//! Randomized property tests (in-repo kit, see `gossip_pga::proptest`)
//! over the coordinator's invariants, plus the schedule-equivalence and
//! checkpoint-resume suites:
//!
//! * pooled execution (any `threads`, explicitly including {1, 2, 3, 8})
//!   is bit-identical to the sequential reference across all six
//!   `AlgorithmKind`s — the scoped per-step threading it replaced held the
//!   same contract, so pooled == scoped == sequential;
//! * overlap mode (double-buffered async gossip) matches BSP exactly at
//!   every global-averaging boundary k·H across ring/grid/one-peer-expo
//!   topologies, and bit-exactly everywhere after a drain;
//! * a checkpoint -> restore -> replay run matches an unbroken run for the
//!   stateful algorithms (Gossip-AGA's adaptive period, SlowMo's outer
//!   buffers, the mixer's gossip clock).
//!
//! scripts/verify.sh runs this suite at `PROPTEST_CASES=16` under both
//! `GOSSIP_PGA_TEST_THREADS=1` and `=4` (the env var feeds the pooled
//! thread-count candidates below).

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::collective::{bus, gossip_exchange, ring_all_reduce, run_nodes};
use gossip_pga::comm::{BackendKind, Compression};
use gossip_pga::coordinator::mixer::Mixer;
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::linalg::beta_of;
use gossip_pga::metrics::consensus_distance;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::proptest::{assert_close, check, ensure};
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::{spectral, Topology, TopologyKind};

/// The pooled thread count scripts/verify.sh sweeps (1 and 4); defaults
/// to 4 for plain `cargo test`.
fn test_threads() -> usize {
    std::env::var("GOSSIP_PGA_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
}

fn random_topology(rng: &mut gossip_pga::rng::Rng, n: usize) -> Topology {
    match rng.below(6) {
        0 => Topology::ring(n),
        1 => Topology::grid(n),
        2 => Topology::star(n),
        3 => Topology::full(n),
        4 => Topology::static_expo(n),
        _ => Topology::one_peer_expo(n),
    }
}

fn random_matrix(rng: &mut gossip_pga::rng::Rng, n: usize, d: usize, scale: f32) -> ParamMatrix {
    ParamMatrix::random(rng, n, d, scale)
}

#[test]
fn prop_weight_matrices_doubly_stochastic() {
    check("W doubly stochastic for every topology/round", |rng| {
        let n = 2 + rng.below(24) as usize;
        let topo = random_topology(rng, n);
        for r in 0..topo.rounds() {
            let w = topo.weight_matrix(r);
            ensure(w.row_sum_err() < 1e-9, format!("{:?} n={n} rows", topo.kind))?;
            ensure(w.col_sum_err() < 1e-9, format!("{:?} n={n} cols", topo.kind))?;
            ensure(
                w.data.iter().all(|&v| v >= -1e-12),
                format!("{:?} n={n} negative weight", topo.kind),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_beta_in_unit_interval() {
    check("beta in [0, 1) for connected topologies", |rng| {
        let n = 2 + rng.below(20) as usize;
        let topo = random_topology(rng, n);
        let beta = topo.beta();
        ensure(
            (0.0..1.0).contains(&beta),
            format!("{:?} n={n}: beta={beta}", topo.kind),
        )
    });
}

#[test]
fn prop_mixing_preserves_ensemble_mean() {
    check("gossip mixing preserves the ensemble mean", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let topo = random_topology(rng, n);
        let mut params = random_matrix(rng, n, d, 1.0);
        let mean_before = params.mean_row();
        let mut mixer = Mixer::new(&topo, d);
        let pool = WorkerPool::new(1);
        let rounds = 1 + rng.below(4) as usize;
        for _ in 0..rounds {
            mixer.gossip(&mut params, &pool).unwrap();
        }
        assert_close(&params.mean_row(), &mean_before, 1e-4)
    });
}

#[test]
fn prop_pooled_mix_bit_identical_to_sequential() {
    // The tentpole invariant: every pool size computes the exact same
    // matrix (mix rows and mean columns have fixed accumulation order).
    check("gossip/global-average agree for any pool size", |rng| {
        let n = 2 + rng.below(16) as usize;
        let d = 1 + rng.below(96) as usize;
        let threads = 2 + rng.below(7) as usize;
        let topo = random_topology(rng, n);
        let mut seq = random_matrix(rng, n, d, 1.0);
        let mut thr = seq.clone();
        let mut m1 = Mixer::new(&topo, d);
        let mut m2 = Mixer::new(&topo, d);
        let p1 = WorkerPool::new(1);
        let pt = WorkerPool::new(threads);
        for _ in 0..topo.rounds().min(3) {
            m1.gossip(&mut seq, &p1).unwrap();
            m2.gossip(&mut thr, &pt).unwrap();
            ensure(seq == thr, format!("{:?} n={n} d={d} t={threads}: gossip diverged", topo.kind))?;
        }
        m1.global_average(&mut seq, &p1).unwrap();
        m2.global_average(&mut thr, &pt).unwrap();
        ensure(seq == thr, format!("{:?} n={n} d={d} t={threads}: average diverged", topo.kind))
    });
}

#[test]
fn prop_stealing_pool_bit_identical_to_static_and_sequential() {
    // The work-stealing invariant: the over-split dynamic chunking changes
    // WHICH thread runs which rows, never the rows' arithmetic or the
    // reduction order — gossip and the global average agree bit-for-bit
    // with both the static pool and the sequential loop.
    check("stealing == static == sequential for mixing", |rng| {
        let n = 2 + rng.below(16) as usize;
        let d = 1 + rng.below(96) as usize;
        let threads = 1 + rng.below(8) as usize;
        let topo = random_topology(rng, n);
        let mut seq = random_matrix(rng, n, d, 1.0);
        let mut sta = seq.clone();
        let mut stl = seq.clone();
        let mut m1 = Mixer::new(&topo, d);
        let mut m2 = Mixer::new(&topo, d);
        let mut m3 = Mixer::new(&topo, d);
        let p1 = WorkerPool::new(1);
        let p2 = WorkerPool::new(threads);
        let p3 = WorkerPool::new_stealing(threads);
        ensure(
            p3.shards(1000) >= p2.shards(1000),
            "stealing must over-split, not under-split",
        )?;
        for _ in 0..topo.rounds().min(3) {
            m1.gossip(&mut seq, &p1).unwrap();
            m2.gossip(&mut sta, &p2).unwrap();
            m3.gossip(&mut stl, &p3).unwrap();
            ensure(seq == sta, format!("{:?} n={n} d={d} t={threads}: static diverged", topo.kind))?;
            ensure(seq == stl, format!("{:?} n={n} d={d} t={threads}: stealing diverged", topo.kind))?;
        }
        m1.global_average(&mut seq, &p1).unwrap();
        m2.global_average(&mut sta, &p2).unwrap();
        m3.global_average(&mut stl, &p3).unwrap();
        ensure(seq == sta, "static average diverged")?;
        ensure(seq == stl, "stealing average diverged")
    });
}

#[test]
fn prop_async_mix_bit_identical_to_sync() {
    // Double-buffer invariant: gossip_async + finish_gossip produce the
    // same bits as the synchronous call, round for round.
    check("async gossip == sync gossip", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let threads = 1 + rng.below(8) as usize;
        let topo = random_topology(rng, n);
        let mut sync = random_matrix(rng, n, d, 1.0);
        let mut asy = sync.clone();
        let mut m1 = Mixer::new(&topo, d);
        let mut m2 = Mixer::new(&topo, d);
        let pool = WorkerPool::new(threads);
        for round in 0..topo.rounds().min(3) {
            m1.gossip(&mut sync, &pool).unwrap();
            // SAFETY: asy and m2 outlive the round; finish_gossip runs
            // before the next access.
            let pending = unsafe { m2.gossip_async(&asy, &pool) }
                .map_err(|e| format!("gossip_async: {e:#}"))?;
            m2.finish_gossip(&mut asy, pending).map_err(|e| format!("finish: {e:#}"))?;
            ensure(
                sync == asy,
                format!("{:?} n={n} d={d} t={threads} round {round}: diverged", topo.kind),
            )?;
        }
        ensure(m1.gossip_clock == m2.gossip_clock, "gossip clocks diverged")
    });
}

#[test]
fn prop_mixing_contracts_consensus_by_beta_squared() {
    // One gossip round satisfies ||x' - xbar'||^2 <= beta^2 ||x - xbar||^2
    // for STATIC symmetric topologies (the deterministic Lemma behind the
    // paper's consensus lemmas).
    check("per-round consensus contraction <= beta^2", |rng| {
        let n = 3 + rng.below(16) as usize;
        let topo = match rng.below(3) {
            0 => Topology::ring(n),
            1 => Topology::grid(n),
            _ => Topology::static_expo(n),
        };
        let d = 1 + rng.below(32) as usize;
        let mut params = random_matrix(rng, n, d, 1.0);
        let before = consensus_distance(&params);
        let mut mixer = Mixer::new(&topo, d);
        mixer.gossip(&mut params, &WorkerPool::new(1)).unwrap();
        let after = consensus_distance(&params);
        let beta = topo.beta();
        ensure(
            after <= beta * beta * before * (1.0 + 1e-3) + 1e-9,
            format!("{:?} n={n}: {after} > beta^2 * {before}", topo.kind),
        )
    });
}

#[test]
fn prop_global_average_is_projection() {
    check("global average is idempotent and exact", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let topo = Topology::ring(n);
        let mut params = random_matrix(rng, n, d, 2.0);
        let mean = params.mean_row();
        let mut mixer = Mixer::new(&topo, d);
        let pool = WorkerPool::new(1);
        mixer.global_average(&mut params, &pool).unwrap();
        for p in params.rows() {
            assert_close(p, &mean, 1e-5)?;
        }
        let snapshot = params.clone();
        mixer.global_average(&mut params, &pool).unwrap(); // idempotent up to f32 rounding
        for (p, s) in params.rows().zip(snapshot.rows()) {
            assert_close(p, s, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_ring_allreduce_equals_sequential_sum() {
    check("ring all-reduce == sequential mean over the bus", |rng| {
        let n = 2 + rng.below(8) as usize;
        let d = 1 + rng.below(200) as usize;
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let expect: Vec<f32> =
            (0..d).map(|c| inputs.iter().map(|p| p[c]).sum::<f32>() / n as f32).collect();
        let eps = bus(n);
        let inputs2 = inputs.clone();
        let results = run_nodes(eps, move |mut ep| {
            let mut x = inputs2[ep.rank].clone();
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(x)
        })
        .map_err(|e| e.to_string())?;
        for r in &results {
            assert_close(r, &expect, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_bus_gossip_equals_mixer() {
    // The threaded message-passing gossip and the in-place Mixer are two
    // implementations of the same operator x <- Wx.
    check("bus gossip == mixer gossip", |rng| {
        let n = 2 + rng.below(10) as usize;
        let kind = match rng.below(3) {
            0 => TopologyKind::Ring,
            1 => TopologyKind::Grid,
            _ => TopologyKind::StaticExponential,
        };
        let topo = Topology::new(kind, n);
        let d = 1 + rng.below(32) as usize;
        let params = random_matrix(rng, n, d, 1.0);

        let mut mixed = params.clone();
        let mut mixer = Mixer::new(&topo, d);
        mixer.gossip(&mut mixed, &WorkerPool::new(1)).unwrap();

        let eps = bus(n);
        let topo2 = topo.clone();
        let rows2 = params.to_rows();
        let bus_out = run_nodes(eps, move |mut ep| {
            let rank = ep.rank;
            let row = topo2.weight_row(rank, 0);
            let outn: Vec<usize> =
                topo2.in_neighbors(rank, 0).into_iter().filter(|&j| j != rank).collect();
            gossip_exchange(&mut ep, &rows2[rank], &row, &outn)
        })
        .map_err(|e| e.to_string())?;
        for (a, b) in bus_out.iter().zip(mixed.rows()) {
            assert_close(a, b, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_c_beta_d_beta_inequalities() {
    // Table 2's caption inequality chain, for random beta/H.
    check("C_beta <= min{1/(1-beta), H} = D_beta bound", |rng| {
        let beta = rng.range(0.01, 0.999);
        let h = 1 + rng.below(128) as usize;
        let c = spectral::c_beta(beta, h);
        let d = spectral::d_beta(beta, h);
        ensure(c <= d + 1e-9, format!("C={c} > D={d} (beta={beta}, H={h})"))?;
        ensure(c <= h as f64 + 1e-9, "C > H")?;
        ensure(c <= 1.0 / (1.0 - beta) + 1e-9, "C > 1/(1-beta)")
    });
}

#[test]
fn prop_beta_of_convex_combination_with_avg_shrinks() {
    // Mixing any doubly-stochastic W with the averaging matrix reduces beta:
    // beta((1-t) W + t avg) = (1-t) beta(W).
    check("beta shrinks linearly under averaging interpolation", |rng| {
        let n = 3 + rng.below(10) as usize;
        let topo = Topology::ring(n);
        let w = topo.weight_matrix(0);
        let avg = gossip_pga::linalg::Mat::avg(n);
        let t = rng.range(0.1, 0.9);
        let mut mixed = gossip_pga::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                mixed[(i, j)] = (1.0 - t) * w[(i, j)] + t * avg[(i, j)];
            }
        }
        let expect = (1.0 - t) * beta_of(&w);
        let got = beta_of(&mixed);
        ensure((got - expect).abs() < 1e-6, format!("{got} vs {expect}"))
    });
}

// ---------------------------------------------------------------------------
// End-to-end trainer equivalences (need the AOT artifacts, like the
// integration tests).
// ---------------------------------------------------------------------------

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_default().expect("run `make artifacts` first"))
}

const ALL_KINDS: [AlgorithmKind; 6] = [
    AlgorithmKind::Parallel,
    AlgorithmKind::Gossip,
    AlgorithmKind::Local,
    AlgorithmKind::GossipPga,
    AlgorithmKind::GossipAga,
    AlgorithmKind::SlowMo,
];

fn trainer_opts(
    algo: AlgorithmKind,
    topo: Topology,
    momentum: f64,
    threads: usize,
) -> TrainerOptions {
    TrainerOptions {
        algorithm: algo,
        topology: topo,
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 },
        momentum,
        nesterov: momentum > 0.0,
        seed: 9,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 5,
        threads,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn logreg_trainer(
    rt: &Arc<Runtime>,
    algo: AlgorithmKind,
    topo: Topology,
    momentum: f64,
    threads: usize,
) -> Trainer {
    let (workload, init) = logreg_workload(rt.clone(), topo.n, 256, true, 9).unwrap();
    Trainer::new(workload, init, trainer_opts(algo, topo, momentum, threads)).unwrap()
}

/// Like [`logreg_trainer`] but with the overlap switch and period exposed
/// (the schedule-equivalence suites sweep both).
fn logreg_trainer_cfg(
    rt: &Arc<Runtime>,
    algo: AlgorithmKind,
    topo: Topology,
    momentum: f64,
    threads: usize,
    overlap: bool,
    period: usize,
) -> Trainer {
    let (workload, init) = logreg_workload(rt.clone(), topo.n, 256, true, 9).unwrap();
    let mut opts = trainer_opts(algo, topo, momentum, threads);
    opts.regime = if overlap { Regime::Overlap } else { Regime::Bsp };
    opts.period = period;
    Trainer::new(workload, init, opts).unwrap()
}

#[test]
fn pooled_trainer_bit_identical_across_all_algorithms() {
    // The pool at GOSSIP_PGA_TEST_THREADS (default 4) vs the sequential
    // reference must produce identical parameters AND identical histories
    // (losses, consensus, sim clock) for every algorithm on both a static
    // ring and the time-varying one-peer graph. The per-step scoped
    // threading this pool replaced held the same contract, so this pins
    // pooled == scoped == sequential.
    let rt = runtime();
    let steps = 14;
    let t = test_threads();
    for mk_topo in [Topology::ring as fn(usize) -> Topology, Topology::one_peer_expo] {
        for algo in ALL_KINDS {
            let topo = mk_topo(4);
            let kind = format!("{:?}/{:?}/t={t}", algo, topo.kind);
            let mut seq = logreg_trainer(&rt, algo, mk_topo(4), 0.0, 1);
            let mut thr = logreg_trainer(&rt, algo, mk_topo(4), 0.0, t);
            let h_seq = seq.run(steps, "seq").unwrap();
            let h_thr = thr.run(steps, "thr").unwrap();
            assert_eq!(h_seq.losses(), h_thr.losses(), "{kind}: losses diverged");
            for (a, b) in h_seq.records.iter().zip(&h_thr.records) {
                assert_eq!(a.consensus, b.consensus, "{kind}: consensus diverged");
                assert_eq!(a.sim_seconds, b.sim_seconds, "{kind}: sim clock diverged");
            }
            for i in 0..seq.n() {
                assert_eq!(
                    seq.worker_params(i),
                    thr.worker_params(i),
                    "{kind}: worker {i} params diverged"
                );
            }
        }
    }
}

#[test]
fn pooled_trainer_bit_identical_for_thread_counts_1_2_3_8() {
    // The explicit schedule-equivalence sweep from the issue: pool sizes
    // 1, 2, 3 and 8 all reproduce the sequential reference bit-for-bit.
    // 8 > n = 5 also exercises the shards() cap (more threads than
    // workers).
    let rt = runtime();
    let steps = 12;
    let mut reference = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(5), 0.9, 1);
    for _ in 0..steps {
        reference.step_once().unwrap();
    }
    for threads in [1usize, 2, 3, 8] {
        let mut t = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(5), 0.9, threads);
        assert_eq!(t.pool().size(), threads.max(1));
        for _ in 0..steps {
            t.step_once().unwrap();
        }
        for i in 0..t.n() {
            assert_eq!(
                reference.worker_params(i),
                t.worker_params(i),
                "threads={threads}: worker {i} params diverged"
            );
        }
        assert_eq!(reference.sim_seconds(), t.sim_seconds(), "threads={threads}");
    }
}

/// [`logreg_trainer`] with the work-stealing pool and an optional seeded
/// straggler (node 1 with 3x compute+latency — clock billing only, so the
/// parameter trajectory must not move by a bit).
fn logreg_trainer_stealing(
    rt: &Arc<Runtime>,
    algo: AlgorithmKind,
    topo: Topology,
    threads: usize,
    straggler: bool,
) -> Trainer {
    let (workload, init) = logreg_workload(rt.clone(), topo.n, 256, true, 9).unwrap();
    let mut opts = trainer_opts(algo, topo, 0.9, threads);
    opts.stealing = true;
    if straggler {
        opts.node_costs = Some(
            NodeCosts::homogeneous(opts.cost, opts.topology.n)
                .with_straggler(1, 3.0)
                .unwrap(),
        );
    }
    Trainer::new(workload, init, opts).unwrap()
}

#[test]
fn stealing_pool_bit_identical_across_all_algorithms_and_thread_counts() {
    // The work-stealing schedule-equivalence suite: for every algorithm,
    // the stealing pool at threads {1, 2, 3, 8} — with a seeded straggler
    // riding along — reproduces the static sequential reference
    // bit-for-bit (parameters AND mean losses). The straggler only bends
    // the virtual clocks: the straggled run's params equal the
    // homogeneous run's, while its critical path is strictly longer.
    let rt = runtime();
    let steps = 10;
    for algo in ALL_KINDS {
        let mut reference = logreg_trainer(&rt, algo, Topology::ring(4), 0.9, 1);
        for _ in 0..steps {
            reference.step_once().unwrap();
        }
        for threads in [1usize, 2, 3, 8] {
            let mut t =
                logreg_trainer_stealing(&rt, algo, Topology::ring(4), threads, true);
            assert!(t.pool().stealing());
            for _ in 0..steps {
                t.step_once().unwrap();
            }
            for i in 0..t.n() {
                assert_eq!(
                    reference.worker_params(i),
                    t.worker_params(i),
                    "{algo:?} threads={threads}: worker {i} diverged under stealing+straggler"
                );
            }
            assert!(
                t.sim_seconds() > reference.sim_seconds(),
                "{algo:?} threads={threads}: straggled critical path must exceed homogeneous"
            );
            assert!(
                t.straggler_slack() > 0.0,
                "{algo:?} threads={threads}: a straggler must open clock slack"
            );
        }
        // And without the straggler, the stealing pool's clocks match the
        // sequential reference exactly (homogeneous bit-exactness).
        let mut plain = logreg_trainer_stealing(&rt, algo, Topology::ring(4), 3, false);
        for _ in 0..steps {
            plain.step_once().unwrap();
        }
        assert_eq!(plain.sim_seconds(), reference.sim_seconds(), "{algo:?}: clocks diverged");
        for i in 0..plain.n() {
            assert_eq!(reference.worker_params(i), plain.worker_params(i), "{algo:?}");
        }
    }
}

#[test]
fn pooled_trainer_bit_identical_with_momentum() {
    // Momentum exercises the per-worker velocity buffers across pool jobs.
    let rt = runtime();
    let mut seq = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(5), 0.9, 1);
    let mut thr = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(5), 0.9, 4);
    for _ in 0..12 {
        seq.step_once().unwrap();
        thr.step_once().unwrap();
    }
    for i in 0..5 {
        assert_eq!(seq.worker_params(i), thr.worker_params(i), "worker {i}");
    }
}

#[test]
fn more_threads_than_workers_uses_one_policy_and_matches_sequential() {
    // The PR-1 policy split (phases capped at n, the mix uncapped) is gone:
    // WorkerPool::shards is the single policy. n = 2 workers on an 8-thread
    // pool must match the sequential run exactly — phases and gossip shard
    // 2 ways, the global-average mean shards by columns (d = 10 > 8, so 8
    // ways), all bit-identical by fixed accumulation order.
    let rt = runtime();
    let mut seq = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(2), 0.9, 1);
    let mut wide = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(2), 0.9, 8);
    assert_eq!(wide.pool().size(), 8);
    assert_eq!(wide.pool().shards(2), 2, "phase/gossip shard count caps at n");
    for _ in 0..13 {
        seq.step_once().unwrap();
        wide.step_once().unwrap();
    }
    for i in 0..2 {
        assert_eq!(seq.worker_params(i), wide.worker_params(i), "worker {i}");
    }
    assert_eq!(seq.sim_seconds(), wide.sim_seconds());
}

#[test]
fn prop_pooled_trainer_matches_sequential_reference() {
    // Randomized schedule equivalence: random algorithm, topology, pool
    // size and momentum — the pooled trainer must reproduce the sequential
    // reference bit-for-bit, step by step.
    let rt = runtime();
    check("pooled trainer == sequential trainer", |rng| {
        let n = 3 + rng.below(3) as usize; // 3..5
        let algo = ALL_KINDS[rng.below(6) as usize];
        let topo_a = rng_topo_pick(n, rng);
        let topo_b = topo_a.clone();
        let threads = [2usize, 3, 8, test_threads()][rng.below(4) as usize];
        let momentum = if rng.below(2) == 0 { 0.0 } else { 0.9 };
        let steps = 6 + rng.below(5) as usize;
        let mut seq = logreg_trainer(&rt, algo, topo_a, momentum, 1);
        let mut thr = logreg_trainer(&rt, algo, topo_b, momentum, threads);
        for k in 0..steps {
            let a = seq.step_once().map_err(|e| format!("seq: {e:#}"))?;
            let b = thr.step_once().map_err(|e| format!("thr: {e:#}"))?;
            ensure(a == b, format!("step {k}: actions diverged ({a:?} vs {b:?})"))?;
            ensure(
                seq.mean_loss() == thr.mean_loss(),
                format!("step {k}: losses diverged"),
            )?;
        }
        for i in 0..seq.n() {
            ensure(
                seq.worker_params(i) == thr.worker_params(i),
                format!("{algo:?} n={n} t={threads}: worker {i} diverged"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_overlap_matches_bsp_at_global_averaging_boundaries() {
    // The async-gossip schedule-equivalence property: at every k·H step the
    // global average is a synchronous barrier, so the overlapped trainer's
    // VISIBLE state must equal BSP bit-for-bit there — across ring, grid
    // and one-peer-expo topologies, any pool size, with and without
    // momentum. Mid-interval the mean loss (computed post-phases) must
    // also agree at every step, and after a final drain the full state
    // matches.
    let rt = runtime();
    check("overlap == BSP at k*H boundaries", |rng| {
        let n = 3 + rng.below(3) as usize; // 3..5
        let topo = match rng.below(3) {
            0 => Topology::ring(n),
            1 => Topology::grid(n),
            _ => Topology::one_peer_expo(n),
        };
        let h = 2 + rng.below(3) as usize; // H in 2..4
        let threads = [1usize, 2, 4, test_threads()][rng.below(4) as usize];
        let momentum = if rng.below(2) == 0 { 0.0 } else { 0.9 };
        let algo =
            if rng.below(4) == 0 { AlgorithmKind::SlowMo } else { AlgorithmKind::GossipPga };
        let steps = h * 3;
        let mut bsp = logreg_trainer_cfg(&rt, algo, topo.clone(), momentum, threads, false, h);
        let mut ovl = logreg_trainer_cfg(&rt, algo, topo.clone(), momentum, threads, true, h);
        for k in 0..steps {
            let a = bsp.step_once().map_err(|e| format!("bsp: {e:#}"))?;
            let b = ovl.step_once().map_err(|e| format!("ovl: {e:#}"))?;
            ensure(a == b, format!("step {k}: actions diverged"))?;
            ensure(
                bsp.mean_loss() == ovl.mean_loss(),
                format!("{:?} H={h} t={threads} step {k}: losses diverged", topo.kind),
            )?;
            if (k + 1) % h == 0 {
                // Global-averaging boundary: nothing in flight, the states
                // must agree without any drain.
                for i in 0..bsp.n() {
                    ensure(
                        bsp.worker_params(i) == ovl.worker_params(i),
                        format!(
                            "{:?} H={h} t={threads} boundary {}: worker {i} diverged",
                            topo.kind,
                            k + 1
                        ),
                    )?;
                }
            }
        }
        ovl.drain().map_err(|e| format!("drain: {e:#}"))?;
        for i in 0..bsp.n() {
            ensure(
                bsp.worker_params(i) == ovl.worker_params(i),
                format!("{:?} H={h} t={threads} final: worker {i} diverged", topo.kind),
            )?;
        }
        ensure(bsp.sim_seconds() == ovl.sim_seconds(), "sim clocks diverged")?;
        ensure(bsp.gossip_clock() == ovl.gossip_clock(), "gossip clocks diverged")
    });
}

#[test]
fn overlap_run_history_is_bit_identical_to_bsp() {
    // Trainer::run drains before every logged row, so the overlap history
    // (losses, consensus, sim clock) is the BSP history, bit for bit.
    let rt = runtime();
    let steps = 17;
    let mk = |overlap| {
        logreg_trainer_cfg(
            &rt,
            AlgorithmKind::GossipPga,
            Topology::one_peer_expo(4),
            0.9,
            test_threads(),
            overlap,
            4,
        )
    };
    let h_bsp = mk(false).run(steps, "bsp").unwrap();
    let h_ovl = mk(true).run(steps, "ovl").unwrap();
    assert_eq!(h_bsp.losses(), h_ovl.losses());
    for (a, b) in h_bsp.records.iter().zip(&h_ovl.records) {
        assert_eq!(a.consensus, b.consensus, "consensus diverged at step {}", a.step);
        assert_eq!(a.sim_seconds, b.sim_seconds, "sim clock diverged at step {}", a.step);
    }
}

/// Helper for the randomized trainer property: pick a topology without
/// holding a borrow on the rng across the trainer builds.
fn rng_topo_pick(n: usize, rng: &mut gossip_pga::rng::Rng) -> Topology {
    match rng.below(3) {
        0 => Topology::ring(n),
        1 => Topology::grid(n),
        _ => Topology::one_peer_expo(n),
    }
}

#[test]
fn aga_checkpoint_restore_replays_bit_identically() {
    // Unbroken run `a` vs a checkpoint restored into a FRESH trainer (the
    // real crash-resume scenario: no in-process replay). Covers the
    // previously-lost state: worker RNG streams, the mixer's gossip clock
    // (mid one-peer period at step 21) and AGA's adaptive-period recursion.
    let rt = runtime();
    let mk = |threads| {
        logreg_trainer(&rt, AlgorithmKind::GossipAga, Topology::one_peer_expo(4), 0.9, threads)
    };
    let mut a = mk(1);
    for _ in 0..21 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    assert!(ck.schedule.is_some(), "AGA must checkpoint its schedule state");
    assert!(ck.velocities.is_some(), "momentum run must checkpoint velocities");
    assert!(ck.gossip_clock > 0, "21 AGA steps must have gossiped");
    assert_eq!(ck.rng_states.len(), a.n(), "worker RNG streams must be checkpointed");
    let h_at_ck = a.current_period();
    for _ in 0..21 {
        a.step_once().unwrap();
    }

    // Fresh trainer, no replay — everything must come from the checkpoint.
    let mut b = mk(4); // resume on a different thread count, same bits
    b.restore(&ck).unwrap();
    assert_eq!(b.gossip_clock() as u64, ck.gossip_clock, "restored gossip clock");
    assert_eq!(b.current_period(), h_at_ck, "restored AGA period");
    for _ in 0..21 {
        b.step_once().unwrap();
    }
    for i in 0..a.n() {
        assert_eq!(a.worker_params(i), b.worker_params(i), "worker {i}");
    }
    assert_eq!(a.sim_seconds(), b.sim_seconds());
}

#[test]
fn slowmo_checkpoint_restore_replays_bit_identically() {
    // SlowMo's outer buffers (x_prev_sync, slow momentum u) mutate at every
    // global sync; checkpoint at step 10 (after the step-8 sync), resume,
    // and the next syncs at 12/16/20 must match the unbroken run exactly.
    let rt = runtime();
    let mk = || logreg_trainer(&rt, AlgorithmKind::SlowMo, Topology::ring(4), 0.9, 1);
    let mut a = mk();
    for _ in 0..10 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    assert!(ck.slowmo.is_some(), "SlowMo must checkpoint its outer buffers");
    for _ in 0..14 {
        a.step_once().unwrap();
    }

    // Fresh trainer, no replay: restore must be a faithful roundtrip of
    // every stateful field, and the continuation must match the unbroken
    // run exactly.
    let mut b = mk();
    b.restore(&ck).unwrap();
    assert_eq!(b.checkpoint().unwrap(), ck);
    for _ in 0..14 {
        b.step_once().unwrap();
    }
    for i in 0..a.n() {
        assert_eq!(a.worker_params(i), b.worker_params(i), "worker {i}");
    }
}

#[test]
fn restore_into_fresh_trainer_restores_adaptive_period() {
    // The AGA state is *live* after restore: a fresh trainer (period still
    // H_init) picks up the grown period from the checkpoint alone.
    let rt = runtime();
    let mut a = logreg_trainer(&rt, AlgorithmKind::GossipAga, Topology::ring(4), 0.0, 1);
    for _ in 0..120 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    let grown = a.current_period();
    assert!(grown > 2, "AGA period should have grown past H_init=2, got {grown}");

    let mut fresh = logreg_trainer(&rt, AlgorithmKind::GossipAga, Topology::ring(4), 0.0, 1);
    assert_eq!(fresh.current_period(), 2, "fresh AGA starts at H_init");
    fresh.restore(&ck).unwrap();
    assert_eq!(fresh.current_period(), grown, "restore must carry the adaptive period");
}
