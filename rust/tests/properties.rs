//! Randomized property tests (in-repo kit, see `gossip_pga::proptest`)
//! over the coordinator's invariants.

use gossip_pga::collective::{bus, gossip_exchange, ring_all_reduce, run_nodes};
use gossip_pga::coordinator::mixer::Mixer;
use gossip_pga::linalg::beta_of;
use gossip_pga::metrics::consensus_distance;
use gossip_pga::proptest::{assert_close, check, ensure};
use gossip_pga::topology::{spectral, Topology, TopologyKind};

fn random_topology(rng: &mut gossip_pga::rng::Rng, n: usize) -> Topology {
    match rng.below(6) {
        0 => Topology::ring(n),
        1 => Topology::grid(n),
        2 => Topology::star(n),
        3 => Topology::full(n),
        4 => Topology::static_expo(n),
        _ => Topology::one_peer_expo(n),
    }
}

#[test]
fn prop_weight_matrices_doubly_stochastic() {
    check("W doubly stochastic for every topology/round", |rng| {
        let n = 2 + rng.below(24) as usize;
        let topo = random_topology(rng, n);
        for r in 0..topo.rounds() {
            let w = topo.weight_matrix(r);
            ensure(w.row_sum_err() < 1e-9, format!("{:?} n={n} rows", topo.kind))?;
            ensure(w.col_sum_err() < 1e-9, format!("{:?} n={n} cols", topo.kind))?;
            ensure(
                w.data.iter().all(|&v| v >= -1e-12),
                format!("{:?} n={n} negative weight", topo.kind),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_beta_in_unit_interval() {
    check("beta in [0, 1) for connected topologies", |rng| {
        let n = 2 + rng.below(20) as usize;
        let topo = random_topology(rng, n);
        let beta = topo.beta();
        ensure(
            (0.0..1.0).contains(&beta),
            format!("{:?} n={n}: beta={beta}", topo.kind),
        )
    });
}

#[test]
fn prop_mixing_preserves_ensemble_mean() {
    check("gossip mixing preserves the ensemble mean", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let topo = random_topology(rng, n);
        let mut params: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let mean_before: Vec<f32> = (0..d)
            .map(|c| params.iter().map(|p| p[c]).sum::<f32>() / n as f32)
            .collect();
        let mut mixer = Mixer::new(&topo, d);
        let rounds = 1 + rng.below(4) as usize;
        for _ in 0..rounds {
            mixer.gossip(&mut params);
        }
        let mean_after: Vec<f32> =
            (0..d).map(|c| params.iter().map(|p| p[c]).sum::<f32>() / n as f32).collect();
        assert_close(&mean_after, &mean_before, 1e-4)
    });
}

#[test]
fn prop_mixing_contracts_consensus_by_beta_squared() {
    // One gossip round satisfies ||x' - xbar'||^2 <= beta^2 ||x - xbar||^2
    // for STATIC symmetric topologies (the deterministic Lemma behind the
    // paper's consensus lemmas).
    check("per-round consensus contraction <= beta^2", |rng| {
        let n = 3 + rng.below(16) as usize;
        let topo = match rng.below(3) {
            0 => Topology::ring(n),
            1 => Topology::grid(n),
            _ => Topology::static_expo(n),
        };
        let d = 1 + rng.below(32) as usize;
        let mut params: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let before = consensus_distance(&params);
        let mut mixer = Mixer::new(&topo, d);
        mixer.gossip(&mut params);
        let after = consensus_distance(&params);
        let beta = topo.beta();
        ensure(
            after <= beta * beta * before * (1.0 + 1e-3) + 1e-9,
            format!("{:?} n={n}: {after} > beta^2 * {before}", topo.kind),
        )
    });
}

#[test]
fn prop_global_average_is_projection() {
    check("global average is idempotent and exact", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let topo = Topology::ring(n);
        let mut params: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 2.0)).collect();
        let mean: Vec<f32> =
            (0..d).map(|c| params.iter().map(|p| p[c]).sum::<f32>() / n as f32).collect();
        let mut mixer = Mixer::new(&topo, d);
        mixer.global_average(&mut params);
        for p in &params {
            assert_close(p, &mean, 1e-5)?;
        }
        let snapshot = params.clone();
        mixer.global_average(&mut params); // idempotent up to f32 rounding
        for (p, s) in params.iter().zip(&snapshot) {
            assert_close(p, s, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_ring_allreduce_equals_sequential_sum() {
    check("ring all-reduce == sequential mean over the bus", |rng| {
        let n = 2 + rng.below(8) as usize;
        let d = 1 + rng.below(200) as usize;
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let expect: Vec<f32> =
            (0..d).map(|c| inputs.iter().map(|p| p[c]).sum::<f32>() / n as f32).collect();
        let eps = bus(n);
        let inputs2 = inputs.clone();
        let results = run_nodes(eps, move |mut ep| {
            let mut x = inputs2[ep.rank].clone();
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(x)
        })
        .map_err(|e| e.to_string())?;
        for r in &results {
            assert_close(r, &expect, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_bus_gossip_equals_mixer() {
    // The threaded message-passing gossip and the in-place Mixer are two
    // implementations of the same operator x <- Wx.
    check("bus gossip == mixer gossip", |rng| {
        let n = 2 + rng.below(10) as usize;
        let kind = match rng.below(3) {
            0 => TopologyKind::Ring,
            1 => TopologyKind::Grid,
            _ => TopologyKind::StaticExponential,
        };
        let topo = Topology::new(kind, n);
        let d = 1 + rng.below(32) as usize;
        let params: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();

        let mut mixed = params.clone();
        let mut mixer = Mixer::new(&topo, d);
        mixer.gossip(&mut mixed);

        let eps = bus(n);
        let topo2 = topo.clone();
        let params2 = params.clone();
        let bus_out = run_nodes(eps, move |mut ep| {
            let rank = ep.rank;
            let row = topo2.weight_row(rank, 0);
            let outn: Vec<usize> =
                topo2.in_neighbors(rank, 0).into_iter().filter(|&j| j != rank).collect();
            gossip_exchange(&mut ep, &params2[rank], &row, &outn)
        })
        .map_err(|e| e.to_string())?;
        for (a, b) in bus_out.iter().zip(&mixed) {
            assert_close(a, b, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_c_beta_d_beta_inequalities() {
    // Table 2's caption inequality chain, for random beta/H.
    check("C_beta <= min{1/(1-beta), H} = D_beta bound", |rng| {
        let beta = rng.range(0.01, 0.999);
        let h = 1 + rng.below(128) as usize;
        let c = spectral::c_beta(beta, h);
        let d = spectral::d_beta(beta, h);
        ensure(c <= d + 1e-9, format!("C={c} > D={d} (beta={beta}, H={h})"))?;
        ensure(c <= h as f64 + 1e-9, "C > H")?;
        ensure(c <= 1.0 / (1.0 - beta) + 1e-9, "C > 1/(1-beta)")
    });
}

#[test]
fn prop_beta_of_convex_combination_with_avg_shrinks() {
    // Mixing any doubly-stochastic W with the averaging matrix reduces beta:
    // beta((1-t) W + t avg) = (1-t) beta(W).
    check("beta shrinks linearly under averaging interpolation", |rng| {
        let n = 3 + rng.below(10) as usize;
        let topo = Topology::ring(n);
        let w = topo.weight_matrix(0);
        let avg = gossip_pga::linalg::Mat::avg(n);
        let t = rng.range(0.1, 0.9);
        let mut mixed = gossip_pga::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                mixed[(i, j)] = (1.0 - t) * w[(i, j)] + t * avg[(i, j)];
            }
        }
        let expect = (1.0 - t) * beta_of(&w);
        let got = beta_of(&mixed);
        ensure((got - expect).abs() < 1e-6, format!("{got} vs {expect}"))
    });
}
