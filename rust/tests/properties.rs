//! Randomized property tests (in-repo kit, see `gossip_pga::proptest`)
//! over the coordinator's invariants, plus the threading and
//! checkpoint-resume equivalences:
//!
//! * threaded (`threads = 4`) and sequential (`threads = 1`) trainers are
//!   bit-identical across all six `AlgorithmKind`s on ring and
//!   one-peer-expo topologies;
//! * a checkpoint -> restore -> replay run matches an unbroken run for the
//!   stateful algorithms (Gossip-AGA's adaptive period, SlowMo's outer
//!   buffers, the mixer's gossip clock).

use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::collective::{bus, gossip_exchange, ring_all_reduce, run_nodes};
use gossip_pga::coordinator::mixer::Mixer;
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::CostModel;
use gossip_pga::linalg::beta_of;
use gossip_pga::metrics::consensus_distance;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::proptest::{assert_close, check, ensure};
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::{spectral, Topology, TopologyKind};

fn random_topology(rng: &mut gossip_pga::rng::Rng, n: usize) -> Topology {
    match rng.below(6) {
        0 => Topology::ring(n),
        1 => Topology::grid(n),
        2 => Topology::star(n),
        3 => Topology::full(n),
        4 => Topology::static_expo(n),
        _ => Topology::one_peer_expo(n),
    }
}

fn random_matrix(rng: &mut gossip_pga::rng::Rng, n: usize, d: usize, scale: f32) -> ParamMatrix {
    ParamMatrix::random(rng, n, d, scale)
}

#[test]
fn prop_weight_matrices_doubly_stochastic() {
    check("W doubly stochastic for every topology/round", |rng| {
        let n = 2 + rng.below(24) as usize;
        let topo = random_topology(rng, n);
        for r in 0..topo.rounds() {
            let w = topo.weight_matrix(r);
            ensure(w.row_sum_err() < 1e-9, format!("{:?} n={n} rows", topo.kind))?;
            ensure(w.col_sum_err() < 1e-9, format!("{:?} n={n} cols", topo.kind))?;
            ensure(
                w.data.iter().all(|&v| v >= -1e-12),
                format!("{:?} n={n} negative weight", topo.kind),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_beta_in_unit_interval() {
    check("beta in [0, 1) for connected topologies", |rng| {
        let n = 2 + rng.below(20) as usize;
        let topo = random_topology(rng, n);
        let beta = topo.beta();
        ensure(
            (0.0..1.0).contains(&beta),
            format!("{:?} n={n}: beta={beta}", topo.kind),
        )
    });
}

#[test]
fn prop_mixing_preserves_ensemble_mean() {
    check("gossip mixing preserves the ensemble mean", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let topo = random_topology(rng, n);
        let mut params = random_matrix(rng, n, d, 1.0);
        let mean_before = params.mean_row();
        let mut mixer = Mixer::new(&topo, d);
        let rounds = 1 + rng.below(4) as usize;
        for _ in 0..rounds {
            mixer.gossip(&mut params, 1);
        }
        assert_close(&params.mean_row(), &mean_before, 1e-4)
    });
}

#[test]
fn prop_threaded_mix_bit_identical_to_sequential() {
    // The tentpole invariant: every thread count computes the exact same
    // matrix (mix rows and mean columns have fixed accumulation order).
    check("gossip/global-average agree for any thread count", |rng| {
        let n = 2 + rng.below(16) as usize;
        let d = 1 + rng.below(96) as usize;
        let threads = 2 + rng.below(7) as usize;
        let topo = random_topology(rng, n);
        let mut seq = random_matrix(rng, n, d, 1.0);
        let mut thr = seq.clone();
        let mut m1 = Mixer::new(&topo, d);
        let mut m2 = Mixer::new(&topo, d);
        for _ in 0..topo.rounds().min(3) {
            m1.gossip(&mut seq, 1);
            m2.gossip(&mut thr, threads);
            ensure(seq == thr, format!("{:?} n={n} d={d} t={threads}: gossip diverged", topo.kind))?;
        }
        m1.global_average(&mut seq, 1);
        m2.global_average(&mut thr, threads);
        ensure(seq == thr, format!("{:?} n={n} d={d} t={threads}: average diverged", topo.kind))
    });
}

#[test]
fn prop_mixing_contracts_consensus_by_beta_squared() {
    // One gossip round satisfies ||x' - xbar'||^2 <= beta^2 ||x - xbar||^2
    // for STATIC symmetric topologies (the deterministic Lemma behind the
    // paper's consensus lemmas).
    check("per-round consensus contraction <= beta^2", |rng| {
        let n = 3 + rng.below(16) as usize;
        let topo = match rng.below(3) {
            0 => Topology::ring(n),
            1 => Topology::grid(n),
            _ => Topology::static_expo(n),
        };
        let d = 1 + rng.below(32) as usize;
        let mut params = random_matrix(rng, n, d, 1.0);
        let before = consensus_distance(&params);
        let mut mixer = Mixer::new(&topo, d);
        mixer.gossip(&mut params, 1);
        let after = consensus_distance(&params);
        let beta = topo.beta();
        ensure(
            after <= beta * beta * before * (1.0 + 1e-3) + 1e-9,
            format!("{:?} n={n}: {after} > beta^2 * {before}", topo.kind),
        )
    });
}

#[test]
fn prop_global_average_is_projection() {
    check("global average is idempotent and exact", |rng| {
        let n = 2 + rng.below(12) as usize;
        let d = 1 + rng.below(64) as usize;
        let topo = Topology::ring(n);
        let mut params = random_matrix(rng, n, d, 2.0);
        let mean = params.mean_row();
        let mut mixer = Mixer::new(&topo, d);
        mixer.global_average(&mut params, 1);
        for p in params.rows() {
            assert_close(p, &mean, 1e-5)?;
        }
        let snapshot = params.clone();
        mixer.global_average(&mut params, 1); // idempotent up to f32 rounding
        for (p, s) in params.rows().zip(snapshot.rows()) {
            assert_close(p, s, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_ring_allreduce_equals_sequential_sum() {
    check("ring all-reduce == sequential mean over the bus", |rng| {
        let n = 2 + rng.below(8) as usize;
        let d = 1 + rng.below(200) as usize;
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let expect: Vec<f32> =
            (0..d).map(|c| inputs.iter().map(|p| p[c]).sum::<f32>() / n as f32).collect();
        let eps = bus(n);
        let inputs2 = inputs.clone();
        let results = run_nodes(eps, move |mut ep| {
            let mut x = inputs2[ep.rank].clone();
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(x)
        })
        .map_err(|e| e.to_string())?;
        for r in &results {
            assert_close(r, &expect, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_bus_gossip_equals_mixer() {
    // The threaded message-passing gossip and the in-place Mixer are two
    // implementations of the same operator x <- Wx.
    check("bus gossip == mixer gossip", |rng| {
        let n = 2 + rng.below(10) as usize;
        let kind = match rng.below(3) {
            0 => TopologyKind::Ring,
            1 => TopologyKind::Grid,
            _ => TopologyKind::StaticExponential,
        };
        let topo = Topology::new(kind, n);
        let d = 1 + rng.below(32) as usize;
        let params = random_matrix(rng, n, d, 1.0);

        let mut mixed = params.clone();
        let mut mixer = Mixer::new(&topo, d);
        mixer.gossip(&mut mixed, 1);

        let eps = bus(n);
        let topo2 = topo.clone();
        let rows2 = params.to_rows();
        let bus_out = run_nodes(eps, move |mut ep| {
            let rank = ep.rank;
            let row = topo2.weight_row(rank, 0);
            let outn: Vec<usize> =
                topo2.in_neighbors(rank, 0).into_iter().filter(|&j| j != rank).collect();
            gossip_exchange(&mut ep, &rows2[rank], &row, &outn)
        })
        .map_err(|e| e.to_string())?;
        for (a, b) in bus_out.iter().zip(mixed.rows()) {
            assert_close(a, b, 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_c_beta_d_beta_inequalities() {
    // Table 2's caption inequality chain, for random beta/H.
    check("C_beta <= min{1/(1-beta), H} = D_beta bound", |rng| {
        let beta = rng.range(0.01, 0.999);
        let h = 1 + rng.below(128) as usize;
        let c = spectral::c_beta(beta, h);
        let d = spectral::d_beta(beta, h);
        ensure(c <= d + 1e-9, format!("C={c} > D={d} (beta={beta}, H={h})"))?;
        ensure(c <= h as f64 + 1e-9, "C > H")?;
        ensure(c <= 1.0 / (1.0 - beta) + 1e-9, "C > 1/(1-beta)")
    });
}

#[test]
fn prop_beta_of_convex_combination_with_avg_shrinks() {
    // Mixing any doubly-stochastic W with the averaging matrix reduces beta:
    // beta((1-t) W + t avg) = (1-t) beta(W).
    check("beta shrinks linearly under averaging interpolation", |rng| {
        let n = 3 + rng.below(10) as usize;
        let topo = Topology::ring(n);
        let w = topo.weight_matrix(0);
        let avg = gossip_pga::linalg::Mat::avg(n);
        let t = rng.range(0.1, 0.9);
        let mut mixed = gossip_pga::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                mixed[(i, j)] = (1.0 - t) * w[(i, j)] + t * avg[(i, j)];
            }
        }
        let expect = (1.0 - t) * beta_of(&w);
        let got = beta_of(&mixed);
        ensure((got - expect).abs() < 1e-6, format!("{got} vs {expect}"))
    });
}

// ---------------------------------------------------------------------------
// End-to-end trainer equivalences (need the AOT artifacts, like the
// integration tests).
// ---------------------------------------------------------------------------

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_default().expect("run `make artifacts` first"))
}

const ALL_KINDS: [AlgorithmKind; 6] = [
    AlgorithmKind::Parallel,
    AlgorithmKind::Gossip,
    AlgorithmKind::Local,
    AlgorithmKind::GossipPga,
    AlgorithmKind::GossipAga,
    AlgorithmKind::SlowMo,
];

fn trainer_opts(
    algo: AlgorithmKind,
    topo: Topology,
    momentum: f64,
    threads: usize,
) -> TrainerOptions {
    TrainerOptions {
        algorithm: algo,
        topology: topo,
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 },
        momentum,
        nesterov: momentum > 0.0,
        seed: 9,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        log_every: 5,
        threads,
    }
}

fn logreg_trainer(
    rt: &Arc<Runtime>,
    algo: AlgorithmKind,
    topo: Topology,
    momentum: f64,
    threads: usize,
) -> Trainer {
    let (workload, init) = logreg_workload(rt.clone(), topo.n, 256, true, 9).unwrap();
    Trainer::new(workload, init, trainer_opts(algo, topo, momentum, threads)).unwrap()
}

#[test]
fn threaded_trainer_bit_identical_across_all_algorithms() {
    // threads = 4 vs threads = 1 must produce identical parameters AND
    // identical histories (losses, consensus, sim clock) for every
    // algorithm on both a static ring and the time-varying one-peer graph.
    let rt = runtime();
    let steps = 14;
    for mk_topo in [Topology::ring as fn(usize) -> Topology, Topology::one_peer_expo] {
        for algo in ALL_KINDS {
            let topo = mk_topo(4);
            let kind = format!("{:?}/{:?}", algo, topo.kind);
            let mut seq = logreg_trainer(&rt, algo, mk_topo(4), 0.0, 1);
            let mut thr = logreg_trainer(&rt, algo, mk_topo(4), 0.0, 4);
            let h_seq = seq.run(steps, "seq").unwrap();
            let h_thr = thr.run(steps, "thr").unwrap();
            assert_eq!(h_seq.losses(), h_thr.losses(), "{kind}: losses diverged");
            for (a, b) in h_seq.records.iter().zip(&h_thr.records) {
                assert_eq!(a.consensus, b.consensus, "{kind}: consensus diverged");
                assert_eq!(a.sim_seconds, b.sim_seconds, "{kind}: sim clock diverged");
            }
            for i in 0..seq.n() {
                assert_eq!(
                    seq.worker_params(i),
                    thr.worker_params(i),
                    "{kind}: worker {i} params diverged"
                );
            }
        }
    }
}

#[test]
fn threaded_trainer_bit_identical_with_momentum() {
    // Momentum exercises the per-worker velocity buffers across threads.
    let rt = runtime();
    let mut seq = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(5), 0.9, 1);
    let mut thr = logreg_trainer(&rt, AlgorithmKind::GossipPga, Topology::ring(5), 0.9, 4);
    for _ in 0..12 {
        seq.step_once().unwrap();
        thr.step_once().unwrap();
    }
    for i in 0..5 {
        assert_eq!(seq.worker_params(i), thr.worker_params(i), "worker {i}");
    }
}

#[test]
fn aga_checkpoint_restore_replays_bit_identically() {
    // Unbroken run `a` vs a checkpoint restored into a FRESH trainer (the
    // real crash-resume scenario: no in-process replay). Covers the
    // previously-lost state: worker RNG streams, the mixer's gossip clock
    // (mid one-peer period at step 21) and AGA's adaptive-period recursion.
    let rt = runtime();
    let mk = |threads| {
        logreg_trainer(&rt, AlgorithmKind::GossipAga, Topology::one_peer_expo(4), 0.9, threads)
    };
    let mut a = mk(1);
    for _ in 0..21 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    assert!(ck.schedule.is_some(), "AGA must checkpoint its schedule state");
    assert!(ck.velocities.is_some(), "momentum run must checkpoint velocities");
    assert!(ck.gossip_clock > 0, "21 AGA steps must have gossiped");
    assert_eq!(ck.rng_states.len(), a.n(), "worker RNG streams must be checkpointed");
    let h_at_ck = a.current_period();
    for _ in 0..21 {
        a.step_once().unwrap();
    }

    // Fresh trainer, no replay — everything must come from the checkpoint.
    let mut b = mk(4); // resume on a different thread count, same bits
    b.restore(&ck).unwrap();
    assert_eq!(b.gossip_clock() as u64, ck.gossip_clock, "restored gossip clock");
    assert_eq!(b.current_period(), h_at_ck, "restored AGA period");
    for _ in 0..21 {
        b.step_once().unwrap();
    }
    for i in 0..a.n() {
        assert_eq!(a.worker_params(i), b.worker_params(i), "worker {i}");
    }
    assert_eq!(a.sim_seconds(), b.sim_seconds());
}

#[test]
fn slowmo_checkpoint_restore_replays_bit_identically() {
    // SlowMo's outer buffers (x_prev_sync, slow momentum u) mutate at every
    // global sync; checkpoint at step 10 (after the step-8 sync), resume,
    // and the next syncs at 12/16/20 must match the unbroken run exactly.
    let rt = runtime();
    let mk = || logreg_trainer(&rt, AlgorithmKind::SlowMo, Topology::ring(4), 0.9, 1);
    let mut a = mk();
    for _ in 0..10 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    assert!(ck.slowmo.is_some(), "SlowMo must checkpoint its outer buffers");
    for _ in 0..14 {
        a.step_once().unwrap();
    }

    // Fresh trainer, no replay: restore must be a faithful roundtrip of
    // every stateful field, and the continuation must match the unbroken
    // run exactly.
    let mut b = mk();
    b.restore(&ck).unwrap();
    assert_eq!(b.checkpoint().unwrap(), ck);
    for _ in 0..14 {
        b.step_once().unwrap();
    }
    for i in 0..a.n() {
        assert_eq!(a.worker_params(i), b.worker_params(i), "worker {i}");
    }
}

#[test]
fn restore_into_fresh_trainer_restores_adaptive_period() {
    // The AGA state is *live* after restore: a fresh trainer (period still
    // H_init) picks up the grown period from the checkpoint alone.
    let rt = runtime();
    let mut a = logreg_trainer(&rt, AlgorithmKind::GossipAga, Topology::ring(4), 0.0, 1);
    for _ in 0..120 {
        a.step_once().unwrap();
    }
    let ck = a.checkpoint().unwrap();
    let grown = a.current_period();
    assert!(grown > 2, "AGA period should have grown past H_init=2, got {grown}");

    let mut fresh = logreg_trainer(&rt, AlgorithmKind::GossipAga, Topology::ring(4), 0.0, 1);
    assert_eq!(fresh.current_period(), 2, "fresh AGA starts at H_init");
    fresh.restore(&ck).unwrap();
    assert_eq!(fresh.current_period(), grown, "restore must carry the adaptive period");
}
