//! Backend-equivalence property suite for the unified CommPlane
//! (`rust/src/comm/`).
//!
//! Contract under test: for all six algorithms' schedules on
//! ring / grid / one-peer-exponential, the message-passing `BusBackend`
//! and the shared-memory `SharedBackend` produce
//!
//! * **bit-identical** `ParamMatrix` trajectories with no compression
//!   (same `mix_row_src` kernel, same weight rows, same fixed-order mean),
//! * trajectories within 1e-6 with TopK / Int8 compression (in practice
//!   also bit-identical: per-node error-feedback codecs run the same ops),
//! * **identical `CommStats`** (scalars, messages), which also match the
//!   analytic counts the tab17 bench derives for the same schedule —
//!   measured-at-the-endpoints == predicted-from-the-topology.
//!
//! The schedule-level tests drive the backends directly with deterministic
//! pseudo-gradient perturbations, so they need no AOT artifacts; the
//! trainer-level test at the bottom needs `make artifacts` like the other
//! integration suites.

use std::sync::Arc;

use gossip_pga::algorithms::{schedule_for, AlgorithmKind, CommAction};
use gossip_pga::comm::{
    schedule_traffic, BackendKind, BusBackend, CommBackend, CommStats, Compression, SharedBackend,
    TcpBackend,
};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::metrics::consensus_distance;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

const ALL_KINDS: [AlgorithmKind; 6] = [
    AlgorithmKind::Parallel,
    AlgorithmKind::Gossip,
    AlgorithmKind::Local,
    AlgorithmKind::GossipPga,
    AlgorithmKind::GossipAga,
    AlgorithmKind::SlowMo,
];

fn backend_for(
    kind: BackendKind,
    topo: &Topology,
    d: usize,
    compression: Compression,
    algo: AlgorithmKind,
) -> Box<dyn CommBackend> {
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    match kind {
        BackendKind::Shared => Box::new(SharedBackend::new(topo, d, &costs, d, compression)),
        BackendKind::Bus => Box::new(BusBackend::new(
            topo,
            d,
            &costs,
            d,
            compression,
            algo != AlgorithmKind::Gossip,
        )),
        BackendKind::Tcp => Box::new(
            TcpBackend::new_loopback(
                topo,
                d,
                &costs,
                d,
                compression,
                algo != AlgorithmKind::Gossip,
                "127.0.0.1:0",
            )
            .unwrap(),
        ),
    }
}

/// Deterministic stand-in for the local-update phase: the same per-step
/// pseudo-gradient is applied on every backend's copy, so any divergence
/// comes from the communication plane alone.
fn perturb(params: &mut ParamMatrix, step: usize) {
    let mut rng = Rng::new(0xFEED ^ (step as u64).wrapping_mul(0x9E37_79B9));
    let noise = rng.normal_vec(params.n() * params.d(), 0.05);
    for (p, g) in params.as_mut_slice().iter_mut().zip(&noise) {
        *p -= g;
    }
}

/// One schedule-replay scenario (shared across the equivalence tests).
struct Replay {
    algo: AlgorithmKind,
    topo: Topology,
    d: usize,
    steps: usize,
    h: usize,
    threads: usize,
    compression: Compression,
}

impl Replay {
    /// Replay the schedule on one backend; returns the final matrix, the
    /// per-step actions and the backend's cumulative stats.
    fn run(&self, kind: BackendKind) -> (ParamMatrix, Vec<CommAction>, CommStats) {
        let pool = WorkerPool::new(self.threads);
        let mut params = ParamMatrix::random(&mut Rng::new(31), self.topo.n, self.d, 1.0);
        let mut backend = backend_for(kind, &self.topo, self.d, self.compression, self.algo);
        let mut schedule = schedule_for(self.algo, self.h, 2, 4).unwrap();
        let mut actions = Vec::new();
        for k in 0..self.steps {
            perturb(&mut params, k);
            // Deterministic loss stream keeps AGA's adaptive period
            // identical across backends.
            let action = schedule.action(k, 1.0 / (k as f64 + 1.0));
            match action {
                CommAction::Gossip => {
                    backend.gossip(&mut params, &pool).unwrap();
                }
                CommAction::GlobalAverage => {
                    backend.global_average(&mut params, &pool).unwrap();
                }
                CommAction::None => {}
            }
            actions.push(action);
        }
        (params, actions, backend.total())
    }
}

#[test]
fn bus_matches_shared_bit_for_bit_all_algorithms_all_topologies() {
    // The acceptance property: six algorithms x {ring, grid,
    // one-peer-expo} x pool sizes {1, 3} — identical trajectories
    // (bit-for-bit, uncompressed) and identical measured-vs-predicted
    // traffic, which also equals the analytic schedule counts.
    let d = 13;
    let steps = 12;
    let h = 3;
    for mk in [
        Topology::ring as fn(usize) -> Topology,
        Topology::grid,
        Topology::one_peer_expo,
    ] {
        for algo in ALL_KINDS {
            for threads in [1usize, 3] {
                let topo = mk(5);
                let label = format!("{:?}/{:?}/t={threads}", algo, topo.kind);
                let spec = Replay {
                    algo,
                    topo: topo.clone(),
                    d,
                    steps,
                    h,
                    threads,
                    compression: Compression::None,
                };
                let (p_shared, a_shared, s_shared) = spec.run(BackendKind::Shared);
                let (p_bus, a_bus, s_bus) = spec.run(BackendKind::Bus);
                assert_eq!(a_shared, a_bus, "{label}: schedules diverged");
                assert_eq!(p_shared, p_bus, "{label}: trajectories diverged");
                assert_eq!(
                    (s_shared.scalars_sent, s_shared.msgs),
                    (s_bus.scalars_sent, s_bus.msgs),
                    "{label}: traffic accounting diverged"
                );
                let expect = schedule_traffic(&topo, d, &a_shared);
                assert_eq!(
                    (s_bus.scalars_sent, s_bus.msgs),
                    expect,
                    "{label}: measured traffic != analytic schedule counts"
                );
            }
        }
    }
}

#[test]
fn bus_matches_shared_on_non_power_of_two_and_d_smaller_than_n() {
    // Chunked global average with empty chunks (d < n) and odd sizes.
    for (n, d) in [(5usize, 3usize), (7, 1), (6, 64), (2, 2)] {
        let topo = Topology::ring(n);
        let spec = Replay {
            algo: AlgorithmKind::GossipPga,
            topo: topo.clone(),
            d,
            steps: 10,
            h: 2,
            threads: 2,
            compression: Compression::None,
        };
        let (p_shared, actions, s_shared) = spec.run(BackendKind::Shared);
        let (p_bus, _, s_bus) = spec.run(BackendKind::Bus);
        assert_eq!(p_shared, p_bus, "n={n} d={d}");
        assert_eq!(s_shared.scalars_sent, s_bus.scalars_sent, "n={n} d={d}");
        assert_eq!(s_shared.msgs, s_bus.msgs, "n={n} d={d}");
        assert_eq!(
            (s_bus.scalars_sent, s_bus.msgs),
            schedule_traffic(&topo, d, &actions),
            "n={n} d={d}"
        );
    }
}

#[test]
fn single_node_degenerates_cleanly_on_both_backends() {
    for kind in [BackendKind::Shared, BackendKind::Bus] {
        let spec = Replay {
            algo: AlgorithmKind::GossipPga,
            topo: Topology::ring(1),
            d: 6,
            steps: 8,
            h: 2,
            threads: 1,
            compression: Compression::None,
        };
        let (p, _, stats) = spec.run(kind);
        assert_eq!(p.n(), 1);
        assert_eq!(stats.scalars_sent, 0, "{kind:?}: a lone node sends nothing");
        assert_eq!(stats.msgs, 0, "{kind:?}");
    }
}

#[test]
fn compressed_gossip_stays_within_1e6_across_backends() {
    // TopK and Int8 transmit paths: per-node error-feedback codecs run the
    // same operations on both planes, so the trajectories agree far inside
    // the 1e-6 acceptance band (and the wire accounting agrees exactly).
    let d = 64;
    let steps = 10;
    for compression in
        [Compression::TopK { frac: 0.25 }, Compression::Int8 { block: 16 }]
    {
        for mk in [Topology::ring as fn(usize) -> Topology, Topology::one_peer_expo] {
            let topo = mk(4);
            let label = format!("{:?}/{:?}", compression, topo.kind);
            let spec = Replay {
                algo: AlgorithmKind::GossipPga,
                topo: topo.clone(),
                d,
                steps,
                h: 3,
                threads: 2,
                compression,
            };
            let (p_shared, _, s_shared) = spec.run(BackendKind::Shared);
            let (p_bus, _, s_bus) = spec.run(BackendKind::Bus);
            for (a, b) in p_shared.as_slice().iter().zip(p_bus.as_slice()) {
                assert!((a - b).abs() <= 1e-6, "{label}: {a} vs {b}");
            }
            let gap = (consensus_distance(&p_shared) - consensus_distance(&p_bus)).abs();
            assert!(gap <= 1e-6, "{label}: consensus gap {gap}");
            assert_eq!(
                (s_shared.scalars_sent, s_shared.msgs),
                (s_bus.scalars_sent, s_bus.msgs),
                "{label}: compressed wire accounting diverged"
            );
            // Compression must actually compress relative to identity.
            let (identity_scalars, _) = schedule_traffic(
                &topo,
                d,
                &(0..steps)
                    .map(|k| {
                        if (k + 1) % 3 == 0 {
                            CommAction::GlobalAverage
                        } else {
                            CommAction::Gossip
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            assert!(
                s_bus.scalars_sent < identity_scalars,
                "{label}: {} !< {identity_scalars}",
                s_bus.scalars_sent
            );
        }
    }
}

#[test]
fn pure_gossip_bus_needs_no_allreduce_edges_and_global_average_errors() {
    // The sparse-setup satellite: a gossip-only bus is built without the
    // all-to-all chunk-exchange edges; asking it to global-average is a
    // clean Err, not a hang.
    let topo = Topology::ring(6);
    let mut backend = BusBackend::new(
        &topo,
        8,
        &NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6),
        8,
        Compression::None,
        false,
    );
    let pool = WorkerPool::new(2);
    let mut params = ParamMatrix::random(&mut Rng::new(3), 6, 8, 1.0);
    backend.gossip(&mut params, &pool).unwrap();
    let err = backend.global_average(&mut params, &pool).unwrap_err().to_string();
    assert!(err.contains("without all-reduce edges"), "{err}");
}

#[test]
fn bus_time_charge_is_per_message() {
    // One ring gossip round: busiest node sends 2 messages of d scalars =>
    // sim = 2 alpha + 2 d theta (cost_dim == d, so no scaling).
    let topo = Topology::ring(6);
    let d = 100;
    let cost = CostModel::generic();
    let costs = NodeCosts::homogeneous(cost, 6);
    let mut backend = BusBackend::new(&topo, d, &costs, d, Compression::None, true);
    let pool = WorkerPool::new(1);
    let mut params = ParamMatrix::random(&mut Rng::new(5), 6, d, 1.0);
    let charge = backend.gossip(&mut params, &pool).unwrap();
    let expect = 2.0 * cost.alpha + 2.0 * d as f64 * cost.theta;
    assert!(
        (charge.stats.sim_seconds - expect).abs() < 1e-12,
        "{} vs {expect}",
        charge.stats.sim_seconds
    );
    // Per-node billing: every ring node sends the same 2 messages, so each
    // node's charge equals the aggregate; barriers are the clocks' job.
    assert_eq!(charge.node_seconds.len(), 6);
    for &s in &charge.node_seconds {
        assert!((s - expect).abs() < 1e-12);
    }
    assert_eq!(charge.stats.barrier_wait, 0.0);
}

#[test]
fn bus_bills_a_link_straggler_per_node() {
    // Node 2's alpha/compute scaled 4x: its gossip messages cost 4x the
    // latency, every other node's charge is unchanged, and the aggregate
    // sim_seconds is the straggler's (critical path of the action).
    let topo = Topology::ring(6);
    let d = 100;
    let base = CostModel::generic();
    let costs = NodeCosts::homogeneous(base, 6).with_straggler(2, 4.0).unwrap();
    let mut backend = BusBackend::new(&topo, d, &costs, d, Compression::None, true);
    let pool = WorkerPool::new(2);
    let mut params = ParamMatrix::random(&mut Rng::new(5), 6, d, 1.0);
    let charge = backend.gossip(&mut params, &pool).unwrap();
    let plain = 2.0 * base.alpha + 2.0 * d as f64 * base.theta;
    let slow = 2.0 * (4.0 * base.alpha) + 2.0 * d as f64 * base.theta;
    for (i, &s) in charge.node_seconds.iter().enumerate() {
        let expect = if i == 2 { slow } else { plain };
        assert!((s - expect).abs() < 1e-12, "node {i}: {s} vs {expect}");
    }
    assert!((charge.stats.sim_seconds - slow).abs() < 1e-12);
}

#[test]
fn out_neighbors_invert_the_dense_weight_matrix_on_every_kind_and_round() {
    // The sparse-sender-table contract the bus builds its edges from:
    // node i must transmit to j at round r exactly when the dense W of
    // that round gives j a non-zero weight on i (i.e. j listens to i) —
    // including the DIRECTED one-peer graph, where the transmit target is
    // the inverse hop, not the in-neighbor. Checked against the dense
    // matrix on every kind and every round of the cycle.
    use gossip_pga::topology::TopologyKind;
    for n in [1usize, 2, 4, 5, 8, 9] {
        let kinds = [
            TopologyKind::Ring,
            TopologyKind::Grid,
            TopologyKind::Hypercube,
            TopologyKind::Star,
            TopologyKind::Full,
            TopologyKind::StaticExponential,
            TopologyKind::OnePeerExponential,
        ];
        for kind in kinds {
            if kind == TopologyKind::Hypercube && !n.is_power_of_two() {
                continue;
            }
            let topo = Topology::new(kind, n);
            for r in 0..topo.rounds() {
                let w = topo.weight_matrix(r);
                for i in 0..n {
                    let out = topo.out_neighbors(i, r);
                    // Sorted, deduplicated, never self.
                    assert!(out.windows(2).all(|p| p[0] < p[1]), "{kind:?} n={n} r={r}");
                    assert!(!out.contains(&i), "{kind:?} n={n} r={r}: self in out set");
                    for j in 0..n {
                        let listens = j != i && w[(j, i)] != 0.0;
                        let sends = out.contains(&j);
                        assert_eq!(
                            listens, sends,
                            "{kind:?} n={n} round {r}: W[({j},{i})]={} but {} sends {:?}",
                            w[(j, i)],
                            i,
                            out
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Trainer-level equivalence (needs the AOT artifacts, like the integration
// tests): the full training loop — PJRT gradients, optimizer, schedule —
// produces identical runs on either backend, and the trainer's reported
// CommStats match the analytic schedule counts.
// ---------------------------------------------------------------------------

fn trainer_with_backend(
    rt: &Arc<Runtime>,
    algo: AlgorithmKind,
    backend: BackendKind,
    threads: usize,
) -> Trainer {
    let n = 4;
    let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 17).unwrap();
    let opts = TrainerOptions {
        algorithm: algo,
        topology: Topology::ring(n),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.9,
        nesterov: true,
        seed: 17,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 5,
        threads,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    };
    Trainer::new(workload, init, opts).unwrap()
}

#[test]
fn trainer_on_bus_matches_trainer_on_shared() {
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let steps = 12;
    for algo in [AlgorithmKind::GossipPga, AlgorithmKind::Gossip, AlgorithmKind::Local] {
        let mut shared = trainer_with_backend(&rt, algo, BackendKind::Shared, 1);
        let mut bus = trainer_with_backend(&rt, algo, BackendKind::Bus, 3);
        let mut actions = Vec::new();
        for k in 0..steps {
            let a = shared.step_once().unwrap();
            let b = bus.step_once().unwrap();
            assert_eq!(a, b, "{algo:?} step {k}: actions diverged");
            assert_eq!(
                shared.mean_loss(),
                bus.mean_loss(),
                "{algo:?} step {k}: losses diverged"
            );
            actions.push(a);
        }
        for i in 0..shared.n() {
            assert_eq!(
                shared.worker_params(i),
                bus.worker_params(i),
                "{algo:?}: worker {i} diverged across backends"
            );
        }
        let s_shared = shared.comm_stats();
        let s_bus = bus.comm_stats();
        assert_eq!(
            (s_shared.scalars_sent, s_shared.msgs),
            (s_bus.scalars_sent, s_bus.msgs),
            "{algo:?}: trainer traffic accounting diverged"
        );
        let topo = Topology::ring(4);
        let d = shared.param_matrix().d();
        assert_eq!(
            (s_bus.scalars_sent, s_bus.msgs),
            schedule_traffic(&topo, d, &actions),
            "{algo:?}: trainer CommStats != tab17-style analytic counts"
        );
        assert_eq!(shared.gossip_clock(), bus.gossip_clock(), "{algo:?}");
    }
}

#[test]
fn checkpoint_resumes_comm_totals_and_compressor_residuals_exactly() {
    // The v3 checkpoint tail: (a) cumulative traffic counters continue
    // across a resume instead of restarting at zero; (b) a compressed run
    // (per-node error-feedback residuals) resumes bit-exactly.
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    for backend in [BackendKind::Shared, BackendKind::Bus] {
        let mk = || {
            let (workload, init) = logreg_workload(rt.clone(), 4, 256, true, 23).unwrap();
            let opts = TrainerOptions {
                algorithm: AlgorithmKind::GossipPga,
                topology: Topology::ring(4),
                period: 4,
                aga_init_period: 2,
                aga_warmup: 4,
                lr: LrSchedule::Const { lr: 0.2 },
                momentum: 0.9,
                nesterov: true,
                seed: 23,
                slowmo: Default::default(),
                cost: CostModel::calibrated_resnet50(),
                cost_dim: 25_500_000,
                node_costs: None,
                stealing: false,
                pin: false,
                pipeline_depth: 1,
                log_every: 5,
                threads: 2,
                regime: Regime::Bsp,
                max_staleness: 0,
                backend,
                compression: Compression::TopK { frac: 0.5 },
                round_timeout: 0.0,
                listen: "127.0.0.1:0".to_string(),
            };
            Trainer::new(workload, init, opts).unwrap()
        };
        let mut a = mk();
        for _ in 0..9 {
            a.step_once().unwrap();
        }
        let ck = a.checkpoint().unwrap();
        assert!(
            ck.ef_residuals.is_some(),
            "{backend:?}: compressed run must checkpoint its residuals"
        );
        let at_ck = ck.comm.expect("v3 checkpoints carry comm totals");
        assert_eq!(at_ck, a.comm_stats(), "{backend:?}: snapshot != live totals");
        assert!(at_ck.scalars_sent > 0, "{backend:?}: 9 steps must have sent traffic");
        for _ in 0..9 {
            a.step_once().unwrap();
        }

        let mut b = mk();
        b.restore(&ck).unwrap();
        assert_eq!(
            b.comm_stats(),
            at_ck,
            "{backend:?}: restored totals must continue from the snapshot"
        );
        for _ in 0..9 {
            b.step_once().unwrap();
        }
        for i in 0..a.n() {
            assert_eq!(
                a.worker_params(i),
                b.worker_params(i),
                "{backend:?}: compressed resume diverged at worker {i}"
            );
        }
        let (sa, sb) = (a.comm_stats(), b.comm_stats());
        assert_eq!(
            (sa.scalars_sent, sa.msgs),
            (sb.scalars_sent, sb.msgs),
            "{backend:?}: resumed traffic accounting diverged"
        );
    }
}

#[test]
fn restoring_compressed_checkpoint_into_uncompressed_run_is_rejected() {
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let (workload, init) = logreg_workload(rt.clone(), 4, 256, true, 23).unwrap();
    let mut opts = TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::ring(4),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.0,
        nesterov: false,
        seed: 23,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 5,
        threads: 1,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::Int8 { block: 64 },
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    };
    let mut compressed = Trainer::new(workload, init, opts.clone()).unwrap();
    for _ in 0..3 {
        compressed.step_once().unwrap();
    }
    let ck = compressed.checkpoint().unwrap();
    assert!(ck.ef_residuals.is_some());
    assert_eq!(ck.ef_compression, Some(Compression::Int8 { block: 64 }));
    // Restoring into an uncompressed run must be rejected...
    let mut plain_opts = opts.clone();
    plain_opts.compression = Compression::None;
    let (workload, init) = logreg_workload(rt.clone(), 4, 256, true, 23).unwrap();
    let mut plain = Trainer::new(workload, init, plain_opts).unwrap();
    let err = plain.restore(&ck).unwrap_err().to_string();
    assert!(err.contains("compression"), "{err}");
    // ...and so must a run with a different codec (or parameters): the
    // residuals are meaningless under another compression scheme.
    opts.compression = Compression::TopK { frac: 0.5 };
    let (workload, init) = logreg_workload(rt, 4, 256, true, 23).unwrap();
    let mut other_codec = Trainer::new(workload, init, opts).unwrap();
    let err = other_codec.restore(&ck).unwrap_err().to_string();
    assert!(err.contains("this run uses"), "{err}");
}

#[test]
fn overlap_on_bus_runs_async_with_zero_fallbacks_and_matches_bsp() {
    // ISSUE 9: the bus core overlaps uncompressed gossip for real now —
    // the old sync downgrade is gone. --overlap must keep the exact BSP
    // trajectory at every drained boundary AND report zero fallbacks.
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let mut bsp = trainer_with_backend(&rt, AlgorithmKind::GossipPga, BackendKind::Bus, 2);
    let (workload, init) = logreg_workload(rt.clone(), 4, 256, true, 17).unwrap();
    let opts_overlap = TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::ring(4),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.9,
        nesterov: true,
        seed: 17,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 5,
        threads: 2,
        regime: Regime::Overlap,
        max_staleness: 0,
        backend: BackendKind::Bus,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    };
    let opts_compressed = TrainerOptions {
        compression: Compression::TopK { frac: 0.5 },
        ..opts_overlap.clone()
    };
    let mut ovl = Trainer::new(workload, init, opts_overlap).unwrap();
    for _ in 0..9 {
        bsp.step_once().unwrap();
        ovl.step_once().unwrap();
    }
    ovl.drain().unwrap();
    for i in 0..bsp.n() {
        assert_eq!(bsp.worker_params(i), ovl.worker_params(i), "worker {i}");
    }
    assert_eq!(bsp.sim_seconds(), ovl.sim_seconds());
    // Zero fallbacks: all 7 gossip rounds of the 9 steps (H = 4 => 2
    // global averages) went down the real async path, and no stale frame
    // ever landed on a clean single-process run.
    assert_eq!(ovl.comm_stats().fallback_rounds, 0, "fallback tally");
    assert_eq!(ovl.comm_stats().stale_frames_dropped, 0, "stale tally");
    assert_eq!(bsp.comm_stats().fallback_rounds, 0);

    // Compressed transmit is the ONE remaining sync downgrade (error
    // feedback is ordered): same schedule, every gossip round tallied.
    let (workload_c, init_c) = logreg_workload(rt, 4, 256, true, 17).unwrap();
    let mut cmp = Trainer::new(workload_c, init_c, opts_compressed).unwrap();
    for _ in 0..9 {
        cmp.step_once().unwrap();
    }
    cmp.drain().unwrap();
    assert_eq!(cmp.comm_stats().fallback_rounds, 7, "compressed fallback tally");
}
