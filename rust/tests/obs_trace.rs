//! Observability-plane acceptance suite (`rust/src/obs/` — the trace
//! plane, the chrome exporter, the counter registry and the warn-once
//! sink).
//!
//! Three contracts under test:
//!
//! * **Bit-equality** — arming `--trace` changes NOTHING about a run:
//!   parameters, billed sim seconds and traffic are bit-identical to the
//!   untraced run on every backend (shared / bus / tcp), synchronous and
//!   pipelined (`--pipeline-depth` 1 and 4). Probes read and annotate;
//!   they never touch arithmetic.
//! * **Ring discipline** — overflow drops the OLDEST spans, the eviction
//!   is tallied (`spans_dropped`), and the surviving window is the most
//!   recent pushes in push order.
//! * **Schema** — the exported document round-trips through
//!   `dump → parse → validate` (valid trace-event fields, monotone `ts`
//!   per tid), `summarize` renders a per-phase table from it, and `load`
//!   reports actionable errors on missing / malformed / non-trace files
//!   (what the `trace` subcommand surfaces).
//!
//! Tracing state is process-global, so every test that arms a session
//! holds the file-local `SERIAL` mutex (the test binary runs tests on
//! parallel threads). The backend replay layers need no AOT artifacts;
//! the trainer-level test skips gracefully when `make artifacts` has not
//! run. `scripts/verify.sh` step 12 runs this suite at
//! `PROPTEST_CASES=16`.

use std::collections::VecDeque;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::comm::{
    BackendKind, BusBackend, CommBackend, CommStats, Compression, PendingComm, SharedBackend,
    TcpBackend,
};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::jsonio::Json;
use gossip_pga::obs::{self, chrome, Counters, Phase};
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

/// Tracing sessions are process-global; the test harness runs tests on
/// parallel threads. Every test that arms (or asserts the absence of) a
/// session holds this.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` on a watchdog thread; FAIL (don't hang) if it overruns.
fn with_timeout(secs: u64, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        f();
        tx.send(()).ok();
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().expect("watchdog body"),
        Err(_) => panic!("timed out after {secs}s — the traced run hung instead of failing"),
    }
}

/// Deterministic pseudo-gradient (same as the overlap_wire suite), applied
/// identically on every replica so any divergence comes from tracing.
fn perturb(params: &mut ParamMatrix, k: u64) {
    let mut rng = Rng::new(0xD1CE ^ k.wrapping_mul(0x9E37_79B9));
    let noise = rng.normal_vec(params.n() * params.d(), 0.05);
    for (p, g) in params.as_mut_slice().iter_mut().zip(&noise) {
        *p -= g;
    }
}

/// An uncompressed backend of `kind` with the given pipeline depth — the
/// three planes behind the one trait object the tracing probes decorate.
fn backend(kind: BackendKind, topo: &Topology, d: usize, depth: usize) -> Box<dyn CommBackend> {
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    match kind {
        BackendKind::Shared => Box::new(SharedBackend::with_depth(
            topo,
            d,
            &costs,
            d,
            Compression::None,
            depth,
        )),
        BackendKind::Bus => Box::new(BusBackend::with_depth(
            topo,
            d,
            &costs,
            d,
            Compression::None,
            true,
            depth,
        )),
        BackendKind::Tcp => Box::new(
            TcpBackend::new_loopback_with_depth(
                topo,
                d,
                &costs,
                d,
                Compression::None,
                true,
                "127.0.0.1:0",
                depth,
            )
            .unwrap(),
        ),
    }
}

/// Replay 3 periods of the PGA schedule — H gossip rounds (synchronous
/// when `depth == 0`, pipelined otherwise), a FIFO drain, one global
/// average, a perturbation — returning the final matrix, total billed sim
/// seconds and cumulative traffic. Identical whether or not a tracing
/// session is armed around the call: that is the contract under test.
fn replay(
    kind: BackendKind,
    topo: &Topology,
    d: usize,
    h: usize,
    depth: usize,
    threads: usize,
) -> (ParamMatrix, f64, CommStats) {
    let mut backend = backend(kind, topo, d, depth.max(1));
    let pool = WorkerPool::new(threads);
    let mut params = ParamMatrix::random(&mut Rng::new(47), topo.n, d, 1.0);
    let mut sim = 0.0;
    let mut pending: VecDeque<PendingComm> = VecDeque::new();
    for burst in 0..3u64 {
        for _ in 0..h {
            if depth == 0 {
                sim += backend.gossip(&mut params, &pool).unwrap().stats.sim_seconds;
            } else {
                if pending.len() == depth {
                    let oldest = pending.pop_front().unwrap();
                    sim += backend.finish(&mut params, oldest).unwrap().stats.sim_seconds;
                }
                let p = unsafe { backend.gossip_async(&params, &pool).unwrap() }
                    .expect("uncompressed backends support async gossip");
                pending.push_back(p);
            }
        }
        while let Some(oldest) = pending.pop_front() {
            sim += backend.finish(&mut params, oldest).unwrap().stats.sim_seconds;
        }
        sim += backend.global_average(&mut params, &pool).unwrap().stats.sim_seconds;
        perturb(&mut params, burst);
    }
    (params, sim, backend.total())
}

/// Count the collected spans of one phase (they all land on the replay's
/// driving thread, but the collection is flattened anyway).
fn count_phase(data: &obs::TraceData, phase: Phase) -> usize {
    data.threads.iter().flat_map(|t| &t.spans).filter(|s| s.phase == phase).count()
}

// ---------------------------------------------------------------------------
// Bit-equality: tracing observes, never perturbs.
// ---------------------------------------------------------------------------

/// The headline contract, per backend: the traced replay is bit-identical
/// to the untraced one (params, billed clocks, traffic), AND the session
/// actually recorded the phases the schedule ran.
fn traced_replay_matches_untraced(kind: BackendKind) {
    let _g = serial();
    let (d, h) = (33, 3);
    let topo = Topology::ring(5);
    for depth in [0usize, 1, 4] {
        assert!(!obs::enabled(), "a previous test leaked an armed session");
        let (want, want_sim, want_total) = replay(kind, &topo, d, h, depth, 2);

        obs::start(1 << 16);
        let (got, got_sim, got_total) = replay(kind, &topo, d, h, depth, 2);
        let data = obs::stop_and_collect();

        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "{kind:?} depth={depth}: tracing perturbed the parameters"
        );
        assert_eq!(
            got_sim.to_bits(),
            want_sim.to_bits(),
            "{kind:?} depth={depth}: tracing perturbed the billed clocks"
        );
        assert_eq!(
            got_total, want_total,
            "{kind:?} depth={depth}: tracing perturbed the traffic totals"
        );

        // The session saw the schedule: one global average per burst, and
        // on the synchronous sweep every gossip round. Pipelined rounds
        // are issued/finished below the trait wrappers, so depth > 0
        // records the boundary collectives only.
        assert_eq!(count_phase(&data, Phase::GlobalAverage), 3, "{kind:?} depth={depth}");
        if depth == 0 {
            assert_eq!(count_phase(&data, Phase::Gossip), 3 * h, "{kind:?}");
        }
        if kind != BackendKind::Shared {
            // The message-passing global average records its sub-phases.
            assert_eq!(count_phase(&data, Phase::ReduceScatter), 3, "{kind:?}");
            assert_eq!(count_phase(&data, Phase::AllGather), 3, "{kind:?}");
        }
        for s in data.threads.iter().flat_map(|t| &t.spans) {
            assert_eq!(s.node, obs::CLUSTER, "backend collectives are cluster-wide");
        }
    }
}

#[test]
fn traced_shared_replay_is_bit_identical_to_untraced() {
    traced_replay_matches_untraced(BackendKind::Shared);
}

#[test]
fn traced_bus_replay_is_bit_identical_to_untraced() {
    traced_replay_matches_untraced(BackendKind::Bus);
}

#[test]
fn traced_tcp_replay_is_bit_identical_to_untraced() {
    let _g = serial();
    with_timeout(240, || {
        // Re-entrant serialization is not possible with a plain Mutex;
        // the outer guard (held by this test thread) already excludes the
        // other tests, so the watchdog body runs the shared helper's
        // logic inline rather than re-locking.
        let (d, h) = (21, 3);
        let topo = Topology::ring(4);
        for depth in [0usize, 4] {
            let (want, want_sim, want_total) = replay(BackendKind::Tcp, &topo, d, h, depth, 2);
            obs::start(1 << 16);
            let (got, got_sim, got_total) = replay(BackendKind::Tcp, &topo, d, h, depth, 2);
            let data = obs::stop_and_collect();
            assert_eq!(got.as_slice(), want.as_slice(), "tcp depth={depth}: params");
            assert_eq!(got_sim.to_bits(), want_sim.to_bits(), "tcp depth={depth}: clocks");
            assert_eq!(got_total, want_total, "tcp depth={depth}: traffic");
            assert_eq!(count_phase(&data, Phase::GlobalAverage), 3, "tcp depth={depth}");
            assert_eq!(count_phase(&data, Phase::ReduceScatter), 3, "tcp depth={depth}");
        }
    });
}

/// Trainer-level contract on top of the backend one: a traced training
/// run (overlap regime, so the sample/grad/issue/drain probes all fire)
/// lands bit-identically, and the session covers the coordinator phases.
/// Skips gracefully when the AOT artifacts are absent.
#[test]
fn traced_trainer_run_is_bit_identical_and_covers_coordinator_phases() {
    let _g = serial();
    let Ok(rt) = Runtime::load_default() else {
        eprintln!("artifacts absent — run `make artifacts` to enable the trainer-level test");
        return;
    };
    let rt = Arc::new(rt);
    let steps = 10;
    let run = |rt: &Arc<Runtime>| -> Trainer {
        let n = 4;
        let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 41).unwrap();
        let opts = TrainerOptions {
            algorithm: AlgorithmKind::GossipPga,
            topology: Topology::ring(n),
            period: 4,
            aga_init_period: 2,
            aga_warmup: 4,
            lr: LrSchedule::Const { lr: 0.2 },
            momentum: 0.9,
            nesterov: true,
            seed: 41,
            slowmo: Default::default(),
            cost: CostModel::calibrated_resnet50(),
            cost_dim: 25_500_000,
            node_costs: None,
            stealing: false,
            pin: false,
            pipeline_depth: 2,
            log_every: 5,
            threads: 2,
            regime: Regime::Overlap,
            max_staleness: 0,
            backend: BackendKind::Bus,
            compression: Compression::None,
            round_timeout: 0.0,
            listen: "127.0.0.1:0".to_string(),
        };
        Trainer::new(workload, init, opts).unwrap()
    };

    let mut want = run(&rt);
    for _ in 0..steps {
        want.step_once().unwrap();
    }
    let want_loss = want.global_loss().unwrap(); // drains

    obs::start(1 << 16);
    let mut got = run(&rt);
    for _ in 0..steps {
        got.step_once().unwrap();
    }
    let got_loss = got.global_loss().unwrap();
    let counters = got.counters(); // BEFORE stop: spans_dropped reads the live ring
    let data = obs::stop_and_collect();

    assert_eq!(
        got.param_matrix().as_slice(),
        want.param_matrix().as_slice(),
        "tracing perturbed the training trajectory"
    );
    assert_eq!(got_loss.to_bits(), want_loss.to_bits(), "tracing perturbed the loss");
    assert_eq!(got.sim_seconds(), want.sim_seconds(), "tracing perturbed the clocks");
    assert_eq!(got.comm_stats(), want.comm_stats(), "tracing perturbed the traffic");
    assert_eq!(counters.spans_dropped, 0, "the ring was big enough for this run");

    for phase in [Phase::Sample, Phase::Grad, Phase::GossipIssue, Phase::Drain, Phase::GlobalAverage]
    {
        assert!(
            count_phase(&data, phase) > 0,
            "traced overlap run recorded no {} spans",
            phase.name()
        );
    }
}

// ---------------------------------------------------------------------------
// Ring discipline: drop-oldest, tallied.
// ---------------------------------------------------------------------------

#[test]
fn ring_overflow_keeps_the_newest_spans_and_counts_the_evicted() {
    let _g = serial();
    obs::start(3);
    for i in 0..8u32 {
        obs::instant(Phase::EvMix, 7000 + i, i as f64);
    }
    assert_eq!(obs::thread_spans_dropped(), 5, "5 of 8 pushes evicted from a 3-ring");
    let data = obs::stop_and_collect();
    let mine: Vec<u32> = data
        .threads
        .iter()
        .flat_map(|t| &t.spans)
        .filter(|s| (7000..7008).contains(&s.node))
        .map(|s| s.node)
        .collect();
    assert_eq!(mine, vec![7005, 7006, 7007], "survivors are the newest, in push order");
    assert_eq!(data.total_dropped(), 5);
    // The eviction tally flows into the exported counter track.
    let counters = Counters { spans_dropped: data.total_dropped(), ..Default::default() };
    let doc = chrome::export(&data, &counters);
    let dumped = doc.dump();
    assert!(dumped.contains("\"spans_dropped\":5"), "{dumped}");
}

// ---------------------------------------------------------------------------
// Chrome schema: export → dump → parse → validate → summarize.
// ---------------------------------------------------------------------------

#[test]
fn chrome_export_round_trips_and_summarizes() {
    let _g = serial();
    obs::start(64);
    {
        let mut sp = obs::span(Phase::Gossip, obs::CLUSTER);
        sp.set_sim(0.125);
    }
    {
        let mut sp = obs::span(Phase::GlobalAverage, obs::CLUSTER);
        sp.set_sim(0.5);
    }
    obs::instant(Phase::EvDeliver, 2, 1.75);
    obs::instant(Phase::EvMix, 2, 2.0);
    let data = obs::stop_and_collect();
    assert!(data.total_spans() >= 4);

    let counters = Counters {
        stale_frames: 1,
        peer_drops: 2,
        row_renorms: 3,
        fallback_rounds: 4,
        spans_dropped: 0,
        pool_panics: 0,
    };
    let doc = chrome::export(&data, &counters);
    chrome::validate(&doc).expect("fresh export validates");

    // The canonical round-trip the `trace` subcommand performs.
    let reparsed = Json::parse(&doc.dump()).expect("dumped trace parses");
    chrome::validate(&reparsed).expect("reparsed trace validates");

    let summary = chrome::summarize(&reparsed).expect("summary renders");
    for needle in ["gossip", "global_average", "ev_deliver", "cluster", "node 2", "counters:"] {
        assert!(summary.contains(needle), "summary missing '{needle}':\n{summary}");
    }
    assert!(summary.contains("peer_drops=2"), "{summary}");

    // Every X event names a known phase, and the metadata names pid 0.
    let events = reparsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    let known: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()) == Some("X") {
            let name = ev.get("name").and_then(|n| n.as_str()).unwrap();
            assert!(known.contains(&name), "unknown phase '{name}' in export");
        }
    }
    assert!(doc.dump().contains("\"cluster\""), "pid 0 metadata names the cluster track");
}

#[test]
fn validate_rejects_non_monotone_and_malformed_events() {
    // Backwards ts on one tid.
    let backwards = Json::parse(
        r#"{"traceEvents":[
            {"name":"a","ph":"X","pid":0,"tid":0,"ts":10.0,"dur":1.0},
            {"name":"b","ph":"X","pid":0,"tid":0,"ts":5.0,"dur":1.0}
        ]}"#,
    )
    .unwrap();
    let err = format!("{:#}", chrome::validate(&backwards).unwrap_err());
    assert!(err.contains("goes backwards"), "{err}");

    // Interleaved tids are each monotone: fine.
    let interleaved = Json::parse(
        r#"{"traceEvents":[
            {"name":"a","ph":"X","pid":0,"tid":0,"ts":10.0,"dur":1.0},
            {"name":"b","ph":"X","pid":0,"tid":1,"ts":5.0,"dur":1.0},
            {"name":"c","ph":"X","pid":0,"tid":0,"ts":11.0,"dur":0.0}
        ]}"#,
    )
    .unwrap();
    chrome::validate(&interleaved).expect("per-tid monotonicity only");

    // Unknown phase type, missing field, negative dur.
    for (body, needle) in [
        (r#"{"traceEvents":[{"name":"a","ph":"Z","pid":0,"tid":0,"ts":0.0}]}"#, "unknown phase"),
        (r#"{"traceEvents":[{"name":"a","ph":"X","pid":0,"tid":0}]}"#, "missing field 'ts'"),
        (
            r#"{"traceEvents":[{"name":"a","ph":"X","pid":0,"tid":0,"ts":0.0,"dur":-1.0}]}"#,
            "negative dur",
        ),
        (r#"{"notTraceEvents":[]}"#, "missing 'traceEvents'"),
    ] {
        let doc = Json::parse(body).unwrap();
        let err = format!("{:#}", chrome::validate(&doc).unwrap_err());
        assert!(err.contains(needle), "'{needle}' not in '{err}'");
    }
}

// ---------------------------------------------------------------------------
// `trace` subcommand error surface (chrome::load is what it calls).
// ---------------------------------------------------------------------------

#[test]
fn trace_file_load_reports_actionable_errors() {
    let dir = std::env::temp_dir();
    let tag = std::process::id();

    // Missing file.
    let missing = dir.join(format!("obs_trace_missing_{tag}.json"));
    let err = format!("{:#}", chrome::load(&missing).unwrap_err());
    assert!(err.contains("cannot read trace file"), "{err}");

    // Malformed JSON.
    let malformed = dir.join(format!("obs_trace_malformed_{tag}.json"));
    std::fs::write(&malformed, "{not json").unwrap();
    let err = format!("{:#}", chrome::load(&malformed).unwrap_err());
    assert!(err.contains("not valid JSON"), "{err}");
    std::fs::remove_file(&malformed).ok();

    // Valid JSON, not a trace document.
    let nontrace = dir.join(format!("obs_trace_nontrace_{tag}.json"));
    std::fs::write(&nontrace, "{\"hello\": 1}").unwrap();
    let err = format!("{:#}", chrome::load(&nontrace).unwrap_err());
    assert!(err.contains("not a chrome trace-event document"), "{err}");
    std::fs::remove_file(&nontrace).ok();

    // A real export loads back.
    let _g = serial();
    obs::start(8);
    obs::instant(Phase::EvReady, 1, 0.0);
    let data = obs::stop_and_collect();
    let good = dir.join(format!("obs_trace_good_{tag}.json"));
    std::fs::write(&good, chrome::export(&data, &Counters::default()).dump()).unwrap();
    let doc = chrome::load(&good).expect("a written trace loads back");
    assert!(chrome::summarize(&doc).is_ok());
    std::fs::remove_file(&good).ok();
}

// ---------------------------------------------------------------------------
// Warn-once: the swappable sink is assertable from outside the crate.
// ---------------------------------------------------------------------------

#[test]
fn warn_once_capture_asserts_exactly_one_firing() {
    let cap = obs::capture_warnings();
    assert!(gossip_pga::warn_once!("obs-trace.integration", "fired with value {}", 7));
    assert!(!gossip_pga::warn_once!("obs-trace.integration", "suppressed"));
    assert!(!obs::warn_once!("obs-trace.integration", "suppressed via the obs re-export"));
    let got = cap.drain();
    let mine: Vec<&String> =
        got.iter().filter(|m| m.starts_with("[obs-trace.integration]")).collect();
    assert_eq!(mine.len(), 1, "exactly one firing per key: {got:?}");
    assert!(mine[0].contains("fired with value 7"));
}

// ---------------------------------------------------------------------------
// BENCH_10 schema gate (same pattern as the overlap_wire BENCH_9 gate).
// ---------------------------------------------------------------------------

#[test]
fn bench_ten_schema_holds_when_the_artifact_exists() {
    // The bench may not have run on this box; when BENCH_10.json IS there,
    // hold it to the schema EXPERIMENTS.md §Observability reads.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_10.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("BENCH_10.json absent — run `cargo bench --bench perf_hotpath` to emit it");
        return;
    };
    let doc = Json::parse(&text).expect("BENCH_10.json parses");
    assert_eq!(
        doc.get("bench").and_then(|j| match j {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }),
        Some("obs_trace")
    );
    let Some(Json::Arr(rows)) = doc.get("tracing_rows") else {
        panic!("BENCH_10.json missing array 'tracing_rows'");
    };
    assert!(!rows.is_empty(), "'tracing_rows' must not be empty");
    for row in rows {
        for field in
            ["backend", "traced", "rounds", "n", "d", "mean_seconds", "spans", "bit_equal"]
        {
            assert!(row.get(field).is_some(), "tracing_rows row missing '{field}'");
        }
        // The in-bench bit-equality assertion must have actually held.
        assert_eq!(row.get("bit_equal"), Some(&Json::Bool(true)), "tracing_rows: bit_equal");
    }
}
