//! The pool's failure path, end to end: a job that PANICS must poison the
//! [`WorkerPool`] and surface as an `Err` from `Trainer::step_once` — never
//! a hang, never an abort. Every test here runs under a watchdog timeout so
//! a deadlock regression fails loudly instead of wedging the suite (no
//! `#[should_panic]` anywhere: panics stay on the pool's worker threads).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::comm::{BackendKind, Compression};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::CostModel;
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::optim::LrSchedule;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

/// Run `f` on a watchdog thread; FAIL (don't hang) if it overruns.
fn with_timeout(secs: u64, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        f();
        tx.send(()).ok();
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().expect("watchdog body"),
        Err(_) => panic!("timed out after {secs}s — the pool hung instead of failing"),
    }
}

fn trainer(threads: usize) -> Trainer {
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let (workload, init) = logreg_workload(rt, 4, 256, true, 21).unwrap();
    let opts = TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::ring(4),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.0,
        nesterov: false,
        seed: 21,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 10,
        threads,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    };
    Trainer::new(workload, init, opts).unwrap()
}

#[test]
fn panicking_job_poisons_pool_and_step_once_returns_err() {
    with_timeout(120, || {
        for threads in [1usize, 3] {
            let mut t = trainer(threads);
            t.step_once().unwrap_or_else(|e| panic!("healthy step failed: {e:#}"));

            // Poison the engine the way a buggy worker closure would: a job
            // that panics mid-batch.
            let err = t
                .pool()
                .run(vec![|| -> anyhow::Result<()> { panic!("injected worker bug") }])
                .expect_err("a panicking job must report Err");
            assert!(
                err.to_string().contains("panicked"),
                "threads={threads}: {err:#}"
            );
            assert!(t.pool().poisoned(), "threads={threads}: pool must be poisoned");

            // The trainer must now FAIL its step as a clean Result — not
            // hang waiting for workers, not abort the process.
            let step = t.step_once();
            let msg = format!("{:#}", step.expect_err("step on a poisoned pool must Err"));
            assert!(msg.contains("poisoned"), "threads={threads}: {msg}");
        }
    });
}

#[test]
fn poisoned_pool_refuses_async_overlap_work_too() {
    with_timeout(120, || {
        let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
        let (workload, init) = logreg_workload(rt, 4, 256, true, 22).unwrap();
        let opts = TrainerOptions {
            algorithm: AlgorithmKind::Gossip, // gossips every step
            topology: Topology::ring(4),
            period: 4,
            aga_init_period: 2,
            aga_warmup: 4,
            lr: LrSchedule::Const { lr: 0.2 },
            momentum: 0.0,
            nesterov: false,
            seed: 22,
            slowmo: Default::default(),
            cost: CostModel::calibrated_resnet50(),
            cost_dim: 25_500_000,
            node_costs: None,
            stealing: false,
            pin: false,
            pipeline_depth: 1,
            log_every: 10,
            threads: 2,
            regime: Regime::Overlap,
            max_staleness: 0,
            backend: BackendKind::Shared,
            compression: Compression::None,
            round_timeout: 0.0,
            listen: "127.0.0.1:0".to_string(),
        };
        let mut t = Trainer::new(workload, init, opts).unwrap();
        t.step_once().unwrap(); // leaves a mix in flight
        t.drain().unwrap();
        let _ = t
            .pool()
            .run(vec![|| -> anyhow::Result<()> { panic!("injected worker bug") }]);
        assert!(t.pool().poisoned());
        // Both the pooled phases and the async gossip submission must
        // surface the poison as Err, and dropping the trainer (with
        // whatever is left) must not hang.
        assert!(t.step_once().is_err(), "overlap step on a poisoned pool must Err");
        drop(t);
    });
}

#[test]
fn standalone_pool_failure_path_is_hang_free() {
    // No artifacts needed: the pure exec-layer contract. One panicking job
    // in a 16-job batch across a small pool — the batch errs, later
    // batches err immediately, nothing hangs, and teardown joins cleanly.
    with_timeout(60, || {
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move || -> anyhow::Result<()> {
                    if i == 11 {
                        panic!("job {i} exploded");
                    }
                    Ok(())
                }
            })
            .collect();
        let err = pool.run(jobs).expect_err("batch with a panicking job");
        assert!(err.to_string().contains("panicked"), "{err:#}");
        assert!(pool.poisoned());
        assert!(pool.run(vec![|| Ok(())]).is_err(), "poisoned pool must refuse work");
        drop(pool); // join must not deadlock (covered by the watchdog)
    });
}
