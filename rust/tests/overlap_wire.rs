//! Overlap-on-the-wire acceptance suite (`BusCore::gossip_async` /
//! `finish` on the message-passing backends — `rust/src/comm/bus.rs`
//! shared by `BusBackend` and `TcpBackend`).
//!
//! Three contracts under test:
//!
//! * **Bit-equality** — uncompressed overlapped / depth-k pipelined
//!   gossip on the bus and on real loopback sockets is bit-identical to
//!   the same schedule run synchronously (BSP) at every drained
//!   boundary: the k·H global average, eval, checkpoint, and resume.
//!   `fallback_rounds` stays 0 on those runs — the old "overlap on bus
//!   runs synchronously" downgrade is gone.
//! * **Epoch hygiene** — a delayed frame from an aborted or
//!   already-drained round (a stale epoch tag) is discarded on receipt,
//!   tallied in `CommStats::stale_frames_dropped`, and never perturbs
//!   the trajectory — on either wire.
//! * **Billing** — overlapped rounds are billed analytically at issue
//!   time on the issued round schedule; the α–β bill must equal the
//!   measured synchronous charge exactly (asserted via `sim_seconds`).
//!
//! The backend replay layers need no AOT artifacts; the trainer-level
//! tests need `make artifacts` like the other integration suites. Every
//! socket test binds `127.0.0.1:0` (OS-assigned ports) and runs under a
//! watchdog so a deadlock regression fails loudly instead of wedging the
//! suite. `scripts/verify.sh` step 11 runs this suite at
//! `PROPTEST_CASES=16` under both `GOSSIP_PGA_TEST_THREADS=1` and `=4`.

use std::collections::VecDeque;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::comm::{
    BackendKind, BusBackend, CommBackend, Compression, PendingComm, TcpBackend,
};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::jsonio::Json;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

/// Run `f` on a watchdog thread; FAIL (don't hang) if it overruns.
fn with_timeout(secs: u64, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        f();
        tx.send(()).ok();
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().expect("watchdog body"),
        Err(_) => panic!("timed out after {secs}s — the overlapped wire hung instead of failing"),
    }
}

/// The pool sizes the suite sweeps: always 1 (inline execution) plus the
/// `GOSSIP_PGA_TEST_THREADS` pool (default 4) — the same env contract
/// `tests/properties.rs` uses, so verify.sh can pin both shapes.
fn pool_sizes() -> Vec<usize> {
    let t = std::env::var("GOSSIP_PGA_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4);
    if t <= 1 {
        vec![1]
    } else {
        vec![1, t]
    }
}

/// Deterministic pseudo-gradient, applied identically on every replica so
/// any divergence comes from the wire alone.
fn perturb(params: &mut ParamMatrix, k: u64) {
    let mut rng = Rng::new(0xD1CE ^ k.wrapping_mul(0x9E37_79B9));
    let noise = rng.normal_vec(params.n() * params.d(), 0.05);
    for (p, g) in params.as_mut_slice().iter_mut().zip(&noise) {
        *p -= g;
    }
}

/// Build an uncompressed message-passing backend of `kind` with the given
/// pipeline depth. Both constructors share `BusCore`, so the suite drives
/// them through one function and the type-erased trait object.
fn wire_backend(kind: BackendKind, topo: &Topology, d: usize, depth: usize) -> Box<dyn CommBackend> {
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    match kind {
        BackendKind::Bus => Box::new(BusBackend::with_depth(
            topo,
            d,
            &costs,
            d,
            Compression::None,
            true,
            depth,
        )),
        BackendKind::Tcp => Box::new(
            TcpBackend::new_loopback_with_depth(
                topo,
                d,
                &costs,
                d,
                Compression::None,
                true,
                "127.0.0.1:0",
                depth,
            )
            .unwrap(),
        ),
        BackendKind::Shared => unreachable!("this suite is about the message-passing wires"),
    }
}

// ---------------------------------------------------------------------------
// Backend layer: the k·H schedule, overlapped, on both wires.
// ---------------------------------------------------------------------------

/// Replay 3 periods of the PGA schedule — H gossip rounds (pipelined when
/// `depth > 0`, synchronous when `depth == 0`), a full FIFO drain, one
/// global average, a perturbation — returning the final matrix, the total
/// billed sim seconds, and the stale-frame tally.
fn wire_replay(
    kind: BackendKind,
    topo: &Topology,
    d: usize,
    h: usize,
    depth: usize,
    threads: usize,
) -> (ParamMatrix, f64, u64) {
    let mut backend = wire_backend(kind, topo, d, depth.max(1));
    let pool = WorkerPool::new(threads);
    let mut params = ParamMatrix::random(&mut Rng::new(47), topo.n, d, 1.0);
    let mut sim = 0.0;
    let mut pending: VecDeque<PendingComm> = VecDeque::new();
    for burst in 0..3u64 {
        for _ in 0..h {
            if depth == 0 {
                sim += backend.gossip(&mut params, &pool).unwrap().stats.sim_seconds;
            } else {
                if pending.len() == depth {
                    let oldest = pending.pop_front().unwrap();
                    sim += backend.finish(&mut params, oldest).unwrap().stats.sim_seconds;
                }
                let p = unsafe { backend.gossip_async(&params, &pool).unwrap() }
                    .expect("uncompressed wire backends support async gossip");
                pending.push_back(p);
            }
        }
        // The k·H boundary: drain everything FIFO, then the global barrier.
        while let Some(oldest) = pending.pop_front() {
            sim += backend.finish(&mut params, oldest).unwrap().stats.sim_seconds;
        }
        sim += backend.global_average(&mut params, &pool).unwrap().stats.sim_seconds;
        perturb(&mut params, burst);
    }
    (params, sim, backend.total().stale_frames_dropped)
}

#[test]
fn overlapped_bus_matches_bsp_at_every_period_boundary() {
    let (d, h) = (97, 5); // h > depth forces steady-state ring reuse
    for mk in [Topology::ring as fn(usize) -> Topology, Topology::one_peer_expo] {
        let topo = mk(6);
        for threads in pool_sizes() {
            let (want, want_sim, _) = wire_replay(BackendKind::Bus, &topo, d, h, 0, threads);
            for depth in [1usize, 2, 4] {
                let (got, got_sim, stale) =
                    wire_replay(BackendKind::Bus, &topo, d, h, depth, threads);
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "{:?} depth={depth} t={threads}: overlapped bus diverged from BSP",
                    topo.kind
                );
                // The analytic issue-time bill must equal the measured
                // synchronous charge — on a time-varying topology a wrong
                // round index shows up here even if the bits agree.
                assert_eq!(got_sim, want_sim, "{:?} depth={depth}: billing drifted", topo.kind);
                assert_eq!(stale, 0, "a clean run must drop no frames");
            }
        }
    }
}

#[test]
fn overlapped_tcp_matches_bsp_at_every_period_boundary() {
    // Same contract over real loopback sockets; one topology and depth
    // sweep keeps the socket count civil.
    with_timeout(240, || {
        let (d, h) = (61, 4);
        let topo = Topology::ring(5);
        for threads in pool_sizes() {
            let (want, want_sim, _) = wire_replay(BackendKind::Tcp, &topo, d, h, 0, threads);
            for depth in [1usize, 2, 4] {
                let (got, got_sim, stale) =
                    wire_replay(BackendKind::Tcp, &topo, d, h, depth, threads);
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "depth={depth} t={threads}: overlapped tcp diverged from BSP"
                );
                assert_eq!(got_sim, want_sim, "depth={depth}: billing drifted");
                assert_eq!(stale, 0, "a clean run must drop no frames");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Epoch hygiene: the stale-straggler regression, on both wires.
// ---------------------------------------------------------------------------

/// The regression body, generic over the wire: `BusCore<Endpoint>` (mpsc
/// channels) and `BusCore<TcpEndpoint>` (loopback sockets) share every
/// line of the epoch filter, so the test drives both through one closure.
fn stale_injection_roundtrip<W: gossip_pga::collective::Wire>(
    topo: &Topology,
    d: usize,
    mk: impl Fn() -> gossip_pga::comm::BusCore<W>,
) {
    let pool = WorkerPool::new(2);
    let mut clean = mk();
    let mut dirty = mk();
    let mut p_clean = ParamMatrix::random(&mut Rng::new(71), topo.n, d, 1.0);
    let mut p_dirty = ParamMatrix::random(&mut Rng::new(71), topo.n, d, 1.0);

    // A straggler from a round that never ran (epoch 99 — e.g. an aborted
    // attempt on a previous incarnation of the run) lands on the 0→1 edge
    // before an OVERLAPPED round is issued. Same-stream FIFO order means
    // the receiver must see (and discard) it before its real frame.
    dirty.inject_stale_frame(0, 1, 99, vec![1e30_f32; d]).unwrap();
    let pend = unsafe { dirty.gossip_async(&p_dirty, &pool).unwrap() }.expect("async supported");
    dirty.finish(&mut p_dirty, pend).unwrap();
    clean.gossip(&mut p_clean, &pool).unwrap();
    assert_eq!(p_dirty.as_slice(), p_clean.as_slice(), "stale frame perturbed the overlap round");
    assert_eq!(dirty.total().stale_frames_dropped, 1, "the discard must be tallied");
    assert_eq!(clean.total().stale_frames_dropped, 0);

    // A straggler from the superseded PRE-OVERLAP epoch (0 — the round
    // plane the async issue moved past) before a SYNCHRONOUS round: same
    // discard, same tally. A NaN payload proves discard means "never
    // touches the mix", not "mixed with weight zero".
    dirty.inject_stale_frame(0, 1, 0, vec![f32::NAN; d]).unwrap();
    dirty.gossip(&mut p_dirty, &pool).unwrap();
    clean.gossip(&mut p_clean, &pool).unwrap();
    assert_eq!(p_dirty.as_slice(), p_clean.as_slice(), "stale frame perturbed the sync round");
    assert_eq!(dirty.total().stale_frames_dropped, 2);

    // Everything the backends billed must agree too: injected frames land
    // outside every round's measurement window (sync rounds snapshot
    // traffic at entry; overlapped rounds bill analytically), so the
    // straggler never pollutes the α–β bill.
    assert_eq!(dirty.total().scalars_sent, clean.total().scalars_sent);
    assert_eq!(dirty.total().sim_seconds.to_bits(), clean.total().sim_seconds.to_bits());
}

#[test]
fn stale_frame_on_the_bus_is_discarded_counted_and_bit_harmless() {
    let topo = Topology::ring(5);
    let d = 9;
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    stale_injection_roundtrip(&topo, d, || {
        BusBackend::with_depth(&topo, d, &costs, d, Compression::None, false, 2)
    });
}

#[test]
fn stale_frame_on_the_socket_is_discarded_counted_and_bit_harmless() {
    with_timeout(240, || {
        let topo = Topology::ring(5);
        let d = 9;
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
        stale_injection_roundtrip(&topo, d, || {
            TcpBackend::new_loopback_with_depth(
                &topo,
                d,
                &costs,
                d,
                Compression::None,
                false,
                "127.0.0.1:0",
                2,
            )
            .unwrap()
        });
    });
}

#[test]
fn restore_total_rebaselines_the_stale_tally() {
    // Checkpoint-restore overwrites the cumulative counters; the delta
    // accounting under stale_frames_dropped must re-baseline, not re-count
    // pre-restore discards or lose post-restore ones.
    let topo = Topology::ring(4);
    let d = 6;
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    let pool = WorkerPool::new(1);
    let mut b = BusBackend::with_depth(&topo, d, &costs, d, Compression::None, false, 1);
    let mut params = ParamMatrix::random(&mut Rng::new(5), topo.n, d, 1.0);
    b.inject_stale_frame(0, 1, 7, vec![0.0; d]).unwrap();
    b.gossip(&mut params, &pool).unwrap();
    assert_eq!(b.total().stale_frames_dropped, 1);

    // The resumed run continues from a checkpointed tally of 40.
    let mut resumed = b.total();
    resumed.stale_frames_dropped = 40;
    b.restore_total(resumed);
    assert_eq!(b.total().stale_frames_dropped, 40, "restore overwrites the tally");
    b.inject_stale_frame(0, 1, 7, vec![0.0; d]).unwrap();
    b.gossip(&mut params, &pool).unwrap();
    assert_eq!(b.total().stale_frames_dropped, 41, "post-restore discards keep counting");
}

// ---------------------------------------------------------------------------
// Trainer layer: --overlap + --pipeline-depth on bus and tcp, with the
// checkpoint/resume drained boundaries.
// ---------------------------------------------------------------------------

fn opts(n: usize, backend: BackendKind, depth: usize, regime: Regime) -> TrainerOptions {
    TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::ring(n),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.9,
        nesterov: true,
        seed: 41,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: depth,
        log_every: 5,
        threads: 2,
        regime,
        max_staleness: 0,
        backend,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn trainer(rt: &Arc<Runtime>, backend: BackendKind, depth: usize, regime: Regime) -> Trainer {
    let n = 4;
    let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 41).unwrap();
    Trainer::new(workload, init, opts(n, backend, depth, regime)).unwrap()
}

fn trainer_overlap_matches_bsp(backend: BackendKind) {
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let steps = 14; // crosses several k·H boundaries
    let mut bsp = trainer(&rt, backend, 1, Regime::Bsp);
    for _ in 0..steps {
        bsp.step_once().unwrap();
    }
    let want_loss = bsp.global_loss().unwrap();
    for depth in [1usize, 2] {
        let mut t = trainer(&rt, backend, depth, Regime::Overlap);
        for _ in 0..steps {
            t.step_once().unwrap();
        }
        // global_loss drains first (eval is a drained boundary), so this
        // is exactly the comparison the contract promises.
        let got_loss = t.global_loss().unwrap();
        assert_eq!(t.pending_rounds(), 0, "depth={depth}: eval left rounds in flight");
        assert_eq!(
            t.param_matrix().as_slice(),
            bsp.param_matrix().as_slice(),
            "{backend:?} depth={depth}: overlap trajectory diverged from BSP"
        );
        assert_eq!(got_loss, want_loss, "{backend:?} depth={depth}: loss diverged");
        assert_eq!(t.sim_seconds(), bsp.sim_seconds(), "{backend:?} depth={depth}: clocks");
        // The headline satellite: the wire really overlaps now — zero
        // fallback rounds, zero stale frames on a clean run.
        let comm = t.comm_stats();
        assert_eq!(comm.fallback_rounds, 0, "{backend:?} depth={depth}: fallback tally");
        assert_eq!(comm.stale_frames_dropped, 0, "{backend:?} depth={depth}: stale tally");
    }
}

#[test]
fn trainer_overlap_on_bus_matches_bsp_with_zero_fallbacks() {
    trainer_overlap_matches_bsp(BackendKind::Bus);
}

#[test]
fn trainer_overlap_on_tcp_matches_bsp_with_zero_fallbacks() {
    with_timeout(480, || trainer_overlap_matches_bsp(BackendKind::Tcp));
}

fn mid_overlap_checkpoint_resumes_bit_exactly(backend: BackendKind) {
    // A checkpoint taken while a wire round is in flight must DRAIN the
    // pipeline (the snapshot is a BSP step boundary), and the restored run
    // must land where the uninterrupted run does — on a FRESH backend with
    // fresh channels/sockets, since the frames themselves are never
    // checkpointed, only the drained parameters.
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let depth = 2;
    let mut straight = trainer(&rt, backend, depth, Regime::Overlap);
    let mut interrupted = trainer(&rt, backend, depth, Regime::Overlap);
    let mut saw_inflight = false;
    for _ in 0..9 {
        straight.step_once().unwrap();
        interrupted.step_once().unwrap();
        saw_inflight |= interrupted.pending_rounds() > 0;
    }
    assert!(saw_inflight, "schedule never overlapped — the test lost its subject");
    let ck = interrupted.checkpoint().unwrap();
    assert_eq!(interrupted.pending_rounds(), 0, "checkpoint must drain, not drop");
    let mut resumed = trainer(&rt, backend, depth, Regime::Overlap);
    resumed.restore(&ck).unwrap();
    for _ in 0..7 {
        straight.step_once().unwrap();
        interrupted.step_once().unwrap();
        resumed.step_once().unwrap();
    }
    let _ = straight.global_loss().unwrap(); // drains all three
    let _ = interrupted.global_loss().unwrap();
    let _ = resumed.global_loss().unwrap();
    assert_eq!(
        interrupted.param_matrix().as_slice(),
        straight.param_matrix().as_slice(),
        "{backend:?}: checkpointing mid-run changed the trajectory"
    );
    assert_eq!(
        resumed.param_matrix().as_slice(),
        straight.param_matrix().as_slice(),
        "{backend:?}: restore did not resume bit-exactly"
    );
    assert_eq!(resumed.gossip_clock(), straight.gossip_clock());
    assert_eq!(resumed.comm_stats().fallback_rounds, 0, "{backend:?}: fallback after resume");
}

#[test]
fn mid_overlap_checkpoint_on_bus_resumes_bit_exactly() {
    mid_overlap_checkpoint_resumes_bit_exactly(BackendKind::Bus);
}

#[test]
fn mid_overlap_checkpoint_on_tcp_resumes_bit_exactly() {
    with_timeout(480, || mid_overlap_checkpoint_resumes_bit_exactly(BackendKind::Tcp));
}

// ---------------------------------------------------------------------------
// BENCH_9 schema gate (same pattern as transport.rs / pipeline.rs).
// ---------------------------------------------------------------------------

#[test]
fn bench_nine_schema_holds_when_the_artifact_exists() {
    // The bench may not have run on this box; when BENCH_9.json IS there,
    // hold it to the schema EXPERIMENTS.md §Overlap on the wire reads.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_9.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("BENCH_9.json absent — run `cargo bench --bench perf_hotpath` to emit it");
        return;
    };
    let doc = Json::parse(&text).expect("BENCH_9.json parses");
    assert_eq!(
        doc.get("bench").and_then(|j| match j {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }),
        Some("overlap_wire")
    );
    let Some(Json::Arr(rows)) = doc.get("overlap_rows") else {
        panic!("BENCH_9.json missing array 'overlap_rows'");
    };
    assert!(!rows.is_empty(), "'overlap_rows' must not be empty");
    for row in rows {
        for field in ["backend", "mode", "depth", "rounds", "n", "d", "mean_seconds", "bit_equal"] {
            assert!(row.get(field).is_some(), "overlap_rows row missing '{field}'");
        }
        // The in-bench bit-equality assertions must have actually held.
        assert_eq!(row.get("bit_equal"), Some(&Json::Bool(true)), "overlap_rows: bit_equal");
    }
}
