//! Kernel-equivalence property suite (`rust/src/coordinator/mixer.rs`
//! §Kernel).
//!
//! The vectorized, cache-blocked [`mix_row_src`] is THE mixing arithmetic —
//! every backend routes through it, so the repo's cross-backend bit-equality
//! contracts all rest on one claim: blocking the d-dimension and unrolling
//! the multiply-add lanes changes *nothing* about any output element's
//! j-accumulation order. This suite pins that claim against the naive
//! reference [`mix_row_src_scalar`] (plain zip loops, no blocking, no
//! unrolling) with **bit** equality — not tolerance — across:
//!
//! * every row-shape arm: 0 neighbors (zero fill), 1 (incl. the w0 == 1.0
//!   copy fast path), 2/3 (fused single-pass), and the general blocked arm
//!   at degrees up to 8;
//! * d spanning the block boundary: {1, 3, MIX_BLOCK-1, MIX_BLOCK,
//!   MIX_BLOCK+1, 4096} plus random odd sizes, so partial tail blocks and
//!   partial 8-lanes are both exercised;
//! * the unrolled lane primitives (`scale` / `fused2` / `fused3` / `axpy`)
//!   against their obvious one-element loops, at every length mod 8.
//!
//! Runs without AOT artifacts; `scripts/verify.sh` step 10 runs it at
//! `PROPTEST_CASES=16`.

use gossip_pga::coordinator::mixer::{
    axpy, fused2, fused3, mix_row_src, mix_row_src_scalar, scale, weight_rows_f32, Mixer,
    MIX_BLOCK,
};
use gossip_pga::exec::WorkerPool;
use gossip_pga::params::ParamMatrix;
use gossip_pga::proptest::{check, ensure, CaseResult};
use gossip_pga::rng::Rng;
use gossip_pga::topology::Topology;

/// Bit equality (`to_bits`, so -0.0 vs 0.0 or NaN payload drift would fail
/// loudly instead of slipping past an epsilon).
fn bits_eq(label: &str, got: &[f32], want: &[f32]) -> CaseResult {
    ensure(got.len() == want.len(), format!("{label}: length {} vs {}", got.len(), want.len()))?;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != w.to_bits() {
            return Err(format!("{label}: element {i}: {g:?} ({:#x}) vs {w:?} ({:#x})",
                g.to_bits(), w.to_bits()));
        }
    }
    Ok(())
}

/// The d grid every property walks: both sides of the cache-block boundary,
/// both sides of the 8-lane boundary, tiny and large.
fn d_grid(rng: &mut Rng) -> Vec<usize> {
    let mut ds = vec![1, 3, MIX_BLOCK - 1, MIX_BLOCK, MIX_BLOCK + 1, 4096];
    // One random size per case so odd tails get coverage beyond the grid.
    ds.push(1 + rng.below(700) as usize);
    ds
}

/// A random weight row of the requested degree over `nsrc` sources
/// (distinct indices; weights in (-1, 1), never the 1.0 fast-path value).
fn random_row(rng: &mut Rng, deg: usize, nsrc: usize) -> Vec<(usize, f32)> {
    rng.choose_distinct(nsrc, deg)
        .into_iter()
        .map(|j| (j, rng.range(-1.0, 1.0) as f32))
        .collect()
}

/// Flat `nsrc` x `d` source pool with magnitudes spread over a few orders
/// so reordered accumulation (the bug this suite exists to catch) actually
/// changes bits when it happens.
fn random_sources(rng: &mut Rng, nsrc: usize, d: usize) -> Vec<f32> {
    (0..nsrc * d)
        .map(|_| {
            let mag = 10f64.powi(rng.below(5) as i32 - 2);
            (rng.range(-1.0, 1.0) * mag) as f32
        })
        .collect()
}

#[test]
fn blocked_kernel_is_bit_identical_to_scalar_reference() {
    check("mix_row_src == mix_row_src_scalar (all arms)", |rng| {
        let nsrc = 9;
        for d in d_grid(rng) {
            let src = random_sources(rng, nsrc, d);
            let srow = |j: usize| &src[j * d..(j + 1) * d];
            for deg in 0..=8usize {
                let row = random_row(rng, deg, nsrc);
                // Poison both outputs differently so a skipped write shows.
                let mut got = vec![f32::NAN; d];
                let mut want = vec![-7.0f32; d];
                mix_row_src(&row, srow, &mut got);
                mix_row_src_scalar(&row, srow, &mut want);
                bits_eq(&format!("deg={deg} d={d}"), &got, &want)?;
            }
        }
        Ok(())
    });
}

#[test]
fn unit_weight_copy_fast_path_matches_scalar() {
    check("w0 == 1.0 single-neighbor copy", |rng| {
        let nsrc = 4;
        for d in d_grid(rng) {
            let src = random_sources(rng, nsrc, d);
            let srow = |j: usize| &src[j * d..(j + 1) * d];
            let j = rng.below(nsrc as u64) as usize;
            let row = [(j, 1.0f32)];
            let mut got = vec![f32::NAN; d];
            let mut want = vec![f32::NAN; d];
            mix_row_src(&row, srow, &mut got);
            mix_row_src_scalar(&row, srow, &mut want);
            bits_eq(&format!("copy d={d}"), &got, &want)?;
            // The fast path is an exact copy of the source row.
            bits_eq(&format!("copy-vs-src d={d}"), &got, srow(j))?;
        }
        Ok(())
    });
}

#[test]
fn lane_primitives_match_naive_loops_at_every_length_mod_8() {
    check("scale/fused2/fused3/axpy == naive", |rng| {
        // 0..=17 covers every residue mod 8 twice; the block sizes cover
        // the lengths the blocked arm actually feeds these kernels.
        let mut lens: Vec<usize> = (0..=17).collect();
        lens.extend([MIX_BLOCK - 1, MIX_BLOCK, 1 + rng.below(500) as usize]);
        for len in lens {
            let a = random_sources(rng, 1, len);
            let b = random_sources(rng, 1, len);
            let c = random_sources(rng, 1, len);
            let (w0, w1, w2) = (
                rng.range(-1.0, 1.0) as f32,
                rng.range(-1.0, 1.0) as f32,
                rng.range(-1.0, 1.0) as f32,
            );

            let mut got = vec![f32::NAN; len];
            scale(w0, &a, &mut got);
            let want: Vec<f32> = a.iter().map(|x| w0 * x).collect();
            bits_eq(&format!("scale len={len}"), &got, &want)?;

            let mut got = vec![f32::NAN; len];
            fused2(w0, &a, w1, &b, &mut got);
            let want: Vec<f32> =
                a.iter().zip(&b).map(|(x, y)| w0 * x + w1 * y).collect();
            bits_eq(&format!("fused2 len={len}"), &got, &want)?;

            let mut got = vec![f32::NAN; len];
            fused3(w0, &a, w1, &b, w2, &c, &mut got);
            let want: Vec<f32> = a
                .iter()
                .zip(&b)
                .zip(&c)
                .map(|((x, y), z)| w0 * x + w1 * y + w2 * z)
                .collect();
            bits_eq(&format!("fused3 len={len}"), &got, &want)?;

            let mut got = b.clone();
            axpy(w0, &a, &mut got);
            let want: Vec<f32> =
                b.iter().zip(&a).map(|(o, x)| o + w0 * x).collect();
            bits_eq(&format!("axpy len={len}"), &got, &want)?;
        }
        Ok(())
    });
}

/// Reference gossip round built on the scalar kernel only: what the mixer
/// must reproduce bit for bit through its blocked kernel, ring scratch and
/// pool sharding.
fn scalar_reference_round(rows: &[Vec<(usize, f32)>], params: &ParamMatrix) -> ParamMatrix {
    let (n, d) = (params.n(), params.d());
    let src = params.as_slice();
    let mut out = ParamMatrix::zeros(n, d);
    for i in 0..n {
        mix_row_src_scalar(&rows[i], |j| &src[j * d..(j + 1) * d], out.row_mut(i));
    }
    out
}

#[test]
fn full_mixer_rounds_match_the_scalar_reference_end_to_end() {
    // The integration layer of the suite: the real Mixer (blocked kernel +
    // scratch ring + pool sharding + time-varying topology clock) against
    // the naive per-row reference, over the three stock topologies and
    // pool sizes {1, 3}, multiple rounds deep.
    check("Mixer::gossip == scalar reference", |rng| {
        let n = 2 + rng.below(7) as usize;
        let d = 1 + rng.below(2 * MIX_BLOCK as u64 + 9) as usize;
        for mk in [
            Topology::ring as fn(usize) -> Topology,
            Topology::grid,
            Topology::one_peer_expo,
        ] {
            let topo = mk(n);
            let rows = weight_rows_f32(&topo);
            for threads in [1usize, 3] {
                let pool = WorkerPool::new(threads);
                let mut mixer = Mixer::new(&topo, d);
                let mut params = ParamMatrix::random(&mut Rng::new(rng.next_u64()), n, d, 1.0);
                for round in 0..topo.rounds().max(2) {
                    let want = scalar_reference_round(&rows[round % topo.rounds()], &params);
                    mixer.gossip(&mut params, &pool).map_err(|e| e.to_string())?;
                    bits_eq(
                        &format!("{:?} n={n} d={d} t={threads} round={round}", topo.kind),
                        params.as_slice(),
                        want.as_slice(),
                    )?;
                }
            }
        }
        Ok(())
    });
}
