//! End-to-end training integration: the coordinator drives PJRT-executed
//! compute under every communication schedule, and the paper's structural
//! identities hold at the system level.

use std::sync::Arc;

use gossip_pga::algorithms::{AlgorithmKind, SlowMoParams};
use gossip_pga::comm::{BackendKind, Compression};
use gossip_pga::coordinator::{logreg_workload, mlp_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::CostModel;
use gossip_pga::eventsim::Regime;
use gossip_pga::metrics::consensus_distance;
use gossip_pga::optim::LrSchedule;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_default().expect("run `make artifacts` first"))
}

fn opts(algo: AlgorithmKind, topo: Topology, h: usize, seed: u64) -> TrainerOptions {
    TrainerOptions {
        algorithm: algo,
        topology: topo,
        period: h,
        aga_init_period: 4,
        aga_warmup: 20,
        lr: LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 },
        momentum: 0.0,
        nesterov: false,
        seed,
        slowmo: SlowMoParams::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 10,
        threads: 1,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn logreg_trainer_with(algo: AlgorithmKind, n: usize, h: usize, seed: u64, non_iid: bool) -> Trainer {
    let rt = runtime();
    let (workload, init) = logreg_workload(rt, n, 512, non_iid, seed).unwrap();
    Trainer::new(workload, init, opts(algo, Topology::ring(n), h.max(1), seed)).unwrap()
}

fn logreg_trainer(algo: AlgorithmKind, n: usize, h: usize, seed: u64) -> Trainer {
    logreg_trainer_with(algo, n, h, seed, true)
}

#[test]
fn every_algorithm_decreases_loss() {
    for algo in [
        AlgorithmKind::Parallel,
        AlgorithmKind::Gossip,
        AlgorithmKind::Local,
        AlgorithmKind::GossipPga,
        AlgorithmKind::GossipAga,
        AlgorithmKind::SlowMo,
    ] {
        // iid data: the global optimum is the shared per-node optimum, so
        // the loss has real room to fall. (Non-iid global floors sit near
        // ln 2 because the per-node optima point in random directions.)
        let mut t = logreg_trainer_with(algo, 6, 8, 1, false);
        let hist = t.run(300, algo.name()).unwrap();
        let first = hist.records.first().unwrap().loss;
        let last = hist.final_loss();
        assert!(
            last < 0.8 * first,
            "{}: loss {first} -> {last} did not decrease",
            algo.name()
        );
    }
}

#[test]
fn pga_h1_identical_to_parallel() {
    // Limiting identity: H = 1 makes Gossip-PGA exactly Parallel SGD —
    // bit-for-bit, because the gossip branch is never taken.
    let mut pga = logreg_trainer(AlgorithmKind::GossipPga, 5, 1, 7);
    let mut par = logreg_trainer(AlgorithmKind::Parallel, 5, 1, 7);
    for _ in 0..40 {
        pga.step_once().unwrap();
        par.step_once().unwrap();
    }
    for i in 0..5 {
        assert_eq!(pga.worker_params(i), par.worker_params(i), "worker {i} diverged");
    }
}

#[test]
fn pga_large_h_matches_gossip_until_first_sync() {
    // Before the first global average (k+1 < H) PGA *is* Gossip SGD.
    let mut pga = logreg_trainer(AlgorithmKind::GossipPga, 5, 50, 3);
    let mut gsp = logreg_trainer(AlgorithmKind::Gossip, 5, 50, 3);
    for _ in 0..49 {
        pga.step_once().unwrap();
        gsp.step_once().unwrap();
    }
    for i in 0..5 {
        assert_eq!(pga.worker_params(i), gsp.worker_params(i));
    }
    // Step 50 is the sync: now they must differ.
    pga.step_once().unwrap();
    gsp.step_once().unwrap();
    assert_ne!(pga.worker_params(0), gsp.worker_params(0));
}

#[test]
fn global_average_zeroes_consensus_distance() {
    let mut t = logreg_trainer(AlgorithmKind::GossipPga, 6, 4, 5);
    // After any step that synced (k+1 % 4 == 0), workers agree exactly.
    for k in 0..12 {
        t.step_once().unwrap();
        let c = consensus_distance(t.param_matrix());
        if (k + 1) % 4 == 0 {
            assert!(c < 1e-10, "step {k}: consensus {c} after sync");
        }
    }
}

#[test]
fn local_sgd_never_mixes_between_syncs() {
    // With W = I semantics (no gossip), workers evolve independently
    // between syncs: consensus grows strictly until the sync wipes it.
    let mut t = logreg_trainer(AlgorithmKind::Local, 4, 6, 9);
    let mut prev = 0.0;
    for k in 0..5 {
        t.step_once().unwrap();
        let c = consensus_distance(t.param_matrix());
        assert!(c > prev, "step {k}: consensus should grow between syncs");
        prev = c;
    }
}

#[test]
fn gossip_contracts_but_never_zeroes_consensus() {
    let mut t = logreg_trainer(AlgorithmKind::Gossip, 8, 1, 11);
    for _ in 0..30 {
        t.step_once().unwrap();
    }
    let c = consensus_distance(t.param_matrix());
    assert!(c > 0.0, "gossip alone should not reach exact consensus");
    assert!(c < 1.0, "but it must keep consensus bounded");
}

#[test]
fn runs_are_deterministic_replayable() {
    let mut a = logreg_trainer(AlgorithmKind::GossipPga, 5, 8, 123);
    let mut b = logreg_trainer(AlgorithmKind::GossipPga, 5, 8, 123);
    let ha = a.run(60, "a").unwrap();
    let hb = b.run(60, "b").unwrap();
    assert_eq!(ha.losses(), hb.losses());
    for i in 0..5 {
        assert_eq!(a.worker_params(i), b.worker_params(i));
    }
}

#[test]
fn pga_tracks_parallel_closer_than_gossip() {
    // The paper's headline (Fig. 1): Gossip-PGA's loss curve hugs the
    // Parallel-SGD curve much earlier than Gossip SGD's (shorter transient
    // stage). Measure each curve's squared deviation from the parallel
    // reference over the run; PGA must deviate less. Also: PGA keeps
    // consensus strictly tighter than Gossip at every logged step.
    let steps = 400;
    let n = 20;
    let mut par = logreg_trainer(AlgorithmKind::Parallel, n, 16, 17);
    let mut pga = logreg_trainer(AlgorithmKind::GossipPga, n, 16, 17);
    let mut gsp = logreg_trainer(AlgorithmKind::Gossip, n, 16, 17);
    let hpar = par.run(steps, "parallel").unwrap();
    let hpga = pga.run(steps, "pga").unwrap();
    let hgsp = gsp.run(steps, "gossip").unwrap();
    let dev = |h: &gossip_pga::metrics::History| -> f64 {
        h.losses()
            .iter()
            .zip(hpar.losses())
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    };
    let (dp, dg) = (dev(&hpga), dev(&hgsp));
    assert!(dp <= dg + 1e-12, "PGA deviation {dp} should be <= Gossip deviation {dg}");
    // Consensus: after each sync PGA is exact; time-averaged it is tighter.
    let avg_cons = |h: &gossip_pga::metrics::History| -> f64 {
        h.records.iter().map(|r| r.consensus).sum::<f64>() / h.records.len() as f64
    };
    assert!(avg_cons(&hpga) < avg_cons(&hgsp));
}

#[test]
fn sim_clock_orders_algorithms_correctly() {
    // Per-iteration simulated time: parallel > PGA > gossip (on the
    // calibrated ResNet-50 model, one-peer graph costs).
    let steps = 24;
    let n = 8;
    let mk = |algo| {
        let rt = runtime();
        let (w, init) = logreg_workload(rt, n, 128, false, 2).unwrap();
        let o = opts(algo, Topology::one_peer_expo(n), 6, 2);
        Trainer::new(w, init, o).unwrap()
    };
    let mut par = mk(AlgorithmKind::Parallel);
    let mut pga = mk(AlgorithmKind::GossipPga);
    let mut gsp = mk(AlgorithmKind::Gossip);
    par.run(steps, "p").unwrap();
    pga.run(steps, "q").unwrap();
    gsp.run(steps, "g").unwrap();
    assert!(par.sim_seconds() > pga.sim_seconds());
    assert!(pga.sim_seconds() > gsp.sim_seconds());
}

#[test]
fn aga_period_adapts_upward() {
    let mut t = logreg_trainer(AlgorithmKind::GossipAga, 6, 4, 31);
    let start_h = t.current_period();
    t.run(300, "aga").unwrap();
    assert!(
        t.current_period() > start_h,
        "AGA period should grow as loss falls: {} -> {}",
        start_h,
        t.current_period()
    );
}

#[test]
fn checkpoint_resume_is_exact() {
    // Save at step 30, keep training to 60; a fresh trainer restored from
    // the checkpoint must reproduce the final state bit-for-bit (same data
    // stream: worker RNGs are indexed by the step via sampling order).
    let mut a = logreg_trainer(AlgorithmKind::GossipPga, 4, 8, 55);
    for _ in 0..30 {
        a.step_once().unwrap();
    }
    let path = std::env::temp_dir().join(format!("gpga_it_ckpt_{}.bin", std::process::id()));
    a.checkpoint().unwrap().save(&path).unwrap();
    for _ in 0..30 {
        a.step_once().unwrap();
    }

    let mut b = logreg_trainer(AlgorithmKind::GossipPga, 4, 8, 55);
    // advance b's worker RNG streams to the checkpoint by replaying 30 steps
    for _ in 0..30 {
        b.step_once().unwrap();
    }
    let ck = gossip_pga::coordinator::checkpoint::Checkpoint::load(&path).unwrap();
    b.restore(&ck).unwrap();
    for _ in 0..30 {
        b.step_once().unwrap();
    }
    for i in 0..4 {
        assert_eq!(a.worker_params(i), b.worker_params(i), "worker {i}");
    }
    assert_eq!(a.sim_seconds(), b.sim_seconds());
    std::fs::remove_file(path).ok();
}

#[test]
fn checkpoint_rejects_shape_mismatch() {
    let mut a = logreg_trainer(AlgorithmKind::GossipPga, 4, 8, 1);
    let ck = a.checkpoint().unwrap(); // n = 4
    let mut b = logreg_trainer(AlgorithmKind::GossipPga, 5, 8, 1);
    assert!(b.restore(&ck).is_err(), "node-count mismatch must be rejected");
}

/// Build an overlap-capable trainer with explicit threads/overlap (the
/// checkpoint-mid-overlap scenarios sweep both; non-iid like the other
/// checkpoint tests).
fn overlap_trainer(n: usize, h: usize, seed: u64, threads: usize, overlap: bool) -> Trainer {
    let rt = runtime();
    let (workload, init) = logreg_workload(rt, n, 512, true, seed).unwrap();
    let mut o = opts(AlgorithmKind::GossipPga, Topology::ring(n), h, seed);
    o.momentum = 0.9;
    o.nesterov = true;
    o.threads = threads;
    o.regime = if overlap { Regime::Overlap } else { Regime::Bsp };
    Trainer::new(workload, init, o).unwrap()
}

#[test]
fn checkpoint_mid_overlap_drains_and_resumes_bit_exactly() {
    // H = 8: after 13 steps the last action was a gossip whose mix is
    // still in flight on the pool. checkpoint() must DRAIN it (the
    // snapshot is then a clean BSP step-13 boundary, gossip clock
    // included), never drop it. Restoring into a fresh process — here a
    // fresh trainer, overlap on or off, any pool size — must continue
    // bit-identically to the unbroken run.
    let mut a = overlap_trainer(4, 8, 55, 4, true);
    for _ in 0..13 {
        a.step_once().unwrap();
    }
    let path = std::env::temp_dir().join(format!("gpga_ovl_ckpt_{}.bin", std::process::id()));
    let ck = a.checkpoint().unwrap();
    assert_eq!(ck.gossip_clock, 12, "steps 1..13 minus the step-8 sync: 12 drained gossips");
    ck.save(&path).unwrap();
    for _ in 0..19 {
        a.step_once().unwrap();
    }
    a.drain().unwrap();

    let loaded = gossip_pga::coordinator::checkpoint::Checkpoint::load(&path).unwrap();
    // Resume in overlap mode on a different pool size…
    let mut b = overlap_trainer(4, 8, 55, 2, true);
    b.restore(&loaded).unwrap();
    for _ in 0..19 {
        b.step_once().unwrap();
    }
    b.drain().unwrap();
    // …and in plain BSP mode: the drained snapshot is schedule-agnostic.
    let mut c = overlap_trainer(4, 8, 55, 1, false);
    c.restore(&loaded).unwrap();
    for _ in 0..19 {
        c.step_once().unwrap();
    }
    for i in 0..4 {
        assert_eq!(a.worker_params(i), b.worker_params(i), "overlap resume: worker {i}");
        assert_eq!(a.worker_params(i), c.worker_params(i), "BSP resume: worker {i}");
    }
    assert_eq!(a.sim_seconds(), b.sim_seconds());
    assert_eq!(a.sim_seconds(), c.sim_seconds());
    assert_eq!(a.gossip_clock(), b.gossip_clock());
    std::fs::remove_file(path).ok();
}

#[test]
fn overlap_trainer_decreases_loss_and_syncs_exactly() {
    // End-to-end sanity for the async path itself: overlap training learns
    // (iid data, so the loss has real room to fall), and at every k·H
    // boundary the (synchronous) global average still zeroes consensus
    // exactly.
    let rt = runtime();
    let (workload, init) = logreg_workload(rt, 6, 512, false, 5).unwrap();
    let mut o = opts(AlgorithmKind::GossipPga, Topology::ring(6), 4, 5);
    o.threads = 3;
    o.regime = Regime::Overlap;
    let mut t = Trainer::new(workload, init, o).unwrap();
    let mut first = None;
    for k in 0..150 {
        t.step_once().unwrap();
        if (k + 1) % 4 == 0 {
            let c = consensus_distance(t.param_matrix());
            assert!(c < 1e-10, "step {k}: consensus {c} after sync");
        }
        if k == 0 {
            t.drain().unwrap();
            first = Some(t.global_loss().unwrap());
        }
    }
    t.drain().unwrap();
    let final_loss = t.global_loss().unwrap();
    let first = first.unwrap();
    assert!(
        final_loss < 0.8 * first,
        "overlap run failed to learn: {first} -> {final_loss}"
    );
}

#[test]
fn mlp_workload_trains() {
    let rt = runtime();
    let (workload, init) = mlp_workload(rt, 4, 512, false, 3).unwrap();
    let mut o = opts(AlgorithmKind::GossipPga, Topology::ring(4), 6, 3);
    o.lr = LrSchedule::Const { lr: 0.1 };
    let mut t = Trainer::new(workload, init, o).unwrap();
    let hist = t.run(80, "mlp").unwrap();
    let first = hist.records.first().unwrap().loss;
    assert!(hist.final_loss() < 0.7 * first, "{} -> {}", first, hist.final_loss());
    let acc = gossip_pga::coordinator::mlp_eval_accuracy(&t).unwrap().unwrap();
    assert!(acc > 0.5, "eval accuracy {acc}");
}
