//! The event-driven async gossip regime, end to end (no AOT artifacts —
//! every suite drives the engine + backends directly, like the
//! virtual-time replay tests):
//!
//! * **(a) strict-mode anchor** — homogeneous costs + `max_staleness = 0`:
//!   the event schedule reproduces the barrier-billed clocks AND the BSP
//!   parameter trajectory bit-exactly on BOTH CommPlane backends, with
//!   identical traffic totals;
//! * **(b) staleness bound** — seeded multi-straggler async runs keep
//!   every mix input within `max_staleness` (and actually exercise the
//!   stale bins);
//! * **(c) checkpoint v6** — a mid-flight async run (payloads still on
//!   the links) snapshots through the v6 file format — a deduplicated
//!   slot table the links reference by index — and resumes bit-exactly
//!   in a fresh engine (v1–v5 load-compat is pinned by the hand-written
//!   files in `coordinator::checkpoint`'s unit tests);
//! * **(d) determinism** — same seed => identical event order (trace),
//!   parameters and clocks across worker-pool sizes.

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::comm::{BusBackend, CommBackend, CommStats, Compression, SharedBackend};
use gossip_pga::coordinator::checkpoint::{Checkpoint, ClockState};
use gossip_pga::costmodel::{CostModel, NodeCosts, VirtualClocks};
use gossip_pga::eventsim::AsyncGossip;
use gossip_pga::exec::WorkerPool;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::topology::Topology;

const COST_DIM: usize = 25_500_000;

/// Deterministic synthetic local update — pure in `(node, iter)`, so any
/// execution order and any pool size produce the same bits.
fn fake_step(params: &mut ParamMatrix, batch: &[(usize, usize)]) -> anyhow::Result<()> {
    for &(node, iter) in batch {
        let mut r = Rng::new(0xE5E5 ^ ((node as u64) << 32) ^ iter as u64);
        for x in params.row_mut(node) {
            *x = 0.95 * *x + 0.05 * r.normal() as f32;
        }
    }
    Ok(())
}

fn mk_backend(
    kind: &str,
    topo: &Topology,
    d: usize,
    costs: &NodeCosts,
    with_global: bool,
) -> Box<dyn CommBackend> {
    match kind {
        "shared" => Box::new(SharedBackend::new(topo, d, costs, COST_DIM, Compression::None)),
        _ => Box::new(BusBackend::new(topo, d, costs, COST_DIM, Compression::None, with_global)),
    }
}

struct EngineRun {
    params: ParamMatrix,
    clocks: VirtualClocks,
    engine: AsyncGossip,
    total: CommStats,
}

#[allow(clippy::too_many_arguments)]
fn run_engine(
    backend_kind: &str,
    topo: &Topology,
    costs: &NodeCosts,
    d: usize,
    staleness: usize,
    algo: AlgorithmKind,
    h: usize,
    steps: usize,
    pool_size: usize,
    trace: bool,
) -> EngineRun {
    let mut params = ParamMatrix::random(&mut Rng::new(31), topo.n, d, 1.0);
    let mut engine =
        AsyncGossip::new(topo, costs, d, COST_DIM, staleness, algo, h, &params).unwrap();
    if trace {
        engine.enable_trace();
    }
    let with_global = h != usize::MAX;
    let mut backend = mk_backend(backend_kind, topo, d, costs, with_global);
    let pool = WorkerPool::new(pool_size);
    let mut clocks = VirtualClocks::new(topo);
    let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
    let mut sync = |_k: usize, _p: &mut ParamMatrix| -> anyhow::Result<()> { Ok(()) };
    for t in 1..=steps {
        engine
            .run_until(t, &mut params, backend.as_mut(), &pool, &mut clocks, costs, &mut step, &mut sync)
            .unwrap();
    }
    let total = backend.total();
    EngineRun { params, clocks, engine, total }
}

/// The BSP reference: identical synthetic updates, backend-level
/// collectives, trainer-style billing.
fn run_bsp_reference(
    backend_kind: &str,
    topo: &Topology,
    costs: &NodeCosts,
    d: usize,
    h: usize,
    steps: usize,
) -> (ParamMatrix, VirtualClocks, CommStats) {
    let mut params = ParamMatrix::random(&mut Rng::new(31), topo.n, d, 1.0);
    let with_global = h != usize::MAX;
    let mut backend = mk_backend(backend_kind, topo, d, costs, with_global);
    let pool = WorkerPool::new(2);
    let mut clocks = VirtualClocks::new(topo);
    for k in 0..steps {
        let batch: Vec<(usize, usize)> = (0..topo.n).map(|i| (i, k)).collect();
        fake_step(&mut params, &batch).unwrap();
        let charge = if h != usize::MAX && (k + 1) % h == 0 {
            backend.global_average(&mut params, &pool).unwrap()
        } else {
            backend.gossip(&mut params, &pool).unwrap()
        };
        clocks.advance(&costs.compute, &charge.node_seconds, charge.barrier);
    }
    (params, clocks, backend.total())
}

#[test]
fn strict_event_schedule_equals_barrier_billing_on_both_backends() {
    // (a) The regression anchor: homogeneous + staleness-0 event-driven
    // runs ARE the BSP runs — parameters, every per-node clock, and the
    // traffic totals, bit for bit, on both planes.
    let d = 23;
    let steps = 13;
    for topo in [Topology::ring(6), Topology::one_peer_expo(8), Topology::grid(9)] {
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
        for (algo, h) in
            [(AlgorithmKind::GossipPga, 4), (AlgorithmKind::Gossip, usize::MAX)]
        {
            for backend_kind in ["shared", "bus"] {
                let ev = run_engine(
                    backend_kind, &topo, &costs, d, 0, algo, h, steps, 2, false,
                );
                let (bsp_params, bsp_clocks, bsp_total) =
                    run_bsp_reference(backend_kind, &topo, &costs, d, h, steps);
                assert_eq!(
                    ev.params, bsp_params,
                    "{backend_kind}/{algo:?} on {:?}: trajectory diverged",
                    topo.kind
                );
                assert_eq!(
                    ev.clocks.seconds(),
                    bsp_clocks.seconds(),
                    "{backend_kind}/{algo:?} on {:?}: clocks diverged",
                    topo.kind
                );
                assert_eq!(
                    ev.clocks.waited(),
                    bsp_clocks.waited(),
                    "{backend_kind}/{algo:?} on {:?}: wait accounts diverged",
                    topo.kind
                );
                assert_eq!(
                    ev.total, bsp_total,
                    "{backend_kind}/{algo:?} on {:?}: traffic totals diverged",
                    topo.kind
                );
                let (stale_max, stale_mean) = ev.engine.staleness();
                assert_eq!((stale_max, stale_mean), (0, 0.0), "strict mode is never stale");
            }
        }
    }
}

#[test]
fn strict_event_schedule_handles_local_sgd_compute_only_steps() {
    // Local SGD: None actions between global averages — the event plane
    // must bill pure compute exactly like BarrierScope::None.
    let topo = Topology::ring(5);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 5);
    let d = 11;
    let ev = run_engine("shared", &topo, &costs, d, 0, AlgorithmKind::Local, 3, 9, 1, false);
    let mut params = ParamMatrix::random(&mut Rng::new(31), 5, d, 1.0);
    let mut backend = mk_backend("shared", &topo, d, &costs, true);
    let pool = WorkerPool::new(1);
    let mut clocks = VirtualClocks::new(&topo);
    let zeros = vec![0.0; 5];
    for k in 0..9 {
        let batch: Vec<(usize, usize)> = (0..5).map(|i| (i, k)).collect();
        fake_step(&mut params, &batch).unwrap();
        if (k + 1) % 3 == 0 {
            let c = backend.global_average(&mut params, &pool).unwrap();
            clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
        } else {
            clocks.advance(&costs.compute, &zeros, gossip_pga::costmodel::BarrierScope::None);
        }
    }
    assert_eq!(ev.params, params);
    assert_eq!(ev.clocks.seconds(), clocks.seconds());
}

#[test]
fn async_mixes_stay_within_the_staleness_bound_under_stragglers() {
    // (b) Multi-straggler (the `--straggler 0:4,3:2` scenario): the bound
    // holds for every mix input, and the stale bins are actually hit.
    let topo = Topology::ring(8);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 8)
        .with_straggler(0, 4.0)
        .unwrap()
        .with_straggler(3, 2.0)
        .unwrap();
    for backend_kind in ["shared", "bus"] {
        for s in [1usize, 2] {
            let ev = run_engine(
                backend_kind,
                &topo,
                &costs,
                15,
                s,
                AlgorithmKind::Gossip,
                usize::MAX,
                24,
                2,
                false,
            );
            let hist = ev.engine.histogram();
            let (stale_max, _) = ev.engine.staleness();
            assert!(
                stale_max as usize <= s,
                "{backend_kind} s={s}: staleness {stale_max} exceeded the bound"
            );
            assert!(
                hist.iter().skip(1).any(|&c| c > 0),
                "{backend_kind} s={s}: straggler run never used a stale copy: {hist:?}"
            );
            // The event plane's critical path undercuts the neighborhood
            // barrier's (which exposes every transfer).
            let (_, barrier_clocks, _) =
                run_bsp_reference(backend_kind, &topo, &costs, 15, usize::MAX, 24);
            assert!(
                ev.clocks.max_seconds() < barrier_clocks.max_seconds(),
                "{backend_kind} s={s}: async {} !< barrier {}",
                ev.clocks.max_seconds(),
                barrier_clocks.max_seconds()
            );
        }
    }
}

#[test]
fn checkpoint_v6_resumes_mid_flight_bit_exactly() {
    // (c) Snapshot an async run with payloads still riding the links,
    // round-trip it through the v6 FILE format (slot table + per-link
    // slot references), import into a fresh engine, and continue both
    // runs: bits must agree throughout.
    let topo = Topology::ring(6);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6)
        .with_straggler(1, 3.0)
        .unwrap();
    let d = 9;
    let (k1, k2) = (7usize, 15usize);
    let algo = AlgorithmKind::GossipPga;
    let h = 5usize;

    // Unbroken run to k2, snapshotting (with the same sync semantics a
    // checkpoint imposes) at k1.
    let mut params = ParamMatrix::random(&mut Rng::new(31), 6, d, 1.0);
    let mut engine = AsyncGossip::new(&topo, &costs, d, COST_DIM, 2, algo, h, &params).unwrap();
    let mut backend = mk_backend("shared", &topo, d, &costs, true);
    let pool = WorkerPool::new(2);
    let mut clocks = VirtualClocks::new(&topo);
    let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
    let mut sync = |_k: usize, _p: &mut ParamMatrix| -> anyhow::Result<()> { Ok(()) };
    for t in 1..=k1 {
        engine
            .run_until(t, &mut params, backend.as_mut(), &pool, &mut clocks, &costs, &mut step, &mut sync)
            .unwrap();
    }
    clocks.sync(); // the checkpoint barrier
    let ck = Checkpoint {
        step: k1 as u64,
        sim_seconds: clocks.max_seconds(),
        params: params.clone(),
        velocities: None,
        gossip_clock: backend.gossip_clock() as u64,
        schedule: None,
        slowmo: None,
        rng_states: Vec::new(),
        comm: Some(backend.total()),
        ef_residuals: None,
        ef_compression: None,
        clocks: Some(ClockState {
            seconds: clocks.seconds().to_vec(),
            waited: clocks.waited().to_vec(),
        }),
        eventsim: Some(engine.export_state()),
        rounds: None,
    };
    let path = std::env::temp_dir().join(format!("gpga_eventsim_{}.bin", std::process::id()));
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ck, loaded, "checkpoint round-trip must be lossless");
    let es = loaded.eventsim.as_ref().unwrap();
    assert!(
        es.links.iter().any(|l| !l.inflight.is_empty()),
        "the snapshot should catch payloads mid-flight (straggler run)"
    );
    // The slot table actually dedups: the pool interns per (src, version),
    // so occurrences (cache + every in-flight copy) outnumber slots.
    let occurrences: usize = es.links.iter().map(|l| 1 + l.inflight.len()).sum();
    assert!(
        es.slots.len() < occurrences,
        "slot table ({}) should be smaller than payload occurrences ({occurrences})",
        es.slots.len()
    );

    // Resume into a fresh engine/backend/clocks from the loaded file.
    let mut r_params = loaded.params.clone();
    let mut r_engine =
        AsyncGossip::new(&topo, &costs, d, COST_DIM, 2, algo, h, &r_params).unwrap();
    r_engine
        .import_state(loaded.eventsim.as_ref().unwrap(), k1, loaded.gossip_clock as usize)
        .unwrap();
    let mut r_backend = mk_backend("shared", &topo, d, &costs, true);
    r_backend.set_gossip_clock(loaded.gossip_clock as usize);
    r_backend.restore_total(loaded.comm.unwrap());
    let mut r_clocks = VirtualClocks::new(&topo);
    let cs = loaded.clocks.as_ref().unwrap();
    r_clocks.restore(&cs.seconds, &cs.waited).unwrap();

    for t in (k1 + 1)..=k2 {
        engine
            .run_until(
                t, &mut params, backend.as_mut(), &pool, &mut clocks, &costs, &mut step, &mut sync,
            )
            .unwrap();
        r_engine
            .run_until(
                t, &mut r_params, r_backend.as_mut(), &pool, &mut r_clocks, &costs, &mut step,
                &mut sync,
            )
            .unwrap();
    }
    assert_eq!(params, r_params, "resumed trajectory diverged");
    assert_eq!(clocks.seconds(), r_clocks.seconds(), "resumed clocks diverged");
    assert_eq!(engine.histogram(), r_engine.histogram(), "resumed staleness diverged");
    assert_eq!(backend.total(), r_backend.total(), "resumed traffic diverged");
}

#[test]
fn event_order_is_identical_across_pool_sizes() {
    // (d) The determinism gate: the heap's (time, kind, src, dst, seq)
    // order is a pure function of the configuration — the pool only
    // shards real work whose arithmetic is order-independent.
    let topo = Topology::one_peer_expo(8);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 8)
        .with_straggler(2, 4.0)
        .unwrap();
    let reference = run_engine(
        "shared", &topo, &costs, 13, 2, AlgorithmKind::GossipPga, 4, 17, 1, true,
    );
    for pool_size in [2usize, 3] {
        let got = run_engine(
            "shared", &topo, &costs, 13, 2, AlgorithmKind::GossipPga, 4, 17, pool_size, true,
        );
        assert_eq!(
            reference.engine.trace(),
            got.engine.trace(),
            "event order changed at pool size {pool_size}"
        );
        assert_eq!(reference.params, got.params, "params changed at pool size {pool_size}");
        assert_eq!(
            reference.clocks.seconds(),
            got.clocks.seconds(),
            "clocks changed at pool size {pool_size}"
        );
    }
}

#[test]
fn strict_mode_trace_is_also_pool_invariant() {
    let topo = Topology::ring(6);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6);
    let a = run_engine("shared", &topo, &costs, 9, 0, AlgorithmKind::GossipPga, 3, 9, 1, true);
    let b = run_engine("shared", &topo, &costs, 9, 0, AlgorithmKind::GossipPga, 3, 9, 4, true);
    assert_eq!(a.engine.trace(), b.engine.trace());
    assert_eq!(a.params, b.params);
}
