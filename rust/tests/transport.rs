//! Socket-transport acceptance suite (`rust/src/comm/tcp.rs` +
//! `rust/src/coordinator/rounds.rs`).
//!
//! Two contracts under test:
//!
//! * **Bit-equality** — uncompressed loopback `TcpBackend` trajectories
//!   are bit-identical to `BusBackend` and `SharedBackend` (same
//!   `mix_row_src` kernel, same rank-ascending chunked exchange), across
//!   topologies and pool sizes. The schedule-replay tests need no AOT
//!   artifacts; the trainer-level tests need `make artifacts` like the
//!   other integration suites.
//! * **Fault tolerance** — a peer that goes silent mid-round is handled
//!   by the round protocol (deadline → mixing-row renormalization → the
//!   run completes, the drop counted in metrics), never by a hang or a
//!   poisoned trainer; the membership snapshot rides checkpoint v7 and a
//!   dropped peer's weight folds back in on rejoin.
//!
//! Every socket test binds `127.0.0.1:0` — OS-assigned ports, so the
//! suite never collides with itself or anything else on the box. The
//! fault tests run under a watchdog so a deadlock regression fails
//! loudly instead of wedging the suite.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use gossip_pga::algorithms::{schedule_for, AlgorithmKind, CommAction};
use gossip_pga::comm::{
    BackendKind, BusBackend, CommBackend, Compression, SharedBackend, TcpBackend,
};
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::jsonio::Json;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

/// Run `f` on a watchdog thread; FAIL (don't hang) if it overruns.
fn with_timeout(secs: u64, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = channel();
    let h = std::thread::spawn(move || {
        f();
        tx.send(()).ok();
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().expect("watchdog body"),
        Err(_) => panic!("timed out after {secs}s — the transport hung instead of failing"),
    }
}

/// Replay a schedule on one backend kind; returns the final matrix. The
/// same deterministic pseudo-gradient is applied on every backend's copy,
/// so any divergence comes from the transport alone.
fn replay(
    kind: BackendKind,
    algo: AlgorithmKind,
    topo: &Topology,
    d: usize,
    steps: usize,
    h: usize,
    threads: usize,
) -> ParamMatrix {
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    let with_global = algo != AlgorithmKind::Gossip;
    let mut backend: Box<dyn CommBackend> = match kind {
        BackendKind::Shared => {
            Box::new(SharedBackend::new(topo, d, &costs, d, Compression::None))
        }
        BackendKind::Bus => {
            Box::new(BusBackend::new(topo, d, &costs, d, Compression::None, with_global))
        }
        BackendKind::Tcp => Box::new(
            TcpBackend::new_loopback(
                topo,
                d,
                &costs,
                d,
                Compression::None,
                with_global,
                "127.0.0.1:0",
            )
            .unwrap(),
        ),
    };
    let pool = WorkerPool::new(threads);
    let mut params = ParamMatrix::random(&mut Rng::new(31), topo.n, d, 1.0);
    let mut schedule = schedule_for(algo, h, 2, 4).unwrap();
    for k in 0..steps {
        let mut rng = Rng::new(0xFEED ^ (k as u64).wrapping_mul(0x9E37_79B9));
        let noise = rng.normal_vec(params.n() * params.d(), 0.05);
        for (p, g) in params.as_mut_slice().iter_mut().zip(&noise) {
            *p -= g;
        }
        match schedule.action(k, 1.0 / (k as f64 + 1.0)) {
            CommAction::Gossip => {
                backend.gossip(&mut params, &pool).unwrap();
            }
            CommAction::GlobalAverage => {
                backend.global_average(&mut params, &pool).unwrap();
            }
            CommAction::None => {}
        }
    }
    params
}

#[test]
fn tcp_matches_bus_and_shared_bit_for_bit() {
    // The tentpole equality property: real sockets, channels and the
    // fused mixer walk identical trajectories — {gossip-only, PGA with
    // its global averages} x {ring, grid, one-peer-expo} x pools {1, 3}.
    let (d, steps, h) = (13, 12, 3);
    for mk in [
        Topology::ring as fn(usize) -> Topology,
        Topology::grid,
        Topology::one_peer_expo,
    ] {
        let topo = mk(5);
        for algo in [AlgorithmKind::Gossip, AlgorithmKind::GossipPga] {
            for threads in [1usize, 3] {
                let label = format!("{:?}/{:?}/t={threads}", algo, topo.kind);
                let p_shared = replay(BackendKind::Shared, algo, &topo, d, steps, h, threads);
                let p_bus = replay(BackendKind::Bus, algo, &topo, d, steps, h, threads);
                let p_tcp = replay(BackendKind::Tcp, algo, &topo, d, steps, h, threads);
                assert_eq!(p_bus, p_shared, "{label}: bus diverged from shared");
                assert_eq!(p_tcp, p_shared, "{label}: tcp diverged from shared");
            }
        }
    }
}

fn opts(algo: AlgorithmKind, n: usize, backend: BackendKind, round_timeout: f64) -> TrainerOptions {
    TrainerOptions {
        algorithm: algo,
        topology: Topology::ring(n),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.9,
        nesterov: true,
        seed: 23,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: 1,
        log_every: 5,
        threads: 2,
        regime: Regime::Bsp,
        max_staleness: 0,
        backend,
        compression: Compression::None,
        round_timeout,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn trainer(rt: &Arc<Runtime>, algo: AlgorithmKind, backend: BackendKind, timeout: f64) -> Trainer {
    let n = 4;
    let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 23).unwrap();
    Trainer::new(workload, init, opts(algo, n, backend, timeout)).unwrap()
}

#[test]
fn trainer_on_tcp_matches_trainer_on_shared() {
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    for algo in [AlgorithmKind::Gossip, AlgorithmKind::GossipPga] {
        let mut on_shared = trainer(&rt, algo, BackendKind::Shared, 0.0);
        let mut on_tcp = trainer(&rt, algo, BackendKind::Tcp, 0.0);
        for _ in 0..8 {
            on_shared.step_once().unwrap();
            on_tcp.step_once().unwrap();
        }
        for i in 0..4 {
            assert_eq!(
                on_shared.worker_params(i),
                on_tcp.worker_params(i),
                "{algo:?}: tcp trainer diverged from shared at worker {i}"
            );
        }
    }
}

#[test]
fn muted_peer_is_dropped_and_the_run_completes() {
    // The acceptance scenario, end to end: a peer goes silent mid-run on
    // real sockets; the round deadline fires, its mixing row is
    // renormalized, the run completes, and the drop lands in the metrics
    // counters. No hang, no poisoned trainer.
    with_timeout(240, || {
        let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
        let mut t = trainer(&rt, AlgorithmKind::Gossip, BackendKind::Tcp, 0.75);
        for _ in 0..2 {
            t.step_once().unwrap(); // healthy rounds first
        }
        assert_eq!((t.peer_drops(), t.row_renorms()), (0, 0));
        t.mute_node(2, true).unwrap(); // node 2 wedges: alive but silent
        for _ in 0..3 {
            t.step_once().unwrap(); // must complete over n-1 nodes
        }
        assert_eq!(t.peer_drops(), 1, "exactly one drop for one wedged peer");
        assert!(t.row_renorms() >= 1, "the drop renormalized mixing rows");
        let state = t.round_state().expect("round machine is on");
        assert_eq!(state.alive, vec![true, true, false, true]);
        for i in [0usize, 1, 3] {
            assert!(
                t.worker_params(i).iter().all(|v| v.is_finite()),
                "surviving worker {i} must stay finite"
            );
        }
    });
}

#[test]
fn dropped_peer_rejoins_with_its_weight_restored() {
    with_timeout(240, || {
        let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
        let mut t = trainer(&rt, AlgorithmKind::Gossip, BackendKind::Tcp, 0.75);
        t.mute_node(1, true).unwrap();
        t.step_once().unwrap();
        assert_eq!(t.peer_drops(), 1);
        assert!(!t.round_state().unwrap().alive[1]);
        // Rejoin before unmuting is the protocol bug the machine guards
        // against only via the next deadline; the test plays it straight:
        // the peer comes back, then re-enters the round.
        t.mute_node(1, false).unwrap();
        t.rejoin_node(1).unwrap();
        let state = t.round_state().unwrap();
        assert!(state.alive.iter().all(|&a| a), "full membership after rejoin");
        assert_eq!(state.rejoins, 1);
        assert!(t.rejoin_node(1).is_err(), "double rejoin refused");
        for _ in 0..3 {
            t.step_once().unwrap(); // pristine rows back in force
        }
        assert_eq!(t.peer_drops(), 1, "no further drops after the rejoin");
    });
}

#[test]
fn checkpoint_v7_roundtrips_round_membership() {
    with_timeout(240, || {
        let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
        let mut t = trainer(&rt, AlgorithmKind::Gossip, BackendKind::Tcp, 0.75);
        t.mute_node(3, true).unwrap();
        for _ in 0..2 {
            t.step_once().unwrap();
        }
        let before = t.round_state().unwrap();
        assert!(!before.alive[3]);

        let ck = t.checkpoint().unwrap();
        let path =
            std::env::temp_dir().join(format!("gpga_transport_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let loaded = gossip_pga::coordinator::checkpoint::Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.rounds.as_ref(), Some(&before), "v7 block round-trips");

        // A restarted process: fresh trainer, same config, same snapshot —
        // the degraded membership is back in force on the fresh backend.
        let mut resumed = trainer(&rt, AlgorithmKind::Gossip, BackendKind::Tcp, 0.75);
        resumed.restore(&loaded).unwrap();
        assert_eq!(resumed.round_state().unwrap(), before);
        resumed.mute_node(3, true).unwrap(); // the peer is still wedged
        resumed.step_once().unwrap(); // and the degraded round still runs
        assert_eq!(resumed.peer_drops(), before.drops, "no re-drop of a dropped peer");

        // Resuming a degraded checkpoint WITHOUT the round machine would
        // silently un-drop dead peers — it must refuse instead.
        let mut no_rounds = trainer(&rt, AlgorithmKind::Gossip, BackendKind::Tcp, 0.0);
        let err = format!("{:#}", no_rounds.restore(&loaded).unwrap_err());
        assert!(err.contains("--round-timeout"), "{err}");
    });
}

#[test]
fn bench_seven_schema_holds_when_the_artifact_exists() {
    // Satellite: BENCH_7.json is anchored at CARGO_MANIFEST_DIR (the
    // BENCH_6 CWD-relative write is why no trajectory was ever
    // committed). The bench may not have run on this box; when the
    // artifact IS there, hold it to the schema the trajectory log reads.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_7.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("BENCH_7.json absent — run `cargo bench --bench perf_hotpath` to emit it");
        return;
    };
    let doc = Json::parse(&text).expect("BENCH_7.json parses");
    assert_eq!(
        doc.get("bench").and_then(|j| match j {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }),
        Some("transport_and_population")
    );
    for key in ["transport_rows", "population_rows"] {
        let Some(Json::Arr(rows)) = doc.get(key) else {
            panic!("BENCH_7.json missing array '{key}'");
        };
        for row in rows {
            for field in match key {
                "transport_rows" => vec!["op", "backend", "n", "d", "wall_seconds"],
                _ => vec!["n", "wall_seconds", "num_links"],
            } {
                assert!(row.get(field).is_some(), "{key} row missing '{field}'");
            }
        }
    }
}
