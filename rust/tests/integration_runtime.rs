//! Integration: AOT artifacts load, compile and execute through PJRT with
//! numerics matching rust-side oracles.

use std::sync::Arc;

use gossip_pga::coordinator::mixer::axpy;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::{lit_f32, lit_i32, GradFn, MixFn, Runtime};

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_default().expect("run `make artifacts` first"))
}

/// Rust-side oracle of the logistic loss+grad (mirrors kernels/ref.py).
fn logreg_ref(w: &[f32], x: &[f32], y: &[f32], d: usize) -> (f32, Vec<f32>) {
    let m = y.len();
    let mut loss = 0.0f64;
    let mut grad = vec![0.0f64; d];
    for s in 0..m {
        let row = &x[s * d..(s + 1) * d];
        let z: f64 = row.iter().zip(w).map(|(a, b)| *a as f64 * *b as f64).sum();
        let margin = y[s] as f64 * z;
        // ln(1 + exp(-margin)), stable
        loss += if margin > 0.0 {
            (-margin).exp().ln_1p()
        } else {
            -margin + margin.exp().ln_1p()
        };
        let sig = 1.0 / (1.0 + margin.exp());
        for (g, a) in grad.iter_mut().zip(row) {
            *g -= y[s] as f64 * sig * *a as f64;
        }
    }
    (
        (loss / m as f64) as f32,
        grad.into_iter().map(|g| (g / m as f64) as f32).collect(),
    )
}

#[test]
fn all_artifacts_compile() {
    let rt = runtime();
    let names: Vec<String> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
    assert!(names.len() >= 10, "expected a full artifact set, got {names:?}");
    for name in names {
        rt.executable(&name).unwrap_or_else(|e| panic!("compiling {name}: {e:#}"));
    }
}

#[test]
fn logreg_grad_matches_rust_oracle() {
    let rt = runtime();
    let spec = rt.manifest.find("logreg", "grad", None).unwrap().clone();
    let d = spec.flat_dim;
    let m = spec.meta_usize("batch").unwrap();
    let mut rng = Rng::new(42);
    let w = rng.normal_vec(d, 0.5);
    let x = rng.normal_vec(m * d, 1.5);
    let y: Vec<f32> = (0..m).map(|_| rng.sign_label(0.5)).collect();

    let grad_fn = GradFn::new(rt, &spec.name).unwrap();
    let mut grad = vec![0.0f32; d];
    let batch = vec![
        lit_f32(&x, &spec.inputs[1].shape).unwrap(),
        lit_f32(&y, &spec.inputs[2].shape).unwrap(),
    ];
    let loss = grad_fn.call_into(&w, batch, &mut grad).unwrap();

    let (loss_ref, grad_ref) = logreg_ref(&w, &x, &y, d);
    assert!((loss - loss_ref).abs() < 2e-5 * (1.0 + loss_ref.abs()), "{loss} vs {loss_ref}");
    for (a, b) in grad.iter().zip(&grad_ref) {
        assert!((a - b).abs() < 2e-5 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn pallas_mix_artifact_matches_rust_mixer() {
    // The Pallas gossip_mix kernel (HLO artifact) and the rust hot-path
    // mixing loop must agree: they are the same operator at L1 and L3.
    let rt = runtime();
    let spec = rt.manifest.by_name("gossip_mix_k3_d4096").unwrap().clone();
    let k = spec.inputs[0].shape[0];
    let d = spec.inputs[1].shape[1];
    let mut rng = Rng::new(7);
    let weights: Vec<f32> = {
        let raw: Vec<f32> = (0..k).map(|_| rng.f32() + 0.1).collect();
        let s: f32 = raw.iter().sum();
        raw.into_iter().map(|w| w / s).collect()
    };
    let stack = rng.normal_vec(k * d, 1.0);

    let mix = MixFn::new(rt, &spec.name).unwrap();
    let out = mix.call(&weights, &stack).unwrap();

    // rust oracle via axpy (the Mixer inner loop).
    let mut expect = vec![0.0f32; d];
    for j in 0..k {
        axpy(weights[j], &stack[j * d..(j + 1) * d], &mut expect);
    }
    for (a, b) in out.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn fused_update_artifact_matches_unfused() {
    let rt = runtime();
    let spec = rt.manifest.by_name("fused_update_k3_d10").unwrap().clone();
    let (k, d) = (3usize, 10usize);
    let mut rng = Rng::new(9);
    let weights = vec![0.5f32, 0.25, 0.25];
    let stack = rng.normal_vec(k * d, 1.0);
    let grad = rng.normal_vec(d, 1.0);
    let lr = 0.2f32;

    let inputs = vec![
        lit_f32(&weights, &[k]).unwrap(),
        lit_f32(&stack, &[k, d]).unwrap(),
        lit_f32(&grad, &[d]).unwrap(),
        lit_f32(&[lr], &[]).unwrap(),
    ];
    let outs = rt.run(&spec.name, &inputs).unwrap();
    let fused = outs[0].to_vec::<f32>().unwrap();

    // Unfused oracle: update row 0, then weighted sum.
    let mut updated = stack.clone();
    for (u, g) in updated[..d].iter_mut().zip(&grad) {
        *u -= lr * g;
    }
    let mut expect = vec![0.0f32; d];
    for j in 0..k {
        axpy(weights[j], &updated[j * d..(j + 1) * d], &mut expect);
    }
    for (a, b) in fused.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn mlp_grad_executes_and_is_finite() {
    let rt = runtime();
    let spec = rt.manifest.find("mlp", "grad", None).unwrap().clone();
    let d = spec.flat_dim;
    let m = spec.meta_usize("batch").unwrap();
    let in_dim = spec.meta_usize("in_dim").unwrap();
    let classes = spec.meta_usize("classes").unwrap();
    let layout = gossip_pga::model::mlp_layout(in_dim, spec.meta_usize("hidden").unwrap(), classes);
    let flat = layout.init(3);
    let mut rng = Rng::new(5);
    let x = rng.normal_vec(m * in_dim, 1.0);
    let y: Vec<i32> = (0..m).map(|_| rng.below(classes as u64) as i32).collect();

    let grad_fn = GradFn::new(rt, &spec.name).unwrap();
    let mut grad = vec![0.0f32; d];
    let batch = vec![
        lit_f32(&x, &spec.inputs[1].shape).unwrap(),
        lit_i32(&y, &spec.inputs[2].shape).unwrap(),
    ];
    let loss = grad_fn.call_into(&flat, batch, &mut grad).unwrap();
    // Fresh init on `classes` classes: loss near ln(classes).
    assert!((loss - (classes as f32).ln()).abs() < 0.5, "loss {loss}");
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(grad.iter().any(|g| g.abs() > 1e-8), "gradient all-zero");
}

#[test]
fn transformer_tiny_grad_executes() {
    let rt = runtime();
    let spec = rt.manifest.find("transformer", "grad", Some("tiny")).unwrap().clone();
    let d = spec.flat_dim;
    let cfg = gossip_pga::model::TransformerConfig {
        vocab: spec.meta_usize("vocab").unwrap(),
        d_model: spec.meta_usize("d_model").unwrap(),
        n_layers: spec.meta_usize("n_layers").unwrap(),
        n_heads: spec.meta_usize("n_heads").unwrap(),
        d_ff: spec.meta_usize("d_ff").unwrap(),
        seq_len: spec.meta_usize("seq_len").unwrap(),
    };
    let flat = gossip_pga::model::transformer_layout(&cfg).init(11);
    let b = spec.meta_usize("batch").unwrap();
    let mut rng = Rng::new(13);
    let toks: Vec<i32> =
        (0..b * (cfg.seq_len + 1)).map(|_| rng.below(cfg.vocab as u64) as i32).collect();

    let grad_fn = GradFn::new(rt, &spec.name).unwrap();
    let mut grad = vec![0.0f32; d];
    let batch = vec![lit_i32(&toks, &spec.inputs[1].shape).unwrap()];
    let loss = grad_fn.call_into(&flat, batch, &mut grad).unwrap();
    // Uniform-random tokens + fresh init: loss ~ ln(vocab).
    assert!((loss - (cfg.vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    assert!(grad.iter().all(|g| g.is_finite()));
}

#[test]
fn grad_execution_is_deterministic() {
    let rt = runtime();
    let spec = rt.manifest.find("logreg", "grad", None).unwrap().clone();
    let d = spec.flat_dim;
    let m = spec.meta_usize("batch").unwrap();
    let mut rng = Rng::new(21);
    let w = rng.normal_vec(d, 1.0);
    let x = rng.normal_vec(m * d, 1.0);
    let y: Vec<f32> = (0..m).map(|_| rng.sign_label(0.5)).collect();
    let grad_fn = GradFn::new(rt, &spec.name).unwrap();
    let mut g1 = vec![0.0f32; d];
    let mut g2 = vec![0.0f32; d];
    let mk = || {
        vec![
            lit_f32(&x, &spec.inputs[1].shape).unwrap(),
            lit_f32(&y, &spec.inputs[2].shape).unwrap(),
        ]
    };
    let l1 = grad_fn.call_into(&w, mk(), &mut g1).unwrap();
    let l2 = grad_fn.call_into(&w, mk(), &mut g2).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}
