//! Multi-round gossip pipelining acceptance suite
//! (`Mixer::with_depth` / `SharedBackend::with_depth` /
//! `TrainerOptions::pipeline_depth`).
//!
//! The contract under test: a depth-k pipeline of chained async gossip
//! rounds, drained strictly FIFO at every k·H global-average / eval /
//! checkpoint boundary, is **bit-identical** to the same schedule run
//! synchronously (BSP) — at every drained point, on every stock topology,
//! at any pool size, and across a mid-pipeline checkpoint/restore. Depth 1
//! must reproduce the pre-pipeline double buffer exactly, so the whole
//! feature is invisible unless you opt in.
//!
//! The mixer/backend replay layers need no AOT artifacts; the
//! trainer-level tests need `make artifacts` like the other integration
//! suites. `scripts/verify.sh` step 10 runs this suite.

use std::collections::VecDeque;
use std::sync::Arc;

use gossip_pga::algorithms::AlgorithmKind;
use gossip_pga::comm::{
    BackendKind, CommBackend, Compression, PendingComm, SharedBackend,
};
use gossip_pga::coordinator::mixer::Mixer;
use gossip_pga::coordinator::{logreg_workload, Trainer, TrainerOptions};
use gossip_pga::costmodel::{CostModel, NodeCosts};
use gossip_pga::eventsim::Regime;
use gossip_pga::exec::WorkerPool;
use gossip_pga::jsonio::Json;
use gossip_pga::optim::LrSchedule;
use gossip_pga::params::ParamMatrix;
use gossip_pga::rng::Rng;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::Topology;

/// The stock topology constructors every layer sweeps.
fn topologies() -> [fn(usize) -> Topology; 3] {
    [Topology::ring, Topology::grid, Topology::one_peer_expo]
}

/// Deterministic pseudo-gradient, applied identically on every replica so
/// any divergence comes from the pipeline alone.
fn perturb(params: &mut ParamMatrix, k: u64) {
    let mut rng = Rng::new(0xBEEF ^ k.wrapping_mul(0x9E37_79B9));
    let noise = rng.normal_vec(params.n() * params.d(), 0.05);
    for (p, g) in params.as_mut_slice().iter_mut().zip(&noise) {
        *p -= g;
    }
}

// ---------------------------------------------------------------------------
// Mixer layer: chained gossip_async against the synchronous round sequence.
// ---------------------------------------------------------------------------

#[test]
fn mixer_pipeline_matches_sync_rounds_at_every_drain() {
    // bursts x (fill the pipeline to depth, drain it FIFO) == the same
    // number of sync gossip calls, bit for bit, with a perturbation between
    // bursts (legal exactly because the pipeline is drained there).
    for mk in topologies() {
        let topo = mk(6);
        let d = 515; // exercises partial 8-lanes and a partial cache block
        for depth in [1usize, 2, 4] {
            for threads in [1usize, 4] {
                let pool = WorkerPool::new(threads);
                let mut sync_mixer = Mixer::new(&topo, d);
                let mut piped = Mixer::with_depth(&topo, d, depth);
                let mut want = ParamMatrix::random(&mut Rng::new(9), topo.n, d, 1.0);
                let mut got = ParamMatrix::random(&mut Rng::new(9), topo.n, d, 1.0);
                assert_eq!(got.as_slice(), want.as_slice());
                for burst in 0..3u64 {
                    let mut handles = VecDeque::new();
                    for _ in 0..depth {
                        assert!(piped.pipeline_ready(), "room before each issue");
                        handles.push_back(unsafe { piped.gossip_async(&got, &pool).unwrap() });
                    }
                    assert_eq!(piped.in_flight_rounds(), depth, "pipeline filled");
                    assert_eq!(piped.issued_clock(), piped.gossip_clock + depth);
                    while let Some(p) = handles.pop_front() {
                        piped.finish_gossip(&mut got, p).unwrap();
                    }
                    assert_eq!(piped.in_flight_rounds(), 0, "drained after each burst");
                    for _ in 0..depth {
                        sync_mixer.gossip(&mut want, &pool).unwrap();
                    }
                    assert_eq!(
                        got.as_slice(),
                        want.as_slice(),
                        "{:?} depth={depth} t={threads} burst={burst}: pipeline diverged",
                        topo.kind
                    );
                    assert_eq!(piped.gossip_clock, sync_mixer.gossip_clock);
                    perturb(&mut got, burst);
                    perturb(&mut want, burst);
                }
            }
        }
    }
}

#[test]
fn rolling_pipeline_never_fully_drained_mid_burst_still_matches() {
    // The steady-state shape the backend replay uses: finish the oldest
    // round only when the ring is full, so the pipeline stays occupied
    // across the whole burst and every slot gets recycled several times.
    let topo = Topology::one_peer_expo(8);
    let d = 300;
    let rounds = 11; // > depth * ring length, forces slot reuse
    for depth in [2usize, 4] {
        for threads in [1usize, 4] {
            let pool = WorkerPool::new(threads);
            let mut sync_mixer = Mixer::new(&topo, d);
            let mut piped = Mixer::with_depth(&topo, d, depth);
            let mut want = ParamMatrix::random(&mut Rng::new(11), topo.n, d, 1.0);
            let mut got = ParamMatrix::random(&mut Rng::new(11), topo.n, d, 1.0);
            let mut handles: VecDeque<_> = VecDeque::new();
            for _ in 0..rounds {
                if !piped.pipeline_ready() {
                    let oldest = handles.pop_front().unwrap();
                    piped.finish_gossip(&mut got, oldest).unwrap();
                }
                handles.push_back(unsafe { piped.gossip_async(&got, &pool).unwrap() });
            }
            while let Some(p) = handles.pop_front() {
                piped.finish_gossip(&mut got, p).unwrap();
            }
            for _ in 0..rounds {
                sync_mixer.gossip(&mut want, &pool).unwrap();
            }
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "depth={depth} t={threads}: rolling pipeline diverged"
            );
            assert_eq!(piped.gossip_clock, rounds);
        }
    }
}

// ---------------------------------------------------------------------------
// Backend layer: SharedBackend::with_depth under the k·H schedule.
// ---------------------------------------------------------------------------

/// Replay 3 periods of the PGA schedule — H pipelined gossip rounds, a
/// full drain, one global average, a perturbation — returning the final
/// matrix and the total billed sim seconds. `depth == 0` runs the whole
/// schedule synchronously (the BSP reference).
fn backend_replay(
    topo: &Topology,
    d: usize,
    h: usize,
    depth: usize,
    threads: usize,
) -> (ParamMatrix, f64) {
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
    let mut backend = if depth == 0 {
        SharedBackend::new(topo, d, &costs, d, Compression::None)
    } else {
        SharedBackend::with_depth(topo, d, &costs, d, Compression::None, depth)
    };
    let pool = WorkerPool::new(threads);
    let mut params = ParamMatrix::random(&mut Rng::new(53), topo.n, d, 1.0);
    let mut sim = 0.0;
    let mut pending: VecDeque<PendingComm> = VecDeque::new();
    for burst in 0..3u64 {
        for _ in 0..h {
            if depth == 0 {
                sim += backend.gossip(&mut params, &pool).unwrap().stats.sim_seconds;
            } else {
                if pending.len() == depth {
                    let oldest = pending.pop_front().unwrap();
                    sim += backend.finish(&mut params, oldest).unwrap().stats.sim_seconds;
                }
                let p = unsafe { backend.gossip_async(&params, &pool).unwrap() }
                    .expect("uncompressed shared backend supports async");
                pending.push_back(p);
            }
        }
        // The k·H boundary: drain everything, then the global barrier.
        while let Some(oldest) = pending.pop_front() {
            sim += backend.finish(&mut params, oldest).unwrap().stats.sim_seconds;
        }
        sim += backend.global_average(&mut params, &pool).unwrap().stats.sim_seconds;
        perturb(&mut params, burst);
    }
    (params, sim)
}

#[test]
fn backend_pipeline_matches_bsp_at_every_period_boundary() {
    let (d, h) = (129, 5); // h > depth forces steady-state ring reuse
    for mk in topologies() {
        let topo = mk(6);
        for threads in [1usize, 3] {
            let (want, want_sim) = backend_replay(&topo, d, h, 0, threads);
            for depth in [1usize, 2, 4] {
                let (got, got_sim) = backend_replay(&topo, d, h, depth, threads);
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "{:?} depth={depth} t={threads}: pipelined schedule diverged from BSP",
                    topo.kind
                );
                // Billing must follow the ISSUED round schedule too — on a
                // time-varying topology a wrong round index shows up here
                // even if the bits happen to agree.
                assert_eq!(got_sim, want_sim, "{:?} depth={depth}: billing drifted", topo.kind);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Trainer layer: pipeline_depth through TrainerOptions, checkpoint drain.
// ---------------------------------------------------------------------------

fn opts(n: usize, depth: usize, regime: Regime) -> TrainerOptions {
    TrainerOptions {
        algorithm: AlgorithmKind::GossipPga,
        topology: Topology::one_peer_expo(n),
        period: 4,
        aga_init_period: 2,
        aga_warmup: 4,
        lr: LrSchedule::Const { lr: 0.2 },
        momentum: 0.9,
        nesterov: true,
        seed: 29,
        slowmo: Default::default(),
        cost: CostModel::calibrated_resnet50(),
        cost_dim: 25_500_000,
        node_costs: None,
        stealing: false,
        pin: false,
        pipeline_depth: depth,
        log_every: 5,
        threads: 2,
        regime,
        max_staleness: 0,
        backend: BackendKind::Shared,
        compression: Compression::None,
        round_timeout: 0.0,
        listen: "127.0.0.1:0".to_string(),
    }
}

fn trainer(rt: &Arc<Runtime>, depth: usize, regime: Regime) -> Trainer {
    let n = 4;
    let (workload, init) = logreg_workload(rt.clone(), n, 256, true, 29).unwrap();
    Trainer::new(workload, init, opts(n, depth, regime)).unwrap()
}

#[test]
fn trainer_pipeline_depths_match_bsp_trajectory_bitwise() {
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    let steps = 14; // crosses several k·H boundaries
    let mut bsp = trainer(&rt, 1, Regime::Bsp);
    for _ in 0..steps {
        bsp.step_once().unwrap();
    }
    let want_loss = bsp.global_loss().unwrap();
    for depth in [1usize, 2, 4] {
        let mut t = trainer(&rt, depth, Regime::Overlap);
        for _ in 0..steps {
            t.step_once().unwrap();
        }
        // global_loss drains first (eval is a drained boundary), so this is
        // exactly the comparison the contract promises.
        let got_loss = t.global_loss().unwrap();
        assert_eq!(t.pending_rounds(), 0, "depth={depth}: eval left rounds in flight");
        assert_eq!(
            t.param_matrix().as_slice(),
            bsp.param_matrix().as_slice(),
            "depth={depth}: overlap trajectory diverged from BSP"
        );
        assert_eq!(got_loss, want_loss, "depth={depth}: loss diverged");
        assert_eq!(t.sim_seconds(), bsp.sim_seconds(), "depth={depth}: clocks diverged");
    }
}

#[test]
fn mid_pipeline_checkpoint_drains_and_resumes_bit_exactly() {
    // A checkpoint taken while a round is in flight must DRAIN the pipeline
    // (completing the issued work — the snapshot is a BSP step boundary),
    // not drop it; the restored run must continue on the exact bits and
    // land where the uninterrupted run does.
    let rt = Arc::new(Runtime::load_default().expect("run `make artifacts` first"));
    for depth in [2usize, 4] {
        let mut straight = trainer(&rt, depth, Regime::Overlap);
        let mut interrupted = trainer(&rt, depth, Regime::Overlap);
        // Step to a point where the overlap regime has a gossip in flight.
        let mut saw_inflight = false;
        for _ in 0..9 {
            straight.step_once().unwrap();
            interrupted.step_once().unwrap();
            saw_inflight |= interrupted.pending_rounds() > 0;
        }
        assert!(saw_inflight, "schedule never overlapped — the test lost its subject");
        let ck = interrupted.checkpoint().unwrap();
        assert_eq!(interrupted.pending_rounds(), 0, "checkpoint must drain, not drop");
        let mut resumed = trainer(&rt, depth, Regime::Overlap);
        resumed.restore(&ck).unwrap();
        for _ in 0..7 {
            straight.step_once().unwrap();
            interrupted.step_once().unwrap();
            resumed.step_once().unwrap();
        }
        let _ = straight.global_loss().unwrap(); // drains all three
        let _ = interrupted.global_loss().unwrap();
        let _ = resumed.global_loss().unwrap();
        assert_eq!(
            interrupted.param_matrix().as_slice(),
            straight.param_matrix().as_slice(),
            "depth={depth}: checkpointing mid-run changed the trajectory"
        );
        assert_eq!(
            resumed.param_matrix().as_slice(),
            straight.param_matrix().as_slice(),
            "depth={depth}: restore did not resume bit-exactly"
        );
        assert_eq!(resumed.gossip_clock(), straight.gossip_clock());
    }
}

#[test]
fn compressed_backend_keeps_its_sync_fallback_at_any_depth() {
    // The compressed transmit pass is ordered (error-feedback state), so
    // gossip_async declines regardless of the configured depth — the
    // trainer falls back to the synchronous round and counts it.
    let topo = Topology::ring(4);
    let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 4);
    let mut backend =
        SharedBackend::with_depth(&topo, 33, &costs, 33, Compression::TopK { frac: 0.5 }, 4);
    let pool = WorkerPool::new(1);
    let params = ParamMatrix::random(&mut Rng::new(3), 4, 33, 1.0);
    assert!(!backend.supports_overlap());
    let issued = unsafe { backend.gossip_async(&params, &pool).unwrap() };
    assert!(issued.is_none(), "compressed transmit must decline async issue");
}

// ---------------------------------------------------------------------------
// BENCH_8 schema gate (same pattern as transport.rs / BENCH_7).
// ---------------------------------------------------------------------------

#[test]
fn bench_eight_schema_holds_when_the_artifact_exists() {
    // The bench may not have run on this box; when BENCH_8.json IS there,
    // hold it to the schema EXPERIMENTS.md §Hot path reads.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_8.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("BENCH_8.json absent — run `cargo bench --bench perf_hotpath` to emit it");
        return;
    };
    let doc = Json::parse(&text).expect("BENCH_8.json parses");
    assert_eq!(
        doc.get("bench").and_then(|j| match j {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }),
        Some("hotpath_kernel_pin_pipeline")
    );
    for key in ["kernel_rows", "pin_rows", "pipeline_rows"] {
        let Some(Json::Arr(rows)) = doc.get(key) else {
            panic!("BENCH_8.json missing array '{key}'");
        };
        assert!(!rows.is_empty(), "'{key}' must not be empty");
        for row in rows {
            for field in match key {
                "kernel_rows" => vec!["kernel", "d", "deg", "mean_seconds", "bit_equal"],
                "pin_rows" => vec!["pinned", "threads", "d", "mean_seconds", "bit_equal"],
                _ => vec!["depth", "rounds", "d", "mean_seconds", "bit_equal"],
            } {
                assert!(row.get(field).is_some(), "{key} row missing '{field}'");
            }
            // The in-bench bit-equality assertions must have actually held.
            assert_eq!(row.get("bit_equal"), Some(&Json::Bool(true)), "{key}: bit_equal");
        }
    }
}
