//! Metrics: loss curves, consensus distance, transient-stage detection and
//! reporters (CSV / JSON / console).
//!
//! The transient stage (paper §1.1) is "the iterations before an algorithm
//! reaches its linear-speedup stage"; empirically (Fig. 1 caption) it is
//! measured by "counting iterations before an algorithm exactly matches the
//! convergence curve of Parallel SGD". [`transient_stage`] implements that
//! detector: the last iteration after which the algorithm's curve stays
//! within a relative `tol` band of the parallel-SGD reference.

use crate::comm::CommStats;
use crate::exec::WorkerPool;
use crate::jsonio::{self, Json};
use crate::obs::Counters;
use crate::params::ParamMatrix;

/// The logged column set, in CSV order — the SINGLE source the CSV
/// header, the JSON keys, and the column-parity test all read. Adding a
/// [`Record`] field means adding its name here and its accessor in
/// [`Record::column`]; nothing else (a mismatch fails the
/// `columns_cover_every_reporter` test instead of silently skipping a
/// reporter).
pub const COLUMNS: [&str; 19] = [
    "step",
    "loss",
    "consensus",
    "lr",
    "sim_seconds",
    "comm_scalars",
    "comm_msgs",
    "sim_min_seconds",
    "straggler_slack",
    "barrier_wait",
    "stale_max",
    "stale_mean",
    "link_util",
    "peer_drops",
    "row_renorms",
    "stale_frames",
    "fallback_rounds",
    "spans_dropped",
    "pool_panics",
];

/// A column value: integers stay integers in both the CSV cell and the
/// JSON array element.
enum ColValue {
    U(u64),
    F(f64),
}

/// One logged training step.
#[derive(Clone, Copy, Debug)]
pub struct Record {
    pub step: usize,
    /// Mean training loss across workers.
    pub loss: f64,
    /// Consensus distance (1/n) sum_i ||x_i - x_bar||^2.
    pub consensus: f64,
    pub lr: f64,
    /// Simulated wall-clock (cost-model) seconds since start: the critical
    /// path through the per-node virtual clocks (the slowest node).
    pub sim_seconds: f64,
    /// Cumulative wire scalars (f32-equivalents) the run's communication
    /// backend has moved up to this step (see [`crate::comm::CommStats`]).
    pub comm_scalars: u64,
    /// Cumulative message count over the same accounting.
    pub comm_msgs: u64,
    /// The fastest node's virtual clock (== `sim_seconds` when per-node
    /// charges are uniform: homogeneous costs on a regular topology).
    pub sim_min_seconds: f64,
    /// Straggler slack: `sim_seconds - sim_min_seconds`, captured before
    /// the eval barrier syncs the cluster. 0 when charges are uniform;
    /// nonzero under cost stragglers AND under structural asymmetry (a
    /// star's leaves trail its hub even with identical node costs).
    pub straggler_slack: f64,
    /// Cumulative seconds nodes have spent stalled at synchronization
    /// barriers behind slower peers, summed over nodes.
    pub barrier_wait: f64,
    /// Async regime: worst staleness (versions behind BSP-fresh) any mix
    /// input has used so far. 0 outside the async regime and in strict
    /// (max_staleness = 0) runs.
    pub stale_max: u64,
    /// Async regime: mean staleness over all mix inputs so far.
    pub stale_mean: f64,
    /// Async regime: mean per-link utilization of the event plane
    /// (transfer occupancy / elapsed critical path, averaged over
    /// directed links). 0 outside the async regime.
    pub link_util: f64,
    /// Peers dropped by the round machine's per-receive deadline so far
    /// (cumulative; 0 without `--round-timeout`).
    pub peer_drops: u64,
    /// Mixing rows renormalized by those drops (cumulative; each drop
    /// folds the dead peer's weight back onto every live row that carried
    /// it).
    pub row_renorms: u64,
    /// Frames discarded on receipt because their epoch tag belonged to an
    /// aborted or already-drained round (cumulative; bus/tcp only — see
    /// [`crate::comm::CommStats::stale_frames_dropped`]). Always 0 on a
    /// clean overlapped run.
    pub stale_frames: u64,
    /// Overlap gossip rounds that fell back to the synchronous path
    /// (cumulative; compressed transmit is the one remaining fallback).
    pub fallback_rounds: u64,
    /// Trace spans evicted from the run's ring buffer so far (drop-oldest
    /// overflow; always 0 when `--trace` is off).
    pub spans_dropped: u64,
    /// Worker-pool jobs that panicked (the pool poisons itself on the
    /// first, so a finished run normally logs 0).
    pub pool_panics: u64,
}

impl Record {
    /// The value of the named [`COLUMNS`] entry.
    fn column(&self, name: &str) -> ColValue {
        match name {
            "step" => ColValue::U(self.step as u64),
            "loss" => ColValue::F(self.loss),
            "consensus" => ColValue::F(self.consensus),
            "lr" => ColValue::F(self.lr),
            "sim_seconds" => ColValue::F(self.sim_seconds),
            "comm_scalars" => ColValue::U(self.comm_scalars),
            "comm_msgs" => ColValue::U(self.comm_msgs),
            "sim_min_seconds" => ColValue::F(self.sim_min_seconds),
            "straggler_slack" => ColValue::F(self.straggler_slack),
            "barrier_wait" => ColValue::F(self.barrier_wait),
            "stale_max" => ColValue::U(self.stale_max),
            "stale_mean" => ColValue::F(self.stale_mean),
            "link_util" => ColValue::F(self.link_util),
            "peer_drops" => ColValue::U(self.peer_drops),
            "row_renorms" => ColValue::U(self.row_renorms),
            "stale_frames" => ColValue::U(self.stale_frames),
            "fallback_rounds" => ColValue::U(self.fallback_rounds),
            "spans_dropped" => ColValue::U(self.spans_dropped),
            "pool_panics" => ColValue::U(self.pool_panics),
            other => unreachable!("column '{other}' is not in metrics::COLUMNS"),
        }
    }
}

/// A training history for one run.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub label: String,
    pub records: Vec<Record>,
}

impl History {
    pub fn new(label: impl Into<String>) -> History {
        History { label: label.into(), records: Vec::new() }
    }

    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn losses(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.loss).collect()
    }

    pub fn final_loss(&self) -> f64 {
        self.records.last().map_or(f64::NAN, |r| r.loss)
    }

    pub fn final_sim_hours(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.sim_seconds / 3600.0)
    }

    /// First step whose loss falls at or below `target` (paper's
    /// "epochs/hrs to 76%" columns); None if never reached.
    pub fn first_step_below(&self, target: f64) -> Option<&Record> {
        self.records.iter().find(|r| r.loss <= target)
    }

    pub fn to_csv(&self) -> String {
        // New columns append after the PR-3 layout so downstream readers
        // keyed on the old prefix keep working; the header IS the
        // [`COLUMNS`] registry.
        let mut out = COLUMNS.join(",");
        out.push('\n');
        for r in &self.records {
            let cells: Vec<String> = COLUMNS
                .iter()
                .map(|c| match r.column(c) {
                    ColValue::U(v) => v.to_string(),
                    ColValue::F(v) => v.to_string(),
                })
                .collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        // One array per [`COLUMNS`] entry (same registry as the CSV
        // header); integer columns stay integer arrays.
        let mut fields: Vec<(&str, Json)> = vec![("label", Json::Str(self.label.clone()))];
        for name in COLUMNS {
            let integral = self
                .records
                .first()
                .map_or(true, |r| matches!(r.column(name), ColValue::U(_)));
            let arr = if integral {
                jsonio::u64_arr(
                    &self
                        .records
                        .iter()
                        .map(|r| match r.column(name) {
                            ColValue::U(v) => v,
                            ColValue::F(_) => unreachable!("column '{name}' changed kind"),
                        })
                        .collect::<Vec<_>>(),
                )
            } else {
                jsonio::num_arr(
                    &self
                        .records
                        .iter()
                        .map(|r| match r.column(name) {
                            ColValue::U(v) => v as f64,
                            ColValue::F(v) => v,
                        })
                        .collect::<Vec<_>>(),
                )
            };
            fields.push((name, arr));
        }
        jsonio::obj(fields)
    }

    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// The CLI's end-of-run `# traffic:` line, rendered from the same
/// [`Counters`] registry the CSV/JSON columns read (the parity test pins
/// that every registered counter appears here by name).
pub fn traffic_line(backend: &str, comm: &CommStats, counters: &Counters) -> String {
    format!(
        "# traffic ({backend} backend): {} msgs | {} scalars ({:.2} MB) | {:.1}s comm sim time | {}",
        comm.msgs,
        comm.scalars_sent,
        comm.bytes_sent() as f64 / 1e6,
        comm.sim_seconds,
        counters.render()
    )
}

/// Consensus distance (1/n) sum_i ||x_i - x_bar||^2 over the contiguous
/// worker parameter matrix (no per-call copy — the trainer logs this
/// directly off its live [`ParamMatrix`]).
pub fn consensus_distance(params: &ParamMatrix) -> f64 {
    consensus_distance_iter(params.n(), params.d(), params.rows())
}

/// [`consensus_distance`] sharded across the worker pool — the logging-path
/// variant (consensus is O(n d), the last big sequential loop PR 1 left on
/// that path). Deterministic at ANY pool size: the column means accumulate
/// rows-ascending per column, each row's squared distance reduces
/// columns-ascending into its own slot, and the slots reduce in row order —
/// the same additions in the same order regardless of sharding. (The
/// scalar [`consensus_distance`] groups its f64 total differently, so the
/// two can differ in the last ulps; within one variant all shard counts are
/// bit-identical.) Falls back to the scalar path if the pool is poisoned.
pub fn consensus_distance_pooled(params: &ParamMatrix, pool: &WorkerPool) -> f64 {
    let (n, d) = (params.n(), params.d());
    if n == 0 || d == 0 {
        return 0.0;
    }
    let src = params.as_slice();
    // Phase A: column-sharded mean.
    let mut mean = vec![0.0f64; d];
    let t = pool.shards(d);
    let per = (d + t - 1) / t;
    let mean_jobs: Vec<_> = mean
        .chunks_mut(per)
        .enumerate()
        .map(|(ci, mchunk)| {
            move || {
                let off = ci * per;
                for r in 0..n {
                    let row = &src[r * d + off..r * d + off + mchunk.len()];
                    for (m, v) in mchunk.iter_mut().zip(row) {
                        *m += *v as f64;
                    }
                }
                for m in mchunk.iter_mut() {
                    *m /= n as f64;
                }
                Ok(())
            }
        })
        .collect();
    if pool.run(mean_jobs).is_err() {
        return consensus_distance(params);
    }
    // Phase B: row-sharded squared distances, one slot per row.
    let mut slots = vec![0.0f64; n];
    let t = pool.shards(n);
    let per = (n + t - 1) / t;
    let mean_ref = &mean;
    let slot_jobs: Vec<_> = slots
        .chunks_mut(per)
        .enumerate()
        .map(|(ci, chunk)| {
            move || {
                for (j, slot) in chunk.iter_mut().enumerate() {
                    let i = ci * per + j;
                    let row = &src[i * d..(i + 1) * d];
                    let mut acc = 0.0f64;
                    for (m, v) in mean_ref.iter().zip(row) {
                        let diff = *v as f64 - m;
                        acc += diff * diff;
                    }
                    *slot = acc;
                }
                Ok(())
            }
        })
        .collect();
    if pool.run(slot_jobs).is_err() {
        return consensus_distance(params);
    }
    slots.iter().sum::<f64>() / n as f64
}

/// [`consensus_distance`] over loose per-worker rows (test/interop helper).
pub fn consensus_distance_rows(params: &[Vec<f32>]) -> f64 {
    let d = params.first().map_or(0, |p| p.len());
    consensus_distance_iter(params.len(), d, params.iter().map(|p| p.as_slice()))
}

fn consensus_distance_iter<'a>(
    n: usize,
    d: usize,
    rows: impl Iterator<Item = &'a [f32]> + Clone,
) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let mut mean = vec![0.0f64; d];
    for p in rows.clone() {
        for (m, v) in mean.iter_mut().zip(p) {
            *m += *v as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut total = 0.0;
    for p in rows {
        for (m, v) in mean.iter().zip(p) {
            let diff = *v as f64 - m;
            total += diff * diff;
        }
    }
    total / n as f64
}

/// Consensus distance over a population of scalar iterates: `(1/n) sum_i
/// (x_i - x_bar)^2` — the d = 1 specialization the surrogate population
/// plane logs (each virtual node carries a scalar mean instead of a model
/// row). Accumulates in f64, ascending, so curves are deterministic at any
/// chunking of the sweep loop. Ignores nothing: callers filter to the live
/// population before calling.
pub fn scalar_consensus(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64
}

/// Empirical transient stage: smallest t such that for every logged step
/// >= t the candidate's loss is within `tol` (relative) of the reference
/// (Parallel SGD) loss at the same step. Both histories must be logged on
/// the same step grid. Returns `None` if the curves never merge.
pub fn transient_stage(candidate: &[f64], reference: &[f64], tol: f64) -> Option<usize> {
    assert_eq!(candidate.len(), reference.len(), "histories on different grids");
    let n = candidate.len();
    if n == 0 {
        return None;
    }
    // Walk backwards: find the last index that is OUT of the band.
    let mut last_bad = None;
    for i in (0..n).rev() {
        let r = reference[i].abs().max(1e-12);
        if (candidate[i] - reference[i]).abs() / r > tol {
            last_bad = Some(i);
            break;
        }
    }
    match last_bad {
        None => Some(0),
        Some(i) if i + 1 < n => Some(i + 1),
        Some(_) => None, // still diverged at the end
    }
}

/// Progress-scaled transient detector: the band is `frac` of the
/// reference's TOTAL progress (initial loss - floor) rather than relative
/// to the loss value — robust when the objective plateaus high (non-iid
/// floors near ln 2) and the method gaps live in the last decimals.
/// Returns the first index after which the candidate stays inside the band.
pub fn transient_stage_scaled(candidate: &[f64], reference: &[f64], frac: f64) -> Option<usize> {
    assert_eq!(candidate.len(), reference.len());
    let n = reference.len();
    if n == 0 {
        return None;
    }
    let floor = reference
        .iter()
        .chain(candidate.iter())
        .fold(f64::INFINITY, |m, &x| m.min(x));
    let progress = (reference[0] - floor).max(1e-12);
    let band = frac * progress;
    let mut last_bad = None;
    for i in (0..n).rev() {
        if (candidate[i] - reference[i]).abs() > band {
            last_bad = Some(i);
            break;
        }
    }
    match last_bad {
        None => Some(0),
        Some(i) if i + 1 < n => Some(i + 1),
        Some(_) => None,
    }
}

/// Smooth a curve with a trailing moving average (stabilizes the detector
/// against minibatch noise before comparing runs).
pub fn smooth(xs: &[f64], window: usize) -> Vec<f64> {
    let w = window.max(1);
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0;
    for i in 0..xs.len() {
        acc += xs[i];
        if i >= w {
            acc -= xs[i - w];
        }
        out.push(acc / (i.min(w - 1) + 1) as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consensus_zero_when_equal() {
        let p = vec![vec![1.0f32, 2.0]; 5];
        assert!(consensus_distance_rows(&p) < 1e-12);
        assert!(consensus_distance(&ParamMatrix::from_rows(&p)) < 1e-12);
    }

    #[test]
    fn consensus_known_value() {
        // two workers at +-1 around mean 0: each ||x_i - x_bar||^2 = d.
        let p = vec![vec![1.0f32; 4], vec![-1.0f32; 4]];
        assert!((consensus_distance_rows(&p) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn consensus_matrix_matches_rows() {
        let rows = vec![vec![0.5f32, -1.0, 3.0], vec![2.0, 0.0, -0.5], vec![1.0, 1.0, 1.0]];
        let m = ParamMatrix::from_rows(&rows);
        assert_eq!(consensus_distance(&m), consensus_distance_rows(&rows));
    }

    #[test]
    fn consensus_pooled_matches_scalar_within_rounding() {
        let m = ParamMatrix::random(&mut crate::rng::Rng::new(5), 7, 33, 1.0);
        let scalar = consensus_distance(&m);
        let pooled = consensus_distance_pooled(&m, &WorkerPool::new(1));
        assert!(
            (scalar - pooled).abs() <= 1e-12 * scalar.max(1.0),
            "{scalar} vs {pooled}"
        );
    }

    #[test]
    fn consensus_pooled_is_shard_count_invariant() {
        // The logging-path determinism contract: every pool size produces
        // the exact same bits (fixed accumulation orders throughout).
        let m = ParamMatrix::random(&mut crate::rng::Rng::new(9), 6, 41, 2.0);
        let reference = consensus_distance_pooled(&m, &WorkerPool::new(1));
        for threads in [2usize, 3, 5, 16] {
            let got = consensus_distance_pooled(&m, &WorkerPool::new(threads));
            assert!(got == reference, "threads {threads}: {got} != {reference}");
        }
    }

    #[test]
    fn scalar_consensus_matches_dense_d1() {
        let vals = [1.0, -1.0, 3.0, 0.5];
        let rows: Vec<Vec<f32>> = vals.iter().map(|&v| vec![v as f32]).collect();
        let dense = consensus_distance_rows(&rows);
        let scalar = scalar_consensus(&vals);
        assert!((dense - scalar).abs() < 1e-6, "{dense} vs {scalar}");
        assert_eq!(scalar_consensus(&[]), 0.0);
        assert_eq!(scalar_consensus(&[7.0, 7.0, 7.0]), 0.0);
    }

    #[test]
    fn transient_detects_merge_point() {
        // Candidate is off by 50% until step 10, then identical.
        let reference: Vec<f64> = (0..50).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let mut candidate = reference.clone();
        for i in 0..10 {
            candidate[i] *= 1.5;
        }
        assert_eq!(transient_stage(&candidate, &reference, 0.05), Some(10));
    }

    #[test]
    fn transient_zero_when_identical() {
        let r: Vec<f64> = (0..20).map(|i| (i as f64).exp().recip()).collect();
        assert_eq!(transient_stage(&r, &r, 0.01), Some(0));
    }

    #[test]
    fn transient_none_when_diverged() {
        let reference = vec![1.0; 20];
        let candidate = vec![2.0; 20];
        assert_eq!(transient_stage(&candidate, &reference, 0.05), None);
    }

    #[test]
    fn smooth_flattens_noise() {
        let noisy: Vec<f64> = (0..100).map(|i| 1.0 + if i % 2 == 0 { 0.1 } else { -0.1 }).collect();
        let s = smooth(&noisy, 10);
        assert!(s[50..].iter().all(|&x| (x - 1.0).abs() < 0.02));
    }

    #[test]
    fn history_csv_and_target() {
        let mut h = History::new("test");
        for i in 0..5 {
            h.push(Record {
                step: i,
                loss: 1.0 / (i + 1) as f64,
                consensus: 0.0,
                lr: 0.1,
                sim_seconds: i as f64,
                comm_scalars: 100 * i as u64,
                comm_msgs: 2 * i as u64,
                sim_min_seconds: i as f64 * 0.5,
                straggler_slack: i as f64 * 0.5,
                barrier_wait: i as f64 * 0.25,
                stale_max: i as u64,
                stale_mean: i as f64 * 0.5,
                link_util: i as f64 * 0.125,
                peer_drops: i as u64 / 2,
                row_renorms: i as u64,
                stale_frames: 3 * i as u64,
                fallback_rounds: 4 * i as u64,
                spans_dropped: 5 * i as u64,
                pool_panics: 0,
            });
        }
        assert_eq!(h.first_step_below(0.35).unwrap().step, 2);
        assert!(h.first_step_below(0.0).is_none());
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 6);
        assert!(csv.starts_with("step,loss"));
        // The PR-3 column prefix is stable; later columns append.
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .starts_with("step,loss,consensus,lr,sim_seconds,comm_scalars,comm_msgs"));
        assert!(csv.lines().next().unwrap().contains(
            "stale_max,stale_mean,link_util,peer_drops,row_renorms,stale_frames"
        ));
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("stale_frames,fallback_rounds,spans_dropped,pool_panics"));
        assert!(csv.lines().nth(3).unwrap().contains(",200,4,"));
        assert!(csv.lines().nth(3).unwrap().ends_with(",1,1,0.5,2,1,0.25,1,2,6,12,15,0"));
        let j = h.to_json().dump();
        assert!(j.contains("\"label\":\"test\""));
        assert!(j.contains("\"step\":[0,1,2,3,4]"));
        assert!(j.contains("\"lr\":[0.1,0.1,0.1,0.1,0.1]"));
        assert!(j.contains("\"comm_scalars\":[0,100,200,300,400]"));
        assert!(j.contains("\"comm_msgs\":[0,2,4,6,8]"));
        assert!(j.contains("\"straggler_slack\":[0,0.5,1,1.5,2]"));
        assert!(j.contains("\"barrier_wait\":[0,0.25,0.5,0.75,1]"));
        assert!(j.contains("\"stale_max\":[0,1,2,3,4]"));
        assert!(j.contains("\"link_util\":[0,0.125,0.25,0.375,0.5]"));
        assert!(j.contains("\"peer_drops\":[0,0,1,1,2]"));
        assert!(j.contains("\"row_renorms\":[0,1,2,3,4]"));
        assert!(j.contains("\"stale_frames\":[0,3,6,9,12]"));
        assert!(j.contains("\"fallback_rounds\":[0,4,8,12,16]"));
        assert!(j.contains("\"spans_dropped\":[0,5,10,15,20]"));
        assert!(j.contains("\"pool_panics\":[0,0,0,0,0]"));
    }

    #[test]
    fn columns_cover_every_reporter() {
        // The parity contract: CSV header, JSON keys and the `# traffic:`
        // line all enumerate exactly the COLUMNS registry — adding a
        // counter in one place and not the others fails here.
        let mut h = History::new("parity");
        h.push(Record {
            step: 1,
            loss: 0.5,
            consensus: 0.1,
            lr: 0.05,
            sim_seconds: 2.0,
            comm_scalars: 10,
            comm_msgs: 3,
            sim_min_seconds: 1.0,
            straggler_slack: 1.0,
            barrier_wait: 0.5,
            stale_max: 1,
            stale_mean: 0.5,
            link_util: 0.25,
            peer_drops: 1,
            row_renorms: 2,
            stale_frames: 3,
            fallback_rounds: 4,
            spans_dropped: 5,
            pool_panics: 6,
        });
        // CSV header == the registry, verbatim.
        let csv = h.to_csv();
        assert_eq!(csv.lines().next().unwrap(), COLUMNS.join(","));
        // Every data row has exactly one cell per column.
        assert_eq!(csv.lines().nth(1).unwrap().split(',').count(), COLUMNS.len());
        // JSON keys == {label} ∪ COLUMNS, each column an array.
        let j = h.to_json();
        assert!(j.get("label").is_some());
        for name in COLUMNS {
            let arr = j.get(name).and_then(|v| v.as_arr());
            assert!(arr.is_some_and(|a| a.len() == 1), "JSON missing column '{name}'");
        }
        // Every registered counter is a column AND appears by name in the
        // traffic line.
        let counters = Counters {
            stale_frames: 3,
            peer_drops: 1,
            row_renorms: 2,
            fallback_rounds: 4,
            spans_dropped: 5,
            pool_panics: 6,
        };
        let comm = CommStats::default();
        let line = traffic_line("shared", &comm, &counters);
        assert!(line.starts_with("# traffic (shared backend):"));
        for (name, value) in counters.iter() {
            assert!(COLUMNS.contains(&name), "counter '{name}' missing from COLUMNS");
            let cell = format!("{name}={value}");
            assert!(line.contains(&cell), "traffic line missing '{cell}': {line}");
        }
    }
}
