//! Contiguous parameter storage for the worker ensemble.
//!
//! [`ParamMatrix`] is the single n x d `Vec<f32>` behind the whole training
//! loop: worker i's parameters are row i (`data[i*d .. (i+1)*d]`, row-major).
//! The mixer, the trainer, the metrics and the checkpointer all operate on
//! this one allocation, which buys:
//!
//! * cache-friendly gossip mixing — a weighted-sum pass streams rows
//!   sequentially instead of chasing `Vec<Vec<f32>>` pointers;
//! * zero-copy hand-off between phases — no more per-action swap dance
//!   moving worker vectors in and out of a scratch matrix;
//! * safe parallelism — `as_mut_slice().chunks_mut(d)` splits the matrix
//!   into disjoint per-row (or per-row-block) `&mut [f32]` views that scoped
//!   threads can own simultaneously.
//!
//! Determinism note: every op here fixes its accumulation order (rows
//! ascending, columns ascending) so results are bit-identical regardless of
//! how callers shard the work across threads.

pub mod pool;

/// Dense n x d row-major f32 matrix of per-worker parameter vectors.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamMatrix {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl ParamMatrix {
    /// All-zeros n x d matrix.
    pub fn zeros(n: usize, d: usize) -> ParamMatrix {
        ParamMatrix { n, d, data: vec![0.0; n * d] }
    }

    /// n copies of one initial parameter vector (the usual trainer start).
    pub fn broadcast(n: usize, row: &[f32]) -> ParamMatrix {
        let d = row.len();
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.extend_from_slice(row);
        }
        ParamMatrix { n, d, data }
    }

    /// n x d matrix of N(0, scale^2) entries, drawn row-major from `rng`
    /// (test/bench helper).
    pub fn random(rng: &mut crate::rng::Rng, n: usize, d: usize, scale: f32) -> ParamMatrix {
        ParamMatrix { n, d, data: rng.normal_vec(n * d, scale) }
    }

    /// Build from per-worker rows; panics on ragged input.
    pub fn from_rows(rows: &[Vec<f32>]) -> ParamMatrix {
        let n = rows.len();
        let d = rows.first().map_or(0, |r| r.len());
        assert!(rows.iter().all(|r| r.len() == d), "ragged rows");
        let mut data = Vec::with_capacity(n * d);
        for r in rows {
            data.extend_from_slice(r);
        }
        ParamMatrix { n, d, data }
    }

    /// Take ownership of a flat row-major buffer (len must be n*d).
    pub fn from_flat(n: usize, d: usize, data: Vec<f32>) -> ParamMatrix {
        assert_eq!(data.len(), n * d, "flat buffer length");
        ParamMatrix { n, d, data }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// The whole matrix as one flat row-major slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat view; `chunks_mut(d)` yields disjoint row views that can
    /// be distributed across threads.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Iterate rows (ascending worker index).
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.d.max(1))
    }

    /// Iterate disjoint mutable rows (ascending worker index).
    pub fn rows_mut(&mut self) -> impl Iterator<Item = &mut [f32]> {
        self.data.chunks_exact_mut(self.d.max(1))
    }

    /// Copy `src` into row i.
    pub fn copy_row_from(&mut self, i: usize, src: &[f32]) {
        self.row_mut(i).copy_from_slice(src);
    }

    /// Overwrite every row with `row` (e.g. the SlowMo outer iterate).
    pub fn fill_rows(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "row length");
        for r in self.rows_mut() {
            r.copy_from_slice(row);
        }
    }

    /// out += a * row(i)  (axpy against one stored row).
    pub fn axpy_row(&self, i: usize, a: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        for (o, v) in out.iter_mut().zip(self.row(i)) {
            *o += a * v;
        }
    }

    /// Column-wise mean over rows, written into `out` (len d). Accumulates
    /// in f32, rows ascending — the exact op the trainer always used, so the
    /// mean is bit-identical to the historical `mean_params`.
    pub fn mean_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.d, "mean_into length");
        out.fill(0.0);
        for r in self.rows() {
            for (m, v) in out.iter_mut().zip(r) {
                *m += v;
            }
        }
        let inv = 1.0 / self.n as f32;
        for m in out.iter_mut() {
            *m *= inv;
        }
    }

    /// Column-wise mean over rows as a fresh vector.
    pub fn mean_row(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        self.mean_into(&mut out);
        out
    }

    /// O(1) storage swap with a same-shape matrix (mixer double-buffering;
    /// in overlap mode this is the drain's buffer flip).
    pub fn swap_data(&mut self, other: &mut ParamMatrix) {
        assert!(self.n == other.n && self.d == other.d, "shape mismatch");
        std::mem::swap(&mut self.data, &mut other.data);
    }

    /// Disjoint blocks of `per` consecutive rows, each as one flat mutable
    /// slice (the worker pool's sharding view; the last block may be
    /// shorter). Safe to hand one block per pool job.
    pub fn row_blocks_mut(&mut self, per: usize) -> impl Iterator<Item = &mut [f32]> {
        self.data.chunks_mut(per.max(1) * self.d.max(1))
    }

    /// Copy out as per-worker rows (interop/debug; allocates).
    pub fn to_rows(&self) -> Vec<Vec<f32>> {
        self.rows().map(|r| r.to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_and_rows() {
        let m = ParamMatrix::broadcast(3, &[1.0, 2.0]);
        assert_eq!((m.n(), m.d()), (3, 2));
        for r in m.rows() {
            assert_eq!(r, &[1.0, 2.0]);
        }
    }

    #[test]
    fn row_views_are_disjoint_and_indexed() {
        let mut m = ParamMatrix::zeros(4, 3);
        for (i, r) in m.rows_mut().enumerate() {
            r.fill(i as f32);
        }
        assert_eq!(m.row(0), &[0.0; 3]);
        assert_eq!(m.row(3), &[3.0; 3]);
        assert_eq!(m.as_slice().len(), 12);
    }

    #[test]
    fn from_rows_roundtrip() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let m = ParamMatrix::from_rows(&rows);
        assert_eq!(m.to_rows(), rows);
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn from_rows_rejects_ragged() {
        ParamMatrix::from_rows(&[vec![1.0f32], vec![1.0, 2.0]]);
    }

    #[test]
    fn mean_matches_naive() {
        let m = ParamMatrix::from_rows(&[vec![1.0f32, 0.0], vec![3.0, 2.0]]);
        assert_eq!(m.mean_row(), vec![2.0, 1.0]);
    }

    #[test]
    fn axpy_row_accumulates() {
        let m = ParamMatrix::from_rows(&[vec![1.0f32, 2.0], vec![10.0, 20.0]]);
        let mut out = vec![1.0f32, 1.0];
        m.axpy_row(1, 0.5, &mut out);
        assert_eq!(out, vec![6.0, 11.0]);
    }

    #[test]
    fn fill_rows_broadcasts() {
        let mut m = ParamMatrix::zeros(3, 2);
        m.fill_rows(&[7.0, 8.0]);
        assert!(m.rows().all(|r| r == [7.0, 8.0]));
    }

    #[test]
    fn swap_data_is_o1_exchange() {
        let mut a = ParamMatrix::broadcast(2, &[1.0]);
        let mut b = ParamMatrix::broadcast(2, &[2.0]);
        a.swap_data(&mut b);
        assert_eq!(a.row(0), &[2.0]);
        assert_eq!(b.row(0), &[1.0]);
    }

    #[test]
    fn chunked_mut_views_split_rows_cleanly() {
        // The pattern the pooled trainer uses: blocks of rows_per_job rows,
        // re-chunked by d inside each job.
        let mut m = ParamMatrix::zeros(5, 4);
        let d = m.d();
        let per = 2usize;
        for (ci, chunk) in m.row_blocks_mut(per).enumerate() {
            for (k, row) in chunk.chunks_mut(d).enumerate() {
                row.fill((ci * per + k) as f32);
            }
        }
        for i in 0..5 {
            assert!(m.row(i).iter().all(|&v| v == i as f32), "row {i}");
        }
    }

    #[test]
    fn row_blocks_mut_covers_all_rows_with_short_tail() {
        let mut m = ParamMatrix::zeros(5, 3);
        let blocks: Vec<usize> = m.row_blocks_mut(2).map(|b| b.len()).collect();
        assert_eq!(blocks, vec![6, 6, 3], "2+2+1 rows of d=3");
    }
}
