//! Ref-counted payload pool — the storage plane behind the event engine's
//! population split (materialized workers vs virtual nodes).
//!
//! PR 5 gave every directed link its own `Vec<f32>` cache and every
//! in-flight message its own payload copy: O(edges * d) memory, which caps
//! the event plane at a few hundred nodes. The pool fixes the identity
//! problem behind that cost: a node that pushes one iterate to `deg`
//! out-neighbors produces ONE payload, not `deg` copies. Slots are
//! ref-counted and interned by `(src, version)` — every link cache and
//! every mid-flight message holds a [`PayloadHandle`] into the pool, so
//! live storage is O(distinct live versions * d), bounded by
//! n * (staleness window) regardless of edge count.
//!
//! Two payload kinds share the slot table:
//!
//! * [`Payload::Dense`] — a real d-vector (materialized workers, and
//!   virtual nodes running the small-d drift model);
//! * [`Payload::Stat`] — the statistical surrogate `(mean, var)` used by
//!   `--surrogate` population sweeps, where no dense scalar is ever
//!   allocated (asserted by the audit counters below).
//!
//! Audit counters ([`PayloadPool::peak_live_slots`],
//! [`PayloadPool::peak_dense_scalars`]) exist so the large-n test suite can
//! assert the memory claim instead of trusting it: a 10^5-node surrogate
//! sweep must finish with `peak_dense_scalars() == 0`, and any sweep must
//! keep `peak_live_slots` far below the directed-edge count.
//!
//! Determinism: the intern map is only ever used for keyed lookup (never
//! iterated), so pooling cannot perturb event order or parameter bits —
//! interned payloads are byte-identical by construction (the async regime
//! rejects compression, so one version of one node is one byte pattern).

use std::collections::HashMap;

/// Index of one pooled payload slot. Copy-cheap; holders must balance
/// every clone of a handle with a [`PayloadPool::release`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PayloadHandle(u32);

impl PayloadHandle {
    pub fn index(self) -> u32 {
        self.0
    }

    /// Rebuild a handle from a checkpointed slot index (the import path
    /// re-validates it against the pool it loads into).
    pub fn from_index(i: u32) -> PayloadHandle {
        PayloadHandle(i)
    }
}

/// One pooled payload: a dense iterate or its statistical surrogate.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Dense(Vec<f32>),
    Stat { mean: f64, var: f64 },
}

struct Slot {
    refs: u32,
    version: u64,
    /// Intern key `(src, version)` if this slot was interned; cleared on
    /// free so the key can be reused by a later incarnation.
    key: Option<(u32, u64)>,
    payload: Payload,
}

const FREE: Payload = Payload::Stat { mean: 0.0, var: 0.0 };

/// The slot table. `d` is the dense payload width this pool enforces
/// (surrogate slots carry no dense data and ignore it).
pub struct PayloadPool {
    d: usize,
    slots: Vec<Slot>,
    free: Vec<u32>,
    interned: HashMap<(u32, u64), u32>,
    live: usize,
    peak_live: usize,
    dense_scalars: usize,
    peak_dense: usize,
}

impl PayloadPool {
    pub fn new(d: usize) -> PayloadPool {
        PayloadPool {
            d,
            slots: Vec::new(),
            free: Vec::new(),
            interned: HashMap::new(),
            live: 0,
            peak_live: 0,
            dense_scalars: 0,
            peak_dense: 0,
        }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    fn alloc(&mut self, version: u64, key: Option<(u32, u64)>, payload: Payload) -> PayloadHandle {
        if let Payload::Dense(v) = &payload {
            assert_eq!(v.len(), self.d, "pooled payload width");
            self.dense_scalars += v.len();
            self.peak_dense = self.peak_dense.max(self.dense_scalars);
        }
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        let idx = match self.free.pop() {
            Some(i) => {
                let s = &mut self.slots[i as usize];
                s.refs = 1;
                s.version = version;
                s.key = key;
                s.payload = payload;
                i
            }
            None => {
                let i = u32::try_from(self.slots.len()).expect("pool slot index overflow");
                self.slots.push(Slot { refs: 1, version, key, payload });
                i
            }
        };
        if let Some(k) = key {
            self.interned.insert(k, idx);
        }
        PayloadHandle(idx)
    }

    /// Insert an owned dense payload (one fresh slot, refcount 1).
    pub fn insert_dense(&mut self, version: u64, data: Vec<f32>) -> PayloadHandle {
        self.alloc(version, None, Payload::Dense(data))
    }

    /// Insert a surrogate payload (one fresh slot, refcount 1).
    pub fn insert_stat(&mut self, version: u64, mean: f64, var: f64) -> PayloadHandle {
        self.alloc(version, None, Payload::Stat { mean, var })
    }

    /// Dense payload interned by `(src, version)`: if that version of that
    /// node is already pooled the existing slot is retained and returned
    /// (and `make` never runs); otherwise `make` produces the payload for a
    /// fresh interned slot. Either way the caller owns one new reference.
    pub fn intern_dense(
        &mut self,
        src: u32,
        version: u64,
        make: impl FnOnce() -> Vec<f32>,
    ) -> PayloadHandle {
        if let Some(&idx) = self.interned.get(&(src, version)) {
            let h = PayloadHandle(idx);
            self.retain(h);
            return h;
        }
        self.alloc(version, Some((src, version)), Payload::Dense(make()))
    }

    /// Surrogate payload interned by `(src, version)` (see
    /// [`PayloadPool::intern_dense`]).
    pub fn intern_stat(&mut self, src: u32, version: u64, mean: f64, var: f64) -> PayloadHandle {
        if let Some(&idx) = self.interned.get(&(src, version)) {
            let h = PayloadHandle(idx);
            self.retain(h);
            return h;
        }
        self.alloc(version, Some((src, version)), Payload::Stat { mean, var })
    }

    pub fn retain(&mut self, h: PayloadHandle) {
        let s = &mut self.slots[h.0 as usize];
        assert!(s.refs > 0, "retain of a freed slot");
        s.refs += 1;
    }

    /// Drop one reference; a slot whose refcount hits zero is recycled
    /// (its dense storage freed, its intern key cleared).
    pub fn release(&mut self, h: PayloadHandle) {
        let s = &mut self.slots[h.0 as usize];
        assert!(s.refs > 0, "release of a freed slot");
        s.refs -= 1;
        if s.refs == 0 {
            if let Payload::Dense(v) = &s.payload {
                self.dense_scalars -= v.len();
            }
            if let Some(k) = s.key.take() {
                self.interned.remove(&k);
            }
            s.payload = FREE;
            self.live -= 1;
            self.free.push(h.0);
        }
    }

    pub fn payload(&self, h: PayloadHandle) -> &Payload {
        let s = &self.slots[h.0 as usize];
        debug_assert!(s.refs > 0, "read of a freed slot");
        &s.payload
    }

    /// The dense payload behind `h`; panics if the slot is a surrogate
    /// (mixing code paths are mode-pure by construction).
    pub fn dense(&self, h: PayloadHandle) -> &[f32] {
        match self.payload(h) {
            Payload::Dense(v) => v,
            Payload::Stat { .. } => panic!("dense read of a surrogate slot"),
        }
    }

    /// The `(mean, var)` surrogate behind `h`; panics on a dense slot.
    pub fn stat(&self, h: PayloadHandle) -> (f64, f64) {
        match self.payload(h) {
            Payload::Stat { mean, var } => (*mean, *var),
            Payload::Dense(_) => panic!("surrogate read of a dense slot"),
        }
    }

    pub fn version(&self, h: PayloadHandle) -> u64 {
        self.slots[h.0 as usize].version
    }

    #[cfg(test)]
    fn refs(&self, h: PayloadHandle) -> u32 {
        self.slots[h.0 as usize].refs
    }

    /// Currently live (ref'd) slots.
    pub fn live_slots(&self) -> usize {
        self.live
    }

    /// High-water mark of live slots — the audit number the large-n suite
    /// compares against the directed-edge count.
    pub fn peak_live_slots(&self) -> usize {
        self.peak_live
    }

    /// f32 scalars currently held by live dense slots.
    pub fn live_dense_scalars(&self) -> usize {
        self.dense_scalars
    }

    /// High-water mark of dense scalars — 0 across a whole surrogate sweep.
    pub fn peak_dense_scalars(&self) -> usize {
        self.peak_dense
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_shares_one_slot_per_version() {
        let mut p = PayloadPool::new(3);
        let a = p.intern_dense(7, 1, || vec![1.0, 2.0, 3.0]);
        let b = p.intern_dense(7, 1, || panic!("must reuse the interned slot"));
        assert_eq!(a, b);
        assert_eq!(p.refs(a), 2);
        assert_eq!(p.live_slots(), 1);
        assert_eq!(p.live_dense_scalars(), 3);
        let c = p.intern_dense(7, 2, || vec![4.0, 5.0, 6.0]);
        assert_ne!(a, c);
        assert_eq!(p.live_slots(), 2);
    }

    #[test]
    fn release_recycles_and_clears_intern_key() {
        let mut p = PayloadPool::new(2);
        let a = p.intern_dense(0, 5, || vec![1.0, 1.0]);
        p.release(a);
        assert_eq!(p.live_slots(), 0);
        assert_eq!(p.live_dense_scalars(), 0);
        // Same key must now produce a FRESH payload, reusing the slot index.
        let b = p.intern_dense(0, 5, || vec![2.0, 2.0]);
        assert_eq!(b.index(), a.index(), "freed slot is recycled");
        assert_eq!(p.dense(b), &[2.0, 2.0]);
        assert_eq!(p.peak_live_slots(), 1);
        assert_eq!(p.peak_dense_scalars(), 2);
    }

    #[test]
    fn surrogate_slots_cost_no_dense_scalars() {
        let mut p = PayloadPool::new(1_000_000);
        let a = p.intern_stat(3, 1, 0.5, 0.25);
        let b = p.intern_stat(3, 1, 0.5, 0.25);
        assert_eq!(a, b);
        assert_eq!(p.stat(a), (0.5, 0.25));
        assert_eq!(p.version(a), 1);
        assert_eq!(p.peak_dense_scalars(), 0);
        assert_eq!(p.live_slots(), 1);
    }

    #[test]
    fn insert_is_never_shared() {
        let mut p = PayloadPool::new(1);
        let a = p.insert_dense(1, vec![1.0]);
        let b = p.insert_dense(1, vec![1.0]);
        assert_ne!(a, b);
        p.retain(a);
        p.release(a);
        assert_eq!(p.refs(a), 1);
        p.release(a);
        p.release(b);
        assert_eq!(p.live_slots(), 0);
    }

    #[test]
    #[should_panic(expected = "pooled payload width")]
    fn wrong_width_is_rejected() {
        let mut p = PayloadPool::new(4);
        p.insert_dense(0, vec![0.0; 3]);
    }

    #[test]
    #[should_panic(expected = "release of a freed slot")]
    fn double_release_is_caught() {
        let mut p = PayloadPool::new(1);
        let a = p.insert_dense(0, vec![0.0]);
        p.release(a);
        p.release(a);
    }
}
