//! The unified CommPlane: one pluggable communication backend behind every
//! training run.
//!
//! The paper's central trade-off (§3, "All-Reduce v.s. multiple Gossips";
//! Table 17) is about *measured* communication, so the code that trains and
//! the code that measures must be the same code. This module makes the
//! communication layer a first-class, swappable component:
//!
//! * [`CommBackend`] — the contract: `gossip`, `global_average`, optional
//!   async `gossip_async`/`finish`, every call returning the [`CommStats`]
//!   it incurred (wire scalars, messages, simulated alpha-beta seconds).
//! * [`SharedBackend`] — the shared-memory hot path: the pool-sharded
//!   [`crate::coordinator::mixer::Mixer`] (overlap mode included, and with
//!   [`SharedBackend::with_depth`] a depth-k pipeline of chained async
//!   rounds on a ring of scratch matrices — drained FIFO, bit-identical
//!   to BSP at every drain point), with traffic *predicted* from the
//!   topology (the counts a message-passing run of the same schedule
//!   would measure) and time billed by the paper's alpha-beta formulas.
//! * [`BusBackend`] — the message-passing plane: one
//!   [`crate::collective::Endpoint`] per worker, every transmitted vector
//!   actually sent/received over channels (compression included), traffic
//!   *measured* at the endpoints and time charged per actual message.
//!   Uncompressed rounds overlap too: `gossip_async` issues the round-t
//!   sends immediately and defers the receive+mix to the drain point on a
//!   depth-K ring of receive buffers keyed by per-round epoch tags
//!   (stale frames discarded on receipt and counted in
//!   [`CommStats::stale_frames_dropped`]).
//! * [`TcpBackend`] — the same message-passing core ([`bus::BusCore`])
//!   over real loopback sockets ([`crate::collective::tcp`]):
//!   length-prefixed frames, per-edge streams, OS-assigned ports. The
//!   first backend whose CommStats are measured off an actual wire.
//!
//! All backends drive the same [`mix_row_src`] kernel with the same weight
//! rows in the same order, so — with identity/no compression — their
//! parameter trajectories are **bit-identical**, and their `CommStats`
//! agree exactly (asserted by `rust/tests/comm_backends.rs`,
//! `rust/tests/transport.rs`, and the rewritten
//! `benches/tab17_comm_overhead.rs`). Select with
//! `TrainerOptions::backend` / `comm.backend` / `--backend
//! {shared,bus,tcp}`.
//!
//! §Fault tolerance: the message-passing planes expose round-membership
//! hooks — receive deadlines ([`CommBackend::set_recv_deadline`]), peer
//! drop/rejoin with mixing-row renormalization
//! ([`CommBackend::drop_node`] / [`CommBackend::rejoin_node`]), and epoch
//! resets for clean retries ([`CommBackend::reset_round`]) — driven by
//! the round state machine in [`crate::coordinator::rounds`]. The shared
//! backend has no wire, so the defaults report "no round membership".

pub mod bus;
pub mod shared;
pub mod tcp;

pub use bus::{BusBackend, BusCore};
pub use shared::SharedBackend;
pub use tcp::TcpBackend;

use std::time::Duration;

use anyhow::{bail, Result};

use crate::algorithms::CommAction;
use crate::compress::{Codec, ErrorFeedback, Int8, TopK};
use crate::coordinator::mixer::PendingMix;
use crate::costmodel::BarrierScope;
use crate::exec::WorkerPool;
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// Traffic + simulated time incurred by one communication action (or
/// accumulated over a run). `scalars_sent` counts f32-equivalents on the
/// wire (compressed messages bill `ceil(wire_bytes / 4)`); `sim_seconds`
/// is the alpha-beta clock charge for the action (the busiest node's —
/// per-node charges travel in [`CommCharge::node_seconds`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStats {
    pub scalars_sent: u64,
    pub msgs: u64,
    pub sim_seconds: f64,
    /// Seconds nodes spent stalled at synchronization barriers behind
    /// slower peers, summed over nodes (the straggler breakdown). Backends
    /// report 0 per action — barriers are applied by the trainer's
    /// [`crate::costmodel::VirtualClocks`], which fills this in on the
    /// cumulative totals. 0 whenever per-node charges stay uniform
    /// (homogeneous costs on a regular topology with even chunks); a
    /// homogeneous STAR still accrues wait — its leaves really do stall
    /// behind the busier hub — as does the bus plane at d % n != 0.
    pub barrier_wait: f64,
    /// Gossip rounds that were REQUESTED asynchronous (overlap mode) but
    /// executed as the synchronous round because the backend cannot
    /// overlap as configured — since the message-passing planes grew
    /// `gossip_async`, that is exactly the compressed-transmit
    /// configurations (error-feedback residuals must update in lockstep
    /// with the round they compress). Backends report 0 per action — the
    /// trainer, which owns the fallback decision, fills this in on the
    /// cumulative totals. A
    /// nonzero count on an overlap run means the configuration lost its
    /// compute/comm overlap — see the README's regime matrix row and the
    /// ROADMAP's async/bus-overlap item.
    pub fallback_rounds: u64,
    /// Frames discarded on receipt because their epoch tag named an
    /// aborted or already-drained round (the message-passing planes'
    /// overlap/retry hygiene; always 0 on the shared backend, which has no
    /// wire). A nonzero count is normal after a round retry; on a clean
    /// overlapped run it must stay 0 — asserted by the overlap_wire suite.
    pub stale_frames_dropped: u64,
}

impl CommStats {
    /// Accumulate another action's stats into this total.
    pub fn merge(&mut self, other: CommStats) {
        self.scalars_sent += other.scalars_sent;
        self.msgs += other.msgs;
        self.sim_seconds += other.sim_seconds;
        self.barrier_wait += other.barrier_wait;
        self.fallback_rounds += other.fallback_rounds;
        self.stale_frames_dropped += other.stale_frames_dropped;
    }

    /// Wire bytes (4 bytes per f32-equivalent).
    pub fn bytes_sent(&self) -> u64 {
        self.scalars_sent * 4
    }
}

/// Everything one communication action costs: aggregate traffic
/// ([`CommStats`]), the per-node simulated seconds the action charges, and
/// the [`BarrierScope`] it imposes on the per-node virtual clocks (a gossip
/// round waits on the in-neighborhood of its topology round; a global
/// average is a full barrier). The trainer feeds this straight into
/// [`crate::costmodel::VirtualClocks::advance`], fused with the per-node
/// compute charge.
#[derive(Clone, Debug)]
pub struct CommCharge {
    pub stats: CommStats,
    /// Per-node comm seconds of this action (len n; node i's own cost
    /// before any barrier wait).
    pub node_seconds: Vec<f64>,
    /// The synchronization the action imposes.
    pub barrier: BarrierScope,
}

/// Which communication plane a trainer runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Pool-sharded shared-memory mixer (the in-proc hot path; default).
    #[default]
    Shared,
    /// Message-passing bus: one endpoint per worker, real send/recv.
    Bus,
    /// The bus core over real loopback sockets (framed TCP streams).
    Tcp,
}

impl BackendKind {
    pub fn from_name(name: &str) -> Result<BackendKind> {
        Ok(match name {
            "shared" | "mixer" => BackendKind::Shared,
            "bus" | "collective" => BackendKind::Bus,
            "tcp" | "socket" => BackendKind::Tcp,
            other => bail!("unknown comm backend '{other}' (shared | bus | tcp)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Shared => "shared",
            BackendKind::Bus => "bus",
            BackendKind::Tcp => "tcp",
        }
    }
}

/// Gossip-message compression applied on the transmit path of either
/// backend (the paper's §2 "orthogonal techniques"; see
/// [`crate::compress`]). Every node carries its own error-feedback
/// residual, so per-node compression state is identical across backends.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum Compression {
    /// Transmit raw vectors (the default; keeps the fused no-copy mixer
    /// path on the shared backend).
    #[default]
    None,
    /// Top-k magnitude sparsification, keeping `frac` of coordinates.
    TopK { frac: f64 },
    /// Per-block int8 linear quantization.
    Int8 { block: usize },
}

impl Compression {
    /// Parse a config/CLI triple (`comm.compression`, `comm.topk_frac`,
    /// `comm.int8_block`).
    pub fn from_parts(name: &str, topk_frac: f64, int8_block: usize) -> Result<Compression> {
        Ok(match name {
            "none" | "identity" => Compression::None,
            "topk" => {
                if !(topk_frac > 0.0 && topk_frac <= 1.0) {
                    bail!("comm.topk_frac must be in (0, 1], got {topk_frac}");
                }
                Compression::TopK { frac: topk_frac }
            }
            "int8" => {
                if int8_block == 0 {
                    bail!("comm.int8_block must be >= 1");
                }
                Compression::Int8 { block: int8_block }
            }
            other => bail!("unknown compression '{other}' (none | topk | int8)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::TopK { .. } => "topk",
            Compression::Int8 { .. } => "int8",
        }
    }

    /// Build the per-node transmit codecs (`None` when no compression is
    /// configured — backends then take their raw fast paths).
    pub(crate) fn build(&self, n: usize, d: usize) -> Vec<Option<ErrorFeedback<Box<dyn Codec>>>> {
        (0..n)
            .map(|_| -> Option<ErrorFeedback<Box<dyn Codec>>> {
                let codec: Box<dyn Codec> = match *self {
                    Compression::None => return None,
                    Compression::TopK { frac } => Box::new(TopK { frac }),
                    Compression::Int8 { block } => Box::new(Int8 { block }),
                };
                Some(ErrorFeedback::new(codec, d))
            })
            .collect()
    }
}

/// Backend-owned payload of an in-flight round. Opaque to callers; each
/// backend adds its own variant, so async support for a new plane (e.g. a
/// tagged-message bus round) extends this enum without touching the trait
/// boundary.
pub(crate) enum PendingPayload {
    /// A [`crate::coordinator::mixer::Mixer::gossip_async`] ticket.
    SharedMix(PendingMix),
    /// An overlapped round on a message-passing plane ([`BusCore`]):
    /// sends issued, receive+mix running on the pool into a ring slot.
    WireRound(bus::PendingWireRound),
}

/// An in-flight asynchronous gossip round on a [`CommBackend`] (overlap
/// mode). Carries the full [`CommCharge`] the round will incur so the
/// caller can advance its clocks at issue time; hand it back to
/// [`CommBackend::finish`] of the SAME backend to complete the round.
pub struct PendingComm {
    pub(crate) payload: PendingPayload,
    pub(crate) charge: CommCharge,
}

impl PendingComm {
    /// The traffic/time/barrier this round incurs (known at issue time).
    pub fn charge(&self) -> &CommCharge {
        &self.charge
    }

    /// The aggregate traffic/time this round incurs.
    pub fn stats(&self) -> CommStats {
        self.charge.stats
    }
}

/// One pluggable communication plane: the two actions Algorithm 1 needs,
/// each reporting what it cost — per node and in aggregate
/// ([`CommCharge`]). Implementations must be deterministic — identical
/// inputs produce identical parameter bits at any pool size.
pub trait CommBackend: Send {
    fn kind(&self) -> BackendKind;

    /// One gossip round: row(i) <- sum_j w_ij transmit(row(j)); advances
    /// the topology round clock. On `Err` the parameters are untouched and
    /// the clock unadvanced — but the backend itself must be treated as
    /// FAILED and not reused (a message-passing plane may hold half-
    /// delivered payloads; [`BusBackend`] poisons itself and refuses
    /// further collectives, mirroring the worker pool's panic semantics).
    fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommCharge>;

    /// Exact global average: every worker ends up holding the ensemble
    /// mean (the paper's All-Reduce step).
    fn global_average(&mut self, params: &mut ParamMatrix, pool: &WorkerPool)
        -> Result<CommCharge>;

    /// Begin an asynchronous gossip round, if this backend supports
    /// overlap; `Ok(None)` means unsupported as configured (today: a
    /// compressed transmit path) and callers fall back to the synchronous
    /// [`CommBackend::gossip`]. A backend built with a pipeline depth > 1
    /// ([`SharedBackend::with_depth`], [`BusBackend::with_depth`],
    /// [`TcpBackend::new_loopback_with_depth`]) accepts up to `depth`
    /// issued-but-unfinished rounds, chained so round t+1 mixes round t's
    /// output; [`CommBackend::finish`] must then be called in issue order
    /// (FIFO), and a fully drained pipeline is bit-identical to the same
    /// rounds run synchronously.
    ///
    /// # Safety
    ///
    /// Same contract as [`crate::coordinator::mixer::Mixer::gossip_async`]:
    /// until every issued round is finished by [`CommBackend::finish`] (or
    /// its [`PendingComm`] is dropped, which blocks), `params` must not be
    /// mutated, moved-from or dropped, this backend must outlive the
    /// rounds, and no `PendingComm` may be leaked.
    unsafe fn gossip_async(
        &mut self,
        _params: &ParamMatrix,
        _pool: &WorkerPool,
    ) -> Result<Option<PendingComm>> {
        Ok(None)
    }

    /// Complete the OLDEST in-flight round started by
    /// [`CommBackend::gossip_async`] (strictly FIFO when several are in
    /// flight).
    fn finish(&mut self, _params: &mut ParamMatrix, _pending: PendingComm) -> Result<CommCharge> {
        bail!("this backend has no asynchronous gossip")
    }

    /// Whether [`CommBackend::gossip_async`] can ever return a round on
    /// this backend as configured. Overlap mode consults this at trainer
    /// construction so the silent synchronous fallback is surfaced as a
    /// startup warning + the [`CommStats::fallback_rounds`] counter
    /// instead of a quiet downgrade.
    fn supports_overlap(&self) -> bool {
        false
    }

    /// Ship node `src`'s current row to `dst` and hand the delivered
    /// payload back to the caller — the event-driven regime
    /// ([`crate::eventsim`]) owns delivery *timing*, the backend owns the
    /// bytes (a real send/recv on the bus plane, a predicted-traffic copy
    /// on the shared plane). Returns the payload plus the one message's
    /// traffic; NOT merged into [`CommBackend::total`] — the engine bills
    /// through [`CommBackend::add_total`] so its per-event time model
    /// rides along.
    fn push_row(
        &mut self,
        _params: &ParamMatrix,
        _src: usize,
        _dst: usize,
    ) -> Result<(Vec<f32>, CommStats)> {
        bail!("this backend has no per-edge push path")
    }

    /// Merge externally billed stats into the cumulative total (the event
    /// engine's per-push traffic and per-wave/per-link time charges).
    fn add_total(&mut self, stats: CommStats);

    /// Per-node alpha-beta seconds this backend bills for one
    /// identity-payload gossip round at `round` — the exact numbers
    /// [`CommBackend::gossip`]'s [`CommCharge`] would carry, exposed so
    /// the event engine's strict mode (max_staleness = 0) can reproduce
    /// the barrier-billed clocks bit-exactly without running the
    /// matrix-level round.
    fn gossip_node_seconds(&self, round: usize) -> Vec<f64>;

    /// Gossip rounds executed so far (drives time-varying topologies;
    /// checkpointed by the trainer).
    fn gossip_clock(&self) -> usize;

    /// Overwrite the round clock (checkpoint restore).
    fn set_gossip_clock(&mut self, rounds: usize);

    /// Cumulative measured traffic/time since construction (completed
    /// actions only; an un-finished async round is not yet counted).
    fn total(&self) -> CommStats;

    /// Overwrite the cumulative traffic counters (checkpoint restore — a
    /// resumed run's `comm_scalars`/`comm_msgs` columns continue from the
    /// snapshot instead of restarting at zero).
    fn restore_total(&mut self, total: CommStats);

    /// Snapshot the per-node compressor state (error-feedback residuals)
    /// as an n x d matrix; `None` when no compression is configured.
    fn export_compressor_state(&self) -> Option<ParamMatrix>;

    /// Restore state from [`CommBackend::export_compressor_state`].
    /// `None` zeroes the residuals (fresh-start semantics for checkpoints
    /// that predate compressor state).
    fn import_compressor_state(&mut self, state: Option<&ParamMatrix>) -> Result<()>;

    /// Arm (`Some`) or disarm (`None`) the stalled-peer receive deadline
    /// on every endpoint: with a deadline armed, a peer that wedges
    /// mid-collective surfaces as a typed
    /// [`crate::collective::RecvTimeout`] naming the silent node instead
    /// of hanging a pool thread forever. No-op on backends without a wire
    /// (the shared mixer cannot stall on a peer).
    fn set_recv_deadline(&mut self, _deadline: Option<Duration>) {}

    /// Whether [`CommBackend::set_recv_deadline`] actually arms anything —
    /// the round state machine requires a deadline-capable plane.
    fn supports_deadlines(&self) -> bool {
        false
    }

    /// Drop `node` from round membership: every other row's weight on it
    /// is folded back onto that row's self-weight (rows stay stochastic),
    /// its transmit sets empty out, and the global average re-chunks over
    /// the alive ranks. Returns the number of rows renormalized (the
    /// metrics counter). The dropped node's parameters are frozen, not
    /// poisoned.
    fn drop_node(&mut self, _node: usize) -> Result<u64> {
        bail!("{} backend has no round membership", self.kind().name())
    }

    /// Re-admit a node dropped by [`CommBackend::drop_node`]: the pristine
    /// mixing rows (its weight included) are back in force.
    fn rejoin_node(&mut self, _node: usize) -> Result<()> {
        bail!("{} backend has no round membership", self.kind().name())
    }

    /// Current alive mask, if this backend tracks round membership.
    fn alive_mask(&self) -> Option<Vec<bool>> {
        None
    }

    /// Abandon a half-delivered round: bump the message epoch (so the
    /// retry discards the aborted attempt's frames) and clear the poison
    /// flag. Only meaningful between a failed collective and its retry.
    fn reset_round(&mut self) {}

    /// Fault injection for tests and scenarios: a muted node stays alive
    /// and connected but transmits nothing — the wedged-peer failure mode
    /// the deadline + drop machinery exists for.
    fn set_muted(&mut self, _node: usize, _muted: bool) -> Result<()> {
        bail!("{} backend has no fault injection", self.kind().name())
    }
}

/// Shared impl for [`CommBackend::export_compressor_state`]: stack the
/// per-node error-feedback residuals into one n x d matrix.
pub(crate) fn export_residuals(
    comps: &[Option<ErrorFeedback<Box<dyn Codec>>>],
    d: usize,
) -> Option<ParamMatrix> {
    if comps.iter().all(|c| c.is_none()) {
        return None;
    }
    let mut m = ParamMatrix::zeros(comps.len(), d);
    for (i, c) in comps.iter().enumerate() {
        m.copy_row_from(i, c.as_ref().expect("compression is all-or-nothing").residual());
    }
    Some(m)
}

/// Shared impl for [`CommBackend::import_compressor_state`].
pub(crate) fn import_residuals(
    comps: &mut [Option<ErrorFeedback<Box<dyn Codec>>>],
    d: usize,
    state: Option<&ParamMatrix>,
) -> Result<()> {
    match state {
        Some(m) => {
            anyhow::ensure!(
                comps.iter().any(|c| c.is_some()),
                "checkpoint carries compressor residuals but this run has compression disabled"
            );
            anyhow::ensure!(
                m.n() == comps.len() && m.d() == d,
                "compressor residuals are {}x{}, backend is {}x{d}",
                m.n(),
                m.d(),
                comps.len()
            );
            for (c, row) in comps.iter_mut().zip(m.rows()) {
                c.as_mut().expect("compression is all-or-nothing").set_residual(row);
            }
        }
        None => {
            // Pre-v3 checkpoint or uncompressed snapshot: residuals restart
            // at zero, exactly like a fresh trainer's.
            for c in comps.iter_mut().flatten() {
                c.reset_residual();
            }
        }
    }
    Ok(())
}

/// Wire traffic of one identity-payload gossip round at `round`: every
/// node sends its d-vector to each of its out-neighbors. Returns
/// `(scalars, msgs)` summed over all nodes — the counts a bus run
/// measures and the shared backend predicts.
pub fn gossip_traffic(topo: &Topology, round: usize, d: usize) -> (u64, u64) {
    let mut scalars = 0u64;
    let mut msgs = 0u64;
    for j in 0..topo.n {
        let deg = topo.out_neighbors(j, round).len() as u64;
        msgs += deg;
        scalars += deg * d as u64;
    }
    (scalars, msgs)
}

/// Wire traffic of the bus plane's chunked global average (direct
/// reduce-scatter + all-gather over [`crate::collective::ring_chunk_bounds`]
/// chunks): `(scalars, msgs)` summed over all nodes. Total scalars are
/// exactly `2 d (n-1)` — the bandwidth-optimal ring's aggregate — while
/// empty chunks (d < n) send nothing.
pub fn global_average_traffic(n: usize, d: usize) -> (u64, u64) {
    let bounds = crate::collective::ring_chunk_bounds(n, d);
    let len = |c: usize| bounds[c + 1] - bounds[c];
    let mut scalars = 0u64;
    let mut msgs = 0u64;
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            if len(j) > 0 {
                // reduce-scatter: i ships chunk j of its row to node j
                scalars += len(j) as u64;
                msgs += 1;
            }
            if len(i) > 0 {
                // all-gather: i ships its reduced chunk to node j
                scalars += len(i) as u64;
                msgs += 1;
            }
        }
    }
    (scalars, msgs)
}

/// Analytic traffic `(scalars, msgs)` of a whole action sequence — THE
/// reference the equivalence suite and the tab17 accounting gate check
/// measured counts against (one definition, so the gates cannot drift
/// apart). Gossip rounds advance through the topology's round cycle in
/// order, exactly like a backend's gossip clock.
pub fn schedule_traffic(topo: &Topology, d: usize, actions: &[CommAction]) -> (u64, u64) {
    let mut gossip_round = 0usize;
    let mut scalars = 0u64;
    let mut msgs = 0u64;
    for a in actions {
        match a {
            CommAction::Gossip => {
                let (s, m) = gossip_traffic(topo, gossip_round % topo.rounds(), d);
                scalars += s;
                msgs += m;
                gossip_round += 1;
            }
            CommAction::GlobalAverage => {
                let (s, m) = global_average_traffic(topo.n, d);
                scalars += s;
                msgs += m;
            }
            CommAction::None => {}
        }
    }
    (scalars, msgs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge_and_bytes() {
        let mut a = CommStats {
            scalars_sent: 10,
            msgs: 2,
            sim_seconds: 0.5,
            barrier_wait: 0.1,
            fallback_rounds: 1,
            stale_frames_dropped: 4,
        };
        a.merge(CommStats {
            scalars_sent: 5,
            msgs: 1,
            sim_seconds: 0.25,
            barrier_wait: 0.2,
            fallback_rounds: 2,
            stale_frames_dropped: 3,
        });
        assert_eq!(a.scalars_sent, 15);
        assert_eq!(a.msgs, 3);
        assert!((a.sim_seconds - 0.75).abs() < 1e-12);
        assert!((a.barrier_wait - 0.3).abs() < 1e-12);
        assert_eq!(a.fallback_rounds, 3);
        assert_eq!(a.stale_frames_dropped, 7);
        assert_eq!(a.bytes_sent(), 60);
    }

    #[test]
    fn backend_kind_names_roundtrip() {
        for k in [BackendKind::Shared, BackendKind::Bus, BackendKind::Tcp] {
            assert_eq!(BackendKind::from_name(k.name()).unwrap(), k);
        }
        assert!(BackendKind::from_name("carrier-pigeon").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Shared);
    }

    #[test]
    fn compression_parses_and_validates() {
        assert_eq!(Compression::from_parts("none", 0.1, 64).unwrap(), Compression::None);
        assert_eq!(
            Compression::from_parts("topk", 0.25, 64).unwrap(),
            Compression::TopK { frac: 0.25 }
        );
        assert_eq!(
            Compression::from_parts("int8", 0.1, 128).unwrap(),
            Compression::Int8 { block: 128 }
        );
        assert!(Compression::from_parts("topk", 0.0, 64).is_err());
        assert!(Compression::from_parts("topk", 1.5, 64).is_err());
        assert!(Compression::from_parts("int8", 0.1, 0).is_err());
        assert!(Compression::from_parts("zip", 0.1, 64).is_err());
    }

    #[test]
    fn gossip_traffic_matches_hand_counts() {
        // Ring n=6: every node transmits to 2 neighbors.
        let (s, m) = gossip_traffic(&Topology::ring(6), 0, 10);
        assert_eq!((s, m), (120, 12));
        // One-peer: exactly one transmit per node, every round.
        let topo = Topology::one_peer_expo(8);
        for r in 0..topo.rounds() {
            assert_eq!(gossip_traffic(&topo, r, 5), (40, 8));
        }
        // n = 1: silence.
        assert_eq!(gossip_traffic(&Topology::ring(1), 0, 7), (0, 0));
    }

    #[test]
    fn global_average_traffic_totals_2d_n_minus_1() {
        for (n, d) in [(4usize, 400usize), (5, 17), (3, 2), (8, 64), (1, 9)] {
            let (scalars, _msgs) = global_average_traffic(n, d);
            assert_eq!(scalars, 2 * (n as u64 - 1) * d as u64, "n={n} d={d}");
        }
        // d < n: empty chunks send nothing, message count shrinks.
        let (s, m) = global_average_traffic(4, 2);
        assert_eq!(s, 2 * 3 * 2);
        assert!(m < 2 * 4 * 3, "empty chunks must be skipped, got {m} msgs");
    }
}
