//! [`SharedBackend`]: the shared-memory communication plane — the
//! pool-sharded [`Mixer`] hot path promoted behind the [`CommBackend`]
//! contract.
//!
//! Parameter arithmetic is exactly the pre-CommPlane trainer's: the fused
//! `mix_row` kernel for plain gossip, [`Mixer::gossip_async`] for overlap
//! mode, the fixed-order column mean for the global average. What this
//! wrapper adds is the accounting: every action reports the [`CommCharge`]
//! a message-passing run of the same schedule would measure (out-neighbor
//! transmit counts for gossip, the chunked reduce-scatter/all-gather
//! traffic for the global average) and bills the paper's alpha-beta model
//! time **per node** from the [`NodeCosts`] table — `|N_i| theta_i d +
//! alpha_i` per gossip round at the node's own neighborhood size,
//! `2 theta_i d + n alpha_i` per all-reduce (§3.4), at the emulated
//! `cost_dim`. On a homogeneous table the busiest node's charge is the
//! pre-virtual-time scalar bill, bit for bit.

use anyhow::Result;

use super::{
    export_residuals, global_average_traffic, gossip_traffic, import_residuals, BackendKind,
    CommBackend, CommCharge, CommStats, Compression, PendingComm, PendingPayload,
};
use crate::compress::{Codec, ErrorFeedback};
use crate::coordinator::mixer::Mixer;
use crate::costmodel::{BarrierScope, NodeCosts};
use crate::exec::WorkerPool;
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// The in-proc shared-memory backend (see module docs).
pub struct SharedBackend {
    mixer: Mixer,
    rounds: usize,
    /// Per-round `(scalars, msgs)` of an identity-payload gossip round.
    round_traffic: Vec<(u64, u64)>,
    /// Per-round per-node out-degree (compressed-gossip accounting).
    outdeg: Vec<Vec<u64>>,
    /// Model-billed per-node gossip seconds per round, at the emulated
    /// `cost_dim` (node i billed at its own in-neighborhood size).
    gossip_node_sim: Vec<Vec<f64>>,
    /// Per-node point-to-point latency (compressed-gossip scaling keeps
    /// the latency term payload-independent).
    alpha: Vec<f64>,
    /// Model-billed per-node all-reduce seconds at `cost_dim`.
    allreduce_node_sim: Vec<f64>,
    /// Bus-equivalent `(scalars, msgs)` of one global average.
    allreduce_traffic: (u64, u64),
    /// Per-node transmit codecs — the single source of truth for whether
    /// compression is on (`build` makes them all-Some or all-None).
    compressors: Vec<Option<ErrorFeedback<Box<dyn Codec>>>>,
    total: CommStats,
}

/// Max of a non-empty f64 slice (per-action critical path).
fn max_of(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

impl SharedBackend {
    /// Depth-1 pipeline (the classic double buffer) — see
    /// [`SharedBackend::with_depth`].
    pub fn new(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
    ) -> SharedBackend {
        SharedBackend::with_depth(topo, d, costs, cost_dim, compression, 1)
    }

    /// A backend whose async gossip pipeline admits up to `depth` rounds
    /// in flight at once (`--pipeline-depth`; the mixer keeps a depth-k
    /// ring of scratch matrices and chains rounds through completion
    /// latches). Depth 1 is today's single double buffer, bit for bit.
    pub fn with_depth(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        depth: usize,
    ) -> SharedBackend {
        let n = topo.n;
        debug_assert_eq!(costs.n(), n, "cost table must cover every node");
        let rounds = topo.rounds();
        let round_traffic = (0..rounds).map(|r| gossip_traffic(topo, r, d)).collect();
        let outdeg = (0..rounds)
            .map(|r| (0..n).map(|j| topo.out_neighbors(j, r).len() as u64).collect())
            .collect();
        let gossip_node_sim = (0..rounds)
            .map(|r| {
                (0..n)
                    .map(|i| costs.gossip_node(i, topo.in_neighbors(i, r).len(), cost_dim))
                    .collect()
            })
            .collect();
        let allreduce_node_sim =
            (0..n).map(|i| costs.all_reduce_node(i, n, cost_dim)).collect();
        let compressors = compression.build(n, d);
        SharedBackend {
            mixer: Mixer::with_depth(topo, d, depth),
            rounds,
            round_traffic,
            outdeg,
            gossip_node_sim,
            alpha: costs.alpha.clone(),
            allreduce_node_sim,
            allreduce_traffic: global_average_traffic(n, d),
            compressors,
            total: CommStats::default(),
        }
    }

    /// The wrapped mixer (test/bench hook).
    pub fn mixer(&mut self) -> &mut Mixer {
        &mut self.mixer
    }

    /// Whether the transmit path compresses (n >= 1 always; `build` makes
    /// the codecs all-or-nothing).
    fn compressed(&self) -> bool {
        self.compressors[0].is_some()
    }
}

impl CommBackend for SharedBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Shared
    }

    fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommCharge> {
        let mut sp = crate::obs::span(crate::obs::Phase::Gossip, crate::obs::CLUSTER);
        let round = self.mixer.gossip_clock % self.rounds;
        let charge = if self.compressed() {
            // Compressed transmit path: per-node error-feedback codecs feed
            // the mixer's transmit hook; wire size is billed per message
            // (one compression per node, one message per out-neighbor —
            // exactly what the bus backend ships).
            let outdeg = &self.outdeg[round];
            let comps = &mut self.compressors;
            let mut scalars = 0u64;
            let mut msgs = 0u64;
            self.mixer.gossip_with(params, pool, |j, x, out| {
                let ef = comps[j].as_mut().expect("compressed backend has per-node codecs");
                let c = ef.compress(x);
                let wire = (c.wire_bytes as u64).div_ceil(4);
                scalars += outdeg[j] * wire;
                msgs += outdeg[j];
                out.extend_from_slice(&c.dense);
            })?;
            // Bill each node's theta term at the compressed fraction of the
            // ideal identity traffic; the latency term is
            // payload-independent.
            let (ideal_scalars, _) = self.round_traffic[round];
            let node_seconds: Vec<f64> = self.gossip_node_sim[round]
                .iter()
                .zip(&self.alpha)
                .map(|(&raw, &alpha)| {
                    if ideal_scalars == 0 {
                        raw
                    } else {
                        alpha + (raw - alpha) * scalars as f64 / ideal_scalars as f64
                    }
                })
                .collect();
            let sim = max_of(&node_seconds);
            CommCharge {
                stats: CommStats {
                    scalars_sent: scalars,
                    msgs,
                    sim_seconds: sim,
                    barrier_wait: 0.0,
                    fallback_rounds: 0,
                    stale_frames_dropped: 0,
                },
                node_seconds,
                barrier: BarrierScope::Neighborhood { round },
            }
        } else {
            self.mixer.gossip(params, pool)?;
            let (scalars, msgs) = self.round_traffic[round];
            let node_seconds = self.gossip_node_sim[round].clone();
            CommCharge {
                stats: CommStats {
                    scalars_sent: scalars,
                    msgs,
                    sim_seconds: max_of(&node_seconds),
                    barrier_wait: 0.0,
                    fallback_rounds: 0,
                    stale_frames_dropped: 0,
                },
                node_seconds,
                barrier: BarrierScope::Neighborhood { round },
            }
        };
        sp.set_sim(charge.stats.sim_seconds);
        self.total.merge(charge.stats);
        Ok(charge)
    }

    fn global_average(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommCharge> {
        let mut sp = crate::obs::span(crate::obs::Phase::GlobalAverage, crate::obs::CLUSTER);
        self.mixer.global_average(params, pool)?;
        let (scalars, msgs) = self.allreduce_traffic;
        let node_seconds = self.allreduce_node_sim.clone();
        let charge = CommCharge {
            stats: CommStats {
                scalars_sent: scalars,
                msgs,
                sim_seconds: max_of(&node_seconds),
                barrier_wait: 0.0,
                fallback_rounds: 0,
                stale_frames_dropped: 0,
            },
            node_seconds,
            barrier: BarrierScope::Global,
        };
        sp.set_sim(charge.stats.sim_seconds);
        self.total.merge(charge.stats);
        Ok(charge)
    }

    unsafe fn gossip_async(
        &mut self,
        params: &ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<Option<PendingComm>> {
        if self.compressed() {
            // The compressed transmit pass is ordered (error-feedback
            // state), so it cannot double-buffer; fall back to sync (the
            // mix pass still shards across the pool).
            return Ok(None);
        }
        // Bill the round the ISSUE schedule runs, not the drained clock:
        // with rounds already in flight this issue mixes a later row of
        // the time-varying topology.
        let round = self.mixer.issued_clock() % self.rounds;
        let (scalars, msgs) = self.round_traffic[round];
        let node_seconds = self.gossip_node_sim[round].clone();
        let mix = self.mixer.gossip_async(params, pool)?;
        Ok(Some(PendingComm {
            payload: PendingPayload::SharedMix(mix),
            charge: CommCharge {
                stats: CommStats {
                    scalars_sent: scalars,
                    msgs,
                    sim_seconds: max_of(&node_seconds),
                    barrier_wait: 0.0,
                    fallback_rounds: 0,
                    stale_frames_dropped: 0,
                },
                node_seconds,
                barrier: BarrierScope::Neighborhood { round },
            },
        }))
    }

    fn finish(&mut self, params: &mut ParamMatrix, pending: PendingComm) -> Result<CommCharge> {
        let charge = pending.charge;
        let mix = match pending.payload {
            PendingPayload::SharedMix(mix) => mix,
            PendingPayload::WireRound(_) => {
                anyhow::bail!("finish: pending round belongs to a message-passing backend")
            }
        };
        self.mixer.finish_gossip(params, mix)?;
        self.total.merge(charge.stats);
        Ok(charge)
    }

    fn supports_overlap(&self) -> bool {
        // The compressed transmit pass is ordered (error-feedback state),
        // so only the raw path can double-buffer.
        !self.compressed()
    }

    fn push_row(
        &mut self,
        params: &ParamMatrix,
        src: usize,
        _dst: usize,
    ) -> Result<(Vec<f32>, CommStats)> {
        // In-proc plane: the "transfer" is a copy; traffic is the same one
        // message a bus run would measure. The event engine owns delivery
        // timing (and the async regime is uncompressed by construction —
        // the trainer rejects compression there).
        let d = self.mixer.d();
        Ok((
            params.row(src).to_vec(),
            CommStats { scalars_sent: d as u64, msgs: 1, ..Default::default() },
        ))
    }

    fn add_total(&mut self, stats: CommStats) {
        self.total.merge(stats);
    }

    fn gossip_node_seconds(&self, round: usize) -> Vec<f64> {
        self.gossip_node_sim[round % self.rounds].clone()
    }

    fn gossip_clock(&self) -> usize {
        self.mixer.gossip_clock
    }

    fn set_gossip_clock(&mut self, rounds: usize) {
        self.mixer.gossip_clock = rounds;
    }

    fn total(&self) -> CommStats {
        self.total
    }

    fn restore_total(&mut self, total: CommStats) {
        self.total = total;
    }

    fn export_compressor_state(&self) -> Option<ParamMatrix> {
        export_residuals(&self.compressors, self.mixer.d())
    }

    fn import_compressor_state(&mut self, state: Option<&ParamMatrix>) -> Result<()> {
        let d = self.mixer.d();
        import_residuals(&mut self.compressors, d, state)
    }
}
