//! [`SharedBackend`]: the shared-memory communication plane — the
//! pool-sharded [`Mixer`] hot path promoted behind the [`CommBackend`]
//! contract.
//!
//! Parameter arithmetic is exactly the pre-CommPlane trainer's: the fused
//! `mix_row` kernel for plain gossip, [`Mixer::gossip_async`] for overlap
//! mode, the fixed-order column mean for the global average. What this
//! wrapper adds is the accounting: every action reports the [`CommStats`] a
//! message-passing run of the same schedule would measure (out-neighbor
//! transmit counts for gossip, the chunked reduce-scatter/all-gather
//! traffic for the global average) and bills the paper's alpha-beta model
//! time — `|N_i| theta d + alpha` per gossip round, `2 theta d + n alpha`
//! per all-reduce (§3.4), at the emulated `cost_dim`.

use anyhow::Result;

use super::{
    export_residuals, global_average_traffic, gossip_traffic, import_residuals, BackendKind,
    CommBackend, CommStats, Compression, PendingComm, PendingPayload,
};
use crate::compress::{Codec, ErrorFeedback};
use crate::coordinator::mixer::Mixer;
use crate::costmodel::CostModel;
use crate::exec::WorkerPool;
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// The in-proc shared-memory backend (see module docs).
pub struct SharedBackend {
    mixer: Mixer,
    rounds: usize,
    /// Per-round `(scalars, msgs)` of an identity-payload gossip round.
    round_traffic: Vec<(u64, u64)>,
    /// Per-round per-node out-degree (compressed-gossip accounting).
    outdeg: Vec<Vec<u64>>,
    /// Model-billed times at the emulated `cost_dim`.
    gossip_sim: f64,
    gossip_alpha: f64,
    allreduce_sim: f64,
    /// Bus-equivalent `(scalars, msgs)` of one global average.
    allreduce_traffic: (u64, u64),
    /// Per-node transmit codecs — the single source of truth for whether
    /// compression is on (`build` makes them all-Some or all-None).
    compressors: Vec<Option<ErrorFeedback<Box<dyn Codec>>>>,
    total: CommStats,
}

impl SharedBackend {
    pub fn new(
        topo: &Topology,
        d: usize,
        cost: CostModel,
        cost_dim: usize,
        compression: Compression,
    ) -> SharedBackend {
        let n = topo.n;
        let rounds = topo.rounds();
        let round_traffic = (0..rounds).map(|r| gossip_traffic(topo, r, d)).collect();
        let outdeg = (0..rounds)
            .map(|r| (0..n).map(|j| topo.out_neighbors(j, r).len() as u64).collect())
            .collect();
        let compressors = compression.build(n, d);
        SharedBackend {
            mixer: Mixer::new(topo, d),
            rounds,
            round_traffic,
            outdeg,
            gossip_sim: cost.gossip(topo, cost_dim),
            gossip_alpha: cost.alpha,
            allreduce_sim: cost.all_reduce(n, cost_dim),
            allreduce_traffic: global_average_traffic(n, d),
            compressors,
            total: CommStats::default(),
        }
    }

    /// The wrapped mixer (test/bench hook).
    pub fn mixer(&mut self) -> &mut Mixer {
        &mut self.mixer
    }

    /// Whether the transmit path compresses (n >= 1 always; `build` makes
    /// the codecs all-or-nothing).
    fn compressed(&self) -> bool {
        self.compressors[0].is_some()
    }
}

impl CommBackend for SharedBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Shared
    }

    fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommStats> {
        let round = self.mixer.gossip_clock % self.rounds;
        let stats = if self.compressed() {
            // Compressed transmit path: per-node error-feedback codecs feed
            // the mixer's transmit hook; wire size is billed per message
            // (one compression per node, one message per out-neighbor —
            // exactly what the bus backend ships).
            let outdeg = &self.outdeg[round];
            let comps = &mut self.compressors;
            let mut scalars = 0u64;
            let mut msgs = 0u64;
            self.mixer.gossip_with(params, pool, |j, x| {
                let ef = comps[j].as_mut().expect("compressed backend has per-node codecs");
                let c = ef.compress(x);
                let wire = (c.wire_bytes as u64).div_ceil(4);
                scalars += outdeg[j] * wire;
                msgs += outdeg[j];
                c.dense
            })?;
            // Bill the theta term at the compressed fraction of the ideal
            // identity traffic; the latency term is payload-independent.
            let (ideal_scalars, _) = self.round_traffic[round];
            let sim = if ideal_scalars == 0 {
                self.gossip_sim
            } else {
                self.gossip_alpha
                    + (self.gossip_sim - self.gossip_alpha) * scalars as f64
                        / ideal_scalars as f64
            };
            CommStats { scalars_sent: scalars, msgs, sim_seconds: sim }
        } else {
            self.mixer.gossip(params, pool)?;
            let (scalars, msgs) = self.round_traffic[round];
            CommStats { scalars_sent: scalars, msgs, sim_seconds: self.gossip_sim }
        };
        self.total.merge(stats);
        Ok(stats)
    }

    fn global_average(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommStats> {
        self.mixer.global_average(params, pool)?;
        let (scalars, msgs) = self.allreduce_traffic;
        let stats = CommStats { scalars_sent: scalars, msgs, sim_seconds: self.allreduce_sim };
        self.total.merge(stats);
        Ok(stats)
    }

    unsafe fn gossip_async(
        &mut self,
        params: &ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<Option<PendingComm>> {
        if self.compressed() {
            // The compressed transmit pass is ordered (error-feedback
            // state), so it cannot double-buffer; fall back to sync (the
            // mix pass still shards across the pool).
            return Ok(None);
        }
        let round = self.mixer.gossip_clock % self.rounds;
        let (scalars, msgs) = self.round_traffic[round];
        let mix = self.mixer.gossip_async(params, pool)?;
        Ok(Some(PendingComm {
            payload: PendingPayload::SharedMix(mix),
            stats: CommStats { scalars_sent: scalars, msgs, sim_seconds: self.gossip_sim },
        }))
    }

    fn finish(&mut self, params: &mut ParamMatrix, pending: PendingComm) -> Result<CommStats> {
        let stats = pending.stats;
        let PendingPayload::SharedMix(mix) = pending.payload;
        self.mixer.finish_gossip(params, mix)?;
        self.total.merge(stats);
        Ok(stats)
    }

    fn gossip_clock(&self) -> usize {
        self.mixer.gossip_clock
    }

    fn set_gossip_clock(&mut self, rounds: usize) {
        self.mixer.gossip_clock = rounds;
    }

    fn total(&self) -> CommStats {
        self.total
    }

    fn restore_total(&mut self, total: CommStats) {
        self.total = total;
    }

    fn export_compressor_state(&self) -> Option<ParamMatrix> {
        export_residuals(&self.compressors, self.mixer.d())
    }

    fn import_compressor_state(&mut self, state: Option<&ParamMatrix>) -> Result<()> {
        let d = self.mixer.d();
        import_residuals(&mut self.compressors, d, state)
    }
}
