//! [`BusBackend`]: the message-passing communication plane.
//!
//! One [`Endpoint`] per worker, built once with exactly the sender edges
//! the run needs (the topology's out-neighbors across all rounds, plus the
//! all-to-all chunk-exchange edges when the schedule global-averages).
//! Every transmitted vector is actually serialized onto a channel and
//! received on the other side — the same code path the `tab17` bench
//! measures — so the traffic a training run reports IS measured traffic,
//! read back from the endpoint counters.
//!
//! §Execution model: collectives run as *phases* sharded across the
//! trainer's [`WorkerPool`] with a barrier between send- and receive-sides
//! (channels are buffered, so a phase's receives can never block on a
//! same-phase send). This keeps one persistent engine for compute AND
//! communication at any pool size — including 1 — with deterministic
//! results: each node's arithmetic is self-contained and
//! [`Endpoint::recv_from`] selects by source, so scheduling order cannot
//! leak into the bits.
//!
//! §Equivalence: the receive-side mix calls the same [`mix_row_src`]
//! kernel with the same f32 weight rows in the same order as the shared
//! mixer, and the global average accumulates rank-ascending per chunk —
//! the shared mean's exact operation order. Uncompressed trajectories are
//! therefore bit-identical to [`super::SharedBackend`]'s (asserted by
//! `rust/tests/comm_backends.rs`). The chunked reduce-scatter/all-gather
//! moves the bandwidth-optimal ring's aggregate traffic (2 d (n-1)
//! scalars); the latency-bound ring schedule itself remains available as
//! [`crate::collective::ring_all_reduce`] for the bench suite.
//!
//! §Time: charged per actual message and per node — node i pays its own
//! `alpha_i` per send plus its own `theta_i` per wire scalar from the
//! [`NodeCosts`] table, scaled to the emulated `cost_dim` (the same
//! emulation the shared backend bills); the aggregate `sim_seconds` is the
//! busiest node's charge (the pre-virtual-time scalar bill on a
//! homogeneous table, bit for bit).

use anyhow::{bail, ensure, Result};

use super::{
    export_residuals, import_residuals, BackendKind, CommBackend, CommCharge, CommStats,
    Compression,
};
use crate::collective::{bus_for, ring_chunk_bounds, Endpoint};
use crate::compress::{Codec, ErrorFeedback};
use crate::coordinator::mixer::{mix_row_src, weight_rows_f32};
use crate::costmodel::{BarrierScope, NodeCosts};
use crate::exec::WorkerPool;
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// The message-passing backend (see module docs).
pub struct BusBackend {
    n: usize,
    d: usize,
    rounds: usize,
    /// Weight rows per round (same f32 quantization as the shared mixer).
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    /// Out-neighbors per round (transmit targets, excl. self).
    outn: Vec<Vec<Vec<usize>>>,
    endpoints: Vec<Endpoint>,
    scratch: ParamMatrix,
    /// Global-average chunk boundaries (`ring_chunk_bounds`).
    bounds: Vec<usize>,
    /// Whether the all-to-all chunk-exchange edges were built.
    with_global: bool,
    compressors: Vec<Option<ErrorFeedback<Box<dyn Codec>>>>,
    /// Per-node link costs the endpoint counters are billed against.
    alpha: Vec<f64>,
    theta: Vec<f64>,
    cost_dim: usize,
    pub gossip_clock: usize,
    total: CommStats,
    /// Set when a collective fails mid-flight: the channels may hold
    /// half-delivered payloads, so the backend refuses further work
    /// instead of silently mixing stale rounds.
    failed: bool,
}

impl BusBackend {
    /// Build the bus for `topo`. `with_global` adds the all-to-all
    /// chunk-exchange edges the global average needs — pass `false` for
    /// pure-gossip schedules so large sparse graphs keep O(edges) setup.
    pub fn new(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        with_global: bool,
    ) -> BusBackend {
        let n = topo.n;
        debug_assert_eq!(costs.n(), n, "cost table must cover every node");
        let rounds = topo.rounds();
        // Same quantization site as the shared mixer (bit-equality is
        // structural, not two parallel copies).
        let rows = weight_rows_f32(topo);
        let outn: Vec<Vec<Vec<usize>>> =
            (0..rounds).map(|r| (0..n).map(|j| topo.out_neighbors(j, r)).collect()).collect();
        // Sender edges: union of the gossip transmit sets over all rounds,
        // plus all-to-all when the schedule global-averages.
        let edges: Vec<Vec<usize>> = (0..n)
            .map(|j| {
                let mut e: Vec<usize> = if with_global {
                    (0..n).filter(|&i| i != j).collect()
                } else {
                    outn.iter().flat_map(|per_round| per_round[j].iter().copied()).collect()
                };
                e.sort_unstable();
                e.dedup();
                e
            })
            .collect();
        BusBackend {
            n,
            d,
            rounds,
            rows,
            outn,
            endpoints: bus_for(n, &edges),
            scratch: ParamMatrix::zeros(n, d),
            bounds: ring_chunk_bounds(n, d),
            with_global,
            compressors: compression.build(n, d),
            alpha: costs.alpha.clone(),
            theta: costs.theta.clone(),
            cost_dim,
            gossip_clock: 0,
            total: CommStats::default(),
            failed: false,
        }
    }

    /// Snapshot the per-endpoint counters (delta accounting per action).
    fn traffic_snapshot(&self) -> Vec<(u64, u64)> {
        self.endpoints.iter().map(|e| (e.scalars_sent, e.msgs_sent)).collect()
    }

    /// Charge incurred since `before`: traffic totals across nodes plus
    /// each node's own alpha-beta bill for its measured messages (message
    /// count and wire scalars taken together per node, so asymmetric
    /// topologies aren't billed a mix-and-match of two different nodes'
    /// worst terms); the aggregate `sim_seconds` is the busiest node's
    /// charge.
    fn charge_since(&self, before: &[(u64, u64)], barrier: BarrierScope) -> CommCharge {
        let scale = self.cost_dim as f64 / self.d.max(1) as f64;
        let mut scalars = 0u64;
        let mut msgs = 0u64;
        let mut critical = 0.0f64;
        let mut node_seconds = Vec::with_capacity(self.n);
        for (i, (ep, &(s0, m0))) in self.endpoints.iter().zip(before).enumerate() {
            let ds = ep.scalars_sent - s0;
            let dm = ep.msgs_sent - m0;
            scalars += ds;
            msgs += dm;
            let node_cost = dm as f64 * self.alpha[i] + ds as f64 * scale * self.theta[i];
            critical = critical.max(node_cost);
            node_seconds.push(node_cost);
        }
        CommCharge {
            stats: CommStats {
                scalars_sent: scalars,
                msgs,
                sim_seconds: critical,
                barrier_wait: 0.0,
                fallback_rounds: 0,
            },
            node_seconds,
            barrier,
        }
    }
}

impl BusBackend {
    fn gossip_inner(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommCharge> {
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let n = self.n;
        let d = self.d;
        let round = self.gossip_clock % self.rounds;
        let before = self.traffic_snapshot();
        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        // Phase A — transmit: each node compresses once and ships the
        // payload to every out-neighbor (send is buffered, never blocks).
        {
            let outn = &self.outn[round];
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.compressors.chunks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, comps))| {
                        move || {
                            for (k, (ep, comp)) in
                                eps.iter_mut().zip(comps.iter_mut()).enumerate()
                            {
                                let j = ci * per + k;
                                let targets = &outn[j];
                                if targets.is_empty() {
                                    continue;
                                }
                                let x = &src[j * d..(j + 1) * d];
                                let (mut payload, wire) = match comp.as_mut() {
                                    Some(ef) => {
                                        let c = ef.compress(x);
                                        let wire = (c.wire_bytes as u64).div_ceil(4);
                                        (c.dense, wire)
                                    }
                                    None => (x.to_vec(), d as u64),
                                };
                                // Clone per extra neighbor only; the last
                                // send takes the buffer itself.
                                let last = targets.len() - 1;
                                for (t, &to) in targets.iter().enumerate() {
                                    let msg = if t == last {
                                        std::mem::take(&mut payload)
                                    } else {
                                        payload.clone()
                                    };
                                    ep.send_billed(to, msg, wire)?;
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        // Phase B — receive + mix: the same kernel, rows and order as the
        // shared mixer (bit-identical by construction).
        {
            let rows = &self.rows[round];
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.scratch.row_blocks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, block))| {
                        move || {
                            for (k, (ep, out)) in
                                eps.iter_mut().zip(block.chunks_mut(d)).enumerate()
                            {
                                let i = ci * per + k;
                                let row = &rows[i];
                                let mut recvd: Vec<(usize, Vec<f32>)> =
                                    Vec::with_capacity(row.len());
                                for &(j, _) in row {
                                    if j != i {
                                        let v = ep.recv_from(j)?;
                                        ensure!(
                                            v.len() == d,
                                            "node {i}: message from {j} carries {} of {d} scalars",
                                            v.len()
                                        );
                                        recvd.push((j, v));
                                    }
                                }
                                mix_row_src(
                                    row,
                                    |j| {
                                        if j == i {
                                            &src[i * d..(i + 1) * d]
                                        } else {
                                            let (_, v) = recvd
                                                .iter()
                                                .find(|(jj, _)| *jj == j)
                                                .expect("received above");
                                            &v[..]
                                        }
                                    },
                                    out,
                                );
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(&mut self.scratch);
        self.gossip_clock += 1;
        let charge = self.charge_since(&before, BarrierScope::Neighborhood { round });
        self.total.merge(charge.stats);
        Ok(charge)
    }

    fn global_average_inner(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommCharge> {
        debug_assert!(params.n() == self.n && params.d() == self.d);
        debug_assert!(self.with_global, "checked by the trait wrapper");
        let n = self.n;
        let d = self.d;
        let inv = 1.0f32 / n as f32;
        let before = self.traffic_snapshot();
        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        let bounds = &self.bounds;
        // Phase A — reduce-scatter sends: node i ships chunk j of its row
        // directly to node j (empty chunks ship nothing).
        {
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .enumerate()
                    .map(|(ci, eps)| {
                        move || {
                            for (k, ep) in eps.iter_mut().enumerate() {
                                let i = ci * per + k;
                                let xi = &src[i * d..(i + 1) * d];
                                for j in 0..n {
                                    if j != i && bounds[j + 1] > bounds[j] {
                                        ep.send(j, xi[bounds[j]..bounds[j + 1]].to_vec())?;
                                    }
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        // Phase B — reduce + gather sends: node i sums its chunk over all
        // ranks ASCENDING (the shared mean's exact accumulation order:
        // copy rank 0, add ranks 1..n, multiply by 1/n), stores it in its
        // scratch row, and broadcasts the reduced chunk. Per-sender FIFO
        // keeps these gather messages behind phase A's scatter messages.
        {
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.scratch.row_blocks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, block))| {
                        move || {
                            for (k, (ep, srow)) in
                                eps.iter_mut().zip(block.chunks_mut(d)).enumerate()
                            {
                                let i = ci * per + k;
                                let (lo, hi) = (bounds[i], bounds[i + 1]);
                                if hi == lo {
                                    continue;
                                }
                                let len = hi - lo;
                                let mut acc: Vec<f32> = if i == 0 {
                                    src[lo..hi].to_vec()
                                } else {
                                    let v = ep.recv_from(0)?;
                                    ensure!(
                                        v.len() == len,
                                        "chunk from 0 has {} of {len}",
                                        v.len()
                                    );
                                    v
                                };
                                for j in 1..n {
                                    if j == i {
                                        let own = &src[j * d + lo..j * d + hi];
                                        for (a, b) in acc.iter_mut().zip(own) {
                                            *a += b;
                                        }
                                    } else {
                                        let v = ep.recv_from(j)?;
                                        ensure!(
                                            v.len() == len,
                                            "chunk from {j} has {} of {len}",
                                            v.len()
                                        );
                                        for (a, b) in acc.iter_mut().zip(&v) {
                                            *a += b;
                                        }
                                    }
                                }
                                for a in acc.iter_mut() {
                                    *a *= inv;
                                }
                                srow[lo..hi].copy_from_slice(&acc);
                                // Broadcast the reduced chunk; the last
                                // send takes the buffer itself (acc is
                                // dead after this loop).
                                let last = if i == n - 1 { n.wrapping_sub(2) } else { n - 1 };
                                for j in 0..n {
                                    if j != i {
                                        let msg = if j == last {
                                            std::mem::take(&mut acc)
                                        } else {
                                            acc.clone()
                                        };
                                        ep.send(j, msg)?;
                                    }
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        // Phase C — assemble: every node fills the rest of its mean row
        // from the other ranks' reduced chunks (its own is already
        // in place). All rows end bit-identical.
        {
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.scratch.row_blocks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, block))| {
                        move || {
                            for (k, (ep, srow)) in
                                eps.iter_mut().zip(block.chunks_mut(d)).enumerate()
                            {
                                let i = ci * per + k;
                                for j in 0..n {
                                    if j != i && bounds[j + 1] > bounds[j] {
                                        let v = ep.recv_from(j)?;
                                        ensure!(
                                            v.len() == bounds[j + 1] - bounds[j],
                                            "reduced chunk from {j} has wrong length"
                                        );
                                        srow[bounds[j]..bounds[j + 1]].copy_from_slice(&v);
                                    }
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(&mut self.scratch);
        let charge = self.charge_since(&before, BarrierScope::Global);
        self.total.merge(charge.stats);
        Ok(charge)
    }
}

impl BusBackend {
    /// One real message over the plane: serialized onto src's channel,
    /// received on dst's side — the endpoint counters measure it like any
    /// phase-A gossip send. The event engine holds the payload until its
    /// virtual delivery time (checkpointable), so the channel never
    /// carries state across calls.
    fn push_row_inner(
        &mut self,
        params: &ParamMatrix,
        src: usize,
        dst: usize,
    ) -> Result<(Vec<f32>, CommStats)> {
        let d = self.d;
        let x = params.row(src).to_vec();
        self.endpoints[src].send_billed(dst, x, d as u64)?;
        let payload = self.endpoints[dst].recv_from(src)?;
        ensure!(payload.len() == d, "pushed row carries {} of {d} scalars", payload.len());
        Ok((payload, CommStats { scalars_sent: d as u64, msgs: 1, ..Default::default() }))
    }
}

impl CommBackend for BusBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Bus
    }

    fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommCharge> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        let result = self.gossip_inner(params, pool);
        self.failed |= result.is_err();
        result
    }

    fn global_average(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommCharge> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        // A missing edge set is a clean configuration error, not a
        // half-delivered collective — don't poison for it.
        if !self.with_global {
            bail!("bus backend was built without all-reduce edges (pure-gossip schedule)");
        }
        let result = self.global_average_inner(params, pool);
        self.failed |= result.is_err();
        result
    }

    fn push_row(
        &mut self,
        params: &ParamMatrix,
        src: usize,
        dst: usize,
    ) -> Result<(Vec<f32>, CommStats)> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        // A failed push leaves the counters half-advanced, so it poisons
        // the backend exactly like a failed collective.
        let result = self.push_row_inner(params, src, dst);
        self.failed |= result.is_err();
        result
    }

    fn add_total(&mut self, stats: CommStats) {
        self.total.merge(stats);
    }

    fn gossip_node_seconds(&self, round: usize) -> Vec<f64> {
        // The same arithmetic charge_since() applies to this round's
        // measured counters — sender-billed, per message and per wire
        // scalar at the emulated cost_dim — so strict-mode event billing
        // is bit-identical to the synchronous round's charge.
        let scale = self.cost_dim as f64 / self.d.max(1) as f64;
        let outn = &self.outn[round % self.rounds];
        (0..self.n)
            .map(|j| {
                let dm = outn[j].len() as u64;
                let ds = dm * self.d as u64;
                dm as f64 * self.alpha[j] + ds as f64 * scale * self.theta[j]
            })
            .collect()
    }

    fn gossip_clock(&self) -> usize {
        self.gossip_clock
    }

    fn set_gossip_clock(&mut self, rounds: usize) {
        self.gossip_clock = rounds;
    }

    fn total(&self) -> CommStats {
        self.total
    }

    fn restore_total(&mut self, total: CommStats) {
        self.total = total;
    }

    fn export_compressor_state(&self) -> Option<ParamMatrix> {
        export_residuals(&self.compressors, self.d)
    }

    fn import_compressor_state(&mut self, state: Option<&ParamMatrix>) -> Result<()> {
        import_residuals(&mut self.compressors, self.d, state)
    }
}
