//! [`BusCore`]: the message-passing communication plane, generic over its
//! transport.
//!
//! One [`Wire`] endpoint per worker, built once with exactly the sender
//! edges the gossip schedule needs (the topology's out-neighbors across
//! all rounds). [`BusBackend`] instantiates the core over mpsc
//! [`Endpoint`]s; [`super::TcpBackend`] instantiates the *same* core over
//! framed loopback sockets ([`crate::collective::tcp`]), which is what
//! makes their uncompressed trajectories bit-identical: every transport
//! runs these exact phases, kernels, and accumulation orders.
//!
//! §Lazy global edges: the all-to-all chunk-exchange table the global
//! average needs is **not** built up front — `with_global` stores a
//! one-shot connector that wires those edges on the first
//! `global_average` call. A schedule that never global-averages (or a run
//! killed before its first k·H boundary) pays O(gossip edges), not
//! O(n^2); pure-gossip construction (`with_global = false`) still bails
//! with a clean configuration error if a global average is requested.
//!
//! §Execution model: collectives run as *phases* sharded across the
//! trainer's [`WorkerPool`] with a barrier between send- and receive-sides
//! (sends are buffered/framed, so a phase's receives can never block on a
//! same-phase send). This keeps one persistent engine for compute AND
//! communication at any pool size — including 1 — with deterministic
//! results: each node's arithmetic is self-contained and `recv_from`
//! selects by source, so scheduling order cannot leak into the bits.
//!
//! §Equivalence: the receive-side mix calls the same [`mix_row_src`]
//! kernel with the same f32 weight rows in the same order as the shared
//! mixer, and the global average accumulates rank-ascending per chunk —
//! the shared mean's exact operation order. Uncompressed trajectories are
//! therefore bit-identical to [`super::SharedBackend`]'s (asserted by
//! `rust/tests/comm_backends.rs` and `rust/tests/transport.rs`). The
//! chunked reduce-scatter/all-gather moves the bandwidth-optimal ring's
//! aggregate traffic (2 d (n-1) scalars); the latency-bound ring schedule
//! itself remains available as [`crate::collective::ring_all_reduce`] for
//! the bench suite.
//!
//! §Overlap: `gossip_async` issues a round's sends immediately and defers
//! the receive+mix to the matching [`CommBackend::finish`], so the wire's
//! latency runs under the caller's compute (the GossipGraD/SGP overlap).
//! The core keeps a depth-K ring of receive planes and a FIFO of in-flight
//! rounds; each issue bumps the frame epoch, so a delayed frame from an
//! aborted or already-drained round is discarded on receipt and counted
//! ([`CommStats::stale_frames_dropped`]) instead of corrupting a live
//! round. Chained issues gate their sends on the predecessor's completion
//! latch and read its output slot, so K overlapped rounds drain to exactly
//! the K-fold synchronous trajectory, bit for bit — same `mix_row_src`
//! kernel, same order. Billing is analytic at issue time (the round the
//! *issue* schedule runs, per the PR 8 convention) and is the same
//! expression `charge_since` bills on measured counters: every issued send
//! delivers in-process, so the analytic and measured charges agree.
//! Compressed transmit keeps error-feedback residual state that must
//! update in transmit order, so `gossip_async` declines (`Ok(None)`) and
//! the trainer counts a fallback round. Membership changes and synchronous
//! collectives are refused while rounds are in flight — drain first.
//!
//! §Membership: the round state machine ([`crate::coordinator::rounds`])
//! drops a peer that misses its receive deadline by calling
//! [`CommBackend::drop_node`]: the dead node's weight in every *other*
//! row is folded back onto the owner's self-weight (rows stay stochastic
//! — "renormalize the mixing row, never poison the trainer"), its
//! transmit sets empty out, and the global average re-chunks over the
//! alive ranks (still rank-ascending, so the healthy path's arithmetic is
//! untouched). `rejoin_node` restores the pristine rows. Dead nodes'
//! parameter rows ride along unchanged — frozen, not corrupted.
//!
//! §Time: charged per actual message and per node — node i pays its own
//! `alpha_i` per send plus its own `theta_i` per wire scalar from the
//! [`NodeCosts`] table, scaled to the emulated `cost_dim` (the same
//! emulation the shared backend bills); the aggregate `sim_seconds` is the
//! busiest node's charge (the pre-virtual-time scalar bill on a
//! homogeneous table, bit for bit).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use super::{
    export_residuals, import_residuals, BackendKind, CommBackend, CommCharge, CommStats,
    Compression, PendingComm, PendingPayload,
};
use crate::collective::{bus_with_handles, ring_chunk_bounds, Endpoint, Wire};
use crate::compress::{Codec, ErrorFeedback};
use crate::coordinator::mixer::{mix_row_src, weight_rows_f32};
use crate::costmodel::{BarrierScope, NodeCosts};
use crate::exec::{Latch, Ticket, WorkerPool};
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// The message-passing backend over in-proc mpsc channels.
pub type BusBackend = BusCore<Endpoint>;

/// One-shot edge builder run on the first `global_average` (lazy
/// all-to-all wiring; see module docs).
type Connector<W> = Box<dyn FnOnce(&mut [W]) -> Result<()> + Send>;

/// Membership overlay when at least one node is dropped: renormalized
/// rows, filtered transmit sets, and the alive-rank chunking of the
/// global average. `None` on the healthy path, which therefore runs the
/// pristine tables — bit for bit the pre-membership backend.
struct LiveView {
    /// Per-round rows with dead peers' weights folded onto self; a dead
    /// node's own row is `[(i, 1.0)]` (it keeps its frozen parameters).
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    /// Per-round transmit targets filtered to alive nodes.
    outn: Vec<Vec<Vec<usize>>>,
    /// Alive ranks, ascending.
    ranks: Vec<usize>,
    /// `ring_chunk_bounds(ranks.len(), d)` — the degraded chunking.
    bounds: Vec<usize>,
}

/// One issued-but-undrained overlapped gossip round (§Overlap).
struct WireFlight {
    /// Ring slot whose buffer the round's mix writes.
    slot: usize,
    /// Arrives when the round's receive+mix jobs have all finished; the
    /// successor round's send jobs gate on it before reading the slot.
    done: Arc<Latch>,
    /// Data address of `ring[slot]` at issue time (pairing check against
    /// the caller's [`PendingWireRound`]).
    addr: usize,
}

/// The caller-held half of an overlapped bus/tcp gossip round: the pool
/// ticket for its send and receive+mix jobs plus the output-slot address
/// that pairs it with the backend's own in-flight FIFO entry.
pub struct PendingWireRound {
    ticket: Ticket,
    slot_addr: usize,
}

/// The union of the gossip transmit sets over all rounds — the edge set a
/// message-passing backend needs for gossip alone (global-average edges
/// are wired lazily; see module docs).
pub fn gossip_union_edges(topo: &Topology) -> Vec<Vec<usize>> {
    let rounds = topo.rounds();
    (0..topo.n)
        .map(|j| {
            let mut e: Vec<usize> =
                (0..rounds).flat_map(|r| topo.out_neighbors(j, r)).collect();
            e.sort_unstable();
            e.dedup();
            e
        })
        .collect()
}

/// The message-passing backend core (see module docs), generic over the
/// [`Wire`] transport.
pub struct BusCore<W: Wire> {
    kind: BackendKind,
    n: usize,
    d: usize,
    rounds: usize,
    /// Pristine weight rows per round (same f32 quantization as the
    /// shared mixer).
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    /// Pristine out-neighbors per round (transmit targets, excl. self).
    outn: Vec<Vec<Vec<usize>>>,
    /// Membership overlay; `None` while every node is alive.
    live: Option<LiveView>,
    endpoints: Vec<W>,
    /// Depth-K ring of receive planes: slot `head` is the next issue's
    /// output buffer and doubles as the synchronous collectives' scratch
    /// (sync ops never advance `head`). A finished round swaps its slot's
    /// buffer into `params` (O(1) pointer swap).
    ring: Vec<ParamMatrix>,
    head: usize,
    /// Pipeline depth K (`--pipeline-depth`); 1 is the plain double buffer.
    depth: usize,
    /// Issued-but-undrained overlapped rounds, oldest first (FIFO drain).
    in_flight: VecDeque<WireFlight>,
    /// Sum of per-endpoint `stale_drops()` already folded into `total`
    /// (delta accounting; `restore_total` re-baselines it).
    stale_seen: u64,
    /// Healthy global-average chunk boundaries (`ring_chunk_bounds`).
    bounds: Vec<usize>,
    /// `0..n`, the healthy alive-rank list (so one code path serves both).
    all_ranks: Vec<usize>,
    /// Whether this run may global-average at all.
    global_allowed: bool,
    /// Pending lazy all-to-all wiring; consumed by the first
    /// `global_average`.
    connector: Option<Connector<W>>,
    compressors: Vec<Option<ErrorFeedback<Box<dyn Codec>>>>,
    /// Per-node link costs the endpoint counters are billed against.
    alpha: Vec<f64>,
    theta: Vec<f64>,
    cost_dim: usize,
    pub gossip_clock: usize,
    total: CommStats,
    /// Set when a collective fails mid-flight: the wires may hold
    /// half-delivered payloads, so the backend refuses further work until
    /// [`CommBackend::reset_round`] bumps the epoch and drains them.
    failed: bool,
    alive: Vec<bool>,
    /// Fault injection: a muted node is alive but wedged — it transmits
    /// nothing, which is what the deadline + drop machinery exists for.
    muted: Vec<bool>,
    /// Current round epoch; bumped by `reset_round` so retried rounds
    /// discard the aborted attempt's frames.
    epoch: u32,
}

impl BusCore<Endpoint> {
    /// Build the mpsc-channel bus for `topo`. `with_global` *permits* the
    /// global average; its all-to-all chunk-exchange edges are wired
    /// lazily on first use, so construction is O(gossip edges) either way.
    pub fn new(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        with_global: bool,
    ) -> BusBackend {
        BusBackend::with_depth(topo, d, costs, cost_dim, compression, with_global, 1)
    }

    /// [`BusBackend::new`] with an async gossip pipeline admitting up to
    /// `depth` overlapped rounds in flight (`--pipeline-depth`); depth 1 is
    /// the classic double buffer, bit for bit.
    #[allow(clippy::too_many_arguments)]
    pub fn with_depth(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        with_global: bool,
        depth: usize,
    ) -> BusBackend {
        let n = topo.n;
        let edges = gossip_union_edges(topo);
        let (endpoints, txs) = bus_with_handles(n, &edges);
        let connector: Option<Connector<Endpoint>> = if with_global {
            Some(Box::new(move |eps: &mut [Endpoint]| {
                for ep in eps.iter_mut() {
                    for (j, tx) in txs.iter().enumerate() {
                        if j != ep.rank {
                            ep.add_sender(j, tx.clone());
                        }
                    }
                }
                Ok(())
            }))
        } else {
            None
        };
        BusCore::from_parts(
            BackendKind::Bus,
            topo,
            d,
            costs,
            cost_dim,
            compression,
            endpoints,
            connector,
            with_global,
            depth,
        )
    }
}

impl<W: Wire> BusCore<W> {
    /// Assemble a core around already-wired endpoints (the transport
    /// constructors build those: mpsc channels or loopback sockets).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        kind: BackendKind,
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        endpoints: Vec<W>,
        connector: Option<Connector<W>>,
        global_allowed: bool,
        depth: usize,
    ) -> BusCore<W> {
        let depth = depth.max(1);
        let n = topo.n;
        debug_assert_eq!(costs.n(), n, "cost table must cover every node");
        debug_assert_eq!(endpoints.len(), n, "one endpoint per node");
        let rounds = topo.rounds();
        // Same quantization site as the shared mixer (bit-equality is
        // structural, not two parallel copies).
        let rows = weight_rows_f32(topo);
        let outn: Vec<Vec<Vec<usize>>> =
            (0..rounds).map(|r| (0..n).map(|j| topo.out_neighbors(j, r)).collect()).collect();
        BusCore {
            kind,
            n,
            d,
            rounds,
            rows,
            outn,
            live: None,
            endpoints,
            ring: (0..depth).map(|_| ParamMatrix::zeros(n, d)).collect(),
            head: 0,
            depth,
            in_flight: VecDeque::new(),
            stale_seen: 0,
            bounds: ring_chunk_bounds(n, d),
            all_ranks: (0..n).collect(),
            global_allowed,
            connector,
            compressors: compression.build(n, d),
            alpha: costs.alpha.clone(),
            theta: costs.theta.clone(),
            cost_dim,
            gossip_clock: 0,
            total: CommStats::default(),
            failed: false,
            alive: vec![true; n],
            muted: vec![false; n],
            epoch: 0,
        }
    }

    /// Out-route count per endpoint — the lazy-edge regression hook: a
    /// pure-gossip ring stays at degree 2 until (and unless) the first
    /// global average wires the chunk-exchange table.
    pub fn edge_degrees(&self) -> Vec<usize> {
        self.endpoints.iter().map(|e| e.degree()).collect()
    }

    /// True while the all-to-all wiring is still deferred.
    pub fn lazy_global_pending(&self) -> bool {
        self.connector.is_some()
    }

    /// Wire the chunk-exchange edges if they are still pending.
    fn ensure_global_edges(&mut self) -> Result<()> {
        if let Some(connect) = self.connector.take() {
            connect(&mut self.endpoints)?;
        }
        Ok(())
    }

    /// Recompute the membership overlay after a drop/rejoin. Healthy
    /// membership clears the overlay entirely so the pristine tables (and
    /// their exact bits) are back in force.
    fn rebuild_live(&mut self) {
        if self.alive.iter().all(|&a| a) {
            self.live = None;
            return;
        }
        let alive = &self.alive;
        let rows = self
            .rows
            .iter()
            .map(|per_round| {
                per_round
                    .iter()
                    .enumerate()
                    .map(|(i, row)| {
                        if !alive[i] {
                            return vec![(i, 1.0f32)];
                        }
                        let mut folded = 0.0f32;
                        let mut out: Vec<(usize, f32)> = Vec::with_capacity(row.len());
                        for &(j, w) in row {
                            if j == i || alive[j] {
                                out.push((j, w));
                            } else {
                                folded += w;
                            }
                        }
                        if folded != 0.0 {
                            if let Some(e) = out.iter_mut().find(|(j, _)| *j == i) {
                                e.1 += folded;
                            } else {
                                out.push((i, folded));
                            }
                        }
                        out
                    })
                    .collect()
            })
            .collect();
        let outn = self
            .outn
            .iter()
            .map(|per_round| {
                per_round
                    .iter()
                    .enumerate()
                    .map(|(j, targets)| {
                        if !alive[j] {
                            return Vec::new();
                        }
                        targets.iter().copied().filter(|&t| alive[t]).collect()
                    })
                    .collect()
            })
            .collect();
        let ranks: Vec<usize> = (0..self.n).filter(|&i| alive[i]).collect();
        let bounds = ring_chunk_bounds(ranks.len().max(1), self.d);
        self.live = Some(LiveView { rows, outn, ranks, bounds });
    }

    /// Snapshot the per-endpoint counters (delta accounting per action).
    fn traffic_snapshot(&self) -> Vec<(u64, u64)> {
        self.endpoints.iter().map(|e| e.traffic()).collect()
    }

    /// Charge incurred since `before`: traffic totals across nodes plus
    /// each node's own alpha-beta bill for its measured messages (message
    /// count and wire scalars taken together per node, so asymmetric
    /// topologies aren't billed a mix-and-match of two different nodes'
    /// worst terms); the aggregate `sim_seconds` is the busiest node's
    /// charge.
    fn charge_since(&self, before: &[(u64, u64)], barrier: BarrierScope) -> CommCharge {
        let scale = self.cost_dim as f64 / self.d.max(1) as f64;
        let mut scalars = 0u64;
        let mut msgs = 0u64;
        let mut critical = 0.0f64;
        let mut node_seconds = Vec::with_capacity(self.n);
        for (i, (ep, &(s0, m0))) in self.endpoints.iter().zip(before).enumerate() {
            let (s1, m1) = ep.traffic();
            let ds = s1 - s0;
            let dm = m1 - m0;
            scalars += ds;
            msgs += dm;
            let node_cost = dm as f64 * self.alpha[i] + ds as f64 * scale * self.theta[i];
            critical = critical.max(node_cost);
            node_seconds.push(node_cost);
        }
        CommCharge {
            stats: CommStats {
                scalars_sent: scalars,
                msgs,
                sim_seconds: critical,
                barrier_wait: 0.0,
                fallback_rounds: 0,
                stale_frames_dropped: 0,
            },
            node_seconds,
            barrier,
        }
    }

    /// Fold newly observed endpoint stale-frame discards into `total`
    /// (delta accounting against `stale_seen`).
    fn harvest_stale(&mut self) {
        let now: u64 = self.endpoints.iter().map(|e| e.stale_drops()).sum();
        self.total.stale_frames_dropped += now - self.stale_seen;
        self.stale_seen = now;
    }

    /// Whether the transmit path compresses (`build` makes the per-node
    /// codecs all-or-nothing).
    fn compressed(&self) -> bool {
        self.compressors[0].is_some()
    }

    /// Whether the async pipeline can accept another issued round.
    pub fn pipeline_ready(&self) -> bool {
        self.in_flight.len() < self.depth
    }

    /// Overlapped rounds currently in flight.
    pub fn in_flight_rounds(&self) -> usize {
        self.in_flight.len()
    }

    /// Rounds issued so far (drained + in flight) — the clock the NEXT
    /// issued round runs at, which is what overlapped billing follows.
    pub fn issued_clock(&self) -> usize {
        self.gossip_clock + self.in_flight.len()
    }

    fn gossip_inner(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommCharge> {
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let n = self.n;
        let d = self.d;
        let round = self.gossip_clock % self.rounds;
        let before = self.traffic_snapshot();
        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        let head = self.head;
        let alive = &self.alive;
        let muted = &self.muted;
        // Phase A — transmit: each node compresses once and ships the
        // payload to every (alive) out-neighbor; sends are buffered/
        // framed and never block on the receive side. Dead and muted
        // nodes transmit nothing.
        {
            let outn = match &self.live {
                Some(v) => &v.outn[round],
                None => &self.outn[round],
            };
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.compressors.chunks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, comps))| {
                        move || {
                            for (k, (ep, comp)) in
                                eps.iter_mut().zip(comps.iter_mut()).enumerate()
                            {
                                let j = ci * per + k;
                                if !alive[j] || muted[j] {
                                    continue;
                                }
                                let targets = &outn[j];
                                if targets.is_empty() {
                                    continue;
                                }
                                let x = &src[j * d..(j + 1) * d];
                                let (mut payload, wire) = match comp.as_mut() {
                                    Some(ef) => {
                                        let c = ef.compress(x);
                                        let wire = (c.wire_bytes as u64).div_ceil(4);
                                        (c.dense, wire)
                                    }
                                    None => (x.to_vec(), d as u64),
                                };
                                // Clone per extra neighbor only; the last
                                // send takes the buffer itself.
                                let last = targets.len() - 1;
                                for (t, &to) in targets.iter().enumerate() {
                                    let msg = if t == last {
                                        std::mem::take(&mut payload)
                                    } else {
                                        payload.clone()
                                    };
                                    ep.send_billed(to, msg, wire)?;
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        // Phase B — receive + mix: the same kernel, rows and order as the
        // shared mixer (bit-identical by construction). A dead node's row
        // is `[(i, 1.0)]`, so its frozen parameters self-copy through the
        // same kernel; a muted node defensively self-copies (the round
        // fails on its silent neighbors before this matters).
        {
            let rows = match &self.live {
                Some(v) => &v.rows[round],
                None => &self.rows[round],
            };
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.ring[head].row_blocks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, block))| {
                        move || {
                            for (k, (ep, out)) in
                                eps.iter_mut().zip(block.chunks_mut(d)).enumerate()
                            {
                                let i = ci * per + k;
                                if muted[i] {
                                    out.copy_from_slice(&src[i * d..(i + 1) * d]);
                                    continue;
                                }
                                let row = &rows[i];
                                let mut recvd: Vec<(usize, Vec<f32>)> =
                                    Vec::with_capacity(row.len());
                                for &(j, _) in row {
                                    if j != i {
                                        let v = ep.recv_from(j)?;
                                        ensure!(
                                            v.len() == d,
                                            "node {i}: message from {j} carries {} of {d} scalars",
                                            v.len()
                                        );
                                        recvd.push((j, v));
                                    }
                                }
                                mix_row_src(
                                    row,
                                    |j| {
                                        if j == i {
                                            &src[i * d..(i + 1) * d]
                                        } else {
                                            let (_, v) = recvd
                                                .iter()
                                                .find(|(jj, _)| *jj == j)
                                                .expect("received above");
                                            &v[..]
                                        }
                                    },
                                    out,
                                );
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(&mut self.ring[head]);
        self.gossip_clock += 1;
        let charge = self.charge_since(&before, BarrierScope::Neighborhood { round });
        self.total.merge(charge.stats);
        self.harvest_stale();
        Ok(charge)
    }

    fn global_average_inner(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommCharge> {
        debug_assert!(params.n() == self.n && params.d() == self.d);
        debug_assert!(self.global_allowed, "checked by the trait wrapper");
        let n = self.n;
        let d = self.d;
        // The chunk schedule runs over the alive ranks ascending; with
        // full membership that is `0..n` over the pristine bounds — the
        // exact pre-membership arithmetic, bit for bit.
        let (ranks, gbounds): (&[usize], &[usize]) = match &self.live {
            Some(v) => (&v.ranks, &v.bounds),
            None => (&self.all_ranks, &self.bounds),
        };
        let m = ranks.len();
        ensure!(m > 0, "global average with every node dropped");
        let inv = 1.0f32 / m as f32;
        let first = ranks[0];
        let before = self.traffic_snapshot();
        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        let alive = &self.alive;
        let muted = &self.muted;
        // Sub-phase spans (wall only; the enclosing global-average span
        // carries the cost-model bill): reduce-scatter covers phases A + B,
        // all-gather covers phase C.
        let rs_span = crate::obs::span(crate::obs::Phase::ReduceScatter, crate::obs::CLUSTER);
        // Phase A — reduce-scatter sends: alive node i ships chunk c of
        // its row directly to the chunk's owner ranks[c] (empty chunks
        // ship nothing).
        {
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .enumerate()
                    .map(|(ci, eps)| {
                        move || {
                            for (k, ep) in eps.iter_mut().enumerate() {
                                let i = ci * per + k;
                                if !alive[i] || muted[i] {
                                    continue;
                                }
                                let xi = &src[i * d..(i + 1) * d];
                                for (c, &to) in ranks.iter().enumerate() {
                                    if to != i && gbounds[c + 1] > gbounds[c] {
                                        ep.send(to, xi[gbounds[c]..gbounds[c + 1]].to_vec())?;
                                    }
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        // Phase B — reduce + gather sends: chunk owner i sums its chunk
        // over the alive ranks ASCENDING (the shared mean's exact
        // accumulation order: copy the first rank, add the rest, multiply
        // by 1/m), stores it in its scratch row, and broadcasts the
        // reduced chunk. Per-sender FIFO keeps these gather messages
        // behind phase A's scatter messages.
        {
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.ring[head].row_blocks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, block))| {
                        move || {
                            for (k, (ep, srow)) in
                                eps.iter_mut().zip(block.chunks_mut(d)).enumerate()
                            {
                                let i = ci * per + k;
                                if !alive[i] || muted[i] {
                                    continue;
                                }
                                let idx = match ranks.binary_search(&i) {
                                    Ok(idx) => idx,
                                    Err(_) => continue,
                                };
                                let (lo, hi) = (gbounds[idx], gbounds[idx + 1]);
                                if hi == lo {
                                    continue;
                                }
                                let len = hi - lo;
                                let mut acc: Vec<f32> = if i == first {
                                    src[i * d + lo..i * d + hi].to_vec()
                                } else {
                                    let v = ep.recv_from(first)?;
                                    ensure!(
                                        v.len() == len,
                                        "chunk from {first} has {} of {len}",
                                        v.len()
                                    );
                                    v
                                };
                                for &j in &ranks[1..] {
                                    if j == i {
                                        let own = &src[j * d + lo..j * d + hi];
                                        for (a, b) in acc.iter_mut().zip(own) {
                                            *a += b;
                                        }
                                    } else {
                                        let v = ep.recv_from(j)?;
                                        ensure!(
                                            v.len() == len,
                                            "chunk from {j} has {} of {len}",
                                            v.len()
                                        );
                                        for (a, b) in acc.iter_mut().zip(&v) {
                                            *a += b;
                                        }
                                    }
                                }
                                for a in acc.iter_mut() {
                                    *a *= inv;
                                }
                                srow[lo..hi].copy_from_slice(&acc);
                                // Broadcast the reduced chunk to the other
                                // alive ranks; the last send takes the
                                // buffer itself (acc is dead after this
                                // loop).
                                let last =
                                    ranks.iter().rev().find(|&&j| j != i).copied();
                                for &j in ranks {
                                    if j != i {
                                        let msg = if Some(j) == last {
                                            std::mem::take(&mut acc)
                                        } else {
                                            acc.clone()
                                        };
                                        ep.send(j, msg)?;
                                    }
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        drop(rs_span);
        let ag_span = crate::obs::span(crate::obs::Phase::AllGather, crate::obs::CLUSTER);
        // Phase C — assemble: every alive node fills the rest of its mean
        // row from the other owners' reduced chunks (its own is already
        // in place); dead (and defensively muted) nodes carry their
        // frozen row into scratch so the swap is total. All alive rows
        // end bit-identical.
        {
            let src = params.as_slice();
            pool.run(
                self.endpoints
                    .chunks_mut(per)
                    .zip(self.ring[head].row_blocks_mut(per))
                    .enumerate()
                    .map(|(ci, (eps, block))| {
                        move || {
                            for (k, (ep, srow)) in
                                eps.iter_mut().zip(block.chunks_mut(d)).enumerate()
                            {
                                let i = ci * per + k;
                                if !alive[i] || muted[i] {
                                    srow.copy_from_slice(&src[i * d..(i + 1) * d]);
                                    continue;
                                }
                                for (c, &j) in ranks.iter().enumerate() {
                                    if j != i && gbounds[c + 1] > gbounds[c] {
                                        let v = ep.recv_from(j)?;
                                        ensure!(
                                            v.len() == gbounds[c + 1] - gbounds[c],
                                            "reduced chunk from {j} has wrong length"
                                        );
                                        srow[gbounds[c]..gbounds[c + 1]].copy_from_slice(&v);
                                    }
                                }
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        drop(ag_span);
        params.swap_data(&mut self.ring[head]);
        let charge = self.charge_since(&before, BarrierScope::Global);
        self.total.merge(charge.stats);
        self.harvest_stale();
        Ok(charge)
    }

    /// One real message over the plane: serialized onto src's wire,
    /// received on dst's side — the endpoint counters measure it like any
    /// phase-A gossip send. The event engine holds the payload until its
    /// virtual delivery time (checkpointable), so the wire never carries
    /// state across calls.
    fn push_row_inner(
        &mut self,
        params: &ParamMatrix,
        src: usize,
        dst: usize,
    ) -> Result<(Vec<f32>, CommStats)> {
        ensure!(
            self.alive[src] && self.alive[dst],
            "push_row {src}->{dst} with a dropped endpoint"
        );
        let d = self.d;
        let x = params.row(src).to_vec();
        self.endpoints[src].send_billed(dst, x, d as u64)?;
        let payload = self.endpoints[dst].recv_from(src)?;
        ensure!(payload.len() == d, "pushed row carries {} of {d} scalars", payload.len());
        Ok((payload, CommStats { scalars_sent: d as u64, msgs: 1, ..Default::default() }))
    }

    /// Issue one overlapped gossip round (§Overlap): the caller must keep
    /// `params` unchanged until the whole chain is drained. Sends go out as
    /// soon as a worker picks up the send wave; the receive+mix wave is
    /// gated behind it by a latch and lands in `ring[head]`.
    unsafe fn gossip_async_inner(
        &mut self,
        params: &ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<Option<PendingComm>> {
        debug_assert!(params.n() == self.n && params.d() == self.d);
        debug_assert!(self.pipeline_ready(), "checked by the trait wrapper");
        let n = self.n;
        let d = self.d;
        let round = self.issued_clock() % self.rounds;
        // Every issued round gets a fresh frame epoch: a delayed frame
        // from an aborted or already-drained round can then never be
        // misattributed to a live round — it is discarded on receipt and
        // counted (`stale_frames_dropped`).
        self.epoch = self.epoch.wrapping_add(1);
        let epoch = self.epoch;
        let slot = self.head;

        // Chained issue: read the predecessor's output slot, gated on its
        // completion latch; an unchained round reads `params` directly.
        let (src_addr, prev) = match self.in_flight.back() {
            Some(p) => (p.addr, Some(p.done.clone())),
            None => (params.as_slice().as_ptr() as usize, None),
        };
        let dst_addr = self.ring[slot].as_mut_slice().as_mut_ptr() as usize;

        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        let chunks = (n + per - 1) / per;

        // Tables for the ISSUED round, captured as raw addresses; the
        // membership overlay cannot move underneath the jobs because
        // drop/rejoin are refused while rounds are in flight.
        let outn_addr = (match &self.live {
            Some(v) => &v.outn[round],
            None => &self.outn[round],
        }) as *const Vec<Vec<usize>> as usize;
        let rows_addr = (match &self.live {
            Some(v) => &v.rows[round],
            None => &self.rows[round],
        }) as *const Vec<Vec<(usize, f32)>> as usize;
        let alive_addr = self.alive.as_ptr() as usize;
        let muted_addr = self.muted.as_ptr() as usize;
        let ep_addr = self.endpoints.as_mut_ptr() as usize;

        // Two latch-gated waves in ONE FIFO submission:
        //   wave A (send jobs)  — stamp the round's epoch on every endpoint
        //     in the chunk, then ship each live node's source row;
        //   wave B (recv+mix)   — gate on `sends` (both waves touch the
        //     same endpoints, and receives must observe the round's epoch
        //     with every same-round send already issued), then receive
        //     in-neighbors and run the one `mix_row_src` kernel.
        // FIFO dequeue makes this deadlock-free at any pool size: every
        // wave-A job is picked up before any wave-B job, so a worker
        // parked on `sends` always leaves workers finishing wave A (and
        // the size-1 pool runs the batch inline in submission order).
        let sends = Arc::new(Latch::new(chunks));
        let done = Arc::new(Latch::new(chunks));
        let mut jobs: Vec<Box<dyn FnOnce() -> Result<()> + Send>> =
            Vec::with_capacity(2 * chunks);
        for ci in 0..chunks {
            let sends = sends.clone();
            let prev = prev.clone();
            jobs.push(Box::new(move || {
                let _arrive = sends.arrive_on_drop();
                if let Some(gate) = &prev {
                    gate.wait();
                }
                let lo = ci * per;
                let hi = ((ci + 1) * per).min(n);
                // SAFETY: endpoints[lo..hi] are touched by exactly this
                // job until `sends` opens; `src` is either the issue-time
                // `params` (caller-pinned until drain) or the predecessor
                // round's output slot, fully mixed once `prev` arrived;
                // the tables are immutable while rounds are in flight.
                let eps = unsafe {
                    std::slice::from_raw_parts_mut((ep_addr as *mut W).add(lo), hi - lo)
                };
                let outn = unsafe { &*(outn_addr as *const Vec<Vec<usize>>) };
                let alive = unsafe { std::slice::from_raw_parts(alive_addr as *const bool, n) };
                let muted = unsafe { std::slice::from_raw_parts(muted_addr as *const bool, n) };
                let src = unsafe { std::slice::from_raw_parts(src_addr as *const f32, n * d) };
                for (k, ep) in eps.iter_mut().enumerate() {
                    let j = lo + k;
                    // Every endpoint — dead and muted included — advances
                    // to the round's tag, so its receive filter stays in
                    // step with the pipeline.
                    ep.set_epoch(epoch);
                    if !alive[j] || muted[j] {
                        continue;
                    }
                    let targets = &outn[j];
                    if targets.is_empty() {
                        continue;
                    }
                    let x = &src[j * d..(j + 1) * d];
                    let mut payload = x.to_vec();
                    let last = targets.len() - 1;
                    for (ti, &to) in targets.iter().enumerate() {
                        let msg = if ti == last {
                            std::mem::take(&mut payload)
                        } else {
                            payload.clone()
                        };
                        ep.send_billed(to, msg, d as u64)?;
                    }
                }
                Ok(())
            }));
        }
        for ci in 0..chunks {
            let sends = sends.clone();
            let done = done.clone();
            jobs.push(Box::new(move || {
                let _arrive = done.arrive_on_drop();
                sends.wait();
                let lo = ci * per;
                let hi = ((ci + 1) * per).min(n);
                // SAFETY: same shard discipline as wave A; `dst` rows
                // [lo, hi) belong to exactly this job, and the slot's
                // buffer is not reused until this round's `done` gate has
                // opened for its successor and the FIFO drain returns it.
                let eps = unsafe {
                    std::slice::from_raw_parts_mut((ep_addr as *mut W).add(lo), hi - lo)
                };
                let rows = unsafe { &*(rows_addr as *const Vec<Vec<(usize, f32)>>) };
                let muted = unsafe { std::slice::from_raw_parts(muted_addr as *const bool, n) };
                let src = unsafe { std::slice::from_raw_parts(src_addr as *const f32, n * d) };
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(
                        (dst_addr as *mut f32).add(lo * d),
                        (hi - lo) * d,
                    )
                };
                for (k, (ep, out)) in eps.iter_mut().zip(dst.chunks_mut(d)).enumerate() {
                    let i = lo + k;
                    if muted[i] {
                        out.copy_from_slice(&src[i * d..(i + 1) * d]);
                        continue;
                    }
                    let row = &rows[i];
                    let mut recvd: Vec<(usize, Vec<f32>)> = Vec::with_capacity(row.len());
                    for &(j, _) in row {
                        if j != i {
                            let v = ep.recv_from(j)?;
                            ensure!(
                                v.len() == d,
                                "node {i}: message from {j} carries {} of {d} scalars",
                                v.len()
                            );
                            recvd.push((j, v));
                        }
                    }
                    mix_row_src(
                        row,
                        |j| {
                            if j == i {
                                &src[i * d..(i + 1) * d]
                            } else {
                                let (_, v) = recvd
                                    .iter()
                                    .find(|(jj, _)| *jj == j)
                                    .expect("received above");
                                &v[..]
                            }
                        },
                        out,
                    );
                }
                Ok(())
            }));
        }

        // Bill analytically at issue time — the wave jobs advance the
        // endpoint counters concurrently, so `charge_since` cannot read
        // them here. Same expression on the same masks and tables, and
        // every issued send delivers in-process, so this equals the
        // measured charge of the identical synchronous round.
        let scale = self.cost_dim as f64 / self.d.max(1) as f64;
        let outn_eff = match &self.live {
            Some(v) => &v.outn[round],
            None => &self.outn[round],
        };
        let mut scalars = 0u64;
        let mut msgs = 0u64;
        let mut critical = 0.0f64;
        let mut node_seconds = Vec::with_capacity(n);
        for j in 0..n {
            let dm = if self.alive[j] && !self.muted[j] { outn_eff[j].len() as u64 } else { 0 };
            let ds = dm * d as u64;
            scalars += ds;
            msgs += dm;
            let node_cost = dm as f64 * self.alpha[j] + ds as f64 * scale * self.theta[j];
            critical = critical.max(node_cost);
            node_seconds.push(node_cost);
        }
        let charge = CommCharge {
            stats: CommStats {
                scalars_sent: scalars,
                msgs,
                sim_seconds: critical,
                barrier_wait: 0.0,
                fallback_rounds: 0,
                stale_frames_dropped: 0,
            },
            node_seconds,
            barrier: BarrierScope::Neighborhood { round },
        };

        let ticket = pool.submit(jobs)?;
        self.in_flight.push_back(WireFlight { slot, done, addr: dst_addr });
        self.head = (self.head + 1) % self.depth;
        Ok(Some(PendingComm {
            payload: PendingPayload::WireRound(PendingWireRound { ticket, slot_addr: dst_addr }),
            charge,
        }))
    }

    /// Drain the oldest in-flight round: wait its ticket, commit its slot
    /// into `params` (O(1) buffer swap — the data stays put, so successor
    /// rounds chained on the slot keep reading valid memory), advance the
    /// drained clock, and fold the issue-time charge into the totals.
    fn finish_inner(&mut self, params: &mut ParamMatrix, pending: PendingComm) -> Result<CommCharge> {
        let PendingComm { payload, charge } = pending;
        let wire = match payload {
            PendingPayload::WireRound(w) => w,
            PendingPayload::SharedMix(_) => {
                bail!("finish: pending round belongs to the shared backend")
            }
        };
        let entry = self
            .in_flight
            .pop_front()
            .ok_or_else(|| anyhow!("finish with no overlapped round in flight"))?;
        ensure!(
            wire.slot_addr == entry.addr,
            "finish got a pending round out of FIFO order or from another backend"
        );
        wire.ticket.wait()?;
        params.swap_data(&mut self.ring[entry.slot]);
        self.gossip_clock += 1;
        self.total.merge(charge.stats);
        self.harvest_stale();
        Ok(charge)
    }

    /// Test/scenario hook: deliver one frame from `from` to `to` tagged
    /// with an arbitrary (stale) epoch — the delayed straggler of an
    /// aborted or already-drained round. At rest every endpoint sits at
    /// the backend's current epoch, so the sender is re-tagged afterwards.
    pub fn inject_stale_frame(
        &mut self,
        from: usize,
        to: usize,
        epoch: u32,
        payload: Vec<f32>,
    ) -> Result<()> {
        ensure!(self.in_flight.is_empty(), "inject_stale_frame while rounds are in flight");
        ensure!(
            from < self.n && to < self.n && from != to,
            "inject_stale_frame {from}->{to} out of range for n={}",
            self.n
        );
        let wire = payload.len() as u64;
        self.endpoints[from].set_epoch(epoch);
        let sent = self.endpoints[from].send_billed(to, payload, wire);
        self.endpoints[from].set_epoch(self.epoch);
        sent
    }
}

impl<W: Wire> CommBackend for BusCore<W> {
    fn kind(&self) -> BackendKind {
        self.kind
    }

    fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<CommCharge> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        ensure!(
            self.in_flight.is_empty(),
            "synchronous gossip with {} overlapped round(s) in flight — drain first",
            self.in_flight.len()
        );
        let mut sp = crate::obs::span(crate::obs::Phase::Gossip, crate::obs::CLUSTER);
        let result = self.gossip_inner(params, pool);
        self.failed |= result.is_err();
        if let Ok(charge) = &result {
            sp.set_sim(charge.stats.sim_seconds);
        }
        result
    }

    fn global_average(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommCharge> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        ensure!(
            self.in_flight.is_empty(),
            "global average with {} overlapped round(s) in flight — drain first",
            self.in_flight.len()
        );
        // A missing edge set is a clean configuration error, not a
        // half-delivered collective — don't poison for it.
        if !self.global_allowed {
            bail!("bus backend was built without all-reduce edges (pure-gossip schedule)");
        }
        if let Err(e) = self.ensure_global_edges() {
            // A half-wired edge table can't be retried (the connector is
            // one-shot), so this does poison.
            self.failed = true;
            return Err(e);
        }
        let mut sp = crate::obs::span(crate::obs::Phase::GlobalAverage, crate::obs::CLUSTER);
        let result = self.global_average_inner(params, pool);
        self.failed |= result.is_err();
        if let Ok(charge) = &result {
            sp.set_sim(charge.stats.sim_seconds);
        }
        result
    }

    fn push_row(
        &mut self,
        params: &ParamMatrix,
        src: usize,
        dst: usize,
    ) -> Result<(Vec<f32>, CommStats)> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        ensure!(
            self.in_flight.is_empty(),
            "push_row with {} overlapped round(s) in flight — drain first",
            self.in_flight.len()
        );
        // A failed push leaves the counters half-advanced, so it poisons
        // the backend exactly like a failed collective.
        let result = self.push_row_inner(params, src, dst);
        self.failed |= result.is_err();
        result
    }

    unsafe fn gossip_async(
        &mut self,
        params: &ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<Option<PendingComm>> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        if self.compressed() {
            // Error-feedback residuals must update in transmit order, so
            // the compressed path stays synchronous (the trainer counts a
            // fallback round).
            return Ok(None);
        }
        ensure!(
            self.pipeline_ready(),
            "gossip_async with the pipeline full (depth {}) — finish the oldest round first",
            self.depth
        );
        let result = self.gossip_async_inner(params, pool);
        self.failed |= result.is_err();
        result
    }

    fn finish(&mut self, params: &mut ParamMatrix, pending: PendingComm) -> Result<CommCharge> {
        ensure!(!self.failed, "bus backend is poisoned by an earlier failed collective");
        let result = self.finish_inner(params, pending);
        // A failed drain leaves the wires (and possibly the slot) half
        // written; poison until `reset_round` bumps the epoch and purges.
        self.failed |= result.is_err();
        result
    }

    fn supports_overlap(&self) -> bool {
        // The compressed transmit pass is ordered (error-feedback state),
        // so only the raw path can overlap.
        !self.compressed()
    }

    fn add_total(&mut self, stats: CommStats) {
        self.total.merge(stats);
    }

    fn gossip_node_seconds(&self, round: usize) -> Vec<f64> {
        // The same arithmetic charge_since() applies to this round's
        // measured counters — sender-billed, per message and per wire
        // scalar at the emulated cost_dim — so strict-mode event billing
        // is bit-identical to the synchronous round's charge.
        let scale = self.cost_dim as f64 / self.d.max(1) as f64;
        let outn = &self.outn[round % self.rounds];
        (0..self.n)
            .map(|j| {
                let dm = outn[j].len() as u64;
                let ds = dm * self.d as u64;
                dm as f64 * self.alpha[j] + ds as f64 * scale * self.theta[j]
            })
            .collect()
    }

    fn gossip_clock(&self) -> usize {
        self.gossip_clock
    }

    fn set_gossip_clock(&mut self, rounds: usize) {
        self.gossip_clock = rounds;
    }

    fn total(&self) -> CommStats {
        self.total
    }

    fn restore_total(&mut self, total: CommStats) {
        self.total = total;
        // Endpoint counters are not restored by checkpoints; re-baseline
        // the delta accounting so pre-restore discards aren't recounted.
        self.stale_seen = self.endpoints.iter().map(|e| e.stale_drops()).sum();
    }

    fn export_compressor_state(&self) -> Option<ParamMatrix> {
        export_residuals(&self.compressors, self.d)
    }

    fn import_compressor_state(&mut self, state: Option<&ParamMatrix>) -> Result<()> {
        import_residuals(&mut self.compressors, self.d, state)
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        for ep in self.endpoints.iter_mut() {
            ep.set_recv_deadline(deadline);
        }
    }

    fn supports_deadlines(&self) -> bool {
        true
    }

    fn drop_node(&mut self, node: usize) -> Result<u64> {
        ensure!(node < self.n, "drop_node {node} out of range for n={}", self.n);
        // In-flight jobs hold raw views of the membership tables.
        ensure!(self.in_flight.is_empty(), "drop_node with overlapped rounds in flight");
        ensure!(self.alive[node], "node {node} is already dropped");
        self.alive[node] = false;
        self.muted[node] = false;
        // Count the renormalized rows: every (round, alive owner) row
        // that held weight on the dead peer gets that weight folded back
        // onto its self entry.
        let mut folds = 0u64;
        for per_round in &self.rows {
            for (i, row) in per_round.iter().enumerate() {
                if i != node && self.alive[i] && row.iter().any(|&(j, _)| j == node) {
                    folds += 1;
                }
            }
        }
        self.rebuild_live();
        Ok(folds)
    }

    fn rejoin_node(&mut self, node: usize) -> Result<()> {
        ensure!(node < self.n, "rejoin_node {node} out of range for n={}", self.n);
        ensure!(self.in_flight.is_empty(), "rejoin_node with overlapped rounds in flight");
        ensure!(!self.alive[node], "node {node} is not dropped");
        self.alive[node] = true;
        self.muted[node] = false;
        self.rebuild_live();
        Ok(())
    }

    fn alive_mask(&self) -> Option<Vec<bool>> {
        Some(self.alive.clone())
    }

    fn reset_round(&mut self) {
        // Frames already discarded-and-counted fold into the total first;
        // the purge below throws frames away sight-unseen (never received,
        // so never counted as stale).
        self.harvest_stale();
        self.epoch = self.epoch.wrapping_add(1);
        for ep in self.endpoints.iter_mut() {
            ep.reset_epoch(self.epoch);
        }
        // Abandon any half-issued pipeline state. Contract: the caller
        // drops its PendingComm handles BEFORE resetting — a dropped
        // ticket blocks until its jobs retire, so no job still holds raw
        // views of the endpoints or ring slots by the time we get here.
        self.in_flight.clear();
        self.failed = false;
    }

    fn set_muted(&mut self, node: usize, muted: bool) -> Result<()> {
        ensure!(node < self.n, "set_muted {node} out of range for n={}", self.n);
        ensure!(self.in_flight.is_empty(), "set_muted with overlapped rounds in flight");
        self.muted[node] = muted;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;

    fn costs(n: usize) -> NodeCosts {
        NodeCosts::homogeneous(CostModel { alpha: 1e-4, theta: 1e-8, compute: 0.0 }, n)
    }

    fn ramp(n: usize, d: usize) -> ParamMatrix {
        let mut p = ParamMatrix::zeros(n, d);
        for i in 0..n {
            for (j, v) in p.row_mut(i).iter_mut().enumerate() {
                *v = (i * d + j) as f32 * 0.25 + 1.0;
            }
        }
        p
    }

    #[test]
    fn pure_gossip_schedule_keeps_degree_sized_edges_with_global_allowed() {
        // ISSUE 7 satellite: `with_global` used to eagerly wire n-1
        // senders per node. Now a ring that never global-averages stays
        // at degree 2, and the first global average wires the table.
        let topo = Topology::ring(8);
        let pool = WorkerPool::new(1);
        let mut params = ramp(8, 12);
        let mut bus = BusBackend::new(&topo, 12, &costs(8), 12, Compression::None, true);
        assert!(bus.lazy_global_pending());
        assert_eq!(bus.edge_degrees(), vec![2; 8], "gossip-union edges only");
        bus.gossip(&mut params, &pool).unwrap();
        assert_eq!(bus.edge_degrees(), vec![2; 8], "gossip never wires chords");
        bus.global_average(&mut params, &pool).unwrap();
        assert!(!bus.lazy_global_pending());
        assert_eq!(bus.edge_degrees(), vec![7; 8], "first global average wires all-to-all");
        // Without the permission flag nothing is wired and the config
        // error stays clean (and non-poisoning).
        let mut pure = BusBackend::new(&topo, 12, &costs(8), 12, Compression::None, false);
        assert!(!pure.lazy_global_pending());
        let err = pure.global_average(&mut params, &pool).unwrap_err().to_string();
        assert!(err.contains("without all-reduce edges"), "{err}");
        pure.gossip(&mut params, &pool).unwrap();
    }

    #[test]
    fn lazy_wiring_matches_eager_average_exactly() {
        // The deferred edge table must not change the global average's
        // bits: compare against the row mean computed the shared way.
        let (n, d) = (5, 17);
        let topo = Topology::ring(n);
        let pool = WorkerPool::new(1);
        let mut params = ramp(n, d);
        let mut expect = vec![0.0f32; d];
        for j in 0..d {
            let mut acc = params.row(0)[j];
            for i in 1..n {
                acc += params.row(i)[j];
            }
            expect[j] = acc * (1.0 / n as f32);
        }
        let mut bus = BusBackend::new(&topo, d, &costs(n), d, Compression::None, true);
        bus.global_average(&mut params, &pool).unwrap();
        for i in 0..n {
            assert_eq!(params.row(i), &expect[..], "node {i}");
        }
    }

    #[test]
    fn drop_renormalizes_rows_and_rejoin_restores() {
        let topo = Topology::ring(6);
        let pool = WorkerPool::new(1);
        let d = 8;
        let mut bus = BusBackend::new(&topo, d, &costs(6), d, Compression::None, true);
        // Ring node 4's neighbors are 3 and 5: dropping 4 renormalizes
        // exactly those two rows (one round in a static ring).
        let folds = bus.drop_node(4).unwrap();
        assert_eq!(folds, 2);
        assert_eq!(bus.alive_mask().unwrap(), vec![true, true, true, true, false, true]);
        assert!(bus.drop_node(4).is_err(), "double drop refused");

        // The renormalized gossip keeps alive rows stochastic and leaves
        // the dead row frozen.
        let mut params = ramp(6, d);
        let frozen = params.row(4).to_vec();
        let before_mean: f32 = (0..6).filter(|&i| i != 4).map(|i| params.row(i)[0]).sum::<f32>();
        bus.gossip(&mut params, &pool).unwrap();
        assert_eq!(params.row(4), &frozen[..], "dead row frozen through gossip");
        let after_mean: f32 = (0..6).filter(|&i| i != 4).map(|i| params.row(i)[0]).sum::<f32>();
        assert!(
            (before_mean - after_mean).abs() < 1e-3,
            "folded rows stay stochastic: {before_mean} vs {after_mean}"
        );

        // The degraded global average averages the 5 alive rows only.
        bus.global_average(&mut params, &pool).unwrap();
        assert_eq!(params.row(4), &frozen[..], "dead row frozen through global average");
        let alive_rows: Vec<usize> = (0..6).filter(|&i| i != 4).collect();
        for &i in &alive_rows[1..] {
            assert_eq!(params.row(i), params.row(alive_rows[0]), "alive consensus");
        }

        bus.rejoin_node(4).unwrap();
        assert!(bus.alive_mask().unwrap().iter().all(|&a| a));
        assert!(bus.rejoin_node(4).is_err(), "rejoin of an alive node refused");
        // Healthy membership is back on the pristine tables: a full
        // global average now includes node 4 again.
        bus.global_average(&mut params, &pool).unwrap();
        assert_eq!(params.row(4), params.row(0), "rejoined node averaged back in");
    }

    #[test]
    fn muted_peer_times_out_and_reset_round_recovers() {
        // The acceptance scenario at the backend level: node 2 wedges,
        // the deadline surfaces a typed stalled-peer error (not a hang),
        // drop + reset_round lets the retried round complete.
        let topo = Topology::ring(4);
        let pool = WorkerPool::new(1);
        let d = 6;
        let mut bus = BusBackend::new(&topo, d, &costs(4), d, Compression::None, false);
        let mut params = ramp(4, d);
        bus.set_muted(2, true).unwrap();
        bus.set_recv_deadline(Some(Duration::from_millis(40)));
        let err = bus.gossip(&mut params, &pool).unwrap_err();
        let text = format!("{err:#}");
        assert_eq!(crate::collective::stalled_peer(&text), Some(2), "{text}");
        // Poisoned until the round is reset...
        assert!(bus.gossip(&mut params, &pool).unwrap_err().to_string().contains("poisoned"));
        // ...then the drop + retry completes cleanly.
        bus.drop_node(2).unwrap();
        bus.reset_round();
        bus.set_recv_deadline(None);
        bus.gossip(&mut params, &pool).unwrap();
    }

    #[test]
    fn overlapped_round_matches_sync_bits_and_charge() {
        // The §Overlap anchor at the unit level: one issued+finished round
        // is the synchronous round, bit for bit, and its analytic
        // issue-time bill equals the measured sync charge exactly.
        for pool_size in [1usize, 4] {
            let topo = Topology::ring(6);
            let pool = WorkerPool::new(pool_size);
            let d = 9;
            let mut sync = BusBackend::new(&topo, d, &costs(6), d, Compression::None, false);
            let mut over = BusBackend::new(&topo, d, &costs(6), d, Compression::None, false);
            assert!(over.supports_overlap());
            let mut ps = ramp(6, d);
            let mut po = ramp(6, d);
            let cs = sync.gossip(&mut ps, &pool).unwrap();
            let pending = unsafe { over.gossip_async(&po, &pool) }
                .unwrap()
                .expect("uncompressed bus overlaps");
            assert_eq!(over.in_flight_rounds(), 1);
            let co = over.finish(&mut po, pending).unwrap();
            assert_eq!(
                ps.as_slice(),
                po.as_slice(),
                "pool={pool_size}: overlapped == sync, bit for bit"
            );
            assert_eq!(cs.stats.scalars_sent, co.stats.scalars_sent);
            assert_eq!(cs.stats.msgs, co.stats.msgs);
            assert_eq!(cs.stats.sim_seconds.to_bits(), co.stats.sim_seconds.to_bits());
            assert_eq!(cs.node_seconds, co.node_seconds, "analytic bill == measured bill");
            assert_eq!(over.gossip_clock, 1);
            assert_eq!(over.in_flight_rounds(), 0);
        }
    }

    #[test]
    fn depth_k_pipeline_matches_k_sync_rounds() {
        // Chained issues over a time-varying schedule (one-peer exp, so
        // the issued-round billing wraps the round table) drain FIFO to
        // the exact synchronous trajectory, with zero stale frames.
        for pool_size in [1usize, 4] {
            let topo = Topology::one_peer_expo(8);
            let d = 7;
            let pool = WorkerPool::new(pool_size);
            let mut sync = BusBackend::new(&topo, d, &costs(8), d, Compression::None, false);
            let mut over =
                BusBackend::with_depth(&topo, d, &costs(8), d, Compression::None, false, 3);
            let mut ps = ramp(8, d);
            let mut po = ramp(8, d);
            let total = topo.rounds() + 2;
            let mut handles = std::collections::VecDeque::new();
            for _ in 0..total {
                if !over.pipeline_ready() {
                    let oldest = handles.pop_front().unwrap();
                    over.finish(&mut po, oldest).unwrap();
                }
                let pending = unsafe { over.gossip_async(&po, &pool) }.unwrap().unwrap();
                handles.push_back(pending);
            }
            while let Some(p) = handles.pop_front() {
                over.finish(&mut po, p).unwrap();
            }
            for _ in 0..total {
                sync.gossip(&mut ps, &pool).unwrap();
            }
            assert_eq!(over.gossip_clock, total);
            assert_eq!(
                ps.as_slice(),
                po.as_slice(),
                "pool={pool_size}: depth-3 chain == {total} sync rounds"
            );
            assert_eq!(sync.total().scalars_sent, over.total().scalars_sent);
            assert_eq!(sync.total().msgs, over.total().msgs);
            assert_eq!(over.total().stale_frames_dropped, 0, "clean run drops nothing");
        }
    }

    #[test]
    fn injected_stale_frame_is_discarded_counted_and_bit_harmless() {
        // Satellite 3 at the unit level: a delayed frame from a dead epoch
        // is dropped on receipt, shows up in the counter, and leaves both
        // the sync and the overlapped trajectory bit-unchanged.
        let topo = Topology::ring(5);
        let pool = WorkerPool::new(1);
        let d = 6;
        let mut clean = BusBackend::new(&topo, d, &costs(5), d, Compression::None, false);
        let mut dirty = BusBackend::new(&topo, d, &costs(5), d, Compression::None, false);
        let mut pc = ramp(5, d);
        let mut pd = ramp(5, d);
        dirty.inject_stale_frame(1, 2, 77, vec![9.0; d]).unwrap();
        clean.gossip(&mut pc, &pool).unwrap();
        dirty.gossip(&mut pd, &pool).unwrap();
        assert_eq!(pc.as_slice(), pd.as_slice(), "stale frame never reaches the mix");
        assert_eq!(dirty.total().stale_frames_dropped, 1);
        assert_eq!(clean.total().stale_frames_dropped, 0);
        // The overlapped path filters identically.
        dirty.inject_stale_frame(2, 3, 123, vec![4.0; d]).unwrap();
        let pending = unsafe { dirty.gossip_async(&pd, &pool) }.unwrap().unwrap();
        dirty.finish(&mut pd, pending).unwrap();
        let pending = unsafe { clean.gossip_async(&pc, &pool) }.unwrap().unwrap();
        clean.finish(&mut pc, pending).unwrap();
        assert_eq!(pc.as_slice(), pd.as_slice(), "overlapped mix ignores the stale frame too");
        assert_eq!(dirty.total().stale_frames_dropped, 2);
    }

    #[test]
    fn sync_collectives_and_membership_refused_mid_flight() {
        // In-flight jobs hold raw views of endpoints and tables, so every
        // operation that would mutate them must refuse (without
        // poisoning) until the pipeline drains.
        let topo = Topology::ring(4);
        let pool = WorkerPool::new(2);
        let d = 5;
        let mut bus = BusBackend::with_depth(&topo, d, &costs(4), d, Compression::None, true, 2);
        let mut params = ramp(4, d);
        let mut other = ramp(4, d);
        let pending = unsafe { bus.gossip_async(&params, &pool) }.unwrap().unwrap();
        for err in [
            bus.gossip(&mut other, &pool).unwrap_err(),
            bus.global_average(&mut other, &pool).unwrap_err(),
            bus.drop_node(1).unwrap_err(),
            bus.set_muted(1, true).unwrap_err(),
        ] {
            assert!(err.to_string().contains("in flight"), "{err}");
        }
        // Refusals don't poison: the drain and the next sync round work.
        bus.finish(&mut params, pending).unwrap();
        bus.gossip(&mut params, &pool).unwrap();
    }

    #[test]
    fn compressed_transmit_declines_overlap() {
        // Error-feedback residuals update in transmit order; the codec
        // path must keep the sync fallback rather than pretend to overlap.
        let topo = Topology::ring(4);
        let pool = WorkerPool::new(1);
        let d = 8;
        let mut bus =
            BusBackend::new(&topo, d, &costs(4), d, Compression::TopK { frac: 0.5 }, false);
        assert!(!bus.supports_overlap());
        let params = ramp(4, d);
        let pending = unsafe { bus.gossip_async(&params, &pool) }.unwrap();
        assert!(pending.is_none(), "compressed transmit falls back to sync");
        assert_eq!(bus.in_flight_rounds(), 0);
    }

    #[test]
    fn healthy_membership_uses_pristine_tables() {
        // Drop + rejoin must leave zero overlay: trajectories after a
        // full recovery are the pristine backend's bits.
        let topo = Topology::ring(5);
        let pool = WorkerPool::new(1);
        let d = 7;
        let mut a = BusBackend::new(&topo, d, &costs(5), d, Compression::None, true);
        let mut b = BusBackend::new(&topo, d, &costs(5), d, Compression::None, true);
        b.drop_node(1).unwrap();
        b.rejoin_node(1).unwrap();
        let mut pa = ramp(5, d);
        let mut pb = ramp(5, d);
        a.gossip(&mut pa, &pool).unwrap();
        b.gossip(&mut pb, &pool).unwrap();
        a.global_average(&mut pa, &pool).unwrap();
        b.global_average(&mut pb, &pool).unwrap();
        assert_eq!(pa.as_slice(), pb.as_slice(), "recovered == never-degraded, bit for bit");
    }
}
