//! [`TcpBackend`]: the message-passing plane over real loopback sockets.
//!
//! This is [`BusCore`] — the exact phase code, [`mix_row_src`] kernel, and
//! rank-ascending chunked global average of [`super::BusBackend`] —
//! instantiated over [`crate::collective::tcp::TcpEndpoint`]s instead of
//! mpsc channels. Every transmitted vector is framed
//! (`u32 epoch | u32 len | f32s`, little-endian) and shipped through an
//! actual `TcpStream`, so the CommStats a training run reports are
//! measured off a real wire. Uncompressed trajectories are bit-identical
//! to both other backends by construction (asserted by
//! `rust/tests/transport.rs`): the socket changes the bytes' journey, not
//! the arithmetic.
//!
//! §Topology of streams: one directed stream per gossip edge, wired at
//! construction from the schedule's gossip union; the all-to-all
//! chunk-exchange streams are dialed lazily on the first `global_average`
//! (the same deferral as the bus, but here each deferred edge is a real
//! `connect()`). The accept fabric lives inside the lazy connector and is
//! torn down as soon as no further edges can be requested.
//!
//! §Deployment shape: `new_loopback` runs every rank in this process with
//! OS-assigned ports (`host:0`), which is the shape verify.sh and the
//! bit-equality suite exercise. A multi-process deployment (`--peers`)
//! needs a join handshake on top of the same frames and is rejected at
//! config parse with a clear message until that lands.

use anyhow::{Context, Result};

use super::bus::{gossip_union_edges, BusCore};
use super::{BackendKind, Compression};
use crate::collective::tcp::{tcp_loopback, TcpEndpoint};
use crate::costmodel::NodeCosts;
use crate::topology::Topology;

/// The socket-transport backend (see module docs).
pub type TcpBackend = BusCore<TcpEndpoint>;

impl BusCore<TcpEndpoint> {
    /// Build the loopback TCP plane for `topo`: one listener per rank at
    /// `listen` (`host:port`; port 0 = OS-assigned, the default — a fixed
    /// port P pins rank r to P + r), one stream per gossip edge.
    /// `with_global` permits the global average; its all-to-all streams
    /// are dialed lazily on first use.
    pub fn new_loopback(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        with_global: bool,
        listen: &str,
    ) -> Result<TcpBackend> {
        TcpBackend::new_loopback_with_depth(
            topo,
            d,
            costs,
            cost_dim,
            compression,
            with_global,
            listen,
            1,
        )
    }

    /// [`TcpBackend::new_loopback`] with an async gossip pipeline admitting
    /// up to `depth` overlapped rounds in flight (`--pipeline-depth`). The
    /// per-stream reader threads already park tagged frames off the compute
    /// thread, so kernel socket buffers never backpressure an overlapped
    /// sender mid-round.
    #[allow(clippy::too_many_arguments)]
    pub fn new_loopback_with_depth(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        with_global: bool,
        listen: &str,
        depth: usize,
    ) -> Result<TcpBackend> {
        let n = topo.n;
        let edges = gossip_union_edges(topo);
        let (endpoints, fabric) =
            tcp_loopback(n, &edges, listen).context("building the loopback tcp fabric")?;
        let connector = if with_global {
            // The fabric moves into the connector: acceptors keep running
            // until the chunk-exchange streams are dialed (or the backend
            // drops), then shut down.
            Some(Box::new(move |eps: &mut [TcpEndpoint]| -> Result<()> {
                for i in 0..eps.len() {
                    for j in 0..eps.len() {
                        if j != i {
                            fabric.connect(&mut eps[i], j)?;
                        }
                    }
                }
                Ok(())
            }) as Box<dyn FnOnce(&mut [TcpEndpoint]) -> Result<()> + Send>)
        } else {
            // Pure gossip: no future edges, tear the acceptors down now.
            drop(fabric);
            None
        };
        Ok(BusCore::from_parts(
            BackendKind::Tcp,
            topo,
            d,
            costs,
            cost_dim,
            compression,
            endpoints,
            connector,
            with_global,
            depth,
        ))
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::super::CommBackend;
    use super::*;
    use crate::costmodel::CostModel;
    use crate::exec::WorkerPool;
    use crate::params::ParamMatrix;

    fn costs(n: usize) -> NodeCosts {
        NodeCosts::homogeneous(CostModel { alpha: 1e-4, theta: 1e-8, compute: 0.0 }, n)
    }

    fn ramp(n: usize, d: usize) -> ParamMatrix {
        let mut p = ParamMatrix::zeros(n, d);
        for i in 0..n {
            for (j, v) in p.row_mut(i).iter_mut().enumerate() {
                *v = (i * d + j) as f32 * 0.5 - 3.0;
            }
        }
        p
    }

    #[test]
    fn kind_and_lazy_edges_over_sockets() {
        let topo = Topology::ring(6);
        let d = 10;
        let mut tcp = TcpBackend::new_loopback(
            &topo,
            d,
            &costs(6),
            d,
            Compression::None,
            true,
            "127.0.0.1:0",
        )
        .unwrap();
        assert_eq!(tcp.kind(), BackendKind::Tcp);
        assert!(tcp.supports_deadlines());
        assert_eq!(tcp.edge_degrees(), vec![2; 6], "gossip streams only at startup");
        let pool = WorkerPool::new(1);
        let mut params = ramp(6, d);
        tcp.global_average(&mut params, &pool).unwrap();
        assert_eq!(tcp.edge_degrees(), vec![5; 6], "first global average dials the rest");
    }

    #[test]
    fn tcp_matches_bus_bit_for_bit_on_one_round() {
        // The module-level claim in miniature (the full ≥3-topology sweep
        // lives in rust/tests/transport.rs): same gossip + global average,
        // identical bits and identical traffic accounting.
        let topo = Topology::ring(5);
        let d = 13;
        let pool = WorkerPool::new(1);
        let mut bus = super::super::BusBackend::new(&topo, d, &costs(5), d, Compression::None, true);
        let mut tcp = TcpBackend::new_loopback(
            &topo,
            d,
            &costs(5),
            d,
            Compression::None,
            true,
            "127.0.0.1:0",
        )
        .unwrap();
        let mut pb = ramp(5, d);
        let mut pt = ramp(5, d);
        let cb = bus.gossip(&mut pb, &pool).unwrap();
        let ct = tcp.gossip(&mut pt, &pool).unwrap();
        assert_eq!(pb.as_slice(), pt.as_slice(), "gossip bits");
        assert_eq!(cb.stats, ct.stats, "gossip traffic");
        let cb = bus.global_average(&mut pb, &pool).unwrap();
        let ct = tcp.global_average(&mut pt, &pool).unwrap();
        assert_eq!(pb.as_slice(), pt.as_slice(), "global-average bits");
        assert_eq!(cb.stats, ct.stats, "global-average traffic");
    }

    #[test]
    fn overlapped_socket_rounds_match_sync_bits() {
        // The §Overlap anchor on the real wire: issue+finish (depth 2,
        // chained) over sockets == the synchronous socket trajectory, bit
        // for bit, with nothing counted stale on a clean run.
        let topo = Topology::ring(5);
        let d = 11;
        let pool = WorkerPool::new(2);
        let mk = || {
            TcpBackend::new_loopback_with_depth(
                &topo,
                d,
                &costs(5),
                d,
                Compression::None,
                false,
                "127.0.0.1:0",
                2,
            )
            .unwrap()
        };
        let mut sync = mk();
        let mut over = mk();
        assert!(over.supports_overlap());
        let mut ps = ramp(5, d);
        let mut po = ramp(5, d);
        let mut handles = std::collections::VecDeque::new();
        for _ in 0..4 {
            if !over.pipeline_ready() {
                let oldest = handles.pop_front().unwrap();
                over.finish(&mut po, oldest).unwrap();
            }
            let pending = unsafe { over.gossip_async(&po, &pool) }.unwrap().unwrap();
            handles.push_back(pending);
        }
        while let Some(p) = handles.pop_front() {
            over.finish(&mut po, p).unwrap();
        }
        for _ in 0..4 {
            sync.gossip(&mut ps, &pool).unwrap();
        }
        assert_eq!(ps.as_slice(), po.as_slice(), "overlapped sockets == sync sockets");
        assert_eq!(sync.total().scalars_sent, over.total().scalars_sent);
        assert_eq!(over.total().stale_frames_dropped, 0);
    }

    #[test]
    fn stale_frame_on_the_socket_is_discarded_and_counted() {
        // Satellite 3 on the tcp wire: a delayed frame from a dead epoch
        // rides a real stream, is dropped on receipt, counted, and leaves
        // the gossip bits untouched.
        let topo = Topology::ring(4);
        let d = 6;
        let pool = WorkerPool::new(1);
        let mk = || {
            TcpBackend::new_loopback(&topo, d, &costs(4), d, Compression::None, false, "127.0.0.1:0")
                .unwrap()
        };
        let mut clean = mk();
        let mut dirty = mk();
        let mut pc = ramp(4, d);
        let mut pd = ramp(4, d);
        dirty.inject_stale_frame(0, 1, 99, vec![7.5; d]).unwrap();
        clean.gossip(&mut pc, &pool).unwrap();
        dirty.gossip(&mut pd, &pool).unwrap();
        assert_eq!(pc.as_slice(), pd.as_slice(), "stale socket frame never reaches the mix");
        assert_eq!(dirty.total().stale_frames_dropped, 1);
        assert_eq!(clean.total().stale_frames_dropped, 0);
    }

    #[test]
    fn wedged_socket_peer_drops_cleanly_mid_round() {
        // Acceptance scenario on the real wire: mute node 1, arm the
        // deadline, watch the round fail with attribution, drop + reset,
        // and the retried round completes over the degraded membership.
        let topo = Topology::ring(4);
        let d = 8;
        let pool = WorkerPool::new(1);
        let mut tcp = TcpBackend::new_loopback(
            &topo,
            d,
            &costs(4),
            d,
            Compression::None,
            false,
            "127.0.0.1:0",
        )
        .unwrap();
        let mut params = ramp(4, d);
        tcp.set_muted(1, true).unwrap();
        tcp.set_recv_deadline(Some(Duration::from_millis(50)));
        let err = tcp.gossip(&mut params, &pool).unwrap_err();
        assert_eq!(crate::collective::stalled_peer(&format!("{err:#}")), Some(1));
        tcp.drop_node(1).unwrap();
        tcp.reset_round();
        tcp.set_recv_deadline(None);
        let frozen = params.row(1).to_vec();
        tcp.gossip(&mut params, &pool).unwrap();
        assert_eq!(params.row(1), &frozen[..], "dropped node frozen, run completes");
    }
}
