//! [`TcpBackend`]: the message-passing plane over real loopback sockets.
//!
//! This is [`BusCore`] — the exact phase code, [`mix_row_src`] kernel, and
//! rank-ascending chunked global average of [`super::BusBackend`] —
//! instantiated over [`crate::collective::tcp::TcpEndpoint`]s instead of
//! mpsc channels. Every transmitted vector is framed
//! (`u32 epoch | u32 len | f32s`, little-endian) and shipped through an
//! actual `TcpStream`, so the CommStats a training run reports are
//! measured off a real wire. Uncompressed trajectories are bit-identical
//! to both other backends by construction (asserted by
//! `rust/tests/transport.rs`): the socket changes the bytes' journey, not
//! the arithmetic.
//!
//! §Topology of streams: one directed stream per gossip edge, wired at
//! construction from the schedule's gossip union; the all-to-all
//! chunk-exchange streams are dialed lazily on the first `global_average`
//! (the same deferral as the bus, but here each deferred edge is a real
//! `connect()`). The accept fabric lives inside the lazy connector and is
//! torn down as soon as no further edges can be requested.
//!
//! §Deployment shape: `new_loopback` runs every rank in this process with
//! OS-assigned ports (`host:0`), which is the shape verify.sh and the
//! bit-equality suite exercise. A multi-process deployment (`--peers`)
//! needs a join handshake on top of the same frames and is rejected at
//! config parse with a clear message until that lands.

use anyhow::{Context, Result};

use super::bus::{gossip_union_edges, BusCore};
use super::{BackendKind, Compression};
use crate::collective::tcp::{tcp_loopback, TcpEndpoint};
use crate::costmodel::NodeCosts;
use crate::topology::Topology;

/// The socket-transport backend (see module docs).
pub type TcpBackend = BusCore<TcpEndpoint>;

impl BusCore<TcpEndpoint> {
    /// Build the loopback TCP plane for `topo`: one listener per rank at
    /// `listen` (`host:port`; port 0 = OS-assigned, the default — a fixed
    /// port P pins rank r to P + r), one stream per gossip edge.
    /// `with_global` permits the global average; its all-to-all streams
    /// are dialed lazily on first use.
    pub fn new_loopback(
        topo: &Topology,
        d: usize,
        costs: &NodeCosts,
        cost_dim: usize,
        compression: Compression,
        with_global: bool,
        listen: &str,
    ) -> Result<TcpBackend> {
        let n = topo.n;
        let edges = gossip_union_edges(topo);
        let (endpoints, fabric) =
            tcp_loopback(n, &edges, listen).context("building the loopback tcp fabric")?;
        let connector = if with_global {
            // The fabric moves into the connector: acceptors keep running
            // until the chunk-exchange streams are dialed (or the backend
            // drops), then shut down.
            Some(Box::new(move |eps: &mut [TcpEndpoint]| -> Result<()> {
                for i in 0..eps.len() {
                    for j in 0..eps.len() {
                        if j != i {
                            fabric.connect(&mut eps[i], j)?;
                        }
                    }
                }
                Ok(())
            }) as Box<dyn FnOnce(&mut [TcpEndpoint]) -> Result<()> + Send>)
        } else {
            // Pure gossip: no future edges, tear the acceptors down now.
            drop(fabric);
            None
        };
        Ok(BusCore::from_parts(
            BackendKind::Tcp,
            topo,
            d,
            costs,
            cost_dim,
            compression,
            endpoints,
            connector,
            with_global,
        ))
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::super::CommBackend;
    use super::*;
    use crate::costmodel::CostModel;
    use crate::exec::WorkerPool;
    use crate::params::ParamMatrix;

    fn costs(n: usize) -> NodeCosts {
        NodeCosts::homogeneous(CostModel { alpha: 1e-4, theta: 1e-8, compute: 0.0 }, n)
    }

    fn ramp(n: usize, d: usize) -> ParamMatrix {
        let mut p = ParamMatrix::zeros(n, d);
        for i in 0..n {
            for (j, v) in p.row_mut(i).iter_mut().enumerate() {
                *v = (i * d + j) as f32 * 0.5 - 3.0;
            }
        }
        p
    }

    #[test]
    fn kind_and_lazy_edges_over_sockets() {
        let topo = Topology::ring(6);
        let d = 10;
        let mut tcp = TcpBackend::new_loopback(
            &topo,
            d,
            &costs(6),
            d,
            Compression::None,
            true,
            "127.0.0.1:0",
        )
        .unwrap();
        assert_eq!(tcp.kind(), BackendKind::Tcp);
        assert!(tcp.supports_deadlines());
        assert_eq!(tcp.edge_degrees(), vec![2; 6], "gossip streams only at startup");
        let pool = WorkerPool::new(1);
        let mut params = ramp(6, d);
        tcp.global_average(&mut params, &pool).unwrap();
        assert_eq!(tcp.edge_degrees(), vec![5; 6], "first global average dials the rest");
    }

    #[test]
    fn tcp_matches_bus_bit_for_bit_on_one_round() {
        // The module-level claim in miniature (the full ≥3-topology sweep
        // lives in rust/tests/transport.rs): same gossip + global average,
        // identical bits and identical traffic accounting.
        let topo = Topology::ring(5);
        let d = 13;
        let pool = WorkerPool::new(1);
        let mut bus = super::super::BusBackend::new(&topo, d, &costs(5), d, Compression::None, true);
        let mut tcp = TcpBackend::new_loopback(
            &topo,
            d,
            &costs(5),
            d,
            Compression::None,
            true,
            "127.0.0.1:0",
        )
        .unwrap();
        let mut pb = ramp(5, d);
        let mut pt = ramp(5, d);
        let cb = bus.gossip(&mut pb, &pool).unwrap();
        let ct = tcp.gossip(&mut pt, &pool).unwrap();
        assert_eq!(pb.as_slice(), pt.as_slice(), "gossip bits");
        assert_eq!(cb.stats, ct.stats, "gossip traffic");
        let cb = bus.global_average(&mut pb, &pool).unwrap();
        let ct = tcp.global_average(&mut pt, &pool).unwrap();
        assert_eq!(pb.as_slice(), pt.as_slice(), "global-average bits");
        assert_eq!(cb.stats, ct.stats, "global-average traffic");
    }

    #[test]
    fn wedged_socket_peer_drops_cleanly_mid_round() {
        // Acceptance scenario on the real wire: mute node 1, arm the
        // deadline, watch the round fail with attribution, drop + reset,
        // and the retried round completes over the degraded membership.
        let topo = Topology::ring(4);
        let d = 8;
        let pool = WorkerPool::new(1);
        let mut tcp = TcpBackend::new_loopback(
            &topo,
            d,
            &costs(4),
            d,
            Compression::None,
            false,
            "127.0.0.1:0",
        )
        .unwrap();
        let mut params = ramp(4, d);
        tcp.set_muted(1, true).unwrap();
        tcp.set_recv_deadline(Some(Duration::from_millis(50)));
        let err = tcp.gossip(&mut params, &pool).unwrap_err();
        assert_eq!(crate::collective::stalled_peer(&format!("{err:#}")), Some(1));
        tcp.drop_node(1).unwrap();
        tcp.reset_round();
        tcp.set_recv_deadline(None);
        let frozen = params.row(1).to_vec();
        tcp.gossip(&mut params, &pool).unwrap();
        assert_eq!(params.row(1), &frozen[..], "dropped node frozen, run completes");
    }
}
