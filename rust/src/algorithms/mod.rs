//! Communication-schedule policies: the paper's algorithm family.
//!
//! Algorithm 1's per-iteration structure is `local update` followed by a
//! communication action; the algorithms differ *only* in which action they
//! take at iteration k:
//!
//! | algorithm     | action at k (0-based)                              |
//! |---------------|----------------------------------------------------|
//! | Parallel SGD  | GlobalAverage every step (W = avg)                 |
//! | Gossip SGD    | Gossip every step (H = infinity)                   |
//! | Local SGD     | GlobalAverage when mod(k+1, H)=0, else nothing     |
//! | Gossip-PGA    | GlobalAverage when mod(k+1, H)=0, else Gossip      |
//! | Gossip-AGA    | PGA with the adaptive period of Algorithm 2        |
//! | SlowMo        | PGA schedule + slow-momentum update at each sync   |
//!
//! The limiting identities (Remarks after Algorithm 1) — H=1 => Parallel,
//! W=I => Local, H=inf => Gossip — are tested here and at the coordinator
//! level (rust/tests/).

use anyhow::{bail, Result};

/// What the coordinator does after the local update at iteration k.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommAction {
    /// No communication (Local SGD between syncs).
    None,
    /// One gossip round with the topology's weight matrix.
    Gossip,
    /// Exact global average via ring all-reduce.
    GlobalAverage,
}

/// Algorithm family selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    Parallel,
    Gossip,
    Local,
    GossipPga,
    GossipAga,
    SlowMo,
}

impl AlgorithmKind {
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "parallel" | "allreduce" => AlgorithmKind::Parallel,
            "gossip" | "dsgd" => AlgorithmKind::Gossip,
            "local" => AlgorithmKind::Local,
            "pga" | "gossip-pga" => AlgorithmKind::GossipPga,
            "aga" | "gossip-aga" => AlgorithmKind::GossipAga,
            "slowmo" => AlgorithmKind::SlowMo,
            other => bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::Parallel => "parallel",
            AlgorithmKind::Gossip => "gossip",
            AlgorithmKind::Local => "local",
            AlgorithmKind::GossipPga => "pga",
            AlgorithmKind::GossipAga => "aga",
            AlgorithmKind::SlowMo => "slowmo",
        }
    }

    /// Paper-style display name for tables.
    pub fn display(&self) -> &'static str {
        match self {
            AlgorithmKind::Parallel => "Parallel SGD",
            AlgorithmKind::Gossip => "Gossip SGD",
            AlgorithmKind::Local => "Local SGD",
            AlgorithmKind::GossipPga => "Gossip-PGA",
            AlgorithmKind::GossipAga => "Gossip-AGA",
            AlgorithmKind::SlowMo => "SlowMo",
        }
    }
}

/// Mutable schedule state worth checkpointing (today: Gossip-AGA's
/// adaptive-period recursion). Fixed schedules are stateless and export
/// `None`; losing this state on resume silently resets Algorithm 2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AgaState {
    pub h: usize,
    pub counter: usize,
    pub f_init: f64,
    pub f_init_ready: bool,
}

/// A communication schedule: maps iteration index (+ observed mean loss)
/// to a [`CommAction`]. Stateful because Gossip-AGA adapts its period from
/// observed losses.
pub trait Schedule: Send {
    /// Decide the action after the local update of iteration `k` (0-based).
    /// `mean_loss` is the across-worker mean training loss at this step
    /// (used by AGA; other schedules ignore it).
    fn action(&mut self, k: usize, mean_loss: f64) -> CommAction;

    /// Current period (for logging; `usize::MAX` = never).
    fn current_period(&self) -> usize;

    /// Can this schedule ever emit [`CommAction::GlobalAverage`]? Lets the
    /// communication plane size its all-reduce edge set at construction
    /// (pure-gossip schedules skip the all-to-all setup). Conservative
    /// default: yes.
    fn uses_global_average(&self) -> bool {
        true
    }

    /// Snapshot mutable state for checkpointing (`None` = stateless).
    fn export_state(&self) -> Option<AgaState> {
        None
    }

    /// Restore state exported by [`Schedule::export_state`] (no-op for
    /// stateless schedules).
    fn import_state(&mut self, _state: &AgaState) {}
}

/// Fixed-period schedules covering Parallel / Gossip / Local / PGA / SlowMo.
#[derive(Clone, Debug)]
pub struct FixedSchedule {
    /// Gossip on non-sync iterations?
    pub gossip_between: bool,
    /// Global-average period; `usize::MAX` disables global averaging.
    pub h: usize,
}

impl FixedSchedule {
    pub fn for_kind(kind: AlgorithmKind, h: usize) -> Result<FixedSchedule> {
        // `action` computes (k + 1) % h, so h = 0 (e.g. `period = 0` in a
        // config file) would panic with a divide-by-zero mid-training.
        // Reject it up front for every kind that consults h.
        if h == 0 && matches!(kind, AlgorithmKind::Local | AlgorithmKind::GossipPga | AlgorithmKind::SlowMo) {
            bail!("{} requires a global-averaging period H >= 1, got 0", kind.display());
        }
        Ok(match kind {
            AlgorithmKind::Parallel => FixedSchedule { gossip_between: false, h: 1 },
            AlgorithmKind::Gossip => FixedSchedule { gossip_between: true, h: usize::MAX },
            AlgorithmKind::Local => FixedSchedule { gossip_between: false, h },
            AlgorithmKind::GossipPga | AlgorithmKind::SlowMo => {
                FixedSchedule { gossip_between: true, h }
            }
            AlgorithmKind::GossipAga => bail!("use AgaSchedule for Gossip-AGA"),
        })
    }
}

impl Schedule for FixedSchedule {
    fn action(&mut self, k: usize, _mean_loss: f64) -> CommAction {
        if self.h != usize::MAX && (k + 1) % self.h == 0 {
            CommAction::GlobalAverage
        } else if self.gossip_between {
            CommAction::Gossip
        } else {
            CommAction::None
        }
    }

    fn current_period(&self) -> usize {
        self.h
    }

    fn uses_global_average(&self) -> bool {
        self.h != usize::MAX
    }
}

/// Gossip-AGA (Algorithm 2): counter C, warmup running-average F_init, then
/// H <- ceil(F_init / F(x_k)) * H_init at each global averaging step.
#[derive(Clone, Debug)]
pub struct AgaSchedule {
    pub h_init: usize,
    pub warmup: usize,
    h: usize,
    counter: usize,
    f_init: f64,
    f_init_ready: bool,
}

impl AgaSchedule {
    pub fn new(h_init: usize, warmup: usize) -> Result<Self> {
        if h_init == 0 {
            bail!("Gossip-AGA requires an initial period H_init >= 1, got 0");
        }
        Ok(AgaSchedule { h_init, warmup, h: h_init, counter: 0, f_init: 0.0, f_init_ready: false })
    }
}

impl Schedule for AgaSchedule {
    fn action(&mut self, k: usize, mean_loss: f64) -> CommAction {
        self.counter += 1;
        if self.counter < self.h {
            return CommAction::Gossip;
        }
        // Global averaging step: update the running loss estimate / period.
        self.counter = 0;
        if k < self.warmup || !self.f_init_ready {
            // Running-average estimate of the initial loss scale.
            self.f_init = if self.f_init_ready { 0.5 * (self.f_init + mean_loss) } else { mean_loss };
            self.f_init_ready = true;
        } else if mean_loss > 1e-12 {
            // Loss decreased => ratio > 1 => period grows (eq. (9), with the
            // exponential term removed per App. G's practical note).
            let ratio = (self.f_init / mean_loss).max(0.0);
            self.h = ((ratio * self.h_init as f64).ceil() as usize).max(1);
        }
        CommAction::GlobalAverage
    }

    fn current_period(&self) -> usize {
        self.h
    }

    fn export_state(&self) -> Option<AgaState> {
        Some(AgaState {
            h: self.h,
            counter: self.counter,
            f_init: self.f_init,
            f_init_ready: self.f_init_ready,
        })
    }

    fn import_state(&mut self, state: &AgaState) {
        self.h = state.h.max(1);
        self.counter = state.counter;
        self.f_init = state.f_init;
        self.f_init_ready = state.f_init_ready;
    }
}

/// Build the right schedule for a kind (validates the period arguments).
pub fn schedule_for(
    kind: AlgorithmKind,
    h: usize,
    aga_init: usize,
    aga_warmup: usize,
) -> Result<Box<dyn Schedule>> {
    Ok(match kind {
        AlgorithmKind::GossipAga => Box::new(AgaSchedule::new(aga_init, aga_warmup)?),
        k => Box::new(FixedSchedule::for_kind(k, h)?),
    })
}

/// SlowMo outer-update hyper-parameters (Wang et al. 2019). The paper's
/// Table 8 comparison uses the slow-momentum update at every global sync:
///   u <- beta_s u + (x_prev_sync - x_avg) / gamma_eff
///   x <- x_prev_sync - alpha_s * gamma_eff * u
#[derive(Clone, Copy, Debug)]
pub struct SlowMoParams {
    pub beta: f64,
    pub alpha: f64,
}

impl Default for SlowMoParams {
    fn default() -> Self {
        // Wang et al. report beta in [0.4, 0.8]; 0.5 is their robust choice.
        SlowMoParams { beta: 0.5, alpha: 1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn actions(kind: AlgorithmKind, h: usize, steps: usize) -> Vec<CommAction> {
        let mut s = schedule_for(kind, h, 4, 10).unwrap();
        (0..steps).map(|k| s.action(k, 1.0)).collect()
    }

    #[test]
    fn parallel_always_averages() {
        assert!(actions(AlgorithmKind::Parallel, 16, 10)
            .iter()
            .all(|a| *a == CommAction::GlobalAverage));
    }

    #[test]
    fn gossip_never_averages() {
        assert!(actions(AlgorithmKind::Gossip, 16, 100)
            .iter()
            .all(|a| *a == CommAction::Gossip));
    }

    #[test]
    fn local_sgd_pattern() {
        let a = actions(AlgorithmKind::Local, 4, 8);
        assert_eq!(
            a,
            vec![
                CommAction::None,
                CommAction::None,
                CommAction::None,
                CommAction::GlobalAverage,
                CommAction::None,
                CommAction::None,
                CommAction::None,
                CommAction::GlobalAverage,
            ]
        );
    }

    #[test]
    fn pga_pattern_matches_algorithm1() {
        // mod(k+1, H) == 0 => global average, else gossip.
        let a = actions(AlgorithmKind::GossipPga, 3, 6);
        assert_eq!(
            a,
            vec![
                CommAction::Gossip,
                CommAction::Gossip,
                CommAction::GlobalAverage,
                CommAction::Gossip,
                CommAction::Gossip,
                CommAction::GlobalAverage,
            ]
        );
    }

    #[test]
    fn pga_h1_equals_parallel() {
        assert_eq!(actions(AlgorithmKind::GossipPga, 1, 5), actions(AlgorithmKind::Parallel, 1, 5));
    }

    #[test]
    fn zero_period_is_rejected_not_divide_by_zero() {
        // `period = 0` in a config used to reach `(k + 1) % 0` and panic.
        for kind in [AlgorithmKind::Local, AlgorithmKind::GossipPga, AlgorithmKind::SlowMo] {
            assert!(FixedSchedule::for_kind(kind, 0).is_err(), "{kind:?}");
            assert!(schedule_for(kind, 0, 4, 10).is_err(), "{kind:?}");
        }
        // Parallel / Gossip never consult h; h = 0 is accepted there.
        assert!(FixedSchedule::for_kind(AlgorithmKind::Parallel, 0).is_ok());
        assert!(FixedSchedule::for_kind(AlgorithmKind::Gossip, 0).is_ok());
        assert!(AgaSchedule::new(0, 10).is_err());
        assert!(schedule_for(AlgorithmKind::GossipAga, 8, 0, 10).is_err());
    }

    #[test]
    fn aga_state_export_import_roundtrip() {
        let mut s = AgaSchedule::new(4, 8).unwrap();
        let mut loss = 8.0;
        for k in 0..40 {
            s.action(k, loss);
            loss *= 0.95;
        }
        let st = s.export_state().expect("AGA exports state");
        let mut fresh = AgaSchedule::new(4, 8).unwrap();
        assert_ne!(fresh.export_state().unwrap(), st);
        fresh.import_state(&st);
        assert_eq!(fresh.export_state().unwrap(), st);
        // Replays identically from the imported state.
        for k in 40..80 {
            assert_eq!(fresh.action(k, 1.0), s.action(k, 1.0), "k={k}");
        }
        // Fixed schedules are stateless.
        assert!(FixedSchedule::for_kind(AlgorithmKind::GossipPga, 4).unwrap().export_state().is_none());
    }

    #[test]
    fn aga_period_grows_as_loss_drops() {
        let mut s = AgaSchedule::new(4, 8).unwrap();
        let mut syncs = Vec::new();
        // Loss decays geometrically; period should increase over time.
        let mut k = 0;
        let mut loss = 8.0;
        for _ in 0..200 {
            let a = s.action(k, loss);
            if a == CommAction::GlobalAverage {
                syncs.push((k, s.current_period()));
            }
            loss *= 0.99;
            k += 1;
        }
        assert!(syncs.len() >= 3);
        let first_h = syncs[1].1;
        let last_h = syncs.last().unwrap().1;
        assert!(last_h > first_h, "period should grow: {syncs:?}");
    }

    #[test]
    fn aga_never_stalls() {
        // Even with garbage losses the schedule must keep syncing.
        let mut s = AgaSchedule::new(2, 4).unwrap();
        let mut got_sync = 0;
        for k in 0..100 {
            if s.action(k, f64::NAN) == CommAction::GlobalAverage {
                got_sync += 1;
            }
        }
        assert!(got_sync >= 2);
        assert!(s.current_period() >= 1);
    }

    #[test]
    fn uses_global_average_tracks_the_action_set() {
        // The comm plane sizes its all-reduce edges from this query; it
        // must agree with the actions each schedule actually emits.
        for kind in [
            AlgorithmKind::Parallel,
            AlgorithmKind::Gossip,
            AlgorithmKind::Local,
            AlgorithmKind::GossipPga,
            AlgorithmKind::GossipAga,
            AlgorithmKind::SlowMo,
        ] {
            let mut s = schedule_for(kind, 4, 2, 4).unwrap();
            let claims = s.uses_global_average();
            let emits =
                (0..64).any(|k| s.action(k, 1.0) == CommAction::GlobalAverage);
            assert_eq!(claims, emits, "{kind:?}");
        }
    }

    #[test]
    fn kind_name_roundtrip() {
        for kind in [
            AlgorithmKind::Parallel,
            AlgorithmKind::Gossip,
            AlgorithmKind::Local,
            AlgorithmKind::GossipPga,
            AlgorithmKind::GossipAga,
            AlgorithmKind::SlowMo,
        ] {
            assert_eq!(AlgorithmKind::from_name(kind.name()).unwrap(), kind);
        }
        assert!(AlgorithmKind::from_name("sgd2").is_err());
    }
}
