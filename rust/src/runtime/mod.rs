//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! training hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All graphs were lowered with
//! `return_tuple=True`, so outputs decompose via `Literal::to_tuple`.
//!
//! Compiled executables are cached per artifact name; typed wrappers
//! ([`GradFn`], [`EvalFn`], [`MixFn`]) enforce the manifest's I/O contract
//! and offer `*_into` variants that write into caller buffers (the zero-
//! alloc path the coordinator uses every step).
//!
//! The runtime is shared across worker threads (`Arc<Runtime>`): the
//! executable cache is behind an `RwLock` so the steady-state path is a
//! read-lock + `Arc` clone, and `execute` runs concurrently from the
//! coordinator's per-worker threads.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, Context, Result};

pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest};

/// The PJRT client handle, scoped so the thread-safety assertion covers
/// exactly the FFI type and nothing else in [`Runtime`].
struct SharedClient(xla::PjRtClient);

/// A compiled executable shared across worker threads via `Arc`.
pub struct SharedExecutable(xla::PjRtLoadedExecutable);

// SAFETY: the PJRT C API is thread-safe by contract — clients, loaded
// executables and `execute` calls may be used concurrently from multiple
// threads (XLA's CPU client serializes internally where required). These
// impls additionally REQUIRE that the vendored `xla` wrapper keeps its
// handles free of non-atomic Rust-side shared state: in particular it must
// NOT hold an `Rc` of the client inside `PjRtLoadedExecutable` the way
// upstream xla-rs once did (a non-atomic refcount cloned/dropped during
// `execute` would race). Re-verify that invariant whenever the vendored
// crate is updated. The impls are deliberately on these two newtypes only,
// so any future non-thread-safe field added to `Runtime` re-enters the
// compiler's auto Send/Sync derivation instead of being silently asserted
// safe.
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}
unsafe impl Send for SharedExecutable {}
unsafe impl Sync for SharedExecutable {}

impl std::ops::Deref for SharedExecutable {
    type Target = xla::PjRtLoadedExecutable;
    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

/// The process-wide PJRT runtime (Send + Sync by composition of the
/// newtypes above; shared across worker threads as `Arc<Runtime>`).
pub struct Runtime {
    client: SharedClient,
    pub manifest: Manifest,
    cache: RwLock<HashMap<String, Arc<SharedExecutable>>>,
}

impl Runtime {
    /// Load the manifest from `dir` and connect the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client: SharedClient(client), manifest, cache: RwLock::new(HashMap::new()) })
    }

    /// Load from the auto-discovered artifacts directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&crate::artifacts_dir())
    }

    /// Compile (or fetch the cached) executable for a manifest artifact.
    pub fn executable(&self, name: &str) -> Result<Arc<SharedExecutable>> {
        if let Some(exe) = self.cache.read().expect("runtime cache poisoned").get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.by_name(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", name))?;
        // Two threads may race to compile the same artifact; the first
        // insert wins so every caller shares one executable.
        let mut cache = self.cache.write().expect("runtime cache poisoned");
        Ok(cache.entry(name.to_string()).or_insert_with(|| Arc::new(SharedExecutable(exe))).clone())
    }

    /// Raw execution: literals in, tuple-decomposed literals out.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.by_name(name)?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "artifact '{name}' wants {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("decomposing tuple of {name}: {e:?}"))
    }
}

/// Build an f32 literal of the given logical shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "literal wants {n} elements, got {}", data.len());
    let flat = xla::Literal::vec1(data);
    if shape.len() == 1 || shape.is_empty() {
        if shape.is_empty() {
            // scalar
            return flat
                .reshape(&[])
                .map_err(|e| anyhow!("reshape scalar: {e:?}"));
        }
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
    flat.reshape(&dims).map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// Build an i32 literal of the given logical shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "literal wants {n} elements, got {}", data.len());
    let flat = xla::Literal::vec1(data);
    if shape.is_empty() {
        // scalar: reshape to rank 0 (mirrors lit_f32; a rank-1 literal here
        // would fail the executable's parameter-shape check).
        return flat.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"));
    }
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
    flat.reshape(&dims).map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// Copy a literal's f32 payload into `out` without allocating.
pub fn lit_copy_f32(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to(out).map_err(|e| anyhow!("copy_raw_to: {e:?}"))
}

/// Typed wrapper for `kind = "grad"` artifacts:
/// `(flat_params, batch...) -> (loss, grad)`.
pub struct GradFn {
    rt: Arc<Runtime>,
    pub spec: ArtifactSpec,
}

impl GradFn {
    pub fn new(rt: Arc<Runtime>, name: &str) -> Result<GradFn> {
        let spec = rt.manifest.by_name(name)?.clone();
        anyhow::ensure!(
            spec.kind == "grad",
            "artifact '{name}' is kind '{}', want 'grad'",
            spec.kind
        );
        rt.executable(name)?; // compile eagerly
        Ok(GradFn { rt, spec })
    }

    pub fn flat_dim(&self) -> usize {
        self.spec.flat_dim
    }

    /// Execute with freshly built batch literals (each step's batch is new
    /// data, so the caller constructs them and hands over ownership);
    /// writes grad into `grad_out` and returns the loss.
    pub fn call_into(
        &self,
        params: &[f32],
        batch: Vec<xla::Literal>,
        grad_out: &mut [f32],
    ) -> Result<f32> {
        anyhow::ensure!(params.len() == self.spec.flat_dim, "params length");
        anyhow::ensure!(grad_out.len() == self.spec.flat_dim, "grad_out length");
        let mut inputs = Vec::with_capacity(1 + batch.len());
        inputs.push(lit_f32(params, &self.spec.inputs[0].shape)?);
        inputs.extend(batch);
        let outs = self.rt.run(&self.spec.name, &inputs)?;
        anyhow::ensure!(outs.len() == 2, "grad artifact must return (loss, grad)");
        let loss = outs[0].to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0];
        lit_copy_f32(&outs[1], grad_out)?;
        Ok(loss)
    }
}

/// Clone a literal (the crate exposes no Clone; round-trip via raw bytes).
pub fn clone_literal(lit: &xla::Literal) -> Result<xla::Literal> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match lit.ty().map_err(|e| anyhow!("ty: {e:?}"))? {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            lit_f32(&v, &dims)
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            lit_i32(&v, &dims)
        }
        other => Err(anyhow!("clone_literal: unsupported {other:?}")),
    }
}

/// Typed wrapper for `kind = "eval"` artifacts: returns the scalar metric.
pub struct EvalFn {
    rt: Arc<Runtime>,
    pub spec: ArtifactSpec,
}

impl EvalFn {
    pub fn new(rt: Arc<Runtime>, name: &str) -> Result<EvalFn> {
        let spec = rt.manifest.by_name(name)?.clone();
        anyhow::ensure!(spec.kind == "eval", "artifact '{name}' is not eval");
        rt.executable(name)?;
        Ok(EvalFn { rt, spec })
    }

    pub fn call(&self, params: &[f32], batch: &[xla::Literal]) -> Result<f32> {
        let mut inputs = Vec::with_capacity(1 + batch.len());
        inputs.push(lit_f32(params, &self.spec.inputs[0].shape)?);
        for b in batch {
            inputs.push(clone_literal(b)?);
        }
        let outs = self.rt.run(&self.spec.name, &inputs)?;
        Ok(outs[0].to_vec::<f32>().map_err(|e| anyhow!("eval out: {e:?}"))?[0])
    }
}

/// Typed wrapper for the Pallas gossip-mix artifacts (`kind = "mix"`).
pub struct MixFn {
    rt: Arc<Runtime>,
    pub spec: ArtifactSpec,
}

impl MixFn {
    pub fn new(rt: Arc<Runtime>, name: &str) -> Result<MixFn> {
        let spec = rt.manifest.by_name(name)?.clone();
        anyhow::ensure!(spec.kind == "mix", "artifact '{name}' is not mix");
        rt.executable(name)?;
        Ok(MixFn { rt, spec })
    }

    /// `weights: (k,)`, `stack: (k*d,)` row-major -> mixed `(d,)`.
    pub fn call(&self, weights: &[f32], stack: &[f32]) -> Result<Vec<f32>> {
        let k = self.spec.inputs[0].shape[0];
        let d = self.spec.inputs[1].shape[1];
        anyhow::ensure!(weights.len() == k && stack.len() == k * d, "mix shapes");
        let inputs = vec![lit_f32(weights, &[k])?, lit_f32(stack, &[k, d])?];
        let outs = self.rt.run(&self.spec.name, &inputs)?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("mix out: {e:?}"))
    }
}
