//! Artifact manifest: the typed mirror of `artifacts/manifest.json` that
//! `python/compile/aot.py` emits. The runtime loads executables strictly
//! through this — no hard-coded shapes on the rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::jsonio::Json;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(anyhow!("unsupported dtype '{other}'")),
        }
    }
}

/// One tensor port of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub model: String,
    pub kind: String,
    pub flat_dim: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    let shape = v
        .req("shape")?
        .as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec {
        name: v.req_str("name")?.to_string(),
        shape,
        dtype: Dtype::from_str(v.req_str("dtype")?)?,
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        anyhow::ensure!(root.req_usize("version")? == 1, "unsupported manifest version");
        let mut artifacts = Vec::new();
        for a in root.req("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts not array"))? {
            let meta = match a.get("meta") {
                Some(Json::Obj(m)) => m.clone(),
                _ => BTreeMap::new(),
            };
            artifacts.push(ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: dir.join(a.req_str("file")?),
                model: a.req_str("model")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                flat_dim: a.req_usize("flat_dim")?,
                inputs: a
                    .req("inputs")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("inputs not array"))?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("outputs not array"))?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
                meta,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Exact-name lookup.
    pub fn by_name(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// First artifact matching model + kind (+ optional meta tag).
    pub fn find(&self, model: &str, kind: &str, tag: Option<&str>) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| {
                a.model == model
                    && a.kind == kind
                    && tag.map_or(true, |t| {
                        a.meta.get("config").and_then(|v| v.as_str()) == Some(t)
                    })
            })
            .ok_or_else(|| anyhow!("no artifact for model={model} kind={kind} tag={tag:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn load_minimal() {
        let dir = std::env::temp_dir().join(format!("gpga_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            r#"{"version":1,"artifacts":[{"name":"a","file":"a.hlo.txt","model":"logreg",
                "kind":"grad","flat_dim":10,
                "inputs":[{"name":"w","shape":[10],"dtype":"f32"}],
                "outputs":[{"name":"loss","shape":[1],"dtype":"f32"}],
                "meta":{"batch":32}}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.by_name("a").unwrap();
        assert_eq!(a.flat_dim, 10);
        assert_eq!(a.inputs[0].elements(), 10);
        assert_eq!(a.meta_usize("batch"), Some(32));
        assert!(m.by_name("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_version() {
        let dir = std::env::temp_dir().join(format!("gpga_manifest_v_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, r#"{"version":2,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find("logreg", "grad", None).is_ok());
            assert!(m.find("transformer", "grad", Some("tiny")).is_ok());
            // every referenced file exists
            for a in &m.artifacts {
                assert!(a.file.exists(), "{:?}", a.file);
            }
        }
    }
}
