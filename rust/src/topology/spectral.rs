//! Spectral quantities and transient-stage theory (paper §1.1, Tables 2–3,
//! Appendix D).
//!
//! Everything is a closed-form function of `beta`, `H` and `n`:
//!   C_beta = sum_{k=0}^{H-1} beta^k = (1 - beta^H)/(1 - beta)
//!   D_beta = min{H, 1/(1 - beta)}
//! plus the transient-stage orders of Appendix D used by the theory benches.

/// C_beta = (1 - beta^H) / (1 - beta), the paper's gossip-decay sum.
pub fn c_beta(beta: f64, h: usize) -> f64 {
    assert!((0.0..=1.0).contains(&beta));
    if beta >= 1.0 - 1e-15 {
        return h as f64;
    }
    (1.0 - beta.powi(h as i32)) / (1.0 - beta)
}

/// D_beta = min{H, 1/(1-beta)} — which force dominates consensus
/// (Lemma 4 / Remark 8).
pub fn d_beta(beta: f64, h: usize) -> f64 {
    if beta >= 1.0 - 1e-15 {
        return h as f64;
    }
    (h as f64).min(1.0 / (1.0 - beta))
}

/// Which consensus force dominates (Scenario I/II of §B.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsensusRegime {
    /// 1/(1-beta) >= H: large/sparse network — global averaging dominates.
    GlobalAveragingDominates,
    /// 1/(1-beta) < H: small/dense network — gossip dominates.
    GossipDominates,
}

pub fn regime(beta: f64, h: usize) -> ConsensusRegime {
    if 1.0 / (1.0 - beta) >= h as f64 {
        ConsensusRegime::GlobalAveragingDominates
    } else {
        ConsensusRegime::GossipDominates
    }
}

/// Transient-stage orders (iterations), Appendix D.1. These are Omega(...)
/// orders — constants dropped — used to compare *growth*, not absolutes.
pub mod transient {
    use super::{c_beta, d_beta};

    /// Gossip SGD, iid: n^3 beta^4 / (1-beta)^2.
    pub fn gossip_iid(n: usize, beta: f64) -> f64 {
        (n as f64).powi(3) * beta.powi(4) / (1.0 - beta).powi(2)
    }

    /// Gossip SGD, non-iid: n^3 beta^4 / (1-beta)^4.
    pub fn gossip_noniid(n: usize, beta: f64) -> f64 {
        (n as f64).powi(3) * beta.powi(4) / (1.0 - beta).powi(4)
    }

    /// Gossip-PGA, iid: n^3 beta^4 C_beta^2.
    pub fn pga_iid(n: usize, beta: f64, h: usize) -> f64 {
        (n as f64).powi(3) * beta.powi(4) * c_beta(beta, h).powi(2)
    }

    /// Gossip-PGA, non-iid: n^3 beta^4 C_beta^2 D_beta^2.
    pub fn pga_noniid(n: usize, beta: f64, h: usize) -> f64 {
        (n as f64).powi(3) * beta.powi(4) * c_beta(beta, h).powi(2) * d_beta(beta, h).powi(2)
    }

    /// Local SGD, iid: n^3 H^2.
    pub fn local_iid(n: usize, h: usize) -> f64 {
        (n as f64).powi(3) * (h as f64).powi(2)
    }

    /// Local SGD, non-iid: n^3 H^4.
    pub fn local_noniid(n: usize, h: usize) -> f64 {
        (n as f64).powi(3) * (h as f64).powi(4)
    }
}

/// Convergence-rate bound evaluator (Theorems 1–2, eq. (7)/(8)):
///   sigma/sqrt(nT) + C^{1/3} beta^{2/3}(sigma^{2/3} + D^{1/3} b^{2/3})/T^{2/3}
///   + beta D / T
/// Used by the Table 4/6 analytic benches to tabulate rates at measured beta.
#[derive(Clone, Copy, Debug)]
pub struct RateParams {
    pub n: usize,
    pub beta: f64,
    pub h: usize,
    pub sigma: f64,
    pub b: f64,
}

impl RateParams {
    pub fn bound(&self, t: f64) -> f64 {
        let cb = c_beta(self.beta, self.h);
        let db = d_beta(self.beta, self.h);
        let term1 = self.sigma / (self.n as f64 * t).sqrt();
        let term2 = cb.powf(1.0 / 3.0)
            * self.beta.powf(2.0 / 3.0)
            * (self.sigma.powf(2.0 / 3.0) + db.powf(1.0 / 3.0) * self.b.powf(2.0 / 3.0))
            / t.powf(2.0 / 3.0);
        let term3 = self.beta * db / t;
        term1 + term2 + term3
    }

    /// First T at which the SGD term dominates both overhead terms —
    /// the empirical-side definition of the transient boundary.
    pub fn transient_boundary(&self) -> f64 {
        let mut lo = 1.0f64;
        let mut hi = 1e18f64;
        let dominated = |t: f64| {
            let sgd = self.sigma.max(1e-9) / (self.n as f64 * t).sqrt();
            let cb = c_beta(self.beta, self.h);
            let db = d_beta(self.beta, self.h);
            let ovh = cb.powf(1.0 / 3.0)
                * self.beta.powf(2.0 / 3.0)
                * (self.sigma.powf(2.0 / 3.0) + db.powf(1.0 / 3.0) * self.b.powf(2.0 / 3.0))
                / t.powf(2.0 / 3.0)
                + self.beta * db / t;
            sgd >= ovh
        };
        if dominated(lo) {
            return lo;
        }
        for _ in 0..200 {
            let mid = (lo * hi).sqrt();
            if dominated(mid) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c_beta_limits() {
        // beta -> 0 => C -> 1; beta -> 1 => C -> H (Remarks 2-3).
        assert!((c_beta(1e-12, 16) - 1.0).abs() < 1e-9);
        assert!((c_beta(1.0, 16) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn c_beta_below_min_h_inv_gap() {
        // Table 2 caption: C_beta < min{1/(1-beta), H}.
        for &beta in &[0.1, 0.5, 0.9, 0.99, 0.999] {
            for &h in &[2usize, 8, 16, 64] {
                let c = c_beta(beta, h);
                assert!(c < (h as f64).min(1.0 / (1.0 - beta)) + 1e-12, "beta={beta} h={h}");
            }
        }
    }

    #[test]
    fn d_beta_piecewise() {
        assert_eq!(d_beta(0.5, 16), 2.0); // 1/(1-0.5) = 2 < 16
        assert_eq!(d_beta(0.99, 16), 16.0); // 1/(0.01) = 100 > 16
    }

    #[test]
    fn regime_switch() {
        assert_eq!(regime(0.99, 16), ConsensusRegime::GlobalAveragingDominates);
        assert_eq!(regime(0.5, 16), ConsensusRegime::GossipDominates);
    }

    #[test]
    fn pga_always_shorter_than_gossip() {
        // Table 2's claim: PGA transient <= Gossip transient for any beta, H.
        for &beta in &[0.3, 0.9, 0.99, 0.998] {
            for &h in &[4usize, 16, 64] {
                let n = 50;
                assert!(
                    transient::pga_noniid(n, beta, h) <= transient::gossip_noniid(n, beta) + 1e-9,
                    "beta={beta} h={h}"
                );
                assert!(transient::pga_iid(n, beta, h) <= transient::gossip_iid(n, beta) + 1e-9);
            }
        }
    }

    #[test]
    fn pga_always_shorter_than_local() {
        // Table 3's claim (C_beta < H, beta < 1).
        for &beta in &[0.1, 0.5, 0.9, 0.99] {
            for &h in &[4usize, 16, 64] {
                let n = 50;
                assert!(transient::pga_noniid(n, beta, h) < transient::local_noniid(n, h));
                assert!(transient::pga_iid(n, beta, h) < transient::local_iid(n, h));
            }
        }
    }

    #[test]
    fn rate_bound_decreases_in_t() {
        let p = RateParams { n: 20, beta: 0.97, h: 16, sigma: 1.0, b: 1.0 };
        assert!(p.bound(1e4) > p.bound(1e6));
    }

    #[test]
    fn transient_boundary_monotone_in_beta() {
        let mk = |beta| RateParams { n: 50, beta, h: 16, sigma: 1.0, b: 1.0 };
        assert!(mk(0.99).transient_boundary() > mk(0.5).transient_boundary());
    }

    #[test]
    fn transient_boundary_tracks_theory_order() {
        // Doubling n should scale the non-iid PGA boundary roughly by n^3
        // (the dominant term) — check the measured boundary grows
        // superlinearly at least.
        let mk = |n| RateParams { n, beta: 0.95, h: 16, sigma: 1.0, b: 1.0 };
        let t1 = mk(20).transient_boundary();
        let t2 = mk(40).transient_boundary();
        let ratio = t2 / t1;
        assert!((5.0..12.0).contains(&ratio), "expected ~8x (n^3), got {ratio}");
    }
}
