//! Network topologies and doubly-stochastic gossip matrices (paper §3).
//!
//! A [`Topology`] produces, for each communication round, the set of
//! in-neighbors of every node and the weight matrix `W` satisfying
//! Assumption 3 (`W 1 = 1`, `1^T W = 1^T`). Static graphs (ring, grid/torus,
//! hypercube, star, fully-connected, static exponential) use
//! uniform-neighbor or Metropolis–Hastings weights; the **one-peer
//! exponential** graph (Assran et al. 2019) is time-varying: round r pairs
//! node `i` with `i ± 2^(r mod log2 n)` with weight 1/2.
//!
//! `beta = ||W - (1/n)11^T||_2` (Remark 1) is computed by deflated power
//! iteration ([`crate::linalg::beta_of`]); for time-varying graphs
//! [`Topology::beta`] returns the per-period effective value
//! `||prod_r (W_r - avg)||_2^(1/R)`.

pub mod spectral;

use crate::linalg::{beta_of, spectral_norm, Mat};

/// Graph families used across the paper's experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Cycle; |N_i| = 3 including self. 1 - beta = O(1/n^2).
    Ring,
    /// 2-D torus (the paper's "grid"); |N_i| = 5 including self.
    /// 1 - beta = O(1/n).
    Grid,
    /// log2(n)-dimensional hypercube (n must be a power of two).
    Hypercube,
    /// Hub-and-spoke; Metropolis–Hastings weights (non-regular).
    Star,
    /// Complete graph: W = (1/n)11^T, beta = 0 — Parallel SGD's implicit
    /// topology.
    Full,
    /// Static exponential: neighbors at hop distances 2^j.
    StaticExponential,
    /// Time-varying one-peer exponential (Assran et al. 2019): a single
    /// directed peer per round, W_r = (I + P_r)/2.
    OnePeerExponential,
}

/// A communication topology over `n` nodes.
#[derive(Clone, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    pub n: usize,
    /// Grid factorization (rows, cols); unused otherwise.
    grid: (usize, usize),
}

impl Topology {
    pub fn new(kind: TopologyKind, n: usize) -> Self {
        assert!(n >= 1);
        if kind == TopologyKind::Hypercube {
            assert!(n.is_power_of_two(), "hypercube needs power-of-two n, got {n}");
        }
        let grid = if kind == TopologyKind::Grid { factor_near_square(n) } else { (n, 1) };
        Topology { kind, n, grid }
    }

    pub fn ring(n: usize) -> Self {
        Self::new(TopologyKind::Ring, n)
    }
    pub fn grid(n: usize) -> Self {
        Self::new(TopologyKind::Grid, n)
    }
    pub fn hypercube(n: usize) -> Self {
        Self::new(TopologyKind::Hypercube, n)
    }
    pub fn star(n: usize) -> Self {
        Self::new(TopologyKind::Star, n)
    }
    pub fn full(n: usize) -> Self {
        Self::new(TopologyKind::Full, n)
    }
    pub fn static_expo(n: usize) -> Self {
        Self::new(TopologyKind::StaticExponential, n)
    }
    pub fn one_peer_expo(n: usize) -> Self {
        Self::new(TopologyKind::OnePeerExponential, n)
    }

    /// Parse a CLI/config name.
    pub fn from_name(name: &str, n: usize) -> anyhow::Result<Self> {
        Ok(match name {
            "ring" => Self::ring(n),
            "grid" | "torus" => Self::grid(n),
            "hypercube" => Self::hypercube(n),
            "star" => Self::star(n),
            "full" | "complete" => Self::full(n),
            "expo" | "static-expo" => Self::static_expo(n),
            "one-peer-expo" | "one-peer" => Self::one_peer_expo(n),
            other => anyhow::bail!("unknown topology '{other}'"),
        })
    }

    /// Number of distinct rounds before the schedule repeats
    /// (1 for static graphs, log2ceil(n) for one-peer exponential).
    pub fn rounds(&self) -> usize {
        match self.kind {
            TopologyKind::OnePeerExponential => log2_ceil(self.n).max(1),
            _ => 1,
        }
    }

    pub fn is_time_varying(&self) -> bool {
        self.rounds() > 1
    }

    /// Undirected neighbor set of `i` **excluding** self, for static kinds.
    fn static_neighbors(&self, i: usize) -> Vec<usize> {
        let n = self.n;
        match self.kind {
            TopologyKind::Ring => {
                if n == 1 {
                    vec![]
                } else if n == 2 {
                    vec![1 - i]
                } else {
                    vec![(i + n - 1) % n, (i + 1) % n]
                }
            }
            TopologyKind::Grid => {
                let (r, c) = self.grid;
                let (y, x) = (i / c, i % c);
                let mut v = vec![
                    ((y + r - 1) % r) * c + x,
                    ((y + 1) % r) * c + x,
                    y * c + (x + c - 1) % c,
                    y * c + (x + 1) % c,
                ];
                v.sort_unstable();
                v.dedup();
                v.retain(|&j| j != i);
                v
            }
            TopologyKind::Hypercube => (0..log2_ceil(n)).map(|b| i ^ (1 << b)).collect(),
            TopologyKind::Star => {
                if i == 0 {
                    (1..n).collect()
                } else {
                    vec![0]
                }
            }
            TopologyKind::Full => (0..n).filter(|&j| j != i).collect(),
            TopologyKind::StaticExponential => {
                let mut v = Vec::new();
                let mut hop = 1;
                while hop < n {
                    v.push((i + hop) % n);
                    v.push((i + n - hop % n) % n);
                    hop *= 2;
                }
                v.sort_unstable();
                v.dedup();
                v.retain(|&j| j != i);
                v
            }
            TopologyKind::OnePeerExponential => unreachable!("time-varying"),
        }
    }

    /// In-neighbors of node `i` at communication round `round`,
    /// **including self** (the gossip step always mixes the self row).
    pub fn in_neighbors(&self, i: usize, round: usize) -> Vec<usize> {
        match self.kind {
            TopologyKind::OnePeerExponential => {
                if self.n == 1 {
                    return vec![i];
                }
                let hop = 1usize << (round % self.rounds());
                let peer = (i + hop) % self.n;
                if peer == i {
                    vec![i]
                } else {
                    vec![i, peer]
                }
            }
            _ => {
                let mut v = self.static_neighbors(i);
                v.push(i);
                v.sort_unstable();
                v
            }
        }
    }

    /// Weight row of node `i` at `round`: `(j, w_ij)` over in-neighbors.
    ///
    /// Regular graphs get uniform weights 1/|N_i|; non-regular static
    /// graphs (star, and any grid with r or c == 1 collapsing degrees) get
    /// Metropolis–Hastings weights, which keep W doubly stochastic.
    pub fn weight_row(&self, i: usize, round: usize) -> Vec<(usize, f64)> {
        match self.kind {
            TopologyKind::OnePeerExponential => {
                let nb = self.in_neighbors(i, round);
                if nb.len() == 1 {
                    vec![(i, 1.0)]
                } else {
                    nb.into_iter().map(|j| (j, 0.5)).collect()
                }
            }
            TopologyKind::Full => (0..self.n).map(|j| (j, 1.0 / self.n as f64)).collect(),
            _ if self.is_regular() => {
                let nb = self.in_neighbors(i, round);
                let w = 1.0 / nb.len() as f64;
                nb.into_iter().map(|j| (j, w)).collect()
            }
            _ => {
                // Metropolis–Hastings: w_ij = 1/(1 + max(d_i, d_j)),
                // w_ii = 1 - sum_j w_ij.
                let di = self.static_neighbors(i).len();
                let mut row: Vec<(usize, f64)> = Vec::new();
                let mut self_w = 1.0;
                for j in self.static_neighbors(i) {
                    let dj = self.static_neighbors(j).len();
                    let w = 1.0 / (1.0 + di.max(dj) as f64);
                    self_w -= w;
                    row.push((j, w));
                }
                row.push((i, self_w));
                row.sort_unstable_by_key(|&(j, _)| j);
                row
            }
        }
    }

    fn is_regular(&self) -> bool {
        !matches!(self.kind, TopologyKind::Star)
    }

    /// Full weight matrix at `round`.
    pub fn weight_matrix(&self, round: usize) -> Mat {
        let mut w = Mat::zeros(self.n, self.n);
        for i in 0..self.n {
            for (j, v) in self.weight_row(i, round) {
                w[(i, j)] = v;
            }
        }
        w
    }

    /// The paper's connectivity measure. For time-varying graphs this is
    /// the per-period effective value `||prod_r (W_r - avg)||^(1/R)` —
    /// the geometric-mean contraction per gossip step.
    ///
    /// The computation materializes the dense n x n weight matrix — O(n^2)
    /// memory and up to O(n^3) time. Callers on the population plane (n up
    /// to 10^5) must use [`Topology::beta_report`], which refuses the dense
    /// path above [`BETA_DENSE_LIMIT`] instead of allocating at startup.
    pub fn beta(&self) -> f64 {
        if self.n == 1 {
            return 0.0;
        }
        if !self.is_time_varying() {
            return beta_of(&self.weight_matrix(0));
        }
        let rounds = self.rounds();
        let avg = Mat::avg(self.n);
        let mut prod = self.weight_matrix(0).sub(&avg);
        for r in 1..rounds {
            prod = self.weight_matrix(r).sub(&avg).matmul(&prod);
        }
        spectral_norm(&prod, 0xBEEF).powf(1.0 / rounds as f64).min(1.0 - 1e-12)
    }

    /// Out-neighbors of node `i` at `round`, **excluding** self: the nodes
    /// that list `i` among their in-neighbors, i.e. the destinations `i`
    /// must transmit to on a real message-passing link. For the undirected
    /// static kinds this is just the (symmetric) neighbor set; for the
    /// directed one-peer exponential graph it is the single inverse-hop
    /// peer `(i - 2^r) mod n`. Sorted ascending, deduplicated.
    pub fn out_neighbors(&self, i: usize, round: usize) -> Vec<usize> {
        match self.kind {
            TopologyKind::OnePeerExponential => {
                if self.n == 1 {
                    return vec![];
                }
                let hop = (1usize << (round % self.rounds())) % self.n;
                let peer = (i + self.n - hop) % self.n;
                if peer == i {
                    vec![]
                } else {
                    vec![peer]
                }
            }
            _ => {
                let mut v = self.static_neighbors(i);
                v.sort_unstable();
                v.dedup();
                v
            }
        }
    }

    /// Max in-neighborhood size incl. self (the paper's |N_i| in §3.4).
    pub fn max_degree_incl_self(&self) -> usize {
        (0..self.rounds())
            .flat_map(|r| (0..self.n).map(move |i| (i, r)))
            .map(|(i, r)| self.in_neighbors(i, r).len())
            .max()
            .unwrap_or(1)
    }

    /// Size-gated beta: [`BetaReport::Exact`] up to [`BETA_DENSE_LIMIT`]
    /// nodes, [`BetaReport::Skipped`] above it. Every startup banner and
    /// report path goes through this instead of [`Topology::beta`], so a
    /// 10^5-node sweep never allocates the n x n matrix just to print a
    /// connectivity number.
    pub fn beta_report(&self) -> BetaReport {
        if self.n <= BETA_DENSE_LIMIT {
            BetaReport::Exact(self.beta())
        } else {
            BetaReport::Skipped { n: self.n }
        }
    }
}

/// Largest n for which the dense spectral beta path is allowed to run.
/// 4096 x 4096 f64 is 128 MiB and a few seconds of power iteration —
/// tolerable at startup; the next power of two is not.
pub const BETA_DENSE_LIMIT: usize = 4096;

/// Outcome of a size-gated beta computation (see [`Topology::beta_report`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BetaReport {
    /// Dense path ran: the exact spectral value.
    Exact(f64),
    /// n exceeded [`BETA_DENSE_LIMIT`]; no n x n matrix was allocated.
    Skipped { n: usize },
}

impl BetaReport {
    /// The exact value, if the dense path ran.
    pub fn exact(&self) -> Option<f64> {
        match self {
            BetaReport::Exact(b) => Some(*b),
            BetaReport::Skipped { .. } => None,
        }
    }
}

impl std::fmt::Display for BetaReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BetaReport::Exact(b) => write!(f, "{b:.6}"),
            BetaReport::Skipped { n } => {
                write!(f, "skipped (n = {n} > dense limit {BETA_DENSE_LIMIT})")
            }
        }
    }
}

fn log2_ceil(n: usize) -> usize {
    let mut bits = 0;
    while (1usize << bits) < n {
        bits += 1;
    }
    bits
}

/// Factor n into (r, c) with r*c == n and r as close to sqrt(n) as possible.
fn factor_near_square(n: usize) -> (usize, usize) {
    let mut best = (n, 1);
    let mut r = (n as f64).sqrt() as usize;
    while r >= 1 {
        if n % r == 0 {
            best = (r, n / r);
            break;
        }
        r -= 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_doubly_stochastic(t: &Topology) {
        for r in 0..t.rounds() {
            let w = t.weight_matrix(r);
            assert!(w.row_sum_err() < 1e-12, "{:?} round {r} rows", t.kind);
            assert!(w.col_sum_err() < 1e-12, "{:?} round {r} cols", t.kind);
            for v in &w.data {
                assert!(*v >= -1e-15, "{:?} negative weight {v}", t.kind);
            }
        }
    }

    #[test]
    fn all_kinds_doubly_stochastic() {
        for t in [
            Topology::ring(12),
            Topology::grid(12),
            Topology::hypercube(16),
            Topology::star(9),
            Topology::full(7),
            Topology::static_expo(12),
            Topology::one_peer_expo(12),
        ] {
            assert_doubly_stochastic(&t);
        }
    }

    #[test]
    fn ring_neighborhood_is_three() {
        let t = Topology::ring(10);
        for i in 0..10 {
            assert_eq!(t.in_neighbors(i, 0).len(), 3); // paper §3.4: |N_i|=3
        }
    }

    #[test]
    fn grid_neighborhood_is_five() {
        let t = Topology::grid(16); // 4x4 torus
        for i in 0..16 {
            assert_eq!(t.in_neighbors(i, 0).len(), 5); // paper §3.4: |N_i|=5
        }
    }

    #[test]
    fn full_is_exact_averaging() {
        let t = Topology::full(6);
        assert!(t.beta() < 1e-9);
    }

    #[test]
    fn ring_beta_scales_inverse_square() {
        // 1 - beta = O(1/n^2): beta(2n) gap ~ 1/4 of beta(n) gap.
        let g20 = 1.0 - Topology::ring(20).beta();
        let g40 = 1.0 - Topology::ring(40).beta();
        let ratio = g20 / g40;
        assert!((3.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn grid_better_connected_than_ring() {
        let n = 36;
        assert!(Topology::grid(n).beta() < Topology::ring(n).beta());
    }

    #[test]
    fn expo_better_connected_than_grid() {
        let n = 32;
        assert!(Topology::static_expo(n).beta() < Topology::grid(n).beta());
    }

    #[test]
    fn ring_beta_matches_closed_form() {
        // Uniform 1/3 ring: beta = (1 + 2 cos(2 pi/n)) / 3.
        let n = 24;
        let expect = (1.0 + 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos()) / 3.0;
        assert!((Topology::ring(n).beta() - expect).abs() < 1e-9);
    }

    #[test]
    fn one_peer_period_is_log2() {
        assert_eq!(Topology::one_peer_expo(16).rounds(), 4);
        assert_eq!(Topology::one_peer_expo(20).rounds(), 5);
    }

    #[test]
    fn one_peer_power_of_two_reaches_consensus() {
        // For n = 2^tau, one period of one-peer exponential gossip computes
        // the exact average: prod_r W_r = avg.
        let t = Topology::one_peer_expo(8);
        let mut prod = t.weight_matrix(0);
        for r in 1..t.rounds() {
            prod = t.weight_matrix(r).matmul(&prod);
        }
        let diff = prod.sub(&Mat::avg(8));
        assert!(diff.frobenius_norm() < 1e-12);
        assert!(t.beta() < 1e-3);
    }

    #[test]
    fn star_metropolis_hastings_valid() {
        let t = Topology::star(8);
        let w = t.weight_matrix(0);
        assert!(w.is_symmetric(1e-12));
        // hub self-weight: 1 - 7 * 1/8
        assert!((w[(0, 0)] - (1.0 - 7.0 / 8.0)).abs() < 1e-12);
    }

    #[test]
    fn grid_factorization() {
        assert_eq!(factor_near_square(20), (4, 5));
        assert_eq!(factor_near_square(100), (10, 10));
        assert_eq!(factor_near_square(7), (1, 7));
    }

    #[test]
    fn from_name_roundtrip() {
        for name in ["ring", "grid", "star", "full", "expo", "one-peer-expo"] {
            assert!(Topology::from_name(name, 8).is_ok(), "{name}");
        }
        assert!(Topology::from_name("mesh", 8).is_err());
    }

    #[test]
    fn out_neighbors_invert_in_neighbors() {
        // j in out(i, r)  <=>  i in in(j, r) \ {j}: the transmit sets the
        // bus backend derives must be exactly the inverse of the listen
        // sets the weight rows consume, on every kind and round.
        for t in [
            Topology::ring(9),
            Topology::grid(12),
            Topology::hypercube(8),
            Topology::star(7),
            Topology::full(6),
            Topology::static_expo(10),
            Topology::one_peer_expo(12),
            Topology::one_peer_expo(8),
        ] {
            for r in 0..t.rounds() {
                for i in 0..t.n {
                    for j in 0..t.n {
                        let sends = t.out_neighbors(i, r).contains(&j);
                        let listens = j != i && t.in_neighbors(j, r).contains(&i);
                        assert_eq!(
                            sends, listens,
                            "{:?} n={} round {r}: edge {i}->{j}",
                            t.kind, t.n
                        );
                    }
                    assert!(
                        !t.out_neighbors(i, r).contains(&i),
                        "{:?} round {r}: self in out({i})",
                        t.kind
                    );
                }
            }
        }
    }

    #[test]
    fn one_peer_out_neighbor_is_inverse_hop() {
        let t = Topology::one_peer_expo(8);
        // Round 1: hop = 2; node 5 listens to 7, so node 7 transmits to 5.
        assert_eq!(t.in_neighbors(5, 1), vec![5, 7]);
        assert_eq!(t.out_neighbors(7, 1), vec![5]);
    }

    #[test]
    fn beta_report_gates_the_dense_path_by_size() {
        let small = Topology::ring(64).beta_report();
        assert_eq!(small.exact(), Some(Topology::ring(64).beta()));
        // Above the limit: must return Skipped WITHOUT touching the dense
        // path (this test would OOM/stall long before failing otherwise).
        let big = Topology::one_peer_expo(100_000).beta_report();
        assert_eq!(big, BetaReport::Skipped { n: 100_000 });
        assert_eq!(big.exact(), None);
        assert!(big.to_string().contains("skipped"), "{big}");
    }

    #[test]
    fn n_equals_one_degenerate() {
        for t in [Topology::ring(1), Topology::one_peer_expo(1), Topology::full(1)] {
            assert_eq!(t.in_neighbors(0, 0), vec![0]);
            assert_eq!(t.weight_row(0, 0), vec![(0, 1.0)]);
            assert!(t.beta() < 1e-12);
        }
    }
}
