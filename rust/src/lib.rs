//! # gossip-pga
//!
//! Production-style reproduction of **"Accelerating Gossip SGD with Periodic
//! Global Averaging"** (Chen, Yuan et al., ICML 2021) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3: the distributed-training *coordinator*. It owns
//! the cluster topology, the gossip / all-reduce collectives, the
//! communication-schedule policies (Parallel SGD, Gossip SGD, Local SGD,
//! Gossip-PGA, Gossip-AGA, SlowMo), the optimizers, the metrics and the
//! launcher CLI. Model compute (loss + gradient) is AOT-compiled from
//! JAX/Pallas into XLA HLO at build time (`make artifacts`) and executed
//! through PJRT ([`runtime`]); Python never runs on the training path.
//!
//! ## Layout
//!
//! Substrates (everything is built in-repo — the offline vendor set only
//! provides `xla` + `anyhow`):
//! * [`rng`] — splitmix64 / xoshiro256** PRNGs + distributions.
//! * [`linalg`] — dense matrices, power iteration for the spectral gap.
//! * [`jsonio`] — JSON parser/writer (artifact manifest, metrics dumps).
//! * [`config`] — TOML-subset experiment config system.
//! * [`topology`] — graphs, doubly-stochastic gossip matrices, beta.
//! * [`collective`] — the wire layer: in-proc message bus (sparse,
//!   topology-sized sender tables) and framed loopback TCP endpoints
//!   behind one [`collective::Wire`] surface, neighbor exchange, ring
//!   all-reduce (reduce-scatter + all-gather), receive deadlines
//!   (typed [`collective::RecvTimeout`]), byte/latency accounting.
//! * [`costmodel`] — the paper's alpha-beta communication time model (§3.4,
//!   App. D/H), its per-node generalization ([`costmodel::NodeCosts`]:
//!   heterogeneous clusters, stragglers, link asymmetry) and the per-node
//!   [`costmodel::VirtualClocks`] critical-path time plane.
//! * [`harness`] — timing/stats/table printing for the bench suite.
//! * [`proptest`] — a minimal randomized-property test kit.
//!
//! Core:
//! * [`runtime`] — PJRT client + artifact registry (loads `artifacts/`);
//!   shared across worker threads (`Arc<Runtime>`, RwLock'd executable
//!   cache).
//! * [`params`] — the contiguous n x d [`params::ParamMatrix`] every
//!   training phase operates on (worker i = row i, row-major).
//! * [`model`] — rust-side model descriptors mirrored from the manifest.
//! * [`data`] — synthetic datasets (paper §5.1 logistic data, cluster
//!   classification, token corpus) + iid/non-iid sharding.
//! * [`optim`] — SGD / momentum / Nesterov + LR schedules.
//! * [`algorithms`] — the paper's communication schedules.
//! * [`comm`] — the unified CommPlane: one pluggable [`comm::CommBackend`]
//!   (shared-memory mixer, message-passing bus, or the same bus core
//!   over real loopback sockets) behind every training run, with
//!   end-to-end [`comm::CommStats`] traffic accounting; select with
//!   `comm.backend` / `--backend {shared,bus,tcp}`.
//! * [`eventsim`] — the event-driven asynchronous gossip regime: a
//!   discrete-event queue over per-link transfer events
//!   ([`eventsim::AsyncGossip`]) with bounded-stale AD-PSGD mixing;
//!   select with `train.regime` / `--regime {bsp,overlap,async}` and
//!   `--max-staleness` (0 reproduces BSP + the barrier-billed clocks
//!   bit-exactly).
//! * [`exec`] — the persistent execution engine: one parked
//!   [`exec::WorkerPool`] per trainer that phases 1-2, the gossip mix and
//!   the eval pass shard across (static or work-stealing chunking behind
//!   one `shards` policy — `train.stealing`), plus the async job tickets
//!   behind double-buffered overlap mode (see the module's determinism
//!   contract).
//! * [`coordinator`] — the per-step training pipeline over n workers,
//!   sharded across the `train.threads`-sized pool (bit-identical to the
//!   sequential run at any thread count); `--overlap` runs the gossip mix
//!   concurrently with the next step's sampling phase;
//!   [`coordinator::rounds`] is the fault-tolerant round state machine
//!   (`--round-timeout`: deadline → drop-by-renormalization → rejoin,
//!   membership in checkpoint v7).
//! * [`metrics`] — loss curves, consensus distance, transient-stage
//!   detection, reporters (one [`metrics::COLUMNS`] registry drives the
//!   CSV header and the JSON keys).
//! * [`obs`] — the observability plane: per-phase span tracing into
//!   lock-free per-thread rings (`--trace out.json`, Chrome trace-event /
//!   Perfetto export, the `trace` subcommand's summary), the unified
//!   [`obs::Counters`] registry, and the [`obs::warn_once!`] sink.
//! * [`population`] — the virtual population plane: scenario scripting
//!   (crash / rejoin / flaky links / region tiers) and the n = 10^5 sweep
//!   driver over pooled payload storage ([`params::pool`]); select with
//!   the `sweep` subcommand (`--virtual-n`, `--surrogate`, `--churn`).

pub mod algorithms;
pub mod collective;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod eventsim;
pub mod exec;
pub mod harness;
pub mod jsonio;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod params;
pub mod population;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod topology;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Default location of AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory or the
/// `GOSSIP_PGA_ARTIFACTS` environment variable (tests and benches run from
/// various target dirs).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("GOSSIP_PGA_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
