//! Population-scale sweep driver: scenario scripting, the n = 10^5 sweep
//! loop, and its machine-readable report.
//!
//! The engine underneath ([`crate::eventsim::AsyncGossip::new_virtual`])
//! distinguishes **materialized workers** — own a `ParamMatrix` row, run
//! real gradient steps — from **virtual nodes**, which carry full clock /
//! staleness / link-occupancy / traffic state but reference pooled payload
//! storage ([`crate::params::pool::PayloadPool`]). This module is the layer
//! the CLI talks to:
//!
//! * [`ChurnScript`] — parse `crash@t:node,rejoin@t:node,
//!   flaky@t:src>dst:factor,restore@t:src>dst` scenario strings, or
//!   generate a seeded random script (crash/rejoin and flaky/restore pairs
//!   over a time horizon) so a 10^5-node churn sweep is reproducible from
//!   one `u64`;
//! * [`SweepSpec`] / [`run_sweep`] — drive the virtual engine in logged
//!   chunks over a flat clock plane ([`VirtualClocks::flat`] — no
//!   per-round neighbor tables, the one O(n·rounds·degree) allocation the
//!   population plane cannot afford), recording consensus / traffic /
//!   liveness curves;
//! * [`SweepReport`] — the curves plus the allocation audit
//!   (`peak_live_slots`, `peak_dense_scalars` vs the directed-edge count)
//!   and churn totals, dumped as JSON for the EXPERIMENTS.md §Massive-n
//!   tables.
//!
//! Determinism: a sweep is a pure function of its [`SweepSpec`] — the
//! engine's event order is chunk-invariant, the seeded script derives from
//! `Rng::new(seed)`, and every curve accumulator fixes its order — so the
//! churn property gate replays reports bit-exactly.

use anyhow::{bail, ensure, Result};

use crate::algorithms::AlgorithmKind;
use crate::costmodel::{CostModel, NodeCosts, RegionMap, VirtualClocks};
use crate::eventsim::{AsyncGossip, ChurnEvent, VirtualConfig};
use crate::jsonio::{self, Json};
use crate::metrics::{consensus_distance_rows, scalar_consensus};
use crate::rng::Rng;
use crate::topology::{BetaReport, Topology};

/// A churn scenario: an (unordered) list of scripted population events.
/// Thin wrapper so parsing/generation live beside the sweep driver; the
/// engine validates node/link identities at construction.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChurnScript {
    pub events: Vec<ChurnEvent>,
}

impl ChurnScript {
    /// Parse the CLI scenario syntax: comma-separated events, each one of
    ///
    /// * `crash@<t>:<node>`
    /// * `rejoin@<t>:<node>`
    /// * `flaky@<t>:<src>><dst>:<factor>`
    /// * `restore@<t>:<src>><dst>`
    ///
    /// with `<t>` in virtual seconds. Empty input parses to an empty
    /// script. Identity/range validation happens in the engine (which
    /// knows n and the edge set); this parser only enforces shape.
    pub fn parse(text: &str) -> Result<ChurnScript> {
        let mut events = Vec::new();
        for term in text.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, rest) = term
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("churn term '{term}': expected '<kind>@<t>:...'"))?;
            let mut parts = rest.split(':');
            let at: f64 = parts
                .next()
                .and_then(|t| t.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("churn term '{term}': bad time"))?;
            let args: Vec<&str> = parts.collect();
            let node_arg = |s: &str| -> Result<usize> {
                s.parse().map_err(|_| anyhow::anyhow!("churn term '{term}': bad node '{s}'"))
            };
            let edge_arg = |s: &str| -> Result<(usize, usize)> {
                let (a, b) = s
                    .split_once('>')
                    .ok_or_else(|| anyhow::anyhow!("churn term '{term}': expected '<src>><dst>'"))?;
                Ok((node_arg(a)?, node_arg(b)?))
            };
            let ev = match (kind, args.as_slice()) {
                ("crash", [node]) => ChurnEvent::Crash { at, node: node_arg(node)? },
                ("rejoin", [node]) => ChurnEvent::Rejoin { at, node: node_arg(node)? },
                ("flaky", [edge, factor]) => {
                    let (src, dst) = edge_arg(edge)?;
                    let factor: f64 = factor
                        .parse()
                        .map_err(|_| anyhow::anyhow!("churn term '{term}': bad factor"))?;
                    ChurnEvent::FlakyLink { at, src, dst, factor }
                }
                ("restore", [edge]) => {
                    let (src, dst) = edge_arg(edge)?;
                    ChurnEvent::LinkRestore { at, src, dst }
                }
                _ => bail!(
                    "churn term '{term}': unknown shape (crash@t:n | rejoin@t:n | \
                     flaky@t:s>d:f | restore@t:s>d)"
                ),
            };
            events.push(ev);
        }
        Ok(ChurnScript { events })
    }

    /// Seeded random scenario: `pairs` disturbances over `[0, horizon)`
    /// virtual seconds, alternating crash/rejoin pairs (distinct nodes, so
    /// the live population can never empty) and flaky/restore pairs on
    /// real gossip edges. A pure function of `(seed, topo, pairs,
    /// horizon)` — the reproducibility contract of the 10^5-node sweep.
    pub fn seeded(seed: u64, topo: &Topology, pairs: usize, horizon: f64) -> Result<ChurnScript> {
        let n = topo.n;
        ensure!(n >= 2, "seeded churn needs at least 2 nodes");
        ensure!(horizon.is_finite() && horizon > 0.0, "churn horizon must be positive");
        let crash_budget = (n - 1).min(pairs.div_ceil(2));
        let mut rng = Rng::new(seed);
        let mut crash_nodes = rng.choose_distinct(n, crash_budget);
        let mut events = Vec::with_capacity(pairs * 2);
        for k in 0..pairs {
            let t0 = rng.range(0.02, 0.55) * horizon;
            let dt = rng.range(0.05, 0.35) * horizon;
            // Alternate kinds while crash nodes remain, then flaky-only.
            if k % 2 == 0 && !crash_nodes.is_empty() {
                let node = crash_nodes.pop().expect("non-empty");
                events.push(ChurnEvent::Crash { at: t0, node });
                events.push(ChurnEvent::Rejoin { at: t0 + dt, node });
            } else {
                let round = rng.below(topo.rounds() as u64) as usize;
                let src = rng.below(n as u64) as usize;
                let Some(&dst) = topo.out_neighbors(src, round).first() else {
                    continue; // degenerate node with no out-edge this round
                };
                let factor = rng.range(2.0, 10.0);
                events.push(ChurnEvent::FlakyLink { at: t0, src, dst, factor });
                events.push(ChurnEvent::LinkRestore { at: t0 + dt, src, dst });
            }
        }
        Ok(ChurnScript { events })
    }
}

/// Full specification of one population sweep — everything
/// [`run_sweep`] needs, so a sweep is replayable from this struct alone.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub topo: Topology,
    pub algo: AlgorithmKind,
    /// Global-averaging period H (Gossip-PGA / Local SGD).
    pub h: usize,
    /// Iterations every (live) node must complete.
    pub steps: usize,
    pub max_staleness: usize,
    /// Dense drift dimension; 0 selects the `(mean, var)` surrogate.
    pub dim: usize,
    pub seed: u64,
    /// Scalar cost model replicated across the population.
    pub cost: CostModel,
    /// Billing dimension (the d the alpha-beta model charges for).
    pub cost_dim: usize,
    /// `(index, factor)` stragglers (the CLI flag is repeatable).
    pub stragglers: Vec<(usize, f64)>,
    pub churn: Vec<ChurnEvent>,
    pub regions: Option<RegionMap>,
    /// Curve resolution: the sweep logs ~this many points.
    pub log_points: usize,
}

impl SweepSpec {
    /// A surrogate one-peer-expo sweep with paper-calibrated costs — the
    /// massive-n default; callers override fields as needed.
    pub fn massive_n(n: usize, steps: usize, seed: u64) -> SweepSpec {
        SweepSpec {
            topo: Topology::one_peer_expo(n),
            algo: AlgorithmKind::GossipPga,
            h: 8,
            steps,
            max_staleness: 2,
            dim: 0,
            seed,
            cost: CostModel::calibrated_resnet50(),
            cost_dim: 25_500_000,
            stragglers: Vec::new(),
            churn: Vec::new(),
            regions: None,
            log_points: 20,
        }
    }
}

/// One logged point of a sweep's transient/traffic curves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CurvePoint {
    /// Iterations completed by the slowest live node at this point.
    pub step: usize,
    /// Critical-path virtual seconds.
    pub time: f64,
    /// Consensus distance over the live population (scalar variance of
    /// the surrogate means, or the d-dim consensus of the drift rows).
    pub consensus: f64,
    /// Cumulative wire scalars / messages billed so far.
    pub scalars: u64,
    pub msgs: u64,
    pub alive: usize,
    pub stale_max: u64,
    pub stale_mean: f64,
    pub link_util: f64,
    /// Cumulative barrier/offline wait seconds summed over nodes.
    pub wait: f64,
}

/// The output of [`run_sweep`]: curves, churn totals, and the allocation
/// audit that backs the bounded-memory claim.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepReport {
    pub n: usize,
    pub steps: usize,
    pub surrogate: bool,
    pub beta: BetaReport,
    pub curve: Vec<CurvePoint>,
    /// First logged step where consensus has contracted below
    /// [`TRANSIENT_FRACTION`] of its initial value — the sweep-plane
    /// transient proxy (no loss curve exists without gradients).
    pub transient_step: Option<usize>,
    /// `(crashes, rejoins, link events, missed barriers)`.
    pub churn_counts: (u64, u64, u64, u64),
    /// Allocation audit: pool high-water marks vs the directed-edge count.
    pub num_links: usize,
    pub peak_live_slots: usize,
    pub peak_dense_scalars: usize,
}

/// Consensus contraction defining the sweep-plane transient proxy.
pub const TRANSIENT_FRACTION: f64 = 0.01;

impl SweepReport {
    pub fn to_json(&self) -> Json {
        let f = |get: fn(&CurvePoint) -> f64| {
            jsonio::num_arr(&self.curve.iter().map(get).collect::<Vec<_>>())
        };
        let u = |get: fn(&CurvePoint) -> u64| {
            jsonio::u64_arr(&self.curve.iter().map(get).collect::<Vec<_>>())
        };
        jsonio::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("surrogate", Json::Bool(self.surrogate)),
            ("beta", match self.beta {
                BetaReport::Exact(b) => Json::Num(b),
                BetaReport::Skipped { .. } => Json::Str(self.beta.to_string()),
            }),
            ("step", u(|p| p.step as u64)),
            ("time", f(|p| p.time)),
            ("consensus", f(|p| p.consensus)),
            ("scalars", u(|p| p.scalars)),
            ("msgs", u(|p| p.msgs)),
            ("alive", u(|p| p.alive as u64)),
            ("stale_max", u(|p| p.stale_max)),
            ("stale_mean", f(|p| p.stale_mean)),
            ("link_util", f(|p| p.link_util)),
            ("wait", f(|p| p.wait)),
            (
                "transient_step",
                self.transient_step.map_or(Json::Null, |s| Json::Num(s as f64)),
            ),
            ("crashes", Json::Num(self.churn_counts.0 as f64)),
            ("rejoins", Json::Num(self.churn_counts.1 as f64)),
            ("link_events", Json::Num(self.churn_counts.2 as f64)),
            ("missed_barriers", Json::Num(self.churn_counts.3 as f64)),
            ("num_links", Json::Num(self.num_links as f64)),
            ("peak_live_slots", Json::Num(self.peak_live_slots as f64)),
            ("peak_dense_scalars", Json::Num(self.peak_dense_scalars as f64)),
        ])
    }

    pub fn write_json(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }
}

/// Drive one population sweep to completion. Chunked: the engine runs to
/// each curve target in turn, and the curve samples its state between
/// chunks (the engine's event order is chunk-invariant, so the chunking
/// only decides WHERE the curve samples, never what happens).
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepReport> {
    let n = spec.topo.n;
    ensure!(spec.steps >= 1, "sweep needs at least one step");
    ensure!(spec.log_points >= 1, "sweep needs at least one curve point");
    // The sweep-path range check (--straggler idx:factor): the train path
    // has validated idx < n since PR 4 (NodeCosts::with_straggler), but a
    // clear front-door message beats a cost-table error deep in setup.
    let mut costs = NodeCosts::homogeneous(spec.cost, n);
    for &(idx, factor) in &spec.stragglers {
        ensure!(
            idx < n,
            "--straggler index {idx} out of range for the virtual population \
             (--virtual-n {n}; valid indices are 0..{n})"
        );
        costs = costs.with_straggler(idx, factor)?;
    }
    let cfg = VirtualConfig {
        dim: spec.dim,
        seed: spec.seed,
        churn: spec.churn.clone(),
        regions: spec.regions.clone(),
    };
    let mut engine = AsyncGossip::new_virtual(
        &spec.topo,
        &costs,
        spec.cost_dim,
        spec.max_staleness,
        spec.algo,
        spec.h,
        cfg,
    )?;
    let mut clocks = VirtualClocks::flat(n);
    let mut curve = Vec::with_capacity(spec.log_points);
    let mut targets: Vec<usize> =
        (1..=spec.log_points).map(|p| spec.steps * p / spec.log_points).collect();
    targets.retain(|&t| t >= 1);
    targets.dedup();
    for &target in &targets {
        {
            let mut sp = crate::obs::span(crate::obs::Phase::SweepChunk, crate::obs::CLUSTER);
            engine.run_virtual_until(target, &mut clocks)?;
            sp.set_sim(clocks.max_seconds());
        }
        curve.push(sample(&engine, &clocks, target));
    }
    let initial = curve.first().map_or(0.0, |p| p.consensus);
    let transient_step = curve
        .iter()
        .find(|p| p.consensus <= TRANSIENT_FRACTION * initial)
        .map(|p| p.step);
    Ok(SweepReport {
        n,
        steps: spec.steps,
        surrogate: spec.dim == 0,
        beta: spec.topo.beta_report(),
        curve,
        transient_step,
        churn_counts: engine.churn_counts(),
        num_links: engine.num_links(),
        peak_live_slots: engine.store().peak_live_slots(),
        peak_dense_scalars: engine.store().peak_dense_scalars(),
    })
}

fn sample(engine: &AsyncGossip, clocks: &VirtualClocks, target: usize) -> CurvePoint {
    let alive = engine.alive();
    let consensus = if let Some(means) = engine.virt_means() {
        let live: Vec<f64> = means
            .iter()
            .zip(alive)
            .filter(|(_, &a)| a)
            .map(|(&m, _)| m)
            .collect();
        scalar_consensus(&live)
    } else if let Some(state) = engine.virt_dense() {
        let live: Vec<Vec<f32>> = (0..state.n())
            .filter(|&i| alive[i])
            .map(|i| state.row(i).to_vec())
            .collect();
        consensus_distance_rows(&live)
    } else {
        0.0
    };
    let now = clocks.max_seconds();
    let (stale_max, stale_mean) = engine.staleness();
    let stats = engine.virt_stats();
    CurvePoint {
        step: engine.min_alive_done().min(target),
        time: now,
        consensus,
        scalars: stats.scalars_sent,
        msgs: stats.msgs,
        alive: engine.alive_count(),
        stale_max,
        stale_mean,
        link_util: engine.link_utilization(now),
        wait: clocks.total_wait() + stats.barrier_wait,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_script_parses_every_shape() {
        let s = ChurnScript::parse(
            "crash@1.5:3, rejoin@2.5:3, flaky@1.0:7>3:4.0, restore@3.25:7>3",
        )
        .unwrap();
        assert_eq!(
            s.events,
            vec![
                ChurnEvent::Crash { at: 1.5, node: 3 },
                ChurnEvent::Rejoin { at: 2.5, node: 3 },
                ChurnEvent::FlakyLink { at: 1.0, src: 7, dst: 3, factor: 4.0 },
                ChurnEvent::LinkRestore { at: 3.25, src: 7, dst: 3 },
            ]
        );
        assert_eq!(ChurnScript::parse("").unwrap().events, vec![]);
    }

    #[test]
    fn churn_script_rejects_malformed_terms() {
        for bad in [
            "crash:3",          // no @
            "crash@x:3",        // bad time
            "crash@1.0:3:9",    // extra arg
            "flaky@1.0:7:4.0",  // missing '>'
            "flaky@1.0:7>3",    // missing factor
            "explode@1.0:3",    // unknown kind
            "rejoin@1.0:minus", // bad node
        ] {
            assert!(ChurnScript::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn seeded_script_is_deterministic_and_paired() {
        let topo = Topology::one_peer_expo(64);
        let a = ChurnScript::seeded(7, &topo, 6, 100.0).unwrap();
        let b = ChurnScript::seeded(7, &topo, 6, 100.0).unwrap();
        assert_eq!(a, b, "same seed, same script");
        assert_ne!(a, ChurnScript::seeded(8, &topo, 6, 100.0).unwrap());
        assert_eq!(a.events.len(), 12, "every disturbance is a paired on/off");
        let crashes = a
            .events
            .iter()
            .filter(|e| matches!(e, ChurnEvent::Crash { .. }))
            .count();
        let rejoins = a
            .events
            .iter()
            .filter(|e| matches!(e, ChurnEvent::Rejoin { .. }))
            .count();
        assert_eq!(crashes, rejoins);
        assert!(crashes < 64, "cannot empty the population");
        for e in &a.events {
            assert!(e.at() >= 0.0 && e.at() <= 100.0, "{e:?} outside horizon");
        }
    }

    #[test]
    fn sweep_runs_and_reports_curves() {
        let mut spec = SweepSpec::massive_n(32, 24, 11);
        spec.log_points = 6;
        spec.churn = ChurnScript::seeded(3, &spec.topo, 2, 5.0).unwrap().events;
        let report = run_sweep(&spec).unwrap();
        assert_eq!(report.n, 32);
        assert!(report.surrogate);
        assert_eq!(report.curve.len(), 6);
        assert_eq!(report.curve.last().unwrap().step, 24);
        assert!(report.peak_dense_scalars == 0, "surrogate sweep allocated dense payloads");
        assert!(report.curve.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(report.curve.windows(2).all(|w| w[0].scalars <= w[1].scalars));
        // Gossip + periodic averaging contracts scalar disagreement.
        let first = report.curve.first().unwrap().consensus;
        let last = report.curve.last().unwrap().consensus;
        assert!(last < first, "consensus did not contract: {first} -> {last}");
        let json = report.to_json().dump();
        assert!(json.contains("\"peak_dense_scalars\":0"), "{json}");
        assert!(json.contains("\"consensus\":["));
    }

    #[test]
    fn sweep_report_is_replayable_bit_exactly() {
        let mut spec = SweepSpec::massive_n(16, 12, 5);
        spec.log_points = 4;
        spec.churn = ChurnScript::seeded(9, &spec.topo, 2, 3.0).unwrap().events;
        let a = run_sweep(&spec).unwrap();
        let b = run_sweep(&spec).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.to_json().dump(), b.to_json().dump());
    }

    #[test]
    fn sweep_straggler_range_is_validated_with_a_clear_message() {
        let mut spec = SweepSpec::massive_n(8, 4, 1);
        spec.stragglers = vec![(8, 3.0)];
        let err = run_sweep(&spec).unwrap_err().to_string();
        assert!(err.contains("--straggler index 8 out of range"), "{err}");
        assert!(err.contains("--virtual-n 8"), "{err}");
        // In range: runs fine and slows the straggler's clock.
        spec.stragglers = vec![(2, 5.0)];
        assert!(run_sweep(&spec).is_ok());
    }

    #[test]
    fn dense_sweep_reports_row_consensus() {
        let mut spec = SweepSpec::massive_n(12, 10, 2);
        spec.dim = 3;
        spec.log_points = 5;
        let report = run_sweep(&spec).unwrap();
        assert!(!report.surrogate);
        assert!(report.peak_dense_scalars > 0);
        let first = report.curve.first().unwrap().consensus;
        let last = report.curve.last().unwrap().consensus;
        assert!(last < first, "dense consensus did not contract: {first} -> {last}");
    }
}
