//! Chrome trace-event JSON: export ([`export`]), schema validation
//! ([`validate`]), loading with clear CLI errors ([`load`]) and the
//! `trace` subcommand's per-phase summary ([`summarize`]).
//!
//! The emitted document is the subset of the trace-event format Perfetto
//! and `chrome://tracing` load directly:
//!
//! * `ph:"M"` metadata names every process and thread — pid 0 is the
//!   cluster-wide track, pid i+1 is node i, tid is the recording thread's
//!   registration order;
//! * `ph:"X"` complete duration events carry `ts`/`dur` in microseconds
//!   of wall time plus `args.sim_seconds`, the cost-model bill;
//! * `ph:"C"` counter events render the [`Counters`] registry as counter
//!   tracks.
//!
//! `X` events are written sorted by `(tid, ts)`, so `ts` is monotone
//! (non-decreasing) per tid in file order — [`validate`] pins that, and
//! the round-trip is tested in `rust/tests/obs_trace.rs`.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use super::{Counters, TraceData, CLUSTER};
use crate::harness::{fmt_duration, Table};
use crate::jsonio::{self, Json};

fn pid_of(node: u32) -> f64 {
    if node == CLUSTER {
        0.0
    } else {
        node as f64 + 1.0
    }
}

fn pid_name(node: u32) -> String {
    if node == CLUSTER {
        "cluster".into()
    } else {
        format!("node {node}")
    }
}

/// Render a collected session (plus the run's counter registry) as a
/// Perfetto-loadable trace-event document.
pub fn export(data: &TraceData, counters: &Counters) -> Json {
    // (tid, start_ns) keyed so the X section is monotone per tid.
    let mut xs: Vec<(u32, u64, Json)> = Vec::new();
    let mut nodes: BTreeMap<u64, u32> = BTreeMap::new();
    let mut end_us = 0.0f64;
    for th in &data.threads {
        for s in &th.spans {
            nodes.entry(pid_of(s.node) as u64).or_insert(s.node);
            let ts = s.start_ns as f64 / 1e3;
            let dur = s.dur_ns as f64 / 1e3;
            end_us = end_us.max(ts + dur);
            xs.push((
                th.tid,
                s.start_ns,
                jsonio::obj(vec![
                    ("name", Json::Str(s.phase.name().into())),
                    ("cat", Json::Str("phase".into())),
                    ("ph", Json::Str("X".into())),
                    ("pid", Json::Num(pid_of(s.node))),
                    ("tid", Json::Num(th.tid as f64)),
                    ("ts", Json::Num(ts)),
                    ("dur", Json::Num(dur)),
                    (
                        "args",
                        jsonio::obj(vec![("sim_seconds", Json::Num(s.sim_seconds))]),
                    ),
                ]),
            ));
        }
    }
    xs.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

    let mut events: Vec<Json> = Vec::new();
    for (&pid, &node) in &nodes {
        events.push(jsonio::obj(vec![
            ("name", Json::Str("process_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("ts", Json::Num(0.0)),
            ("args", jsonio::obj(vec![("name", Json::Str(pid_name(node)))])),
        ]));
    }
    for th in &data.threads {
        events.push(jsonio::obj(vec![
            ("name", Json::Str("thread_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(th.tid as f64)),
            ("ts", Json::Num(0.0)),
            (
                "args",
                jsonio::obj(vec![("name", Json::Str(format!("worker {}", th.tid)))]),
            ),
        ]));
    }
    events.extend(xs.into_iter().map(|(_, _, e)| e));
    for (name, value) in counters.iter() {
        events.push(jsonio::obj(vec![
            ("name", Json::Str(name.into())),
            ("ph", Json::Str("C".into())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(0.0)),
            ("ts", Json::Num(end_us)),
            ("args", jsonio::obj(vec![(name, Json::Num(value as f64))])),
        ]));
    }
    jsonio::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Schema check: every event is a trace-event object (`name`/`ph`/`pid`/
/// `tid`/`ts`, `dur >= 0` on `X`), and `X` timestamps are monotone
/// (non-decreasing) per tid in file order.
pub fn validate(doc: &Json) -> Result<()> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("missing 'traceEvents' array")?;
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let field = |k: &str| {
            ev.get(k).with_context(|| format!("event {i}: missing field '{k}'"))
        };
        let num = |k: &str| -> Result<f64> {
            field(k)?.as_f64().with_context(|| format!("event {i}: '{k}' is not a number"))
        };
        ensure!(
            field("name")?.as_str().is_some(),
            "event {i}: 'name' is not a string"
        );
        let ph = field("ph")?
            .as_str()
            .with_context(|| format!("event {i}: 'ph' is not a string"))?;
        ensure!(
            matches!(ph, "X" | "M" | "C"),
            "event {i}: unknown phase type '{ph}' (expected X, M or C)"
        );
        num("pid")?;
        let tid = num("tid")?;
        let ts = num("ts")?;
        ensure!(ts >= 0.0, "event {i}: negative ts {ts}");
        if ph == "X" {
            let dur = num("dur")?;
            ensure!(dur >= 0.0, "event {i}: negative dur {dur}");
            let key = tid.to_bits();
            if let Some(&prev) = last_ts.get(&key) {
                ensure!(
                    ts >= prev,
                    "event {i}: ts {ts} goes backwards on tid {tid} (previous {prev})"
                );
            }
            last_ts.insert(key, ts);
        }
    }
    Ok(())
}

/// Read + parse + validate a trace file, with errors a CLI user can act
/// on (missing file, malformed JSON, not a trace-event document).
pub fn load(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("cannot read trace file '{}'", path.display()))?;
    let doc = Json::parse(&text)
        .with_context(|| format!("trace file '{}' is not valid JSON", path.display()))?;
    validate(&doc).with_context(|| {
        format!("trace file '{}' is not a chrome trace-event document", path.display())
    })?;
    Ok(doc)
}

struct PhaseAgg {
    durs_us: Vec<f64>,
    sim: f64,
}

/// Summarize a validated trace document: one row per (node, phase) with
/// span count, p50/p99/total wall time and total sim seconds, plus the
/// final counter-track values.
pub fn summarize(doc: &Json) -> Result<String> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("missing 'traceEvents' array")?;
    // pid → display name from the metadata, falling back to "pid N".
    let mut pid_names: BTreeMap<u64, String> = BTreeMap::new();
    let mut groups: BTreeMap<(u64, String), PhaseAgg> = BTreeMap::new();
    let mut counters: BTreeMap<String, f64> = BTreeMap::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        let pid = ev.get("pid").and_then(|p| p.as_f64()).unwrap_or(0.0) as u64;
        match ph {
            "M" if name == "process_name" => {
                if let Some(n) = ev.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str())
                {
                    pid_names.insert(pid, n.to_string());
                }
            }
            "X" => {
                let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
                let sim = ev
                    .get("args")
                    .and_then(|a| a.get("sim_seconds"))
                    .and_then(|s| s.as_f64())
                    .unwrap_or(0.0);
                let agg = groups
                    .entry((pid, name))
                    .or_insert(PhaseAgg { durs_us: Vec::new(), sim: 0.0 });
                agg.durs_us.push(dur);
                agg.sim += sim;
            }
            "C" => {
                // Counter tracks: the LAST value per counter name wins.
                if let Some(args) = ev.get("args") {
                    if let Some(v) = args.get(&name).and_then(|v| v.as_f64()) {
                        counters.insert(name, v);
                    }
                }
            }
            _ => {}
        }
    }
    if groups.is_empty() {
        bail!("trace contains no duration (ph:\"X\") events to summarize");
    }
    let mut table =
        Table::new(&["node", "phase", "count", "p50", "p99", "total wall", "sim s"]);
    for ((pid, phase), agg) in &mut groups {
        agg.durs_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = agg.durs_us.len();
        let pct = |p: f64| agg.durs_us[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        let total: f64 = agg.durs_us.iter().sum();
        let node = pid_names.get(pid).cloned().unwrap_or_else(|| format!("pid {pid}"));
        table.rowv(vec![
            node,
            phase.clone(),
            n.to_string(),
            fmt_duration(pct(0.5) / 1e6),
            fmt_duration(pct(0.99) / 1e6),
            fmt_duration(total / 1e6),
            crate::harness::fmt_f(agg.sim),
        ]);
    }
    let mut out = table.render();
    if !counters.is_empty() {
        let shown: Vec<String> =
            counters.iter().map(|(k, v)| format!("{k}={v}")).collect();
        out.push_str(&format!("counters: {}\n", shown.join(" ")));
    }
    Ok(out)
}
