//! `obs` — the observability plane: structured per-phase span tracing,
//! the unified counter registry, and the warn-once sink.
//!
//! ## Trace plane
//!
//! A traced run records one [`Span`] per phase execution — sample / grad,
//! gossip issue, the deferred recv+mix drain, reduce-scatter / all-gather,
//! barrier stalls, the round machine's announce/gossip/collect/commit
//! states, eventsim DELIVER/MIX events, sweep chunks — each carrying both
//! wall nanoseconds and cost-model sim seconds. Spans land in per-thread
//! fixed-capacity ring buffers ([`Ring`]): the hot path takes **no lock**
//! (one relaxed atomic load when tracing is off, an owner-thread ring
//! write when on), overflow drops the OLDEST spans and counts them
//! (`spans_dropped`), and an untraced run executes byte-for-byte the same
//! arithmetic — every probe is behind [`enabled`], and no probe ever
//! touches parameter or clock state.
//!
//! Lifecycle: [`start`] arms a session (bumping a global session counter
//! so stale thread-local rings from a previous session re-register);
//! [`stop_and_collect`] disarms it and returns the surviving spans per
//! thread. Call `stop_and_collect` only after the traced run has returned
//! (threads quiesced) — ring writes are owner-thread-exclusive.
//! [`chrome::export`] renders the collection as a Perfetto-loadable
//! Chrome trace-event document (`--trace out.json`), and the `trace` CLI
//! subcommand summarizes such a file per phase and node.
//!
//! ## Counter registry
//!
//! [`Counters`] folds the scattered per-run tallies (`stale_frames`,
//! `peer_drops`, `row_renorms`, `fallback_rounds`, `spans_dropped`,
//! `pool_panics`) into one struct with stable names
//! ([`Counters::NAMES`]): the History CSV/JSON columns, the launcher's
//! `# traffic:` line, and the trace export's counter tracks all render
//! from this single source (`Trainer::counters`).
//!
//! ## Warn-once
//!
//! [`warn_once!`] fires a keyed warning exactly once per process through
//! a swappable sink — stderr in production, a capture buffer under
//! [`capture_warnings`] so tests assert "warned exactly once" without
//! scraping stderr.

pub mod chrome;

use std::cell::{RefCell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// The phases a traced run records. Names are stable (they key the trace
/// JSON and the `trace` subcommand's summary table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Overlap-mode batch sampling (runs while the previous mix drains).
    Sample,
    /// Local gradient + optimizer update (phases 1-2 of Algorithm 1).
    Grad,
    /// Issuing an async gossip round (sends on the wire, mix deferred).
    GossipIssue,
    /// Draining deferred recv+mix rounds, oldest first.
    Drain,
    /// One synchronous gossip collective.
    Gossip,
    /// One global average (the k·H barrier).
    GlobalAverage,
    /// Bus/tcp global average, scatter + reduce sub-phase.
    ReduceScatter,
    /// Bus/tcp global average, broadcast + assemble sub-phase.
    AllGather,
    /// Barrier stall: sim seconds nodes spent waiting behind slower peers
    /// at this synchronization point (wall duration is 0 — the stall is a
    /// cost-model quantity).
    Barrier,
    /// Round machine: arm the per-receive deadline.
    RoundAnnounce,
    /// Round machine: the collective attempt, deadline in force.
    RoundGossip,
    /// Round machine: classify the outcome (success / stalled peer).
    RoundCollect,
    /// Round machine: disarm + advance the round counter.
    RoundCommit,
    /// Eventsim: a payload delivery (node = receiver; sim = event time).
    EvDeliver,
    /// Eventsim: a bounded-stale mix (node = mixer; sim = event time).
    EvMix,
    /// Eventsim: a node ready/compute event.
    EvReady,
    /// Eventsim: a churn script event.
    EvChurn,
    /// Population plane: one `run_virtual_until` chunk of a sweep.
    SweepChunk,
}

impl Phase {
    pub const ALL: [Phase; 18] = [
        Phase::Sample,
        Phase::Grad,
        Phase::GossipIssue,
        Phase::Drain,
        Phase::Gossip,
        Phase::GlobalAverage,
        Phase::ReduceScatter,
        Phase::AllGather,
        Phase::Barrier,
        Phase::RoundAnnounce,
        Phase::RoundGossip,
        Phase::RoundCollect,
        Phase::RoundCommit,
        Phase::EvDeliver,
        Phase::EvMix,
        Phase::EvReady,
        Phase::EvChurn,
        Phase::SweepChunk,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Sample => "sample",
            Phase::Grad => "grad",
            Phase::GossipIssue => "gossip_issue",
            Phase::Drain => "drain",
            Phase::Gossip => "gossip",
            Phase::GlobalAverage => "global_average",
            Phase::ReduceScatter => "reduce_scatter",
            Phase::AllGather => "all_gather",
            Phase::Barrier => "barrier",
            Phase::RoundAnnounce => "round_announce",
            Phase::RoundGossip => "round_gossip",
            Phase::RoundCollect => "round_collect",
            Phase::RoundCommit => "round_commit",
            Phase::EvDeliver => "ev_deliver",
            Phase::EvMix => "ev_mix",
            Phase::EvReady => "ev_ready",
            Phase::EvChurn => "ev_churn",
            Phase::SweepChunk => "sweep_chunk",
        }
    }
}

/// Node sentinel for spans that cover the whole cluster (the coordinator's
/// sharded phases execute all nodes at once). Exported as pid 0.
pub const CLUSTER: u32 = u32::MAX;

/// One recorded phase execution: wall time (relative to session start)
/// AND the cost-model seconds the phase billed (0 for pure-wall phases).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub phase: Phase,
    /// Node the span belongs to, or [`CLUSTER`].
    pub node: u32,
    /// Wall start, nanoseconds since [`start`].
    pub start_ns: u64,
    /// Wall duration in nanoseconds (0 for instant events).
    pub dur_ns: u64,
    /// Cost-model seconds: the billed sim time for collectives/barriers,
    /// the event time for eventsim instants, 0 where the model bills
    /// nothing.
    pub sim_seconds: f64,
}

const ZERO_SPAN: Span =
    Span { phase: Phase::Sample, node: 0, start_ns: 0, dur_ns: 0, sim_seconds: 0.0 };

/// Fixed-capacity drop-oldest span ring. The owning thread is the only
/// writer (`push`); `snapshot` reads are taken after [`stop_and_collect`]
/// disarms the session and the owner has quiesced, so the unsynchronized
/// buffer access never races.
pub struct Ring {
    buf: UnsafeCell<Box<[Span]>>,
    /// Total pushes ever (monotone); `pushes - capacity` spans were
    /// dropped once it exceeds the buffer length.
    pushes: AtomicUsize,
}

// SAFETY: writes are owner-thread-exclusive and reads happen only after
// the session is disarmed (see type docs); the atomic push counter
// publishes the written slots.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new(capacity: usize) -> Ring {
        Ring {
            buf: UnsafeCell::new(vec![ZERO_SPAN; capacity.max(1)].into_boxed_slice()),
            pushes: AtomicUsize::new(0),
        }
    }

    fn push(&self, s: Span) {
        let i = self.pushes.load(Ordering::Relaxed);
        // SAFETY: owner-thread exclusive (see type docs).
        let buf = unsafe { &mut *self.buf.get() };
        buf[i % buf.len()] = s;
        self.pushes.store(i + 1, Ordering::Release);
    }

    fn dropped(&self) -> u64 {
        let total = self.pushes.load(Ordering::Acquire);
        // SAFETY: reading the length only.
        let cap = unsafe { &*self.buf.get() }.len();
        total.saturating_sub(cap) as u64
    }

    /// Surviving spans in push order plus the drop-oldest tally.
    fn snapshot(&self) -> (Vec<Span>, u64) {
        let total = self.pushes.load(Ordering::Acquire);
        // SAFETY: owner quiesced before collection (see type docs).
        let buf = unsafe { &*self.buf.get() };
        let cap = buf.len();
        let mut out = Vec::with_capacity(total.min(cap));
        if total <= cap {
            out.extend_from_slice(&buf[..total]);
        } else {
            let head = total % cap;
            out.extend_from_slice(&buf[head..]);
            out.extend_from_slice(&buf[..head]);
        }
        (out, total.saturating_sub(cap) as u64)
    }
}

/// One tracing session: the rings of every thread that recorded a span,
/// in registration order (registration index = exported tid).
struct Tracer {
    capacity: usize,
    start: Instant,
    rings: Mutex<Vec<Arc<Ring>>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SESSION: AtomicU64 = AtomicU64::new(0);
static TRACER: Mutex<Option<Arc<Tracer>>> = Mutex::new(None);

struct LocalRing {
    session: u64,
    ring: Arc<Ring>,
    start: Instant,
}

thread_local! {
    static LOCAL: RefCell<Option<LocalRing>> = const { RefCell::new(None) };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Whether a tracing session is armed — ONE relaxed atomic load; every
/// probe in the codebase is behind this, so untraced runs pay nothing
/// else.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// An in-flight span: records itself into the current thread's ring on
/// drop. A no-op (no clock read, no ring touch) when tracing is off.
pub struct SpanGuard {
    live: Option<(Phase, u32, Instant, f64)>,
}

impl SpanGuard {
    /// Attach the cost-model seconds this phase billed (call once the
    /// charge is known, before the guard drops).
    #[inline]
    pub fn set_sim(&mut self, sim_seconds: f64) {
        if let Some(l) = self.live.as_mut() {
            l.3 = sim_seconds;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((phase, node, t0, sim)) = self.live.take() {
            record_span(phase, node, t0, t0.elapsed(), sim);
        }
    }
}

/// Open a span for `phase` on `node` (or [`CLUSTER`]). Duration runs
/// until the returned guard drops.
#[inline]
pub fn span(phase: Phase, node: u32) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    SpanGuard { live: Some((phase, node, Instant::now(), 0.0)) }
}

/// Record a zero-duration event (eventsim deliveries/mixes, barrier
/// stalls) carrying only sim time.
#[inline]
pub fn instant(phase: Phase, node: u32, sim_seconds: f64) {
    if !enabled() {
        return;
    }
    record_span(phase, node, Instant::now(), Duration::ZERO, sim_seconds);
}

fn record_span(phase: Phase, node: u32, t0: Instant, dur: Duration, sim: f64) {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        let session = SESSION.load(Ordering::Acquire);
        if slot.as_ref().map(|l| l.session != session).unwrap_or(true) {
            // First span from this thread in this session: register a
            // fresh ring (cold path — the only lock in the plane).
            let tracer = lock(&TRACER);
            let Some(t) = tracer.as_ref() else {
                return; // raced with stop(); the session is gone
            };
            let ring = Arc::new(Ring::new(t.capacity));
            lock(&t.rings).push(ring.clone());
            *slot = Some(LocalRing { session, ring, start: t.start });
        }
        let l = slot.as_ref().expect("registered above");
        let start_ns =
            t0.checked_duration_since(l.start).unwrap_or_default().as_nanos() as u64;
        l.ring.push(Span {
            phase,
            node,
            start_ns,
            dur_ns: dur.as_nanos() as u64,
            sim_seconds: sim,
        });
    });
}

/// Arm a tracing session with per-thread ring capacity `capacity`
/// (clamped to >= 1; `trace.capacity` validates earlier with a clear
/// message). Restarting bumps the session counter so rings from the
/// previous session re-register lazily.
pub fn start(capacity: usize) {
    let tracer = Arc::new(Tracer {
        capacity: capacity.max(1),
        start: Instant::now(),
        rings: Mutex::new(Vec::new()),
    });
    *lock(&TRACER) = Some(tracer);
    SESSION.fetch_add(1, Ordering::AcqRel);
    ENABLED.store(true, Ordering::Release);
}

/// The spans one thread recorded (tid = registration order).
pub struct ThreadTrace {
    pub tid: u32,
    pub spans: Vec<Span>,
    pub dropped: u64,
}

/// Everything a session recorded, per thread.
pub struct TraceData {
    pub threads: Vec<ThreadTrace>,
}

impl TraceData {
    pub fn total_spans(&self) -> usize {
        self.threads.iter().map(|t| t.spans.len()).sum()
    }

    pub fn total_dropped(&self) -> u64 {
        self.threads.iter().map(|t| t.dropped).sum()
    }
}

/// Disarm the session and collect every ring. Call only after the traced
/// run has returned (ring writes are owner-thread-exclusive; the pool
/// parks between jobs and the driving thread is the caller).
pub fn stop_and_collect() -> TraceData {
    ENABLED.store(false, Ordering::Release);
    let tracer = lock(&TRACER).take();
    let Some(t) = tracer else {
        return TraceData { threads: Vec::new() };
    };
    let rings = lock(&t.rings);
    let threads = rings
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let (spans, dropped) = r.snapshot();
            ThreadTrace { tid: i as u32, spans, dropped }
        })
        .collect();
    TraceData { threads }
}

/// Spans the CURRENT thread's ring has dropped in the active session — 0
/// when tracing is off. A run's spans are pushed from its own driving
/// thread, so this is the per-run `spans_dropped` counter the trainer
/// logs (deterministic under parallel test harnesses, unlike a process
/// global).
pub fn thread_spans_dropped() -> u64 {
    if !enabled() {
        return 0;
    }
    LOCAL.with(|slot| {
        let session = SESSION.load(Ordering::Acquire);
        slot.borrow()
            .as_ref()
            .filter(|l| l.session == session)
            .map(|l| l.ring.dropped())
            .unwrap_or(0)
    })
}

// ---------------------------------------------------------------------------
// Counter registry
// ---------------------------------------------------------------------------

/// The unified per-run counter registry (see module docs). Field names ==
/// [`Counters::NAMES`] == the History CSV/JSON column names, so every
/// reporter renders the same set from the same source.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// Frames discarded on receipt for a stale epoch tag (bus/tcp).
    pub stale_frames: u64,
    /// Peers dropped by the round machine's per-receive deadline.
    pub peer_drops: u64,
    /// Mixing rows renormalized by those drops.
    pub row_renorms: u64,
    /// Overlap gossip rounds that fell back to the synchronous path.
    pub fallback_rounds: u64,
    /// Trace spans evicted from the run's ring (drop-oldest overflow).
    pub spans_dropped: u64,
    /// Worker-pool jobs that panicked (the pool poisons itself on the
    /// first one, so a finished run normally reports 0).
    pub pool_panics: u64,
}

impl Counters {
    /// Stable names, in [`Counters::values`] order.
    pub const NAMES: [&'static str; 6] = [
        "stale_frames",
        "peer_drops",
        "row_renorms",
        "fallback_rounds",
        "spans_dropped",
        "pool_panics",
    ];

    pub fn values(&self) -> [u64; 6] {
        [
            self.stale_frames,
            self.peer_drops,
            self.row_renorms,
            self.fallback_rounds,
            self.spans_dropped,
            self.pool_panics,
        ]
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> {
        Self::NAMES.into_iter().zip(self.values())
    }

    /// `name=value` list for the `# traffic:` line and trace counter
    /// tracks.
    pub fn render(&self) -> String {
        self.iter().map(|(n, v)| format!("{n}={v}")).collect::<Vec<_>>().join(" ")
    }
}

// ---------------------------------------------------------------------------
// Warn-once
// ---------------------------------------------------------------------------

enum Sink {
    Stderr,
    Capture(Vec<String>),
}

struct WarnState {
    fired: Vec<&'static str>,
    sink: Sink,
}

static WARN: Mutex<WarnState> = Mutex::new(WarnState { fired: Vec::new(), sink: Sink::Stderr });
static WARN_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Fire a keyed warning at most once per process (see [`warn_once!`]).
/// Returns whether this call fired. The message closure only runs on the
/// first call.
pub fn warn_once_impl(key: &'static str, msg: impl FnOnce() -> String) -> bool {
    let mut w = lock(&WARN);
    if w.fired.contains(&key) {
        return false;
    }
    w.fired.push(key);
    let text = msg();
    match &mut w.sink {
        Sink::Stderr => eprintln!("warning: {text}"),
        Sink::Capture(v) => v.push(format!("[{key}] {text}")),
    }
    true
}

/// Emit a warning exactly once per process, keyed by a stable string:
/// `obs::warn_once!("exec.pin-unavailable", "core pinning unavailable")`.
/// Goes to stderr in production and to the capture buffer under
/// [`capture_warnings`].
#[macro_export]
macro_rules! warn_once {
    ($key:expr, $($fmt:tt)*) => {
        $crate::obs::warn_once_impl($key, || format!($($fmt)*))
    };
}
pub use crate::warn_once;

/// Test hook: redirect the warn-once sink to a capture buffer and reset
/// the fired-key set, serialized against other captures (the guard holds
/// a global test lock). Dropping the guard restores stderr.
pub fn capture_warnings() -> WarnCapture {
    let guard = lock(&WARN_TEST_LOCK);
    let mut w = lock(&WARN);
    w.fired.clear();
    w.sink = Sink::Capture(Vec::new());
    WarnCapture { _guard: guard }
}

/// Live warning capture (see [`capture_warnings`]).
pub struct WarnCapture {
    _guard: MutexGuard<'static, ()>,
}

impl WarnCapture {
    /// Take the warnings captured so far (each `"[key] message"`).
    pub fn drain(&self) -> Vec<String> {
        match &mut lock(&WARN).sink {
            Sink::Capture(v) => std::mem::take(v),
            Sink::Stderr => Vec::new(),
        }
    }
}

impl Drop for WarnCapture {
    fn drop(&mut self) {
        lock(&WARN).sink = Sink::Stderr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global: serialize the tests that arm it.
    static SERIAL: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_probes_are_noops() {
        let _g = lock(&SERIAL);
        assert!(!enabled());
        let mut sp = span(Phase::Gossip, CLUSTER);
        sp.set_sim(1.0);
        drop(sp);
        instant(Phase::EvMix, 3, 2.0);
        assert_eq!(thread_spans_dropped(), 0);
    }

    #[test]
    fn spans_record_wall_and_sim() {
        let _g = lock(&SERIAL);
        start(64);
        {
            let mut sp = span(Phase::Gossip, CLUSTER);
            sp.set_sim(0.25768);
        }
        instant(Phase::EvDeliver, 9007, 1.5);
        let data = stop_and_collect();
        let spans: Vec<&Span> = data.threads.iter().flat_map(|t| &t.spans).collect();
        // Discriminate on the exact sim value: parallel lib tests may land
        // spans of the same phase in this session.
        let g = spans
            .iter()
            .find(|s| s.phase == Phase::Gossip && s.sim_seconds == 0.25768)
            .expect("gossip span");
        assert_eq!(g.node, CLUSTER);
        let d = spans
            .iter()
            .find(|s| s.phase == Phase::EvDeliver && s.node == 9007)
            .expect("deliver span");
        assert_eq!((d.dur_ns, d.sim_seconds), (0, 1.5));
        assert!(!enabled());
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let _g = lock(&SERIAL);
        start(4);
        // Distinctive node ids: parallel lib tests may trace-register other
        // threads' rings into this session; ours is the one with these.
        for i in 0..10u32 {
            instant(Phase::EvMix, 9000 + i, i as f64);
        }
        assert_eq!(thread_spans_dropped(), 6);
        let data = stop_and_collect();
        let mine: Vec<&ThreadTrace> = data
            .threads
            .iter()
            .filter(|t| t.spans.iter().any(|s| (9000..9010).contains(&s.node)))
            .collect();
        assert_eq!(mine.len(), 1);
        let t = mine[0];
        assert_eq!(t.dropped, 6);
        // Oldest dropped: pushes 6..10 survive, in push order.
        let nodes: Vec<u32> = t.spans.iter().map(|s| s.node).collect();
        assert_eq!(nodes, vec![9006, 9007, 9008, 9009]);
    }

    #[test]
    fn restart_reregisters_thread_rings() {
        let _g = lock(&SERIAL);
        let count = |data: &TraceData, node: u32| {
            data.threads
                .iter()
                .flat_map(|t| &t.spans)
                .filter(|s| s.phase == Phase::EvReady && s.node == node)
                .count()
        };
        start(8);
        instant(Phase::EvReady, 9001, 0.0);
        let first = stop_and_collect();
        assert_eq!(count(&first, 9001), 1);
        start(8);
        instant(Phase::EvReady, 9002, 0.0);
        let second = stop_and_collect();
        // The stale thread-local ring re-registered: only the new span.
        assert_eq!(count(&second, 9001), 0);
        assert_eq!(count(&second, 9002), 1);
    }

    #[test]
    fn counters_registry_is_consistent() {
        let c = Counters {
            stale_frames: 1,
            peer_drops: 2,
            row_renorms: 3,
            fallback_rounds: 4,
            spans_dropped: 5,
            pool_panics: 6,
        };
        assert_eq!(Counters::NAMES.len(), c.values().len());
        assert_eq!(c.values(), [1, 2, 3, 4, 5, 6]);
        let rendered = c.render();
        for (name, value) in c.iter() {
            assert!(rendered.contains(&format!("{name}={value}")), "{rendered}");
        }
    }

    #[test]
    fn warn_once_fires_exactly_once_per_key() {
        let cap = capture_warnings();
        assert!(warn_once!("obs.test-key", "value {}", 42));
        assert!(!warn_once!("obs.test-key", "value {}", 43));
        assert!(warn_once!("obs.test-other", "other"));
        let got = cap.drain();
        let mine: Vec<&String> =
            got.iter().filter(|m| m.starts_with("[obs.test")).collect();
        assert_eq!(mine.len(), 2, "{got:?}");
        assert!(mine[0].contains("value 42"));
    }

    #[test]
    fn phase_names_are_unique_and_total() {
        let mut names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Phase::ALL.len());
    }
}
