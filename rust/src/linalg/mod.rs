//! Dense linear algebra substrate.
//!
//! Just enough for the paper's spectral machinery: row-major [`Mat`],
//! matvec/matmul, norms, and deflated power iteration to compute
//! `beta = ||W - (1/n) 11^T||_2` (Assumption 3 / Remark 1) for any gossip
//! matrix. No external BLAS — n here is the *node count* (<= a few hundred),
//! not the model dimension.

use crate::rng::Rng;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// The averaging matrix (1/n) 11^T.
    pub fn avg(n: usize) -> Self {
        Mat { rows: n, cols: n, data: vec![1.0 / n as f64; n * n] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// C = A B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    /// A - B.
    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&b.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |row sum - 1| — doubly-stochastic check helper.
    pub fn row_sum_err(&self) -> f64 {
        (0..self.rows)
            .map(|i| (self.row(i).iter().sum::<f64>() - 1.0).abs())
            .fold(0.0, f64::max)
    }

    pub fn col_sum_err(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for j in 0..self.cols {
            let s: f64 = (0..self.rows).map(|i| self[(i, j)]).sum();
            worst = worst.max((s - 1.0).abs());
        }
        worst
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Largest singular value of A via power iteration on A^T A.
///
/// Deterministic start vector derived from `seed`; converges to |sigma_max|
/// within `tol` (relative) or `max_iter` iterations.
pub fn spectral_norm(a: &Mat, seed: u64) -> f64 {
    let at = a.transpose();
    let mut rng = Rng::new(seed);
    let mut v: Vec<f64> = (0..a.cols).map(|_| rng.normal()).collect();
    let n = norm2(&v).max(1e-300);
    v.iter_mut().for_each(|x| *x /= n);
    let mut lambda = 0.0;
    for _ in 0..2000 {
        let w = at.matvec(&a.matvec(&v)); // A^T A v
        let nw = norm2(&w);
        if nw < 1e-300 {
            return 0.0;
        }
        let new_lambda = nw;
        v = w.iter().map(|x| x / nw).collect();
        if (new_lambda - lambda).abs() <= 1e-12 * new_lambda.max(1.0) {
            lambda = new_lambda;
            break;
        }
        lambda = new_lambda;
    }
    lambda.sqrt()
}

/// `beta = ||W - (1/n) 11^T||_2` — the paper's connectivity measure.
pub fn beta_of(w: &Mat) -> f64 {
    assert_eq!(w.rows, w.cols);
    let deflated = w.sub(&Mat::avg(w.rows));
    spectral_norm(&deflated, 0x5EED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn spectral_norm_diagonal() {
        let mut d = Mat::eye(4);
        d[(2, 2)] = -3.5; // largest singular value 3.5
        assert!((spectral_norm(&d, 1) - 3.5).abs() < 1e-6);
    }

    #[test]
    fn spectral_norm_rank_one() {
        // ||u v^T||_2 = |u| |v|
        let u = [1.0, 2.0];
        let v = [3.0, 4.0];
        let mut a = Mat::zeros(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                a[(i, j)] = u[i] * v[j];
            }
        }
        let expect = (5.0f64).sqrt() * 5.0;
        assert!((spectral_norm(&a, 2) - expect).abs() < 1e-6);
    }

    #[test]
    fn beta_of_full_averaging_is_zero() {
        // W = (1/n)11^T => W - avg = 0 => beta = 0.
        assert!(beta_of(&Mat::avg(8)) < 1e-9);
    }

    #[test]
    fn beta_of_identity_is_one() {
        // W = I: null(I-W) is all of R^n but beta = ||I - avg|| = 1.
        assert!((beta_of(&Mat::eye(6)) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn row_col_sums() {
        let a = Mat::avg(5);
        assert!(a.row_sum_err() < 1e-12);
        assert!(a.col_sum_err() < 1e-12);
        assert!(a.is_symmetric(1e-12));
    }
}
