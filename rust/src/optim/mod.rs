//! Optimizers and learning-rate schedules (L3-owned; the AOT graphs emit
//! loss + gradient only).
//!
//! The paper trains with Nesterov momentum SGD for ImageNet (App. F.1) and
//! plain SGD for the convex experiments and Table 16. LR schedules: the
//! convex runs halve gamma every 1000 iterations; deep runs use warmup +
//! step decay.

/// Learning-rate schedule.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Const {
        lr: f64,
    },
    /// Multiply by `factor` every `every` steps (paper §5.1: 0.5 / 1000).
    StepDecay {
        lr: f64,
        every: usize,
        factor: f64,
    },
    /// Linear warmup for `warmup` steps, then multiply by `factor` at each
    /// milestone (paper App. F.1: warmup 5 epochs, /10 at 30/60/90).
    WarmupMilestones {
        lr: f64,
        warmup: usize,
        milestones: Vec<usize>,
        factor: f64,
    },
    /// Linear warmup then polynomial decay to zero at `total` (BERT, F.1).
    WarmupPoly {
        lr: f64,
        warmup: usize,
        total: usize,
        power: f64,
    },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f64 {
        match self {
            LrSchedule::Const { lr } => *lr,
            LrSchedule::StepDecay { lr, every, factor } => {
                lr * factor.powi((step / every.max(&1usize)) as i32)
            }
            LrSchedule::WarmupMilestones { lr, warmup, milestones, factor } => {
                if step < *warmup {
                    lr * (step + 1) as f64 / *warmup as f64
                } else {
                    let passed = milestones.iter().filter(|&&m| step >= m).count() as i32;
                    lr * factor.powi(passed)
                }
            }
            LrSchedule::WarmupPoly { lr, warmup, total, power } => {
                if step < *warmup {
                    lr * (step + 1) as f64 / *warmup as f64
                } else if step >= *total {
                    0.0
                } else {
                    let frac = (total - step) as f64 / (total - warmup) as f64;
                    lr * frac.powf(*power)
                }
            }
        }
    }
}

/// Per-worker first-order optimizer state.
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub momentum: f64,
    pub nesterov: bool,
    /// Velocity buffer (empty until first step when momentum == 0).
    velocity: Vec<f32>,
}

impl Optimizer {
    pub fn sgd() -> Self {
        Optimizer { momentum: 0.0, nesterov: false, velocity: Vec::new() }
    }

    pub fn momentum_sgd(momentum: f64, nesterov: bool) -> Self {
        assert!((0.0..1.0).contains(&momentum));
        Optimizer { momentum, nesterov, velocity: Vec::new() }
    }

    /// Velocity buffer view, if momentum is active and a step has run
    /// (checkpointing).
    pub fn velocity_buf(&self) -> Option<&[f32]> {
        (!self.velocity.is_empty()).then_some(self.velocity.as_slice())
    }

    /// Restore the velocity buffer (checkpoint resume).
    pub fn set_velocity(&mut self, v: &[f32]) {
        self.velocity = v.to_vec();
    }

    /// In-place parameter update given the gradient and step LR.
    ///
    /// Heavy-ball: v <- mu v + g;           x <- x - lr v
    /// Nesterov:   v <- mu v + g;           x <- x - lr (g + mu v)
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        debug_assert_eq!(params.len(), grad.len());
        let lr = lr as f32;
        if self.momentum == 0.0 {
            for (p, g) in params.iter_mut().zip(grad) {
                *p -= lr * g;
            }
            return;
        }
        if self.velocity.len() != params.len() {
            self.velocity = vec![0.0; params.len()];
        }
        let mu = self.momentum as f32;
        if self.nesterov {
            for ((p, g), v) in params.iter_mut().zip(grad).zip(&mut self.velocity) {
                *v = mu * *v + g;
                *p -= lr * (g + mu * *v);
            }
        } else {
            for ((p, g), v) in params.iter_mut().zip(grad).zip(&mut self.velocity) {
                *v = mu * *v + g;
                *p -= lr * *v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = LrSchedule::Const { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn step_decay_halves_every_1000() {
        // Paper §5.1: initialized 0.2, halved every 1000 iterations.
        let s = LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 };
        assert_eq!(s.at(0), 0.2);
        assert_eq!(s.at(999), 0.2);
        assert_eq!(s.at(1000), 0.1);
        assert_eq!(s.at(2500), 0.05);
    }

    #[test]
    fn warmup_milestones_profile() {
        let s = LrSchedule::WarmupMilestones {
            lr: 1.0,
            warmup: 10,
            milestones: vec![30, 60, 90],
            factor: 0.1,
        };
        assert!(s.at(0) < s.at(9));
        assert_eq!(s.at(10), 1.0);
        assert!((s.at(30) - 0.1).abs() < 1e-12);
        assert!((s.at(95) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn warmup_poly_hits_zero() {
        let s = LrSchedule::WarmupPoly { lr: 1.0, warmup: 5, total: 100, power: 1.0 };
        assert!(s.at(0) < 1.0);
        assert!((s.at(5) - 1.0).abs() < 1e-2);
        assert!(s.at(100) == 0.0);
        assert!(s.at(50) > s.at(80));
    }

    #[test]
    fn sgd_step_matches_formula() {
        let mut opt = Optimizer::sgd();
        let mut p = vec![1.0f32, 2.0];
        opt.step(&mut p, &[0.5, -1.0], 0.1);
        assert!((p[0] - 0.95).abs() < 1e-6);
        assert!((p[1] - 2.1).abs() < 1e-6);
    }

    #[test]
    fn heavy_ball_accumulates_velocity() {
        let mut opt = Optimizer::momentum_sgd(0.9, false);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        opt.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn nesterov_differs_from_heavy_ball() {
        let mut hb = Optimizer::momentum_sgd(0.9, false);
        let mut nag = Optimizer::momentum_sgd(0.9, true);
        let mut p1 = vec![0.0f32];
        let mut p2 = vec![0.0f32];
        for _ in 0..3 {
            hb.step(&mut p1, &[1.0], 0.1);
            nag.step(&mut p2, &[1.0], 0.1);
        }
        assert!((p1[0] - p2[0]).abs() > 1e-6);
        // Nesterov looks ahead: larger effective step in the same direction.
        assert!(p2[0] < p1[0]);
    }

    #[test]
    fn momentum_converges_on_quadratic() {
        // minimize 0.5 x^2: gradient = x.
        let mut opt = Optimizer::momentum_sgd(0.9, true);
        let mut p = vec![10.0f32];
        for _ in 0..200 {
            let g = [p[0]];
            opt.step(&mut p, &g, 0.05);
        }
        assert!(p[0].abs() < 1e-2, "{}", p[0]);
    }
}
