//! Shared experiment-suite plumbing for the `benches/` targets (one bench
//! per paper table/figure). Each bench assembles rows from these helpers so
//! the workload wiring lives in one place.

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::AlgorithmKind;
use crate::comm::{BackendKind, Compression};
use crate::coordinator::{
    lm_eval_loss, lm_workload, logreg_workload, mlp_eval_accuracy, mlp_workload, Trainer,
    TrainerOptions,
};
use crate::costmodel::CostModel;
use crate::eventsim::Regime;
use crate::metrics::History;
use crate::optim::LrSchedule;
use crate::runtime::Runtime;
use crate::topology::Topology;

/// Scale factor for bench step counts: set `GOSSIP_PGA_FAST=1` to run the
/// suite at 1/4 scale (single-core CI), default full scale.
pub fn step_scale(steps: usize) -> usize {
    if std::env::var("GOSSIP_PGA_FAST").is_ok() {
        (steps / 4).max(10)
    } else {
        steps
    }
}

/// One experiment specification shared by the suites.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub algo: AlgorithmKind,
    pub topology: Topology,
    pub h: usize,
    pub steps: usize,
    pub seed: u64,
    pub non_iid: bool,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub log_every: usize,
    /// Cost model + emulated model size for the simulated clock.
    pub cost: CostModel,
    pub cost_dim: usize,
    pub aga_init: usize,
    pub aga_warmup: usize,
    /// Worker-pool size (1 = sequential; see `TrainerOptions::threads`).
    pub threads: usize,
    /// Double-buffered async gossip (maps to `Regime::Overlap`; see
    /// `TrainerOptions::regime`).
    pub overlap: bool,
    /// Communication plane (see `TrainerOptions::backend`).
    pub backend: BackendKind,
}

impl RunSpec {
    /// Defaults for the convex §5.1 experiments (Figs. 1/4-7).
    pub fn logreg(algo: AlgorithmKind, topology: Topology, h: usize, non_iid: bool, steps: usize) -> RunSpec {
        RunSpec {
            algo,
            topology,
            h,
            steps,
            seed: 42,
            non_iid,
            // Paper §5.1: gamma = 0.2, halved every 1000 iterations.
            lr: LrSchedule::StepDecay { lr: 0.2, every: 1000, factor: 0.5 },
            momentum: 0.0,
            log_every: 20,
            cost: CostModel::calibrated_resnet50(),
            cost_dim: 25_500_000,
            aga_init: 4,
            aga_warmup: 50,
            threads: 1,
            overlap: false,
            backend: BackendKind::Shared,
        }
    }

    /// Defaults for the image-classification substitute (Tables 7-10, 15-16).
    pub fn image(algo: AlgorithmKind, topology: Topology, h: usize, steps: usize) -> RunSpec {
        RunSpec {
            algo,
            topology,
            h,
            steps,
            seed: 42,
            non_iid: false,
            lr: LrSchedule::WarmupMilestones {
                lr: 0.2,
                warmup: steps / 20,
                milestones: vec![steps / 4, steps / 2, steps * 3 / 4],
                factor: 0.1,
            },
            momentum: 0.9,
            log_every: 10,
            cost: CostModel::calibrated_resnet50(),
            cost_dim: 25_500_000, // bill comms as ResNet-50
            aga_init: 4,
            aga_warmup: steps / 20,
            threads: 1,
            overlap: false,
            backend: BackendKind::Shared,
        }
    }

    /// Defaults for the LM substitute (Table 11 / Fig. 3).
    pub fn lm(algo: AlgorithmKind, topology: Topology, h: usize, steps: usize) -> RunSpec {
        RunSpec {
            algo,
            topology,
            h,
            steps,
            seed: 42,
            non_iid: false,
            lr: LrSchedule::WarmupPoly { lr: 0.5, warmup: steps / 20, total: steps, power: 1.0 },
            momentum: 0.9,
            log_every: 10,
            cost: CostModel::calibrated_bert(),
            cost_dim: 330_000_000, // bill comms as BERT-Large
            aga_init: 4,
            aga_warmup: steps / 20,
            threads: 1,
            overlap: false,
            backend: BackendKind::Shared,
        }
    }

    fn options(&self) -> TrainerOptions {
        TrainerOptions {
            algorithm: self.algo,
            topology: self.topology.clone(),
            period: self.h,
            aga_init_period: self.aga_init,
            aga_warmup: self.aga_warmup,
            lr: self.lr.clone(),
            momentum: self.momentum,
            nesterov: self.momentum > 0.0,
            seed: self.seed,
            slowmo: Default::default(),
            cost: self.cost,
            cost_dim: self.cost_dim,
            node_costs: None,
            log_every: self.log_every,
            threads: self.threads,
            stealing: false,
            pin: false,
            pipeline_depth: 1,
            regime: if self.overlap { Regime::Overlap } else { Regime::Bsp },
            max_staleness: 0,
            backend: self.backend,
            compression: Compression::None,
            round_timeout: 0.0,
            listen: "127.0.0.1:0".to_string(),
        }
    }

    pub fn label(&self) -> String {
        format!("{} (H={})", self.algo.display(), self.h)
    }
}

/// Run the §5.1 logistic-regression experiment; returns the loss history.
pub fn run_logreg(rt: Arc<Runtime>, spec: &RunSpec, samples_per_node: usize) -> Result<History> {
    let (workload, init) = logreg_workload(rt, spec.topology.n, samples_per_node, spec.non_iid, spec.seed)?;
    let mut trainer = Trainer::new(workload, init, spec.options())?;
    trainer.run(spec.steps, &spec.label())
}

/// Image-suite result row.
pub struct ImageResult {
    pub history: History,
    pub accuracy: f32,
    pub sim_hours: f64,
    pub final_period: usize,
}

/// Run the MLP classification suite; returns curve + eval accuracy + time.
pub fn run_image(rt: Arc<Runtime>, spec: &RunSpec, samples_per_node: usize) -> Result<ImageResult> {
    let (workload, init) = mlp_workload(rt, spec.topology.n, samples_per_node, spec.non_iid, spec.seed)?;
    let mut trainer = Trainer::new(workload, init, spec.options())?;
    let history = trainer.run(spec.steps, &spec.label())?;
    let accuracy = mlp_eval_accuracy(&trainer)?.unwrap_or(f32::NAN);
    Ok(ImageResult {
        accuracy,
        sim_hours: trainer.sim_seconds() / 3600.0,
        final_period: trainer.current_period(),
        history,
    })
}

/// LM-suite result row.
pub struct LmResult {
    pub history: History,
    pub eval_loss: f32,
    pub sim_hours: f64,
}

/// Run the transformer-LM suite on a config tag ("tiny" for benches).
pub fn run_lm(rt: Arc<Runtime>, spec: &RunSpec, tag: &str) -> Result<LmResult> {
    let (workload, init) = lm_workload(rt, tag, spec.seed)?;
    let mut trainer = Trainer::new(workload, init, spec.options())?;
    let history = trainer.run(spec.steps, &spec.label())?;
    let eval_loss = lm_eval_loss(&trainer, 4, spec.seed)?.unwrap_or(f32::NAN);
    Ok(LmResult { history, eval_loss, sim_hours: trainer.sim_seconds() / 3600.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_scale_fast_mode() {
        std::env::remove_var("GOSSIP_PGA_FAST");
        assert_eq!(step_scale(800), 800);
    }

    #[test]
    fn specs_build_options() {
        let s = RunSpec::logreg(AlgorithmKind::GossipPga, Topology::ring(8), 16, true, 100);
        let o = s.options();
        assert_eq!(o.period, 16);
        let s = RunSpec::image(AlgorithmKind::Parallel, Topology::one_peer_expo(8), 1, 200);
        assert!(s.momentum > 0.0);
        let s = RunSpec::lm(AlgorithmKind::GossipAga, Topology::one_peer_expo(8), 6, 200);
        assert_eq!(s.cost_dim, 330_000_000);
    }
}
