//! Bench harness substrate: timing, summary statistics and table printing.
//!
//! criterion is unavailable offline, so the `benches/` targets (one per
//! paper table/figure, `harness = false`) use this module: warmup +
//! repeated measurement, robust stats, and aligned/markdown table output
//! matching the paper's rows.

pub mod suite;

use std::time::Instant;

/// Summary statistics over a sample of measurements (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats { n, mean, std: var.sqrt(), min: xs[0], max: xs[n - 1], p50: pct(0.5), p95: pct(0.95) }
    }

    pub fn fmt_mean(&self) -> String {
        fmt_duration(self.mean)
    }
}

/// Human-friendly duration formatting.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else {
        format!("{:.2} h", secs / 3600.0)
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    Stats::from_samples(samples)
}

/// Aligned console table (the benches print paper-style rows with this).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowv(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as a markdown-ish aligned table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            out.push('|');
            for (c, width) in cells.iter().zip(w) {
                out.push_str(&format!(" {:<width$} |", c, width = width));
            }
            out.push('\n');
        };
        line(&self.headers, &w, &mut out);
        out.push('|');
        for width in &w {
            out.push_str(&format!("{:-<1$}|", "", width + 2));
        }
        out.push('\n');
        for row in &self.rows {
            line(row, &w, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float like the paper's tables (2 decimals, or sci for extremes).
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1e5 || x.abs() < 1e-3 {
        format!("{x:.2e}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_known_values() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn measure_runs_and_counts() {
        let mut count = 0;
        let s = measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Acc.%"]);
        t.row(&["Parallel SGD".into(), "76.26".into()]);
        t.row(&["Gossip-PGA".into(), "76.28".into()]);
        let r = t.render();
        assert!(r.contains("| Method"));
        assert!(r.lines().count() == 4);
        // All lines same length (alignment).
        let lens: Vec<usize> = r.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn fmt_duration_bands() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-5).ends_with("µs"));
        assert!(fmt_duration(5e-2).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with(" s"));
        assert!(fmt_duration(7200.0).ends_with(" h"));
    }
}
