//! PRNG substrate: splitmix64 + xoshiro256** and sampling helpers.
//!
//! Offline builds have no `rand` crate, so the data generators, property
//! tests and schedulers use this module. Determinism is a feature: every
//! experiment is replayable from a single `u64` seed (the coordinator
//! derives per-worker streams with [`Rng::split`]).

/// splitmix64 step — used for seeding and stream splitting.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 (the reference seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Snapshot the 256-bit generator state (checkpointing).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the stream
    /// continues exactly where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Derive an independent stream (worker i gets `root.split(i)`).
    pub fn split(&self, idx: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[3] ^ idx.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method (unbiased).
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (no cached spare: keeps state simple).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// Bernoulli(+1 with prob p, else -1) — the paper's label scheme.
    pub fn sign_label(&mut self, p: f64) -> f32 {
        if self.f64() <= p {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n), order randomized.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(11);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var {m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct_unique() {
        let mut r = Rng::new(5);
        let picks = r.choose_distinct(20, 8);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn sign_label_extremes() {
        let mut r = Rng::new(6);
        assert_eq!(r.sign_label(1.1), 1.0); // p >= 1 always +1
        assert_eq!(r.sign_label(-0.1), -1.0); // p <= 0 always -1
    }
}
