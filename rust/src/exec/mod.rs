//! The persistent execution engine: a long-lived [`WorkerPool`] behind
//! every parallel phase of the training loop.
//!
//! PR 1 sharded phases 1-2 and the gossip mix across `std::thread::scope`
//! threads spawned *per step*; at small d the spawn/join cost dominates the
//! actual row work (see `benches/perf_hotpath.rs`, "task dispatch" rows).
//! This module replaces that with `threads` parked OS threads created once
//! per [`crate::coordinator::Trainer`]: each step broadcasts a batch of
//! jobs onto a shared queue, the workers drain it, and the caller collects
//! the per-job outcomes in index order.
//!
//! §Determinism contract. The pool adds NO nondeterminism:
//!
//! * every job owns a disjoint slice of the output (rows of the
//!   [`crate::params::ParamMatrix`], column ranges of a mean, per-node
//!   eval slots), so execution order across jobs cannot matter;
//! * every reduction a job performs fixes its accumulation order (rows
//!   ascending, columns ascending) — the same additions in the same order
//!   as the sequential loop;
//! * job *results* are collected and reported in job-index order, so even
//!   error selection is deterministic.
//!
//! Together these make pooled, scoped and sequential execution bit-identical
//! (asserted by `rust/tests/properties.rs`).
//!
//! §Sharding policy. [`WorkerPool::shards`] is the ONE policy for how many
//! ways a parallel region splits, never 0. In static mode (the default)
//! it is `min(pool size, work items)`: one chunk per thread, perfectly
//! balanced when every item costs the same. PR 1 had two policies (phases
//! capped at n workers, the mix left uncapped) — every call site now asks
//! the pool.
//!
//! §Work stealing ([`WorkerPool::new_stealing`]). With heterogeneous
//! per-item costs (simulated stragglers, uneven rows) one-chunk-per-thread
//! pins the batch's wall time to the unluckiest thread. Stealing mode
//! splits the same region `min(size * STEAL_GRAIN, items)` ways instead:
//! the chunks land on the shared queue and whichever thread finishes early
//! pulls the next one — dynamic balancing through the exact queue the pool
//! already has, no second scheduler. Determinism is untouched, because the
//! chunk boundaries never change any item's arithmetic: every item owns a
//! disjoint output slice, every in-chunk loop runs items in ascending
//! index order, and every cross-item reduction happens OUTSIDE the pool in
//! fixed ascending order (per-node slots, per-column accumulators). So a
//! stealing pool is bit-identical to static sharding — and to sequential —
//! at any pool size and any steal interleaving (asserted by
//! `rust/tests/properties.rs` and `rust/tests/virtual_time.rs`).
//!
//! §Failure. A job that returns `Err` fails its batch cleanly (first error
//! in index order wins). A job that PANICS poisons the pool: the panic is
//! caught on the worker thread, the batch reports `Err`, and every later
//! submission is refused with `Err` immediately — the trainer surfaces a
//! broken step as a `Result`, never as a hang or an abort
//! (`rust/tests/exec_pool.rs` proves this under a watchdog timeout).
//!
//! §Async. [`WorkerPool::submit`] enqueues `'static` jobs without blocking
//! and returns a [`Ticket`]; this is what double-buffered overlap mode
//! rides on (the round-t gossip mix runs here while the main thread starts
//! round t+1). Dropping a `Ticket` BLOCKS until its jobs finish — in-flight
//! jobs hold raw views of the parameter buffers, so the ticket is the
//! lifetime anchor that makes early teardown sound. Chained submissions
//! (the depth-k gossip pipeline) gate on a [`Latch`] instead of a ticket:
//! jobs of round t+1 wait for round t's latch before reading its output.
//! This cannot deadlock because the queue is strictly FIFO — a worker can
//! only be blocked on a round whose jobs were all dequeued earlier, so
//! they are running or done on other workers, and by induction the oldest
//! unfinished round waits on nothing.
//!
//! §Pinning ([`WorkerPool::with_options`], `--pin`). The workers are
//! long-lived (that was the whole point of PR 2), so pinning them finally
//! sticks: worker i is pinned to core `i % available_parallelism`, which
//! keeps its ParamMatrix row shard on the same core's cache across rounds
//! (the static sharding policy hands thread i the same row range every
//! round). Affinity is best-effort: where the syscall is unavailable or
//! refused (non-Linux, restrictive cgroups) the pool warns ONCE on stderr
//! and runs unpinned — never an error, and never a behavior change
//! (pinning moves threads, not arithmetic; bits are identical either way).
//! A size-1 pool has no worker threads, so pinning is a no-op there.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

/// A boxed job with caller-chosen lifetime (see [`WorkerPool::run`] for the
/// lifetime-erasure contract).
type Job<'a> = Box<dyn FnOnce() -> Result<()> + Send + 'a>;

/// Internal queue entry: the job already wrapped with panic capture and the
/// result send.
type QueuedTask = Box<dyn FnOnce() + Send + 'static>;

/// Per-job outcome shipped back to the submitting thread. The error is a
/// rendered string (panic payloads and `anyhow` chains are not `Clone`).
type Outcome = (usize, Result<(), String>);

struct Queue {
    tasks: VecDeque<QueuedTask>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    available: Condvar,
    /// Set when any job panics; checked (and refused) on every submission.
    poisoned: AtomicBool,
    /// How many jobs have panicked (normally 0 — the first one poisons
    /// the pool; surfaced through [`WorkerPool::panic_count`] into the
    /// `pool_panics` counter).
    panics: AtomicU64,
}

/// A fixed-size pool of parked worker threads (see module docs).
///
/// Size 1 is the sequential mode: no threads are spawned and every job runs
/// inline on the calling thread, so `--threads 1` keeps the zero-overhead
/// hot path it had before the pool existed (results are bit-identical
/// either way).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
    /// Chunks per thread the sharding policy hands out: 1 = static
    /// sharding, [`STEAL_GRAIN`] = work-stealing dynamic chunking.
    grain: usize,
    /// Whether core affinity was requested for the worker threads.
    pin: bool,
}

/// Chunks per thread in stealing mode: fine enough that a 4x-slow item
/// chain rebalances within a batch, coarse enough that queue dispatch
/// stays amortized over real row work.
pub const STEAL_GRAIN: usize = 4;

impl WorkerPool {
    /// Spawn a pool of `threads` workers (clamped to >= 1; size 1 spawns
    /// nothing and runs jobs inline). Static sharding: `shards` hands out
    /// one chunk per thread.
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool::with_grain(threads, 1, false)
    }

    /// Spawn a work-stealing pool: same threads, but `shards` splits every
    /// region [`STEAL_GRAIN`] ways per thread so idle threads pull extra
    /// chunks from the shared queue (see module docs §Work stealing).
    /// Bit-identical results to [`WorkerPool::new`] by construction.
    pub fn new_stealing(threads: usize) -> WorkerPool {
        WorkerPool::with_grain(threads, STEAL_GRAIN, false)
    }

    /// The full-knob constructor the trainer uses: `stealing` picks the
    /// sharding grain, `pin` requests core affinity for the worker threads
    /// (see module docs §Pinning; best-effort, warns once and runs
    /// unpinned where affinity is unavailable).
    pub fn with_options(threads: usize, stealing: bool, pin: bool) -> WorkerPool {
        WorkerPool::with_grain(threads, if stealing { STEAL_GRAIN } else { 1 }, pin)
    }

    fn with_grain(threads: usize, grain: usize, pin: bool) -> WorkerPool {
        let size = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { tasks: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
            poisoned: AtomicBool::new(false),
            panics: AtomicU64::new(0),
        });
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let handles = if size >= 2 {
            (0..size)
                .map(|i| {
                    let shared = shared.clone();
                    std::thread::Builder::new()
                        .name(format!("gpga-pool-{i}"))
                        .spawn(move || {
                            if pin {
                                pin_current_thread(i % cores);
                            }
                            worker_loop(&shared)
                        })
                        .expect("spawning pool worker")
                })
                .collect()
        } else {
            Vec::new()
        };
        WorkerPool { shared, handles, size, grain: grain.max(1), pin }
    }

    /// Worker-thread count (>= 1).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Whether the sharding policy over-splits for dynamic balancing.
    pub fn stealing(&self) -> bool {
        self.grain > 1
    }

    /// Whether core affinity was REQUESTED for the workers (best-effort:
    /// the request may have fallen back to unpinned with a warning).
    pub fn pinned(&self) -> bool {
        self.pin
    }

    /// How many jobs have panicked on this pool (normally 0; the first
    /// panic poisons the pool, so a finished run reporting > 0 means a
    /// fallback path absorbed it).
    pub fn panic_count(&self) -> u64 {
        self.shared.panics.load(Ordering::Relaxed)
    }

    /// THE sharding policy: how many ways to split `items` units of work.
    /// `min(size * grain, items)` and never 0 — phases cap at n workers, a
    /// column mean caps at d columns, and every call site agrees (the PR-1
    /// split between capped phases and an uncapped mix is gone). Static
    /// pools have grain 1; stealing pools over-split so the queue
    /// rebalances uneven chunks onto idle threads.
    pub fn shards(&self, items: usize) -> usize {
        (self.size * self.grain).min(items).max(1)
    }

    /// Companion to [`WorkerPool::shards`]: the ceiling chunk length that
    /// splits `items` into at most `shards(items)` contiguous chunks —
    /// the `chunks(_mut)` argument every sharded phase passes (the event
    /// regime's ready-batch dispatch included).
    pub fn chunk_len(&self, items: usize) -> usize {
        let t = self.shards(items);
        ((items + t - 1) / t).max(1)
    }

    /// True once any job has panicked; the pool refuses further work.
    pub fn poisoned(&self) -> bool {
        self.shared.poisoned.load(Ordering::Acquire)
    }

    /// Run a batch of borrowing jobs to completion, blocking the caller.
    /// Outcomes are reported in job-index order: the first failing index
    /// decides the returned error, independent of execution interleaving.
    ///
    /// Jobs may borrow from the caller's stack (`&mut` chunks of a matrix,
    /// `&Workload`, ...): the borrows are erased to `'static` internally,
    /// which is sound because this method does not return until every job
    /// has finished (a panicked job still reports completion — it is caught
    /// on the worker thread, never unwound across the queue).
    pub fn run<'a, F>(&self, jobs: Vec<F>) -> Result<()>
    where
        F: FnOnce() -> Result<()> + Send + 'a,
    {
        let boxed: Vec<Job<'a>> = jobs.into_iter().map(|f| Box::new(f) as Job<'a>).collect();
        // SAFETY: the jobs (and therefore every borrow they capture) are
        // complete before this function returns — `Ticket::wait` below
        // receives one outcome per job, and a `Ticket` cannot outlive this
        // call. Erasing the lifetime never lets a borrow escape.
        let eternal: Vec<Job<'static>> =
            unsafe { std::mem::transmute::<Vec<Job<'a>>, Vec<Job<'static>>>(boxed) };
        self.submit_boxed(eternal)?.wait()
    }

    /// Enqueue `'static` jobs without blocking; the returned [`Ticket`]
    /// collects their outcomes. This is the overlap primitive: the caller
    /// keeps running while the pool works.
    pub fn submit<F>(&self, jobs: Vec<F>) -> Result<Ticket>
    where
        F: FnOnce() -> Result<()> + Send + 'static,
    {
        self.submit_boxed(jobs.into_iter().map(|f| Box::new(f) as Job<'static>).collect())
    }

    fn submit_boxed(&self, jobs: Vec<Job<'static>>) -> Result<Ticket> {
        if self.poisoned() {
            bail!("worker pool is poisoned by an earlier job panic");
        }
        let count = jobs.len();
        let (tx, rx) = channel::<Outcome>();
        if self.handles.is_empty() {
            // Sequential pool: run inline, with the same panic capture and
            // poisoning semantics as the threaded path.
            for (idx, job) in jobs.into_iter().enumerate() {
                execute(&self.shared, idx, job, &tx);
            }
            return Ok(Ticket { remaining: count, collected: Vec::with_capacity(count), rx });
        }
        {
            let mut q = self.shared.queue.lock().expect("pool queue lock");
            for (idx, job) in jobs.into_iter().enumerate() {
                let tx = tx.clone();
                let shared = self.shared.clone();
                q.tasks.push_back(Box::new(move || execute(&shared, idx, job, &tx)));
            }
        }
        self.shared.available.notify_all();
        Ok(Ticket { remaining: count, collected: Vec::with_capacity(count), rx })
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool queue lock");
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            // Workers drain the queue before honoring shutdown, so any
            // still-queued job (e.g. an unfinished async mix whose Ticket
            // was leaked) completes rather than vanishing.
            h.join().expect("pool worker thread");
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().expect("pool queue lock");
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break Some(t);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).expect("pool queue wait");
            }
        };
        match task {
            Some(t) => t(),
            None => return,
        }
    }
}

/// Run one job, converting a panic into a poisoned pool + an `Err` outcome.
/// Exactly one outcome is sent per job — the invariant that makes waiting
/// hang-free.
fn execute(shared: &Shared, idx: usize, job: Job<'static>, tx: &Sender<Outcome>) {
    let outcome = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
        Ok(Ok(())) => Ok(()),
        Ok(Err(e)) => Err(format!("{e:#}")),
        Err(payload) => {
            shared.panics.fetch_add(1, Ordering::Relaxed);
            shared.poisoned.store(true, Ordering::Release);
            Err(format!("job panicked: {}", panic_message(&payload)))
        }
    };
    // The receiver only disappears after all outcomes are drained (the
    // Ticket blocks in drop), so a send failure is benign teardown.
    let _ = tx.send((idx, outcome));
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Pin the calling thread to `core` (best-effort, see module docs
/// §Pinning). Uses `sched_setaffinity` straight from the system libc that
/// std already links — no crate dependency; the raw syscall is per-thread,
/// and pid 0 means "the calling thread".
#[cfg(target_os = "linux")]
fn pin_current_thread(core: usize) {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // 16 x u64 = 1024 CPUs, the size of glibc's default cpu_set_t.
    let mut mask = [0u64; 16];
    mask[(core / 64) % mask.len()] |= 1u64 << (core % 64);
    let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    if rc != 0 {
        warn_pin_unavailable();
    }
}

/// Non-Linux: affinity is not portable without a platform layer — warn
/// once and run unpinned.
#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_core: usize) {
    warn_pin_unavailable();
}

fn warn_pin_unavailable() {
    crate::warn_once!(
        "exec.pin-unavailable",
        "core pinning unavailable (affinity call failed or unsupported \
         platform); pool threads run unpinned"
    );
}

/// A countdown latch: `wait` blocks until `count` arrivals have happened.
/// This is the read gate of the depth-k gossip pipeline — round t+1's jobs
/// wait on round t's latch before reading its output slot. `arrive_on_drop`
/// returns a guard that arrives even if the holder panics, so a failed job
/// can never leave its successors blocked forever (they read a partial
/// slot, the pool reports the panic, and `finish_gossip` refuses to commit
/// the round).
pub struct Latch {
    count: Mutex<usize>,
    zero: Condvar,
}

impl Latch {
    pub fn new(count: usize) -> Latch {
        Latch { count: Mutex::new(count), zero: Condvar::new() }
    }

    /// Record one arrival (saturating — spurious extra arrivals are benign).
    pub fn arrive(&self) {
        let mut c = self.count.lock().expect("latch lock");
        *c = c.saturating_sub(1);
        if *c == 0 {
            self.zero.notify_all();
        }
    }

    /// Block until the count reaches zero.
    pub fn wait(&self) {
        let mut c = self.count.lock().expect("latch lock");
        while *c > 0 {
            c = self.zero.wait(c).expect("latch wait");
        }
    }

    /// An RAII arrival: the latch is arrived when the guard drops, panic
    /// or not.
    pub fn arrive_on_drop(&self) -> ArriveGuard<'_> {
        ArriveGuard(self)
    }
}

/// See [`Latch::arrive_on_drop`].
pub struct ArriveGuard<'a>(&'a Latch);

impl Drop for ArriveGuard<'_> {
    fn drop(&mut self) {
        self.0.arrive();
    }
}

/// Receipt for a batch of in-flight jobs ([`WorkerPool::submit`]).
///
/// `wait` consumes the ticket and reports the batch outcome (first failing
/// job in index order). Dropping a ticket without waiting still BLOCKS
/// until all jobs have finished: in-flight jobs may hold raw views of
/// caller-owned buffers (the double-buffered gossip mix does), so the
/// ticket going away must mean the jobs are done.
pub struct Ticket {
    remaining: usize,
    collected: Vec<Outcome>,
    rx: Receiver<Outcome>,
}

impl Ticket {
    fn collect_all(&mut self) {
        while self.remaining > 0 {
            match self.rx.recv() {
                Ok(outcome) => {
                    self.collected.push(outcome);
                    self.remaining -= 1;
                }
                // Senders live inside the queued jobs; disconnection before
                // all outcomes arrive means the pool was torn down
                // mid-batch. Record it and stop (wait() reports it).
                Err(_) => break,
            }
        }
    }

    /// Block until every job in the batch has finished; `Err` carries the
    /// first failure in job-index order.
    pub fn wait(mut self) -> Result<()> {
        self.collect_all();
        if self.remaining > 0 {
            bail!("worker pool shut down with {} job(s) unfinished", self.remaining);
        }
        self.collected.sort_by_key(|(idx, _)| *idx);
        for (idx, outcome) in std::mem::take(&mut self.collected) {
            if let Err(msg) = outcome {
                bail!("pool job {idx} failed: {msg}");
            }
        }
        Ok(())
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        self.collect_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    /// Run `f` on a watchdog thread; panic if it does not finish in time.
    /// Every poisoning/panic test runs under this so a regression shows up
    /// as a test FAILURE, never as a hung suite.
    fn with_timeout(secs: u64, f: impl FnOnce() + Send + 'static) {
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            f();
            tx.send(()).ok();
        });
        match rx.recv_timeout(Duration::from_secs(secs)) {
            Ok(()) => h.join().expect("watchdog body"),
            Err(_) => panic!("timed out after {secs}s — the pool hung"),
        }
    }

    #[test]
    fn run_executes_every_job_at_every_size() {
        for size in [1usize, 2, 3, 8] {
            let pool = WorkerPool::new(size);
            let counter = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..7)
                .map(|_| {
                    let counter = &counter;
                    move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                        Ok(())
                    }
                })
                .collect();
            pool.run(jobs).unwrap();
            assert_eq!(counter.load(Ordering::Relaxed), 7, "size {size}");
        }
    }

    #[test]
    fn run_jobs_borrow_disjoint_chunks() {
        // The trainer's exact pattern: jobs own disjoint &mut chunks of one
        // caller-stack buffer.
        let pool = WorkerPool::new(4);
        let mut data = vec![0usize; 10];
        let jobs: Vec<_> = data
            .chunks_mut(3)
            .enumerate()
            .map(|(ci, chunk)| {
                move || {
                    for v in chunk.iter_mut() {
                        *v = ci + 1;
                    }
                    Ok(())
                }
            })
            .collect();
        pool.run(jobs).unwrap();
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3, 3, 3, 4]);
    }

    #[test]
    fn shards_is_the_unified_policy() {
        let pool = WorkerPool::new(8);
        assert!(!pool.stealing());
        assert_eq!(pool.size(), 8);
        assert_eq!(pool.shards(3), 3, "caps at the work-item count");
        assert_eq!(pool.shards(100), 8, "caps at the pool size");
        assert_eq!(pool.shards(0), 1, "never zero");
        assert_eq!(WorkerPool::new(0).size(), 1, "size clamps to >= 1");
        assert_eq!(WorkerPool::new(1).shards(16), 1);
        // chunk_len is the matching ceiling split: chunks(per) yields at
        // most shards(items) chunks and covers every item.
        assert_eq!(pool.chunk_len(100), 13);
        assert_eq!(pool.chunk_len(3), 1);
        assert_eq!(pool.chunk_len(0), 1, "safe on empty work");
        assert_eq!(WorkerPool::new(1).chunk_len(16), 16);
    }

    #[test]
    fn stealing_pool_oversplits_behind_the_same_policy() {
        let pool = WorkerPool::new_stealing(2);
        assert!(pool.stealing());
        assert_eq!(pool.size(), 2, "same thread count, different chunking");
        assert_eq!(pool.shards(100), 2 * STEAL_GRAIN, "grain chunks per thread");
        assert_eq!(pool.shards(3), 3, "still caps at the work-item count");
        assert_eq!(pool.shards(0), 1, "never zero");
        // A sequential stealing pool still runs inline (no threads), just
        // in more chunks.
        let seq = WorkerPool::new_stealing(1);
        assert_eq!(seq.size(), 1);
        assert_eq!(seq.shards(16), STEAL_GRAIN);
    }

    #[test]
    fn stealing_chunks_produce_identical_output_to_static() {
        // The determinism contract: the same disjoint-output job pattern
        // the trainer uses, run under static and stealing chunking with an
        // artificially slow item, fills the buffer identically.
        let items = 23usize;
        let run_with = |pool: &WorkerPool| -> Vec<usize> {
            let mut data = vec![0usize; items];
            let t = pool.shards(items);
            let per = (items + t - 1) / t;
            let jobs: Vec<_> = data
                .chunks_mut(per)
                .enumerate()
                .map(|(ci, chunk)| {
                    move || {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            let i = ci * per + j;
                            if i == 5 {
                                // Straggler item: stealing should let other
                                // threads drain the rest meanwhile.
                                std::thread::sleep(Duration::from_millis(20));
                            }
                            *v = i * i + 1;
                        }
                        Ok(())
                    }
                })
                .collect();
            pool.run(jobs).unwrap();
            data
        };
        let expect: Vec<usize> = (0..items).map(|i| i * i + 1).collect();
        for pool in [
            WorkerPool::new(1),
            WorkerPool::new(4),
            WorkerPool::new_stealing(1),
            WorkerPool::new_stealing(4),
        ] {
            assert_eq!(run_with(&pool), expect, "size {} grain {}", pool.size(), pool.grain);
        }
    }

    #[test]
    fn first_error_in_index_order_wins() {
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..5)
            .map(|i| move || if i == 1 || i == 3 { bail!("job {i} says no") } else { Ok(()) })
            .collect();
        let err = pool.run(jobs).unwrap_err().to_string();
        assert!(err.contains("job 1"), "want the LOWEST failing index, got: {err}");
        assert!(!pool.poisoned(), "clean Err must not poison the pool");
    }

    #[test]
    fn panic_poisons_and_errs_without_hanging() {
        with_timeout(30, || {
            for size in [1usize, 2] {
                let pool = WorkerPool::new(size);
                let jobs: Vec<_> = (0..3)
                    .map(|i| {
                        move || -> Result<()> {
                            if i == 1 {
                                panic!("boom at job {i}");
                            }
                            Ok(())
                        }
                    })
                    .collect();
                let err = pool.run(jobs).unwrap_err().to_string();
                assert!(err.contains("panicked"), "size {size}: {err}");
                assert!(err.contains("boom"), "size {size}: panic payload lost: {err}");
                assert!(pool.poisoned(), "size {size}");
                // Poisoned pool refuses new work immediately (no hang).
                let refused = pool.run(vec![|| Ok(())]).unwrap_err().to_string();
                assert!(refused.contains("poisoned"), "size {size}: {refused}");
            }
        });
    }

    #[test]
    fn submit_runs_in_background_and_wait_collects() {
        let pool = WorkerPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..4)
            .map(|_| {
                let done = done.clone();
                move || {
                    done.fetch_add(1, Ordering::Relaxed);
                    Ok(())
                }
            })
            .collect();
        let ticket = pool.submit(jobs).unwrap();
        ticket.wait().unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn dropping_a_ticket_blocks_until_jobs_finish() {
        let pool = WorkerPool::new(2);
        let flag = Arc::new(AtomicBool::new(false));
        let f = flag.clone();
        let ticket = pool
            .submit(vec![move || {
                std::thread::sleep(Duration::from_millis(50));
                f.store(true, Ordering::Release);
                Ok(())
            }])
            .unwrap();
        drop(ticket);
        assert!(
            flag.load(Ordering::Acquire),
            "ticket drop returned before its job completed"
        );
    }

    #[test]
    fn pool_drop_finishes_queued_work() {
        with_timeout(30, || {
            let pool = WorkerPool::new(2);
            let done = Arc::new(AtomicUsize::new(0));
            let jobs: Vec<_> = (0..16)
                .map(|_| {
                    let done = done.clone();
                    move || {
                        std::thread::sleep(Duration::from_millis(2));
                        done.fetch_add(1, Ordering::Relaxed);
                        Ok(())
                    }
                })
                .collect();
            let ticket = pool.submit(jobs).unwrap();
            drop(pool); // workers drain the queue before exiting
            ticket.wait().unwrap();
            assert_eq!(done.load(Ordering::Relaxed), 16);
        });
    }

    #[test]
    fn pinned_pool_runs_jobs_identically() {
        // Pinning moves threads, never arithmetic: a pinned pool must run
        // the standard disjoint-chunk pattern to the same result (and not
        // error even where the affinity call fails — it warns and runs).
        for (stealing, pin) in [(false, true), (true, true), (false, false)] {
            let pool = WorkerPool::with_options(4, stealing, pin);
            assert_eq!(pool.pinned(), pin);
            assert_eq!(pool.stealing(), stealing);
            let mut data = vec![0usize; 13];
            let jobs: Vec<_> = data
                .chunks_mut(4)
                .enumerate()
                .map(|(ci, chunk)| {
                    move || {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = ci * 4 + j + 1;
                        }
                        Ok(())
                    }
                })
                .collect();
            pool.run(jobs).unwrap();
            let expect: Vec<usize> = (1..=13).collect();
            assert_eq!(data, expect, "stealing {stealing} pin {pin}");
        }
        // Size-1 pinned pool: no worker threads, pinning is a no-op.
        let seq = WorkerPool::with_options(1, false, true);
        assert!(seq.pinned());
        seq.run(vec![|| Ok(())]).unwrap();
    }

    #[test]
    fn latch_gates_until_all_arrivals() {
        with_timeout(30, || {
            let latch = Arc::new(Latch::new(2));
            let flag = Arc::new(AtomicBool::new(false));
            let (l, f) = (latch.clone(), flag.clone());
            let waiter = std::thread::spawn(move || {
                l.wait();
                f.store(true, Ordering::Release);
            });
            latch.arrive();
            std::thread::sleep(Duration::from_millis(20));
            assert!(!flag.load(Ordering::Acquire), "one arrival must not release");
            latch.arrive();
            waiter.join().unwrap();
            assert!(flag.load(Ordering::Acquire));
            latch.wait(); // at zero, wait returns immediately
            latch.arrive(); // saturating: arriving past zero is benign
            latch.wait();
        });
    }

    #[test]
    fn latch_arrive_on_drop_fires_on_panic() {
        with_timeout(30, || {
            let latch = Latch::new(1);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _guard = latch.arrive_on_drop();
                panic!("job died");
            }));
            assert!(r.is_err());
            latch.wait(); // must not hang: the guard arrived during unwind
        });
    }

    #[test]
    fn chained_submissions_gated_by_latches_make_progress() {
        // The pipeline shape: batch 2's jobs wait on batch 1's latch. FIFO
        // dequeue means this can never deadlock, at any pool size.
        with_timeout(30, || {
            for size in [1usize, 2, 4] {
                let pool = WorkerPool::new(size);
                let order = Arc::new(Mutex::new(Vec::new()));
                let l1 = Arc::new(Latch::new(2));
                let first: Vec<_> = (0..2)
                    .map(|i| {
                        let l1 = l1.clone();
                        let order = order.clone();
                        move || {
                            let _g = l1.arrive_on_drop();
                            std::thread::sleep(Duration::from_millis(5));
                            order.lock().unwrap().push(("a", i));
                            Ok(())
                        }
                    })
                    .collect();
                let second: Vec<_> = (0..2)
                    .map(|i| {
                        let l1 = l1.clone();
                        let order = order.clone();
                        move || {
                            l1.wait();
                            order.lock().unwrap().push(("b", i));
                            Ok(())
                        }
                    })
                    .collect();
                let t1 = pool.submit(first).unwrap();
                let t2 = pool.submit(second).unwrap();
                t2.wait().unwrap();
                t1.wait().unwrap();
                let order = order.lock().unwrap();
                let first_b = order.iter().position(|(tag, _)| *tag == "b").unwrap();
                assert!(
                    order[..first_b].iter().filter(|(tag, _)| *tag == "a").count() == 2,
                    "size {size}: every gated job ran after the full first batch: {order:?}"
                );
            }
        });
    }
}
