//! The gossip-mixing engine: the L3 hot path.
//!
//! Applies one communication action to the contiguous [`ParamMatrix`] of
//! worker parameters, in place and without per-step allocation: the mixer
//! owns a same-shape scratch matrix, writes the next iterate into it, and
//! swaps storage with the input (an O(1) pointer exchange). The weighted-sum
//! inner loop is the rust counterpart of the Pallas `gossip_mix` kernel;
//! equality between the two is asserted by `rust/tests/integration_runtime.rs`.
//!
//! §Threads: every output row i depends only on *input* rows, so the row
//! loop shards freely across the persistent [`WorkerPool`] (disjoint
//! `chunks_mut(d)` views of the scratch). Each row's arithmetic is
//! identical in sequential and pooled runs — results are bit-equal by
//! construction, asserted by `rust/tests/properties.rs`.
//!
//! §Async: [`Mixer::gossip_async`] is the double-buffer mode — it enqueues
//! the same row jobs on the pool and returns a [`PendingMix`] immediately,
//! so the round-t mix runs while the trainer starts round t+1.
//! [`Mixer::finish_gossip`] waits, swaps the buffers and advances the
//! gossip clock; until then `params` holds the PRE-mix iterate and the
//! scratch is in flight (read-only `params`, writer-owned scratch — no
//! aliasing). The bits that come out are identical to the synchronous call.

use std::sync::Arc;

use anyhow::Result;

use crate::exec::{Ticket, WorkerPool};
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// Reusable mixing engine over `n` workers x `d` parameters.
pub struct Mixer {
    n: usize,
    d: usize,
    /// Scratch: the next-iterate matrix, storage-swapped with the input
    /// after each mix.
    scratch: ParamMatrix,
    /// Mean buffer for [`Mixer::global_average`].
    mean: Vec<f32>,
    /// Cached weight rows per round: rows[round][i] = Vec<(j, w)>.
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    rounds: usize,
    /// True while a [`Mixer::gossip_async`] job batch owns the scratch.
    in_flight: bool,
    /// Gossip rounds executed so far (advances the time-varying topology).
    /// Checkpointed: one-peer-expo must resume mid-period, not at round 0.
    pub gossip_clock: usize,
}

/// The per-round f32-quantized weight rows (`rows[round][i] = [(j, w)]`)
/// that EVERY mixing implementation consumes. One quantization site — the
/// shared mixer and the message-passing [`crate::comm::BusBackend`] both
/// build their row tables here, so cross-backend bit-equality is
/// structural rather than two copies that could drift.
pub fn weight_rows_f32(topo: &Topology) -> Vec<Vec<Vec<(usize, f32)>>> {
    (0..topo.rounds())
        .map(|r| {
            (0..topo.n)
                .map(|i| topo.weight_row(i, r).into_iter().map(|(j, w)| (j, w as f32)).collect())
                .collect()
        })
        .collect()
}

impl Mixer {
    pub fn new(topo: &Topology, d: usize) -> Mixer {
        let n = topo.n;
        let rounds = topo.rounds();
        let rows = weight_rows_f32(topo);
        Mixer {
            n,
            d,
            scratch: ParamMatrix::zeros(n, d),
            mean: vec![0.0; d],
            rows,
            rounds,
            in_flight: false,
            gossip_clock: 0,
        }
    }

    /// One gossip round: row(i) <- sum_j w_ij row(j), sharded across the
    /// pool. Advances the topology clock (matters for one-peer exponential
    /// graphs). `Err` (a failed or poisoned pool) leaves `params` untouched
    /// and the clock unadvanced — the round never happened.
    ///
    /// §Perf: rows of 2 or 3 neighbors (one-peer / ring — the common cases)
    /// are fused into a single output pass instead of init + (k-1) axpy
    /// passes: one write traversal of d instead of k, ~1.5x measured (see
    /// EXPERIMENTS.md §Perf).
    pub fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<()> {
        assert!(!self.in_flight, "gossip while an async mix is in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let round = self.gossip_clock % self.rounds;
        let weight_rows = &self.rows[round];
        let d = self.d;
        let src = params.as_slice();
        let t = pool.shards(self.n);
        if t <= 1 {
            for (i, out) in self.scratch.rows_mut().enumerate() {
                mix_row(&weight_rows[i], src, d, out);
            }
        } else {
            let per = (self.n + t - 1) / t;
            pool.run(
                self.scratch
                    .row_blocks_mut(per)
                    .enumerate()
                    .map(|(ci, chunk)| {
                        move || {
                            for (k, out) in chunk.chunks_mut(d).enumerate() {
                                mix_row(&weight_rows[ci * per + k], src, d, out);
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(&mut self.scratch);
        self.gossip_clock += 1;
        Ok(())
    }

    /// Begin one gossip round WITHOUT waiting for it: the row jobs are
    /// enqueued on `pool` and run in the background while the caller keeps
    /// going (double-buffered overlap mode).
    ///
    /// On a size-1 pool the jobs run inline, so overlap mode degenerates to
    /// the synchronous schedule with identical bits.
    ///
    /// # Safety
    ///
    /// The jobs capture raw addresses of `params`' and this mixer's heap
    /// buffers, so until [`Mixer::finish_gossip`] returns (or the
    /// [`PendingMix`] is dropped, which blocks until the jobs end) the
    /// caller must ensure that:
    ///
    /// * `params` is not mutated, moved-from, reallocated or dropped
    ///   (shared reads are fine — the jobs only read it);
    /// * this mixer is not dropped (its scratch is the jobs' write target;
    ///   the `in_flight` guard already panics on re-entrant mixing);
    /// * the `PendingMix` is not leaked (`std::mem::forget` would let the
    ///   jobs outlive both buffers).
    ///
    /// [`crate::coordinator::Trainer`] upholds this by draining before any
    /// `&mut` access and by dropping its pending mix before the matrices.
    pub unsafe fn gossip_async(
        &mut self,
        params: &ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<PendingMix> {
        assert!(!self.in_flight, "gossip_async while an async mix is already in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let round = self.gossip_clock % self.rounds;
        // Clone this round's weight rows into shared ownership: tiny (a few
        // (j, w) pairs per node) next to the O(n d) row work, and it keeps
        // the jobs free of references into the mixer.
        let weights: Arc<Vec<Vec<(usize, f32)>>> = Arc::new(self.rows[round].clone());
        let (n, d) = (self.n, self.d);
        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        // The jobs outlive this call, so they carry raw addresses instead
        // of borrows. Soundness contract (upheld by Trainer + in_flight):
        //   * src (the live params data) is only READ, by jobs and by any
        //     concurrent main-thread accessor — no &mut exists until
        //     finish_gossip, which first waits for the jobs;
        //   * each job writes a disjoint row range of the scratch, which
        //     nothing else touches while in_flight;
        //   * both heap buffers outlive the batch: PendingMix's Ticket
        //     blocks on drop, and Trainer drops its pending mix before the
        //     matrices.
        let src_addr = params.as_slice().as_ptr() as usize;
        let dst_addr = self.scratch.as_mut_slice().as_mut_ptr() as usize;
        let jobs: Vec<_> = (0..t)
            .map(|ci| {
                let weights = weights.clone();
                move || -> Result<()> {
                    let lo = ci * per;
                    let hi = ((ci + 1) * per).min(n);
                    let src =
                        unsafe { std::slice::from_raw_parts(src_addr as *const f32, n * d) };
                    for i in lo..hi {
                        let out = unsafe {
                            std::slice::from_raw_parts_mut((dst_addr as *mut f32).add(i * d), d)
                        };
                        mix_row(&weights[i], src, d, out);
                    }
                    Ok(())
                }
            })
            .collect();
        let ticket = pool.submit(jobs)?;
        self.in_flight = true;
        Ok(PendingMix { ticket, scratch_addr: dst_addr })
    }

    /// Complete an async gossip round: wait for the row jobs, swap the
    /// mixed buffer in, advance the gossip clock. After this returns the
    /// state is bit-identical to a synchronous [`Mixer::gossip`] call.
    /// Panics if nothing is in flight on THIS mixer or the `PendingMix`
    /// came from a different mixer (swapping a foreign ticket's scratch
    /// while this mixer's own jobs still write it would be a data race).
    pub fn finish_gossip(&mut self, params: &mut ParamMatrix, pending: PendingMix) -> Result<()> {
        assert!(self.in_flight, "finish_gossip without a mix in flight");
        assert!(
            pending.scratch_addr == self.scratch.as_slice().as_ptr() as usize,
            "finish_gossip got a PendingMix from a different mixer"
        );
        let outcome = pending.ticket.wait();
        // Clear the flag even on failure so the mixer is not wedged; on
        // Err the scratch is partial and must NOT be swapped in.
        self.in_flight = false;
        outcome?;
        params.swap_data(&mut self.scratch);
        self.gossip_clock += 1;
        Ok(())
    }

    /// One gossip round where each node's *transmitted* vector is
    /// transformed by `transmit(j, x_j)` (e.g. compressed, see
    /// [`crate::compress`]); the self term always uses the local copy.
    /// `row(i) <- w_ii x_i + sum_{j != i} w_ij transmit(j, x_j)`.
    ///
    /// The transmit pass is inherently sequential — `transmit` is `FnMut`
    /// (codecs carry error-feedback state), ordered by node index. The mix
    /// pass over the materialized messages shards across `pool` like the
    /// plain gossip path (bit-identical at any pool size).
    pub fn gossip_with<F>(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
        mut transmit: F,
    ) -> Result<()>
    where
        F: FnMut(usize, &[f32]) -> Vec<f32>,
    {
        assert!(!self.in_flight, "gossip_with while an async mix is in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let round = self.gossip_clock % self.rounds;
        // Which nodes are actually listened to this round?
        let mut needed = vec![false; self.n];
        for i in 0..self.n {
            for &(j, _) in &self.rows[round][i] {
                if j != i {
                    needed[j] = true;
                }
            }
        }
        let tx: Vec<Option<Vec<f32>>> = (0..self.n)
            .map(|j| needed[j].then(|| transmit(j, params.row(j))))
            .collect();
        // Same fused kernel as the plain gossip path (and as the bus
        // backend's receive-side mix), so identity-compressed rounds are
        // bit-identical to uncompressed ones across every backend.
        let d = self.d;
        let rows = &self.rows[round];
        let src = params.as_slice();
        let tx = &tx;
        let t = pool.shards(self.n);
        if t <= 1 {
            for (i, out) in self.scratch.rows_mut().enumerate() {
                mix_row_with(&rows[i], i, src, d, tx, out);
            }
        } else {
            let per = (self.n + t - 1) / t;
            pool.run(
                self.scratch
                    .row_blocks_mut(per)
                    .enumerate()
                    .map(|(ci, chunk)| {
                        move || {
                            for (k, out) in chunk.chunks_mut(d).enumerate() {
                                let i = ci * per + k;
                                mix_row_with(&rows[i], i, src, d, tx, out);
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(&mut self.scratch);
        self.gossip_clock += 1;
        Ok(())
    }

    /// Exact global average (the All-Reduce step): every worker gets the
    /// ensemble mean. The mean shards by column ranges and the broadcast by
    /// rows — both through [`WorkerPool::shards`]; per-column accumulation
    /// order (rows ascending) is fixed, so all pool sizes agree bitwise.
    /// `Err` (a failed or poisoned pool) may leave `params` partially
    /// broadcast — callers must treat the trainer as failed, exactly as
    /// [`crate::coordinator::Trainer::step_once`] does by propagating it.
    pub fn global_average(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<()> {
        assert!(!self.in_flight, "global_average while an async mix is in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let n = self.n;
        let d = self.d;
        let inv = 1.0 / n as f32;
        let t = pool.shards(d);
        let src = params.as_slice();
        if t <= 1 || d < 2 {
            self.mean.copy_from_slice(&src[..d]);
            for r in 1..n {
                for (m, v) in self.mean.iter_mut().zip(&src[r * d..(r + 1) * d]) {
                    *m += v;
                }
            }
            for m in self.mean.iter_mut() {
                *m *= inv;
            }
        } else {
            let per = (d + t - 1) / t;
            let mean = self.mean.as_mut_slice();
            pool.run(
                mean.chunks_mut(per)
                    .enumerate()
                    .map(|(ci, mchunk)| {
                        move || {
                            let off = ci * per;
                            let len = mchunk.len();
                            mchunk.copy_from_slice(&src[off..off + len]);
                            for r in 1..n {
                                let row = &src[r * d + off..r * d + off + len];
                                for (m, v) in mchunk.iter_mut().zip(row) {
                                    *m += v;
                                }
                            }
                            for m in mchunk.iter_mut() {
                                *m *= inv;
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        let mean = &self.mean;
        let rt = pool.shards(n);
        if rt <= 1 {
            for row in params.rows_mut() {
                row.copy_from_slice(mean);
            }
        } else {
            let per = (n + rt - 1) / rt;
            pool.run(
                params
                    .row_blocks_mut(per)
                    .map(|chunk| {
                        move || {
                            for row in chunk.chunks_mut(d) {
                                row.copy_from_slice(mean);
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        Ok(())
    }

    pub fn d(&self) -> usize {
        self.d
    }
}

/// An in-flight [`Mixer::gossip_async`] round. Hand it back to
/// [`Mixer::finish_gossip`] of the SAME mixer to complete the round;
/// dropping it instead blocks until the row jobs finish and DISCARDS the
/// result (the gossip clock does not advance — the round never happened).
pub struct PendingMix {
    ticket: Ticket,
    /// Identity of the scratch buffer the jobs write — pairing check so a
    /// foreign mixer cannot finish someone else's round.
    scratch_addr: usize,
}

/// One output row over the flat n x d source: out = sum_j w_ij *
/// src[j*d..][..d], with the 2/3-neighbor fast paths fused into a single
/// pass. Operating on the flat slice (not `&ParamMatrix`) lets the async
/// jobs and the scoped jobs share one kernel.
fn mix_row(row: &[(usize, f32)], src: &[f32], d: usize, out: &mut [f32]) {
    mix_row_src(row, |j| &src[j * d..(j + 1) * d], out)
}

/// One transmit-transformed output row (the `gossip_with` kernel): self
/// term from the live matrix, every other term from the materialized
/// message table. Free function so the pooled jobs can call it without
/// borrowing the mixer.
fn mix_row_with(
    row: &[(usize, f32)],
    i: usize,
    src: &[f32],
    d: usize,
    tx: &[Option<Vec<f32>>],
    out: &mut [f32],
) {
    mix_row_src(
        row,
        |j| {
            if j == i {
                &src[i * d..(i + 1) * d]
            } else {
                tx[j].as_deref().expect("transmitted above")
            }
        },
        out,
    )
}

/// The weighted-row kernel over an arbitrary source lookup: out = sum_j
/// w_ij * src_of(j), with the 2/3-neighbor fast paths fused into a single
/// pass. This is THE mixing arithmetic — the in-place mixer, the
/// compressed transmit path and the message-passing
/// [`crate::comm::BusBackend`] all call it, which is what makes backends
/// bit-identical: same terms, same order, same rounding.
pub fn mix_row_src<'s>(
    row: &[(usize, f32)],
    srow: impl Fn(usize) -> &'s [f32],
    out: &mut [f32],
) {
    match row.len() {
        0 => out.fill(0.0),
        1 => {
            let (j0, w0) = row[0];
            if w0 == 1.0 {
                out.copy_from_slice(srow(j0));
            } else {
                for (o, x) in out.iter_mut().zip(srow(j0)) {
                    *o = w0 * x;
                }
            }
        }
        2 => {
            let (j0, w0) = row[0];
            let (j1, w1) = row[1];
            fused2(w0, srow(j0), w1, srow(j1), out);
        }
        3 => {
            let (j0, w0) = row[0];
            let (j1, w1) = row[1];
            let (j2, w2) = row[2];
            fused3(w0, srow(j0), w1, srow(j1), w2, srow(j2), out);
        }
        _ => {
            // General case: init with the first source, accumulate.
            let (j0, w0) = row[0];
            for (o, s) in out.iter_mut().zip(srow(j0)) {
                *o = w0 * s;
            }
            for &(j, w) in &row[1..] {
                axpy(w, srow(j), out);
            }
        }
    }
}

/// out = w0*a + w1*b in a single pass.
#[inline]
pub fn fused2(w0: f32, a: &[f32], w1: f32, b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == out.len() && b.len() == out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = w0 * x + w1 * y;
    }
}

/// out = w0*a + w1*b + w2*c in a single pass (ring row).
#[inline]
pub fn fused3(w0: f32, a: &[f32], w1: f32, b: &[f32], w2: f32, c: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == out.len() && b.len() == out.len() && c.len() == out.len());
    for (((o, x), y), z) in out.iter_mut().zip(a).zip(b).zip(c) {
        *o = w0 * x + w1 * y + w2 * z;
    }
}

/// out += a * x, 8-wide unrolled (the hot inner loop; see EXPERIMENTS.md
/// §Perf for the measured effect vs. the naive zip loop).
#[inline]
pub fn axpy(a: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let chunks = x.len() / 8;
    let (xh, xt) = x.split_at(chunks * 8);
    let (oh, ot) = out.split_at_mut(chunks * 8);
    for (xc, oc) in xh.chunks_exact(8).zip(oh.chunks_exact_mut(8)) {
        oc[0] += a * xc[0];
        oc[1] += a * xc[1];
        oc[2] += a * xc[2];
        oc[3] += a * xc[3];
        oc[4] += a * xc[4];
        oc[5] += a * xc[5];
        oc[6] += a * xc[6];
        oc[7] += a * xc[7];
    }
    for (o, v) in ot.iter_mut().zip(xt) {
        *o += a * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::consensus_distance;
    use crate::rng::Rng;

    fn random_params(n: usize, d: usize, seed: u64) -> ParamMatrix {
        ParamMatrix::random(&mut Rng::new(seed), n, d, 1.0)
    }

    fn seq() -> WorkerPool {
        WorkerPool::new(1)
    }

    #[test]
    fn axpy_matches_naive() {
        let mut rng = Rng::new(1);
        for len in [0, 1, 7, 8, 9, 100] {
            let x = rng.normal_vec(len, 1.0);
            let mut out = rng.normal_vec(len, 1.0);
            let mut expect = out.clone();
            for (e, v) in expect.iter_mut().zip(&x) {
                *e += 0.3 * v;
            }
            axpy(0.3, &x, &mut out);
            assert_eq!(out, expect, "len {len}");
        }
    }

    #[test]
    fn gossip_matches_matrix_multiply() {
        let topo = Topology::ring(6);
        let w = topo.weight_matrix(0);
        let mut params = random_params(6, 4, 2);
        let expect: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                (0..4)
                    .map(|c| {
                        (0..6).map(|j| w[(i, j)] as f32 * params.row(j)[c]).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        let mut mixer = Mixer::new(&topo, 4);
        mixer.gossip(&mut params, &seq()).unwrap();
        for (p, e) in params.rows().zip(&expect) {
            for (a, b) in p.iter().zip(e) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gossip_preserves_mean() {
        let topo = Topology::grid(9);
        let mut params = random_params(9, 16, 3);
        let mean_before = params.mean_row();
        let mut mixer = Mixer::new(&topo, 16);
        for _ in 0..5 {
            mixer.gossip(&mut params, &seq()).unwrap();
        }
        for (after, before) in params.mean_row().iter().zip(&mean_before) {
            assert!((after - before).abs() < 1e-4);
        }
    }

    #[test]
    fn gossip_contracts_consensus() {
        let topo = Topology::ring(10);
        let mut params = random_params(10, 8, 4);
        let before = consensus_distance(&params);
        let mut mixer = Mixer::new(&topo, 8);
        mixer.gossip(&mut params, &seq()).unwrap();
        let after = consensus_distance(&params);
        assert!(after < before, "{after} !< {before}");
        // And beta^2 bounds the per-step contraction in expectation-ish:
        // one deterministic step must satisfy after <= beta^2 * before.
        let beta = topo.beta();
        assert!(after <= beta * beta * before * 1.01, "{after} vs {}", beta * beta * before);
    }

    #[test]
    fn pooled_gossip_is_bit_identical_to_sequential() {
        let pool = WorkerPool::new(4);
        for topo in [Topology::ring(10), Topology::one_peer_expo(8), Topology::grid(9)] {
            let n = topo.n;
            let mut a = random_params(n, 33, 5);
            let mut b = a.clone();
            let mut m1 = Mixer::new(&topo, 33);
            let mut m2 = Mixer::new(&topo, 33);
            for _ in 0..topo.rounds() + 2 {
                m1.gossip(&mut a, &seq()).unwrap();
                m2.gossip(&mut b, &pool).unwrap();
                assert_eq!(a, b, "{:?}", topo.kind);
            }
            m1.global_average(&mut a, &seq()).unwrap();
            m2.global_average(&mut b, &pool).unwrap();
            assert_eq!(a, b, "{:?} global average", topo.kind);
        }
    }

    #[test]
    fn async_gossip_matches_sync_bitwise() {
        let pool = WorkerPool::new(4);
        for topo in [Topology::ring(10), Topology::one_peer_expo(8), Topology::grid(9)] {
            let n = topo.n;
            let mut sync = random_params(n, 29, 11);
            let mut asy = sync.clone();
            let mut m1 = Mixer::new(&topo, 29);
            let mut m2 = Mixer::new(&topo, 29);
            for round in 0..topo.rounds() + 2 {
                m1.gossip(&mut sync, &pool).unwrap();
                // SAFETY: asy and m2 outlive the round; finish_gossip runs
                // before the next access.
                let pending = unsafe { m2.gossip_async(&asy, &pool) }.unwrap();
                m2.finish_gossip(&mut asy, pending).unwrap();
                assert_eq!(sync, asy, "{:?} round {round}", topo.kind);
                assert_eq!(m1.gossip_clock, m2.gossip_clock);
            }
        }
    }

    #[test]
    fn async_gossip_runs_inline_on_sequential_pool() {
        let topo = Topology::ring(5);
        let mut a = random_params(5, 9, 13);
        let mut b = a.clone();
        Mixer::new(&topo, 9).gossip(&mut a, &seq()).unwrap();
        let mut m = Mixer::new(&topo, 9);
        // SAFETY: b and m outlive the round; finish_gossip runs next.
        let pending = unsafe { m.gossip_async(&b, &seq()) }.unwrap();
        m.finish_gossip(&mut b, pending).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dropped_pending_mix_discards_the_round() {
        let topo = Topology::ring(4);
        let params = random_params(4, 6, 14);
        let before = params.clone();
        let mut m = Mixer::new(&topo, 6);
        let pool = WorkerPool::new(2);
        {
            // SAFETY: params and m outlive this block; the drop at the end
            // of the block waits for the jobs.
            let _pending = unsafe { m.gossip_async(&params, &pool) }.unwrap();
            // dropped without finish_gossip: blocks until the jobs end,
            // then the round is discarded
        }
        assert_eq!(params, before, "params must be untouched");
        assert_eq!(m.gossip_clock, 0, "an unfinished round must not advance the clock");
        // The mixer stays wedged on purpose until told otherwise? No — the
        // ticket is gone, but in_flight still guards the scratch. A fresh
        // round must go through finish_gossip, so this is a programming
        // error; assert the guard trips.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.gossip(&mut params.clone(), &pool)
        }));
        assert!(r.is_err(), "reusing a mixer after dropping its pending mix must assert");
    }

    #[test]
    fn pooled_gossip_handles_more_threads_than_rows() {
        let topo = Topology::ring(3);
        let mut a = random_params(3, 7, 12);
        let mut b = a.clone();
        Mixer::new(&topo, 7).gossip(&mut a, &WorkerPool::new(64)).unwrap();
        Mixer::new(&topo, 7).gossip(&mut b, &seq()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn global_average_zeroes_consensus() {
        let topo = Topology::ring(7);
        let mut params = random_params(7, 8, 5);
        let mut mixer = Mixer::new(&topo, 8);
        mixer.global_average(&mut params, &seq()).unwrap();
        assert!(consensus_distance(&params) < 1e-10);
        let first = params.row(0).to_vec();
        for i in 1..7 {
            assert_eq!(params.row(i), &first[..]);
        }
    }

    #[test]
    fn one_peer_expo_full_period_averages_pow2() {
        // For n = 2^tau, tau one-peer rounds reach exact consensus.
        let n = 8;
        let topo = Topology::one_peer_expo(n);
        let mut params = random_params(n, 4, 6);
        let mean = params.mean_row();
        let mut mixer = Mixer::new(&topo, 4);
        for _ in 0..topo.rounds() {
            mixer.gossip(&mut params, &seq()).unwrap();
        }
        for p in params.rows() {
            for (a, m) in p.iter().zip(&mean) {
                assert!((a - m).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gossip_with_identity_matches_gossip() {
        let topo = Topology::grid(9);
        let params = random_params(9, 16, 8);
        let mut a = params.clone();
        let mut b = params.clone();
        let mut m1 = Mixer::new(&topo, 16);
        let mut m2 = Mixer::new(&topo, 16);
        m1.gossip(&mut a, &seq()).unwrap();
        m2.gossip_with(&mut b, &seq(), |_j, x| x.to_vec()).unwrap();
        for (pa, pb) in a.rows().zip(b.rows()) {
            for (x, y) in pa.iter().zip(pb) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gossip_with_pooled_mix_is_bit_identical_to_sequential() {
        // The transmit pass is ordered, but the mix pass shards: every
        // pool size must produce the same bits.
        let topo = Topology::grid(9);
        let params = random_params(9, 33, 15);
        let mut a = params.clone();
        let mut b = params.clone();
        let mut m1 = Mixer::new(&topo, 33);
        let mut m2 = Mixer::new(&topo, 33);
        let pool = WorkerPool::new(4);
        m1.gossip_with(&mut a, &seq(), |_j, x| x.to_vec()).unwrap();
        m2.gossip_with(&mut b, &pool, |_j, x| x.to_vec()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn gossip_with_compression_stays_near_plain() {
        use crate::compress::{Codec, Int8};
        let topo = Topology::ring(6);
        let params = random_params(6, 256, 9);
        let mut plain = params.clone();
        let mut comp = params.clone();
        let mut m1 = Mixer::new(&topo, 256);
        let mut m2 = Mixer::new(&topo, 256);
        m1.gossip(&mut plain, &seq()).unwrap();
        let codec = Int8::default();
        m2.gossip_with(&mut comp, &seq(), |_j, x| codec.compress(x).dense).unwrap();
        for (pa, pb) in plain.rows().zip(comp.rows()) {
            for (x, y) in pa.iter().zip(pb) {
                assert!((x - y).abs() < 0.05, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn identity_topology_is_noop() {
        // A row with weight 1 on self must leave params bit-unchanged (the
        // single-neighbor fast path takes the copy branch).
        let topo = Topology::ring(3);
        let mut mixer = Mixer::new(&topo, 4);
        // Overwrite cached rows with identity.
        for i in 0..3 {
            mixer.rows[0][i] = vec![(i, 1.0)];
        }
        let mut params = random_params(3, 4, 7);
        let before = params.clone();
        mixer.gossip(&mut params, &seq()).unwrap();
        assert_eq!(params, before);
    }
}
