//! The gossip-mixing engine: the L3 hot path.
//!
//! Applies one communication action to the ensemble of worker parameter
//! vectors, in place and without per-step allocation (scratch buffers are
//! owned by the [`Mixer`] and reused). The weighted-sum inner loop is the
//! rust counterpart of the Pallas `gossip_mix` kernel; equality between the
//! two is asserted by `rust/tests/integration_runtime.rs`.

use crate::topology::Topology;

/// Reusable mixing engine over `n` workers x `d` parameters.
pub struct Mixer {
    n: usize,
    d: usize,
    /// Scratch: next-iterate buffers, swapped with worker params after mix.
    scratch: Vec<Vec<f32>>,
    /// Cached weight rows per round: rows[round][i] = Vec<(j, w)>.
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    rounds: usize,
    /// Gossip rounds executed so far (advances the time-varying topology).
    pub gossip_clock: usize,
}

impl Mixer {
    pub fn new(topo: &Topology, d: usize) -> Mixer {
        let n = topo.n;
        let rounds = topo.rounds();
        let rows = (0..rounds)
            .map(|r| {
                (0..n)
                    .map(|i| {
                        topo.weight_row(i, r)
                            .into_iter()
                            .map(|(j, w)| (j, w as f32))
                            .collect()
                    })
                    .collect()
            })
            .collect();
        Mixer { n, d, scratch: vec![vec![0.0; d]; n], rows, rounds, gossip_clock: 0 }
    }

    /// One gossip round: params[i] <- sum_j w_ij params[j]. Advances the
    /// topology clock (matters for one-peer exponential graphs).
    ///
    /// §Perf: rows of 2 or 3 neighbors (one-peer / ring — the common cases)
    /// are fused into a single output pass instead of init + (k-1) axpy
    /// passes: one write traversal of d instead of k, ~1.5x measured (see
    /// EXPERIMENTS.md §Perf).
    pub fn gossip(&mut self, params: &mut [Vec<f32>]) {
        debug_assert_eq!(params.len(), self.n);
        let round = self.gossip_clock % self.rounds;
        for i in 0..self.n {
            let row = &self.rows[round][i];
            let out = &mut self.scratch[i];
            match row.len() {
                1 => out.copy_from_slice(&params[row[0].0]),
                2 => {
                    let (j0, w0) = row[0];
                    let (j1, w1) = row[1];
                    fused2(w0, &params[j0], w1, &params[j1], out);
                }
                3 => {
                    let (j0, w0) = row[0];
                    let (j1, w1) = row[1];
                    let (j2, w2) = row[2];
                    fused3(w0, &params[j0], w1, &params[j1], w2, &params[j2], out);
                }
                _ => {
                    // General case: init with the first source, accumulate.
                    let (j0, w0) = row[0];
                    let src0 = &params[j0];
                    for (o, s) in out.iter_mut().zip(src0) {
                        *o = w0 * s;
                    }
                    for &(j, w) in &row[1..] {
                        axpy(w, &params[j], out);
                    }
                }
            }
        }
        for (p, s) in params.iter_mut().zip(&mut self.scratch) {
            std::mem::swap(p, s);
        }
        self.gossip_clock += 1;
    }

    /// One gossip round where each node's *transmitted* vector is
    /// transformed by `transmit(j, x_j)` (e.g. compressed, see
    /// [`crate::compress`]); the self term always uses the local copy.
    /// `params[i] <- w_ii x_i + sum_{j != i} w_ij transmit(j, x_j)`.
    pub fn gossip_with<F>(&mut self, params: &mut [Vec<f32>], mut transmit: F)
    where
        F: FnMut(usize, &[f32]) -> Vec<f32>,
    {
        debug_assert_eq!(params.len(), self.n);
        let round = self.gossip_clock % self.rounds;
        // Which nodes are actually listened to this round?
        let mut needed = vec![false; self.n];
        for i in 0..self.n {
            for &(j, _) in &self.rows[round][i] {
                if j != i {
                    needed[j] = true;
                }
            }
        }
        let tx: Vec<Option<Vec<f32>>> = (0..self.n)
            .map(|j| needed[j].then(|| transmit(j, &params[j])))
            .collect();
        for i in 0..self.n {
            let row = &self.rows[round][i];
            let out = &mut self.scratch[i];
            out.iter_mut().for_each(|v| *v = 0.0);
            for &(j, w) in row {
                let src: &[f32] =
                    if j == i { &params[i] } else { tx[j].as_deref().expect("needed") };
                axpy(w, src, out);
            }
        }
        for (p, s) in params.iter_mut().zip(&mut self.scratch) {
            std::mem::swap(p, s);
        }
        self.gossip_clock += 1;
    }

    /// Exact global average (the All-Reduce step): every worker gets the
    /// ensemble mean.
    pub fn global_average(&mut self, params: &mut [Vec<f32>]) {
        debug_assert_eq!(params.len(), self.n);
        let (first, rest) = self.scratch.split_first_mut().expect("n >= 1");
        let mean = first;
        mean.copy_from_slice(&params[0]);
        for p in &params[1..] {
            for (m, v) in mean.iter_mut().zip(p) {
                *m += v;
            }
        }
        let inv = 1.0 / self.n as f32;
        for m in mean.iter_mut() {
            *m *= inv;
        }
        for p in params.iter_mut() {
            p.copy_from_slice(mean);
        }
        let _ = rest;
    }

    pub fn d(&self) -> usize {
        self.d
    }
}

/// out = w0*a + w1*b in a single pass.
#[inline]
pub fn fused2(w0: f32, a: &[f32], w1: f32, b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == out.len() && b.len() == out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = w0 * x + w1 * y;
    }
}

/// out = w0*a + w1*b + w2*c in a single pass (ring row).
#[inline]
pub fn fused3(w0: f32, a: &[f32], w1: f32, b: &[f32], w2: f32, c: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == out.len() && b.len() == out.len() && c.len() == out.len());
    for (((o, x), y), z) in out.iter_mut().zip(a).zip(b).zip(c) {
        *o = w0 * x + w1 * y + w2 * z;
    }
}

/// out += a * x, 8-wide unrolled (the hot inner loop; see EXPERIMENTS.md
/// §Perf for the measured effect vs. the naive zip loop).
#[inline]
pub fn axpy(a: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let chunks = x.len() / 8;
    let (xh, xt) = x.split_at(chunks * 8);
    let (oh, ot) = out.split_at_mut(chunks * 8);
    for (xc, oc) in xh.chunks_exact(8).zip(oh.chunks_exact_mut(8)) {
        oc[0] += a * xc[0];
        oc[1] += a * xc[1];
        oc[2] += a * xc[2];
        oc[3] += a * xc[3];
        oc[4] += a * xc[4];
        oc[5] += a * xc[5];
        oc[6] += a * xc[6];
        oc[7] += a * xc[7];
    }
    for (o, v) in ot.iter_mut().zip(xt) {
        *o += a * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::consensus_distance;
    use crate::rng::Rng;

    fn random_params(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_vec(d, 1.0)).collect()
    }

    #[test]
    fn axpy_matches_naive() {
        let mut rng = Rng::new(1);
        for len in [0, 1, 7, 8, 9, 100] {
            let x = rng.normal_vec(len, 1.0);
            let mut out = rng.normal_vec(len, 1.0);
            let mut expect = out.clone();
            for (e, v) in expect.iter_mut().zip(&x) {
                *e += 0.3 * v;
            }
            axpy(0.3, &x, &mut out);
            assert_eq!(out, expect, "len {len}");
        }
    }

    #[test]
    fn gossip_matches_matrix_multiply() {
        let topo = Topology::ring(6);
        let w = topo.weight_matrix(0);
        let mut params = random_params(6, 4, 2);
        let expect: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                (0..4)
                    .map(|c| {
                        (0..6).map(|j| w[(i, j)] as f32 * params[j][c]).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        let mut mixer = Mixer::new(&topo, 4);
        mixer.gossip(&mut params);
        for (p, e) in params.iter().zip(&expect) {
            for (a, b) in p.iter().zip(e) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gossip_preserves_mean() {
        let topo = Topology::grid(9);
        let mut params = random_params(9, 16, 3);
        let mean_before: Vec<f64> = (0..16)
            .map(|c| params.iter().map(|p| p[c] as f64).sum::<f64>() / 9.0)
            .collect();
        let mut mixer = Mixer::new(&topo, 16);
        for _ in 0..5 {
            mixer.gossip(&mut params);
        }
        for c in 0..16 {
            let after: f64 = params.iter().map(|p| p[c] as f64).sum::<f64>() / 9.0;
            assert!((after - mean_before[c]).abs() < 1e-4);
        }
    }

    #[test]
    fn gossip_contracts_consensus() {
        let topo = Topology::ring(10);
        let mut params = random_params(10, 8, 4);
        let before = consensus_distance(&params);
        let mut mixer = Mixer::new(&topo, 8);
        mixer.gossip(&mut params);
        let after = consensus_distance(&params);
        assert!(after < before, "{after} !< {before}");
        // And beta^2 bounds the per-step contraction in expectation-ish:
        // one deterministic step must satisfy after <= beta^2 * before.
        let beta = topo.beta();
        assert!(after <= beta * beta * before * 1.01, "{after} vs {}", beta * beta * before);
    }

    #[test]
    fn global_average_zeroes_consensus() {
        let topo = Topology::ring(7);
        let mut params = random_params(7, 8, 5);
        let mut mixer = Mixer::new(&topo, 8);
        mixer.global_average(&mut params);
        assert!(consensus_distance(&params) < 1e-10);
        for p in &params[1..] {
            assert_eq!(p, &params[0]);
        }
    }

    #[test]
    fn one_peer_expo_full_period_averages_pow2() {
        // For n = 2^tau, tau one-peer rounds reach exact consensus.
        let n = 8;
        let topo = Topology::one_peer_expo(n);
        let mut params = random_params(n, 4, 6);
        let mean: Vec<f32> = (0..4)
            .map(|c| params.iter().map(|p| p[c]).sum::<f32>() / n as f32)
            .collect();
        let mut mixer = Mixer::new(&topo, 4);
        for _ in 0..topo.rounds() {
            mixer.gossip(&mut params);
        }
        for p in &params {
            for (a, m) in p.iter().zip(&mean) {
                assert!((a - m).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gossip_with_identity_matches_gossip() {
        let topo = Topology::grid(9);
        let params = random_params(9, 16, 8);
        let mut a = params.clone();
        let mut b = params.clone();
        let mut m1 = Mixer::new(&topo, 16);
        let mut m2 = Mixer::new(&topo, 16);
        m1.gossip(&mut a);
        m2.gossip_with(&mut b, |_j, x| x.to_vec());
        for (pa, pb) in a.iter().zip(&b) {
            for (x, y) in pa.iter().zip(pb) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gossip_with_compression_stays_near_plain() {
        use crate::compress::{Codec, Int8};
        let topo = Topology::ring(6);
        let params = random_params(6, 256, 9);
        let mut plain = params.clone();
        let mut comp = params.clone();
        let mut m1 = Mixer::new(&topo, 256);
        let mut m2 = Mixer::new(&topo, 256);
        m1.gossip(&mut plain);
        let codec = Int8::default();
        m2.gossip_with(&mut comp, |_j, x| codec.compress(x).dense);
        for (pa, pb) in plain.iter().zip(&comp) {
            for (x, y) in pa.iter().zip(pb) {
                assert!((x - y).abs() < 0.05, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn identity_topology_is_noop() {
        // W = I via a 1-node "full" graph per worker is equivalent to Local
        // SGD's no-comm branch; emulate with ring(1)... instead verify that
        // a star row with weight 1 on self leaves params unchanged.
        let topo = Topology::ring(3);
        let mut mixer = Mixer::new(&topo, 4);
        // Overwrite cached rows with identity.
        for i in 0..3 {
            mixer.rows[0][i] = vec![(i, 1.0)];
        }
        let mut params = random_params(3, 4, 7);
        let before = params.clone();
        mixer.gossip(&mut params);
        assert_eq!(params, before);
    }
}
