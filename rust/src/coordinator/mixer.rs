//! The gossip-mixing engine: the L3 hot path.
//!
//! Applies one communication action to the contiguous [`ParamMatrix`] of
//! worker parameters, in place and without per-step allocation: the mixer
//! owns a ring of same-shape scratch matrices, writes the next iterate into
//! the current slot, and swaps storage with the input (an O(1) pointer
//! exchange). The weighted-sum inner loop is the rust counterpart of the
//! Pallas `gossip_mix` kernel; equality between the two is asserted by
//! `rust/tests/integration_runtime.rs`.
//!
//! §Kernel. [`mix_row_src`] is THE mixing arithmetic — every backend calls
//! it. It is explicitly vectorized: the 1/2/3-neighbor arms run 8-wide
//! unrolled multiply-add lanes ([`scale`], [`fused2`], [`fused3`]), and the
//! general arm walks the d-dimension in [`MIX_BLOCK`]-element cache blocks,
//! accumulating every neighbor into one resident block before advancing
//! (one write traversal of d, all source streams hot in L1). Each output
//! element is an independent dot product across sources whose j-order the
//! blocking never changes, so the kernel is bit-identical to the naive
//! reference [`mix_row_src_scalar`] by construction — asserted for every
//! row shape by `rust/tests/mix_kernel.rs`.
//!
//! §Threads: every output row i depends only on *input* rows, so the row
//! loop shards freely across the persistent [`WorkerPool`] (disjoint
//! `chunks_mut(d)` views of the scratch). Each row's arithmetic is
//! identical in sequential and pooled runs — results are bit-equal by
//! construction, asserted by `rust/tests/properties.rs`.
//!
//! §Async + pipelining: [`Mixer::gossip_async`] enqueues the row jobs and
//! returns a [`PendingMix`] immediately, so the round-t mix runs while the
//! caller keeps going. With `depth > 1` ([`Mixer::with_depth`]) up to
//! `depth` rounds chain in flight at once: round t+1's jobs read round t's
//! output slot, gated on a completion [`Latch`] so they never observe a
//! partial write, and [`Mixer::finish_gossip`] drains strictly oldest-first.
//! Until a round is finished `params` holds the PRE-pipeline iterate; the
//! bits that come out of a fully drained pipeline are identical to the same
//! number of synchronous [`Mixer::gossip`] calls (asserted by
//! `rust/tests/pipeline.rs`).

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::exec::{Latch, Ticket, WorkerPool};
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// Cache block width (f32 elements) of the general mixing arm: 256 f32 =
/// 1 KiB per source stream, so a many-neighbor row keeps every stream's
/// block L1-resident while it accumulates instead of streaming the whole
/// d-length row once per neighbor. Exposed so the kernel-equivalence suite
/// can probe the block boundary (d = MIX_BLOCK ± 1).
pub const MIX_BLOCK: usize = 256;

/// Reusable mixing engine over `n` workers x `d` parameters.
pub struct Mixer {
    n: usize,
    d: usize,
    /// Scratch ring: `depth` next-iterate matrices. `ring[head]` is the
    /// write target of the next round; chained async rounds walk the ring
    /// so several rounds can be in flight at once.
    ring: Vec<ParamMatrix>,
    /// Next ring slot to write.
    head: usize,
    /// Ring length = max rounds in flight (1 = classic double buffer).
    depth: usize,
    /// Mean buffer for [`Mixer::global_average`].
    mean: Vec<f32>,
    /// Cached weight rows per round: rows[round][i] = Vec<(j, w)>.
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    rounds: usize,
    /// In-flight async rounds, oldest first ([`Mixer::finish_gossip`]
    /// drains strictly FIFO).
    in_flight: VecDeque<FlightEntry>,
    /// Reusable transmit buffers for [`Mixer::gossip_with`]: one
    /// capacity-retaining Vec per node, so the steady-state compressed hot
    /// path allocates nothing after the first round.
    tx_arena: Vec<Vec<f32>>,
    /// Reusable listened-to mask for [`Mixer::gossip_with`].
    tx_mask: Vec<bool>,
    /// Gossip rounds executed so far (advances the time-varying topology).
    /// Checkpointed: one-peer-expo must resume mid-period, not at round 0.
    pub gossip_clock: usize,
}

/// One issued-but-unfinished async round, tracked by the mixer itself.
struct FlightEntry {
    /// Ring slot the round writes.
    slot: usize,
    /// Released once every row job of the round has finished writing the
    /// slot — the read gate for the successor round's jobs.
    latch: Arc<Latch>,
    /// Data address of the slot at issue time (pairing check + the
    /// successor round's source address).
    addr: usize,
}

/// The per-round f32-quantized weight rows (`rows[round][i] = [(j, w)]`)
/// that EVERY mixing implementation consumes. One quantization site — the
/// shared mixer and the message-passing [`crate::comm::BusBackend`] both
/// build their row tables here, so cross-backend bit-equality is
/// structural rather than two copies that could drift.
pub fn weight_rows_f32(topo: &Topology) -> Vec<Vec<Vec<(usize, f32)>>> {
    (0..topo.rounds())
        .map(|r| {
            (0..topo.n)
                .map(|i| topo.weight_row(i, r).into_iter().map(|(j, w)| (j, w as f32)).collect())
                .collect()
        })
        .collect()
}

impl Mixer {
    pub fn new(topo: &Topology, d: usize) -> Mixer {
        Mixer::with_depth(topo, d, 1)
    }

    /// A mixer whose async pipeline admits up to `depth` rounds in flight
    /// (depth 1 = the classic double buffer; panics on depth 0 — config
    /// validation rejects it before any mixer is built).
    pub fn with_depth(topo: &Topology, d: usize, depth: usize) -> Mixer {
        assert!(depth >= 1, "pipeline depth must be >= 1");
        let n = topo.n;
        let rounds = topo.rounds();
        let rows = weight_rows_f32(topo);
        Mixer {
            n,
            d,
            ring: (0..depth).map(|_| ParamMatrix::zeros(n, d)).collect(),
            head: 0,
            depth,
            mean: vec![0.0; d],
            rows,
            rounds,
            in_flight: VecDeque::new(),
            tx_arena: Vec::new(),
            tx_mask: Vec::new(),
            gossip_clock: 0,
        }
    }

    /// Ring length = max async rounds in flight.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Async rounds currently issued but not yet finished.
    pub fn in_flight_rounds(&self) -> usize {
        self.in_flight.len()
    }

    /// Whether [`Mixer::gossip_async`] can admit another round right now.
    pub fn pipeline_ready(&self) -> bool {
        self.in_flight.len() < self.depth
    }

    /// The round index the NEXT issued round will run: the committed clock
    /// plus the rounds already in flight ahead of it (billing and topology
    /// advance must see the issued schedule, not the drained one).
    pub fn issued_clock(&self) -> usize {
        self.gossip_clock + self.in_flight.len()
    }

    /// One gossip round: row(i) <- sum_j w_ij row(j), sharded across the
    /// pool. Advances the topology clock (matters for one-peer exponential
    /// graphs). `Err` (a failed or poisoned pool) leaves `params` untouched
    /// and the clock unadvanced — the round never happened.
    ///
    /// §Perf: rows of 2 or 3 neighbors (one-peer / ring — the common cases)
    /// are fused into a single output pass instead of init + (k-1) axpy
    /// passes: one write traversal of d instead of k, ~1.5x measured (see
    /// EXPERIMENTS.md §Perf).
    pub fn gossip(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<()> {
        assert!(self.in_flight.is_empty(), "gossip while an async mix is in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let round = self.gossip_clock % self.rounds;
        let weight_rows = &self.rows[round];
        let d = self.d;
        let src = params.as_slice();
        let t = pool.shards(self.n);
        let scratch = &mut self.ring[self.head];
        if t <= 1 {
            for (i, out) in scratch.rows_mut().enumerate() {
                mix_row(&weight_rows[i], src, d, out);
            }
        } else {
            let per = (self.n + t - 1) / t;
            pool.run(
                scratch
                    .row_blocks_mut(per)
                    .enumerate()
                    .map(|(ci, chunk)| {
                        move || {
                            for (k, out) in chunk.chunks_mut(d).enumerate() {
                                mix_row(&weight_rows[ci * per + k], src, d, out);
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(scratch);
        self.gossip_clock += 1;
        Ok(())
    }

    /// Begin one gossip round WITHOUT waiting for it: the row jobs are
    /// enqueued on `pool` and run in the background while the caller keeps
    /// going. Up to [`Mixer::depth`] rounds may be in flight at once
    /// (panics beyond that — callers gate on [`Mixer::pipeline_ready`]):
    /// a chained round's jobs read the PREDECESSOR round's output slot,
    /// gated on its completion latch, so the issued sequence computes
    /// exactly the synchronous round sequence.
    ///
    /// On a size-1 pool the jobs run inline, so overlap mode degenerates to
    /// the synchronous schedule with identical bits (each round's latch is
    /// already released when its successor is issued).
    ///
    /// # Safety
    ///
    /// The jobs capture raw addresses of `params`' and this mixer's heap
    /// buffers, so until every issued round is finished by
    /// [`Mixer::finish_gossip`] (or its [`PendingMix`] is dropped, which
    /// blocks until the jobs end) the caller must ensure that:
    ///
    /// * `params` is not mutated, moved-from, reallocated or dropped
    ///   (shared reads are fine — the jobs only read it). Note that
    ///   finishing a round swaps heap buffers between `params` and the
    ///   round's ring slot: an O(1) pointer exchange that moves ownership
    ///   but never touches the data a chained successor is still reading;
    /// * this mixer is not dropped (its ring slots are the jobs' targets);
    /// * no `PendingMix` is leaked (`std::mem::forget` would let the jobs
    ///   outlive the buffers).
    ///
    /// [`crate::coordinator::Trainer`] upholds this by draining before any
    /// `&mut` access and by dropping its pending queue before the matrices.
    pub unsafe fn gossip_async(
        &mut self,
        params: &ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<PendingMix> {
        assert!(
            self.in_flight.len() < self.depth,
            "gossip_async with the pipeline full (depth {})",
            self.depth
        );
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let round = self.issued_clock() % self.rounds;
        // Clone this round's weight rows into shared ownership: tiny (a few
        // (j, w) pairs per node) next to the O(n d) row work, and it keeps
        // the jobs free of references into the mixer.
        let weights: Arc<Vec<Vec<(usize, f32)>>> = Arc::new(self.rows[round].clone());
        let (n, d) = (self.n, self.d);
        let t = pool.shards(n);
        let per = (n + t - 1) / t;
        // Source: the live params for the first round in flight, the
        // predecessor's output slot for a chained round (its jobs wait on
        // the predecessor's latch before reading).
        let (src_addr, prev_latch) = match self.in_flight.back() {
            Some(prev) => (prev.addr, Some(prev.latch.clone())),
            None => (params.as_slice().as_ptr() as usize, None),
        };
        let slot = self.head;
        let dst_addr = self.ring[slot].as_mut_slice().as_mut_ptr() as usize;
        let done = Arc::new(Latch::new(t));
        // The jobs outlive this call, so they carry raw addresses instead
        // of borrows. Soundness contract (upheld by Trainer + the FIFO
        // in-flight queue):
        //   * src (live params or a predecessor slot) is only READ; the
        //     predecessor's latch guarantees the slot is fully written
        //     first, and a slot is recycled as a write target only after
        //     `depth` further issues — by which point the round reading it
        //     has been finished (the pipeline admits at most `depth`);
        //   * each job writes a disjoint row range of its own slot, which
        //     nothing else touches while the round is in flight;
        //   * the latch is released through a drop guard, so a panicking
        //     job still unblocks its successors (the pool reports the
        //     panic; finish_gossip refuses to swap the partial slot);
        //   * pool jobs are dequeued strictly FIFO across submissions, so
        //     a worker blocked on a latch implies every job of the earlier
        //     round is already running or done — no deadlock.
        let jobs: Vec<_> = (0..t)
            .map(|ci| {
                let weights = weights.clone();
                let prev = prev_latch.clone();
                let done = done.clone();
                move || -> Result<()> {
                    let _arrive = done.arrive_on_drop();
                    if let Some(gate) = &prev {
                        gate.wait();
                    }
                    let lo = ci * per;
                    let hi = ((ci + 1) * per).min(n);
                    let src =
                        unsafe { std::slice::from_raw_parts(src_addr as *const f32, n * d) };
                    for i in lo..hi {
                        let out = unsafe {
                            std::slice::from_raw_parts_mut((dst_addr as *mut f32).add(i * d), d)
                        };
                        mix_row(&weights[i], src, d, out);
                    }
                    Ok(())
                }
            })
            .collect();
        let ticket = pool.submit(jobs)?;
        self.in_flight.push_back(FlightEntry { slot, latch: done, addr: dst_addr });
        self.head = (self.head + 1) % self.depth;
        Ok(PendingMix { ticket, scratch_addr: dst_addr })
    }

    /// Complete the OLDEST in-flight gossip round: wait for its row jobs,
    /// swap the mixed slot in, advance the gossip clock. After a full drain
    /// the state is bit-identical to the same number of synchronous
    /// [`Mixer::gossip`] calls. Panics if nothing is in flight on THIS
    /// mixer, or the `PendingMix` is foreign / out of order (rounds must be
    /// finished strictly FIFO — swapping a later slot first would hand the
    /// trainer an intermediate iterate).
    pub fn finish_gossip(&mut self, params: &mut ParamMatrix, pending: PendingMix) -> Result<()> {
        let entry = self.in_flight.pop_front().expect("finish_gossip without a mix in flight");
        assert!(
            pending.scratch_addr == entry.addr,
            "finish_gossip got a PendingMix from a different mixer or out of order"
        );
        let outcome = pending.ticket.wait();
        // The entry is already popped, so the mixer is not wedged on Err —
        // but the slot is partial and must NOT be swapped in, and any
        // chained successor read garbage: the caller must treat the whole
        // trainer as failed (Trainer propagates and its pending queue
        // drops, which blocks out the remaining jobs).
        outcome?;
        params.swap_data(&mut self.ring[entry.slot]);
        self.gossip_clock += 1;
        Ok(())
    }

    /// One gossip round where each node's *transmitted* vector is
    /// transformed by `transmit(j, x_j, out)` writing into a mixer-owned
    /// scratch buffer (e.g. compressed, see [`crate::compress`]); the self
    /// term always uses the local copy.
    /// `row(i) <- w_ii x_i + sum_{j != i} w_ij transmit(j, x_j)`.
    ///
    /// The transmit pass is inherently sequential — `transmit` is `FnMut`
    /// (codecs carry error-feedback state), ordered by node index. The mix
    /// pass over the materialized messages shards across `pool` like the
    /// plain gossip path (bit-identical at any pool size). The transmit
    /// buffers live in a per-mixer arena and retain their capacity, so the
    /// steady-state compressed hot path performs zero allocations here.
    pub fn gossip_with<F>(
        &mut self,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
        mut transmit: F,
    ) -> Result<()>
    where
        F: FnMut(usize, &[f32], &mut Vec<f32>),
    {
        assert!(self.in_flight.is_empty(), "gossip_with while an async mix is in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let round = self.gossip_clock % self.rounds;
        // Which nodes are actually listened to this round?
        self.tx_mask.clear();
        self.tx_mask.resize(self.n, false);
        for i in 0..self.n {
            for &(j, _) in &self.rows[round][i] {
                if j != i {
                    self.tx_mask[j] = true;
                }
            }
        }
        if self.tx_arena.len() != self.n {
            self.tx_arena.resize_with(self.n, Vec::new);
        }
        for j in 0..self.n {
            // clear() keeps the allocation — round 2 onward reuses it.
            self.tx_arena[j].clear();
            if self.tx_mask[j] {
                transmit(j, params.row(j), &mut self.tx_arena[j]);
            }
        }
        // Same fused kernel as the plain gossip path (and as the bus
        // backend's receive-side mix), so identity-compressed rounds are
        // bit-identical to uncompressed ones across every backend.
        let d = self.d;
        let rows = &self.rows[round];
        let src = params.as_slice();
        let tx: &[Vec<f32>] = &self.tx_arena;
        let t = pool.shards(self.n);
        let scratch = &mut self.ring[self.head];
        if t <= 1 {
            for (i, out) in scratch.rows_mut().enumerate() {
                mix_row_with(&rows[i], i, src, d, tx, out);
            }
        } else {
            let per = (self.n + t - 1) / t;
            pool.run(
                scratch
                    .row_blocks_mut(per)
                    .enumerate()
                    .map(|(ci, chunk)| {
                        move || {
                            for (k, out) in chunk.chunks_mut(d).enumerate() {
                                let i = ci * per + k;
                                mix_row_with(&rows[i], i, src, d, tx, out);
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        params.swap_data(scratch);
        self.gossip_clock += 1;
        Ok(())
    }

    /// Exact global average (the All-Reduce step): every worker gets the
    /// ensemble mean. The mean shards by column ranges and the broadcast by
    /// rows — both through [`WorkerPool::shards`]; per-column accumulation
    /// order (rows ascending) is fixed, so all pool sizes agree bitwise.
    /// `Err` (a failed or poisoned pool) may leave `params` partially
    /// broadcast — callers must treat the trainer as failed, exactly as
    /// [`crate::coordinator::Trainer::step_once`] does by propagating it.
    pub fn global_average(&mut self, params: &mut ParamMatrix, pool: &WorkerPool) -> Result<()> {
        assert!(self.in_flight.is_empty(), "global_average while an async mix is in flight");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        let n = self.n;
        let d = self.d;
        let inv = 1.0 / n as f32;
        let t = pool.shards(d);
        let src = params.as_slice();
        if t <= 1 || d < 2 {
            self.mean.copy_from_slice(&src[..d]);
            for r in 1..n {
                for (m, v) in self.mean.iter_mut().zip(&src[r * d..(r + 1) * d]) {
                    *m += v;
                }
            }
            for m in self.mean.iter_mut() {
                *m *= inv;
            }
        } else {
            let per = (d + t - 1) / t;
            let mean = self.mean.as_mut_slice();
            pool.run(
                mean.chunks_mut(per)
                    .enumerate()
                    .map(|(ci, mchunk)| {
                        move || {
                            let off = ci * per;
                            let len = mchunk.len();
                            mchunk.copy_from_slice(&src[off..off + len]);
                            for r in 1..n {
                                let row = &src[r * d + off..r * d + off + len];
                                for (m, v) in mchunk.iter_mut().zip(row) {
                                    *m += v;
                                }
                            }
                            for m in mchunk.iter_mut() {
                                *m *= inv;
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        let mean = &self.mean;
        let rt = pool.shards(n);
        if rt <= 1 {
            for row in params.rows_mut() {
                row.copy_from_slice(mean);
            }
        } else {
            let per = (n + rt - 1) / rt;
            pool.run(
                params
                    .row_blocks_mut(per)
                    .map(|chunk| {
                        move || {
                            for row in chunk.chunks_mut(d) {
                                row.copy_from_slice(mean);
                            }
                            Ok(())
                        }
                    })
                    .collect(),
            )?;
        }
        Ok(())
    }

    pub fn d(&self) -> usize {
        self.d
    }
}

/// An in-flight [`Mixer::gossip_async`] round. Hand it back to
/// [`Mixer::finish_gossip`] of the SAME mixer, in issue order, to complete
/// the round; dropping it instead blocks until the row jobs finish and
/// DISCARDS the result (the gossip clock does not advance — the round
/// never happened).
pub struct PendingMix {
    ticket: Ticket,
    /// Identity of the ring slot the jobs write — pairing check so a
    /// foreign mixer cannot finish someone else's round, and FIFO check so
    /// rounds cannot be finished out of order.
    scratch_addr: usize,
}

/// One output row over the flat n x d source: out = sum_j w_ij *
/// src[j*d..][..d], with the 2/3-neighbor fast paths fused into a single
/// pass. Operating on the flat slice (not `&ParamMatrix`) lets the async
/// jobs and the scoped jobs share one kernel.
fn mix_row(row: &[(usize, f32)], src: &[f32], d: usize, out: &mut [f32]) {
    mix_row_src(row, |j| &src[j * d..(j + 1) * d], out)
}

/// One transmit-transformed output row (the `gossip_with` kernel): self
/// term from the live matrix, every other term from the arena of
/// materialized messages. Free function so the pooled jobs can call it
/// without borrowing the mixer.
fn mix_row_with(
    row: &[(usize, f32)],
    i: usize,
    src: &[f32],
    d: usize,
    tx: &[Vec<f32>],
    out: &mut [f32],
) {
    mix_row_src(
        row,
        |j| {
            if j == i {
                &src[i * d..(i + 1) * d]
            } else {
                tx[j].as_slice()
            }
        },
        out,
    )
}

/// The weighted-row kernel over an arbitrary source lookup: out = sum_j
/// w_ij * src_of(j). This is THE mixing arithmetic — the in-place mixer,
/// the compressed transmit path and the message-passing
/// [`crate::comm::BusBackend`] all call it, which is what makes backends
/// bit-identical: same terms, same order, same rounding.
///
/// Vectorization (see module docs §Kernel): the 1/2/3-neighbor arms run
/// 8-wide unrolled lanes in a single fused pass; the general arm is
/// cache-blocked over [`MIX_BLOCK`]-element spans of d. Neither changes any
/// output element's j-accumulation order, so this kernel is bit-identical
/// to [`mix_row_src_scalar`] (asserted by `rust/tests/mix_kernel.rs`).
pub fn mix_row_src<'s>(
    row: &[(usize, f32)],
    srow: impl Fn(usize) -> &'s [f32],
    out: &mut [f32],
) {
    match row.len() {
        0 => out.fill(0.0),
        1 => {
            let (j0, w0) = row[0];
            if w0 == 1.0 {
                out.copy_from_slice(srow(j0));
            } else {
                scale(w0, srow(j0), out);
            }
        }
        2 => {
            let (j0, w0) = row[0];
            let (j1, w1) = row[1];
            fused2(w0, srow(j0), w1, srow(j1), out);
        }
        3 => {
            let (j0, w0) = row[0];
            let (j1, w1) = row[1];
            let (j2, w2) = row[2];
            fused3(w0, srow(j0), w1, srow(j1), w2, srow(j2), out);
        }
        _ => {
            // General case, cache-blocked: init the block with the first
            // source, accumulate the rest into it while it stays resident,
            // then advance. Per output element the j-order is exactly the
            // unblocked init + axpy sweep — bit-identical by construction.
            let (j0, w0) = row[0];
            let len = out.len();
            let mut pos = 0;
            while pos < len {
                let end = (pos + MIX_BLOCK).min(len);
                let block = &mut out[pos..end];
                scale(w0, &srow(j0)[pos..end], block);
                for &(j, w) in &row[1..] {
                    axpy(w, &srow(j)[pos..end], block);
                }
                pos = end;
            }
        }
    }
}

/// The naive reference kernel: same terms, same per-element j-order as
/// [`mix_row_src`], plain zip loops, no blocking, no unrolling (the w0 ==
/// 1.0 copy fast path is semantic, so it stays). Kept as the ground truth
/// for the kernel-equivalence suite and the blocked-vs-scalar bench rows —
/// the two must agree bit-for-bit on every input.
pub fn mix_row_src_scalar<'s>(
    row: &[(usize, f32)],
    srow: impl Fn(usize) -> &'s [f32],
    out: &mut [f32],
) {
    match row.len() {
        0 => out.fill(0.0),
        1 => {
            let (j0, w0) = row[0];
            if w0 == 1.0 {
                out.copy_from_slice(srow(j0));
            } else {
                for (o, x) in out.iter_mut().zip(srow(j0)) {
                    *o = w0 * x;
                }
            }
        }
        2 => {
            let (j0, w0) = row[0];
            let (j1, w1) = row[1];
            for ((o, x), y) in out.iter_mut().zip(srow(j0)).zip(srow(j1)) {
                *o = w0 * x + w1 * y;
            }
        }
        3 => {
            let (j0, w0) = row[0];
            let (j1, w1) = row[1];
            let (j2, w2) = row[2];
            for (((o, x), y), z) in out.iter_mut().zip(srow(j0)).zip(srow(j1)).zip(srow(j2)) {
                *o = w0 * x + w1 * y + w2 * z;
            }
        }
        _ => {
            let (j0, w0) = row[0];
            for (o, x) in out.iter_mut().zip(srow(j0)) {
                *o = w0 * x;
            }
            for &(j, w) in &row[1..] {
                for (o, x) in out.iter_mut().zip(srow(j)) {
                    *o += w * x;
                }
            }
        }
    }
}

/// out = w * x, 8-wide unrolled (the single-neighbor non-unit arm and the
/// init pass of the blocked general arm).
#[inline]
pub fn scale(w: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let lanes = x.len() / 8 * 8;
    let (xh, xt) = x.split_at(lanes);
    let (oh, ot) = out.split_at_mut(lanes);
    for (xc, oc) in xh.chunks_exact(8).zip(oh.chunks_exact_mut(8)) {
        oc[0] = w * xc[0];
        oc[1] = w * xc[1];
        oc[2] = w * xc[2];
        oc[3] = w * xc[3];
        oc[4] = w * xc[4];
        oc[5] = w * xc[5];
        oc[6] = w * xc[6];
        oc[7] = w * xc[7];
    }
    for (o, v) in ot.iter_mut().zip(xt) {
        *o = w * v;
    }
}

/// out = w0*a + w1*b in a single pass, 8-wide unrolled.
#[inline]
pub fn fused2(w0: f32, a: &[f32], w1: f32, b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == out.len() && b.len() == out.len());
    let lanes = out.len() / 8 * 8;
    let (ah, at) = a.split_at(lanes);
    let (bh, bt) = b.split_at(lanes);
    let (oh, ot) = out.split_at_mut(lanes);
    for ((oc, ac), bc) in
        oh.chunks_exact_mut(8).zip(ah.chunks_exact(8)).zip(bh.chunks_exact(8))
    {
        oc[0] = w0 * ac[0] + w1 * bc[0];
        oc[1] = w0 * ac[1] + w1 * bc[1];
        oc[2] = w0 * ac[2] + w1 * bc[2];
        oc[3] = w0 * ac[3] + w1 * bc[3];
        oc[4] = w0 * ac[4] + w1 * bc[4];
        oc[5] = w0 * ac[5] + w1 * bc[5];
        oc[6] = w0 * ac[6] + w1 * bc[6];
        oc[7] = w0 * ac[7] + w1 * bc[7];
    }
    for ((o, x), y) in ot.iter_mut().zip(at).zip(bt) {
        *o = w0 * x + w1 * y;
    }
}

/// out = w0*a + w1*b + w2*c in a single pass (ring row), 8-wide unrolled.
#[inline]
pub fn fused3(w0: f32, a: &[f32], w1: f32, b: &[f32], w2: f32, c: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == out.len() && b.len() == out.len() && c.len() == out.len());
    let lanes = out.len() / 8 * 8;
    let (ah, at) = a.split_at(lanes);
    let (bh, bt) = b.split_at(lanes);
    let (ch, ct) = c.split_at(lanes);
    let (oh, ot) = out.split_at_mut(lanes);
    for (((oc, ac), bc), cc) in oh
        .chunks_exact_mut(8)
        .zip(ah.chunks_exact(8))
        .zip(bh.chunks_exact(8))
        .zip(ch.chunks_exact(8))
    {
        oc[0] = w0 * ac[0] + w1 * bc[0] + w2 * cc[0];
        oc[1] = w0 * ac[1] + w1 * bc[1] + w2 * cc[1];
        oc[2] = w0 * ac[2] + w1 * bc[2] + w2 * cc[2];
        oc[3] = w0 * ac[3] + w1 * bc[3] + w2 * cc[3];
        oc[4] = w0 * ac[4] + w1 * bc[4] + w2 * cc[4];
        oc[5] = w0 * ac[5] + w1 * bc[5] + w2 * cc[5];
        oc[6] = w0 * ac[6] + w1 * bc[6] + w2 * cc[6];
        oc[7] = w0 * ac[7] + w1 * bc[7] + w2 * cc[7];
    }
    for (((o, x), y), z) in ot.iter_mut().zip(at).zip(bt).zip(ct) {
        *o = w0 * x + w1 * y + w2 * z;
    }
}

/// out += a * x, 8-wide unrolled (the hot inner loop; see EXPERIMENTS.md
/// §Perf for the measured effect vs. the naive zip loop).
#[inline]
pub fn axpy(a: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let chunks = x.len() / 8;
    let (xh, xt) = x.split_at(chunks * 8);
    let (oh, ot) = out.split_at_mut(chunks * 8);
    for (xc, oc) in xh.chunks_exact(8).zip(oh.chunks_exact_mut(8)) {
        oc[0] += a * xc[0];
        oc[1] += a * xc[1];
        oc[2] += a * xc[2];
        oc[3] += a * xc[3];
        oc[4] += a * xc[4];
        oc[5] += a * xc[5];
        oc[6] += a * xc[6];
        oc[7] += a * xc[7];
    }
    for (o, v) in ot.iter_mut().zip(xt) {
        *o += a * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::consensus_distance;
    use crate::rng::Rng;

    fn random_params(n: usize, d: usize, seed: u64) -> ParamMatrix {
        ParamMatrix::random(&mut Rng::new(seed), n, d, 1.0)
    }

    fn seq() -> WorkerPool {
        WorkerPool::new(1)
    }

    #[test]
    fn axpy_matches_naive() {
        let mut rng = Rng::new(1);
        for len in [0, 1, 7, 8, 9, 100] {
            let x = rng.normal_vec(len, 1.0);
            let mut out = rng.normal_vec(len, 1.0);
            let mut expect = out.clone();
            for (e, v) in expect.iter_mut().zip(&x) {
                *e += 0.3 * v;
            }
            axpy(0.3, &x, &mut out);
            assert_eq!(out, expect, "len {len}");
        }
    }

    #[test]
    fn blocked_kernel_matches_scalar_reference() {
        // The full property sweep lives in rust/tests/mix_kernel.rs; this
        // is the in-module smoke across the block boundary.
        let mut rng = Rng::new(7);
        for d in [1usize, 3, MIX_BLOCK - 1, MIX_BLOCK, MIX_BLOCK + 1, 4096] {
            for deg in [0usize, 1, 2, 3, 5, 8] {
                let srcs: Vec<Vec<f32>> = (0..deg.max(1)).map(|_| rng.normal_vec(d, 1.0)).collect();
                let row: Vec<(usize, f32)> =
                    (0..deg).map(|j| (j, 1.0 / (deg as f32 + 1.0))).collect();
                let mut fast = vec![f32::NAN; d];
                let mut slow = vec![f32::NAN; d];
                mix_row_src(&row, |j| &srcs[j][..], &mut fast);
                mix_row_src_scalar(&row, |j| &srcs[j][..], &mut slow);
                assert_eq!(fast, slow, "d {d} deg {deg}");
            }
        }
    }

    #[test]
    fn gossip_matches_matrix_multiply() {
        let topo = Topology::ring(6);
        let w = topo.weight_matrix(0);
        let mut params = random_params(6, 4, 2);
        let expect: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                (0..4)
                    .map(|c| {
                        (0..6).map(|j| w[(i, j)] as f32 * params.row(j)[c]).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        let mut mixer = Mixer::new(&topo, 4);
        mixer.gossip(&mut params, &seq()).unwrap();
        for (p, e) in params.rows().zip(&expect) {
            for (a, b) in p.iter().zip(e) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gossip_preserves_mean() {
        let topo = Topology::grid(9);
        let mut params = random_params(9, 16, 3);
        let mean_before = params.mean_row();
        let mut mixer = Mixer::new(&topo, 16);
        for _ in 0..5 {
            mixer.gossip(&mut params, &seq()).unwrap();
        }
        for (after, before) in params.mean_row().iter().zip(&mean_before) {
            assert!((after - before).abs() < 1e-4);
        }
    }

    #[test]
    fn gossip_contracts_consensus() {
        let topo = Topology::ring(10);
        let mut params = random_params(10, 8, 4);
        let before = consensus_distance(&params);
        let mut mixer = Mixer::new(&topo, 8);
        mixer.gossip(&mut params, &seq()).unwrap();
        let after = consensus_distance(&params);
        assert!(after < before, "{after} !< {before}");
        // And beta^2 bounds the per-step contraction in expectation-ish:
        // one deterministic step must satisfy after <= beta^2 * before.
        let beta = topo.beta();
        assert!(after <= beta * beta * before * 1.01, "{after} vs {}", beta * beta * before);
    }

    #[test]
    fn pooled_gossip_is_bit_identical_to_sequential() {
        let pool = WorkerPool::new(4);
        for topo in [Topology::ring(10), Topology::one_peer_expo(8), Topology::grid(9)] {
            let n = topo.n;
            let mut a = random_params(n, 33, 5);
            let mut b = a.clone();
            let mut m1 = Mixer::new(&topo, 33);
            let mut m2 = Mixer::new(&topo, 33);
            for _ in 0..topo.rounds() + 2 {
                m1.gossip(&mut a, &seq()).unwrap();
                m2.gossip(&mut b, &pool).unwrap();
                assert_eq!(a, b, "{:?}", topo.kind);
            }
            m1.global_average(&mut a, &seq()).unwrap();
            m2.global_average(&mut b, &pool).unwrap();
            assert_eq!(a, b, "{:?} global average", topo.kind);
        }
    }

    #[test]
    fn async_gossip_matches_sync_bitwise() {
        let pool = WorkerPool::new(4);
        for topo in [Topology::ring(10), Topology::one_peer_expo(8), Topology::grid(9)] {
            let n = topo.n;
            let mut sync = random_params(n, 29, 11);
            let mut asy = sync.clone();
            let mut m1 = Mixer::new(&topo, 29);
            let mut m2 = Mixer::new(&topo, 29);
            for round in 0..topo.rounds() + 2 {
                m1.gossip(&mut sync, &pool).unwrap();
                // SAFETY: asy and m2 outlive the round; finish_gossip runs
                // before the next access.
                let pending = unsafe { m2.gossip_async(&asy, &pool) }.unwrap();
                m2.finish_gossip(&mut asy, pending).unwrap();
                assert_eq!(sync, asy, "{:?} round {round}", topo.kind);
                assert_eq!(m1.gossip_clock, m2.gossip_clock);
            }
        }
    }

    #[test]
    fn chained_pipeline_matches_sync_bitwise() {
        // Depth-k chaining: issue up to k rounds before draining any. The
        // fully drained pipeline must equal the same number of synchronous
        // rounds bit-for-bit, at every depth and pool size.
        for depth in [2usize, 4] {
            for pool in [WorkerPool::new(1), WorkerPool::new(4)] {
                for topo in
                    [Topology::ring(10), Topology::one_peer_expo(8), Topology::grid(9)]
                {
                    let n = topo.n;
                    let total = topo.rounds() + 3;
                    let mut sync = random_params(n, 29, 21);
                    let mut pipe = sync.clone();
                    let mut m1 = Mixer::new(&topo, 29);
                    let mut m2 = Mixer::with_depth(&topo, 29, depth);
                    for _ in 0..total {
                        m1.gossip(&mut sync, &pool).unwrap();
                    }
                    let mut pending = std::collections::VecDeque::new();
                    let mut issued = 0;
                    while m2.gossip_clock < total {
                        if issued < total && m2.pipeline_ready() {
                            // SAFETY: pipe and m2 outlive the pipeline; all
                            // rounds are finished below before any &mut use.
                            pending
                                .push_back(unsafe { m2.gossip_async(&pipe, &pool) }.unwrap());
                            issued += 1;
                        } else {
                            let p = pending.pop_front().unwrap();
                            m2.finish_gossip(&mut pipe, p).unwrap();
                        }
                    }
                    assert_eq!(sync, pipe, "depth {depth} {:?}", topo.kind);
                    assert_eq!(m1.gossip_clock, m2.gossip_clock);
                    assert_eq!(m2.in_flight_rounds(), 0);
                }
            }
        }
    }

    #[test]
    fn issued_clock_tracks_the_pipeline() {
        let topo = Topology::one_peer_expo(8);
        let params = random_params(8, 5, 3);
        let mut m = Mixer::with_depth(&topo, 5, 3);
        assert_eq!(m.issued_clock(), 0);
        // SAFETY: params and m outlive the block; the drops below block
        // until the jobs are done and the rounds are discarded.
        let p1 = unsafe { m.gossip_async(&params, &seq()) }.unwrap();
        assert_eq!(m.issued_clock(), 1, "issue advances the issued clock");
        assert_eq!(m.gossip_clock, 0, "…but not the committed clock");
        let p2 = unsafe { m.gossip_async(&params, &seq()) }.unwrap();
        assert_eq!(m.issued_clock(), 2);
        assert!(m.pipeline_ready(), "depth 3 still has a free slot");
        drop(p1);
        drop(p2);
    }

    #[test]
    fn pipeline_full_asserts() {
        let topo = Topology::ring(4);
        let params = random_params(4, 6, 15);
        let mut m = Mixer::new(&topo, 6); // depth 1
        let pool = WorkerPool::new(2);
        // SAFETY: params and m outlive the block; the drop blocks.
        let _pending = unsafe { m.gossip_async(&params, &pool) }.unwrap();
        assert!(!m.pipeline_ready());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: never issued — the full-pipeline assert fires first.
            let _ = unsafe { m.gossip_async(&params, &pool) };
        }));
        assert!(r.is_err(), "a depth-1 mixer must refuse a second in-flight round");
    }

    #[test]
    fn async_gossip_runs_inline_on_sequential_pool() {
        let topo = Topology::ring(5);
        let mut a = random_params(5, 9, 13);
        let mut b = a.clone();
        Mixer::new(&topo, 9).gossip(&mut a, &seq()).unwrap();
        let mut m = Mixer::new(&topo, 9);
        // SAFETY: b and m outlive the round; finish_gossip runs next.
        let pending = unsafe { m.gossip_async(&b, &seq()) }.unwrap();
        m.finish_gossip(&mut b, pending).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dropped_pending_mix_discards_the_round() {
        let topo = Topology::ring(4);
        let params = random_params(4, 6, 14);
        let before = params.clone();
        let mut m = Mixer::new(&topo, 6);
        let pool = WorkerPool::new(2);
        {
            // SAFETY: params and m outlive this block; the drop at the end
            // of the block waits for the jobs.
            let _pending = unsafe { m.gossip_async(&params, &pool) }.unwrap();
            // dropped without finish_gossip: blocks until the jobs end,
            // then the round is discarded
        }
        assert_eq!(params, before, "params must be untouched");
        assert_eq!(m.gossip_clock, 0, "an unfinished round must not advance the clock");
        // The mixer stays wedged on purpose until told otherwise? No — the
        // ticket is gone, but the in-flight entry still guards the slot. A
        // fresh round must go through finish_gossip, so this is a
        // programming error; assert the guard trips.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.gossip(&mut params.clone(), &pool)
        }));
        assert!(r.is_err(), "reusing a mixer after dropping its pending mix must assert");
    }

    #[test]
    fn pooled_gossip_handles_more_threads_than_rows() {
        let topo = Topology::ring(3);
        let mut a = random_params(3, 7, 12);
        let mut b = a.clone();
        Mixer::new(&topo, 7).gossip(&mut a, &WorkerPool::new(64)).unwrap();
        Mixer::new(&topo, 7).gossip(&mut b, &seq()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn global_average_zeroes_consensus() {
        let topo = Topology::ring(7);
        let mut params = random_params(7, 8, 5);
        let mut mixer = Mixer::new(&topo, 8);
        mixer.global_average(&mut params, &seq()).unwrap();
        assert!(consensus_distance(&params) < 1e-10);
        let first = params.row(0).to_vec();
        for i in 1..7 {
            assert_eq!(params.row(i), &first[..]);
        }
    }

    #[test]
    fn one_peer_expo_full_period_averages_pow2() {
        // For n = 2^tau, tau one-peer rounds reach exact consensus.
        let n = 8;
        let topo = Topology::one_peer_expo(n);
        let mut params = random_params(n, 4, 6);
        let mean = params.mean_row();
        let mut mixer = Mixer::new(&topo, 4);
        for _ in 0..topo.rounds() {
            mixer.gossip(&mut params, &seq()).unwrap();
        }
        for p in params.rows() {
            for (a, m) in p.iter().zip(&mean) {
                assert!((a - m).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gossip_with_identity_matches_gossip() {
        let topo = Topology::grid(9);
        let params = random_params(9, 16, 8);
        let mut a = params.clone();
        let mut b = params.clone();
        let mut m1 = Mixer::new(&topo, 16);
        let mut m2 = Mixer::new(&topo, 16);
        m1.gossip(&mut a, &seq()).unwrap();
        m2.gossip_with(&mut b, &seq(), |_j, x, out| out.extend_from_slice(x)).unwrap();
        for (pa, pb) in a.rows().zip(b.rows()) {
            for (x, y) in pa.iter().zip(pb) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gossip_with_pooled_mix_is_bit_identical_to_sequential() {
        // The transmit pass is ordered, but the mix pass shards: every
        // pool size must produce the same bits.
        let topo = Topology::grid(9);
        let params = random_params(9, 33, 15);
        let mut a = params.clone();
        let mut b = params.clone();
        let mut m1 = Mixer::new(&topo, 33);
        let mut m2 = Mixer::new(&topo, 33);
        let pool = WorkerPool::new(4);
        m1.gossip_with(&mut a, &seq(), |_j, x, out| out.extend_from_slice(x)).unwrap();
        m2.gossip_with(&mut b, &pool, |_j, x, out| out.extend_from_slice(x)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn gossip_with_arena_reuse_is_stable_across_rounds() {
        // Round 2 onward reuses the arena buffers (clear() keeps capacity);
        // multi-round compressed-style runs must match a fresh-mixer
        // round-by-round replay bit-for-bit.
        let topo = Topology::one_peer_expo(8);
        let mut a = random_params(8, 48, 16);
        let mut b = a.clone();
        let mut reused = Mixer::new(&topo, 48);
        for _ in 0..topo.rounds() + 2 {
            let mut fresh = Mixer::new(&topo, 48);
            fresh.gossip_clock = reused.gossip_clock;
            reused.gossip_with(&mut a, &seq(), |_j, x, out| out.extend_from_slice(x)).unwrap();
            fresh.gossip_with(&mut b, &seq(), |_j, x, out| out.extend_from_slice(x)).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn gossip_with_compression_stays_near_plain() {
        use crate::compress::{Codec, Int8};
        let topo = Topology::ring(6);
        let params = random_params(6, 256, 9);
        let mut plain = params.clone();
        let mut comp = params.clone();
        let mut m1 = Mixer::new(&topo, 256);
        let mut m2 = Mixer::new(&topo, 256);
        m1.gossip(&mut plain, &seq()).unwrap();
        let codec = Int8::default();
        m2.gossip_with(&mut comp, &seq(), |_j, x, out| {
            out.extend_from_slice(&codec.compress(x).dense)
        })
        .unwrap();
        for (pa, pb) in plain.rows().zip(comp.rows()) {
            for (x, y) in pa.iter().zip(pb) {
                assert!((x - y).abs() < 0.05, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn identity_topology_is_noop() {
        // A row with weight 1 on self must leave params bit-unchanged (the
        // single-neighbor fast path takes the copy branch).
        let topo = Topology::ring(3);
        let mut mixer = Mixer::new(&topo, 4);
        // Overwrite cached rows with identity.
        for i in 0..3 {
            mixer.rows[0][i] = vec![(i, 1.0)];
        }
        let mut params = random_params(3, 4, 7);
        let before = params.clone();
        mixer.gossip(&mut params, &seq()).unwrap();
        assert_eq!(params, before);
    }
}
