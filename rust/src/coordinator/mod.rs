//! The training coordinator: Algorithm 1 (and its whole family) over n
//! workers, with the model compute executed through PJRT.
//!
//! Per iteration k (the paper's main recursion, eq. (10)):
//!   1. every worker samples a local minibatch and executes the AOT grad
//!      graph: `(loss, g_i) = grad(x_i, batch_i)`;
//!   2. local optimizer update `x_i <- x_i - gamma (momentum) g_i`;
//!   3. the [`Schedule`] decides the communication action — gossip mix,
//!      exact global average, or nothing — executed on the pluggable
//!      [`CommBackend`] ([`TrainerOptions::backend`]: the shared-memory
//!      mixer or the message-passing bus), which reports the
//!      [`crate::comm::CommCharge`] it incurred (aggregate traffic plus
//!      per-node alpha-beta seconds);
//!   4. the per-node [`VirtualClocks`] advance by the charge under the
//!      action's barrier scope — gossip synchronizes each node with its
//!      in-neighborhood only, a global average (and eval / checkpoint) is a
//!      full barrier — so `sim_seconds` is the run's true critical path.
//!      With homogeneous costs ([`TrainerOptions::node_costs`] unset) every
//!      barrier is a no-op and the clocks reproduce the pre-virtual-time
//!      scalar clock bit-exactly; per-node overrides and `--straggler`
//!      open the heterogeneous regimes (the cumulative traffic still flows
//!      into every logged [`Record`], now alongside the straggler-slack and
//!      barrier-wait columns).
//!
//! Storage: all worker parameters live in one contiguous
//! [`ParamMatrix`] (worker i = row i). Phases 1-2, the gossip mix, the
//! global-average mean and the eval pass all shard across one persistent
//! [`WorkerPool`] of [`TrainerOptions::threads`] parked threads (see
//! [`crate::exec`] for the determinism contract) — each worker owns its
//! RNG, gradient buffer, batch scratch and parameter row, so the split is
//! data-race-free by construction. This is how the deployed decentralized
//! baselines run (one process per node); here it buys back the n-fold
//! serialization tax of simulating n workers on one thread, without the
//! per-step thread spawn/join the PR-1 scoped version paid.
//!
//! §Regimes ([`TrainerOptions::regime`] / `train.regime` / `--regime`):
//! BSP (the synchronous default), overlap (below), and async — the
//! event-driven AD-PSGD plane in [`crate::eventsim`], where each node runs
//! its own iteration counter and mixes bounded-stale neighbor copies
//! (`--max-staleness`), billed per link by a discrete-event queue. The
//! async step loop advances a one-step iteration horizon per
//! [`Trainer::step_once`], so logging/eval/checkpoint always observe
//! drained step boundaries; at `max_staleness = 0` with homogeneous costs
//! it reproduces BSP parameters AND the barrier-billed clocks bit-exactly.
//!
//! §Overlap ([`Regime::Overlap`] / `--overlap`): the double-buffer
//! mode. A gossip round issued at step t runs asynchronously on the pool
//! ([`mixer::Mixer::gossip_async`]) while the main thread begins step t+1's
//! parameter-independent sampling phase; the mix is drained before step
//! t+1's gradients read the rows. The OPERATIONS and their order on the
//! parameter matrix are exactly the BSP schedule's, so overlapped runs are
//! bit-identical to BSP runs at every drained boundary — in particular at
//! every global-averaging step k·H, where the synchronous all-reduce acts
//! as the barrier (asserted by `rust/tests/properties.rs`). Between drains
//! the public accessors ([`Trainer::worker_params`] etc.) see the PRE-mix
//! iterate; call [`Trainer::drain`] first for exact state. Checkpointing
//! drains (never drops) the in-flight mix.
//!
//! Workers are deterministic: worker i's batch stream is `seed.split(i)`
//! and every reduction fixes its order, so sequential, pooled and
//! overlapped runs of the same seed agree bit-for-bit at synchronization
//! points (asserted by rust/tests/properties.rs).

pub mod checkpoint;
pub mod mixer;
pub mod rounds;

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{schedule_for, AlgorithmKind, CommAction, Schedule, SlowMoParams};
use crate::comm::{
    BackendKind, BusBackend, CommBackend, CommStats, Compression, PendingComm, SharedBackend,
    TcpBackend,
};
use crate::config::ExperimentConfig;
use crate::costmodel::{BarrierScope, CostModel, NodeCosts, VirtualClocks};
use crate::data::{ClusterData, LogRegData, TokenCorpus};
use crate::eventsim::{AsyncGossip, Regime};
use crate::exec::WorkerPool;
use crate::metrics::{consensus_distance_pooled, History, Record};
use crate::model;
use crate::obs::{self, Phase};
use crate::optim::{LrSchedule, Optimizer};
use crate::params::ParamMatrix;
use crate::rng::Rng;
use crate::runtime::{lit_f32, lit_i32, EvalFn, GradFn, Runtime};
use crate::topology::Topology;

use self::rounds::{require_deadline_support, RoundMachine, RoundState};

/// The workload: dataset + AOT executables + batch plumbing.
pub enum Workload {
    LogReg { data: LogRegData, grad: GradFn },
    Mlp { data: ClusterData, grad: GradFn, eval: Option<EvalFn> },
    Lm { corpus: TokenCorpus, grad: GradFn, eval: Option<EvalFn>, seq_plus_one: usize },
}

impl Workload {
    pub fn grad_fn(&self) -> &GradFn {
        match self {
            Workload::LogReg { grad, .. } => grad,
            Workload::Mlp { grad, .. } => grad,
            Workload::Lm { grad, .. } => grad,
        }
    }

    pub fn flat_dim(&self) -> usize {
        self.grad_fn().flat_dim()
    }

    fn batch_size(&self) -> usize {
        self.grad_fn().spec.meta_usize("batch").unwrap_or(32)
    }

    /// Draw this step's batch for `worker` into `scratch` (pure RNG + copy
    /// work, no XLA). `&self` + caller-owned rng/scratch: safe to call for
    /// distinct workers concurrently. Split from [`Workload::literals`] so
    /// overlap mode can sample while the previous round's mix is still in
    /// flight — sampling never reads parameters.
    fn sample_scratch(&self, worker: usize, rng: &mut Rng, scratch: &mut BatchScratch) {
        match self {
            Workload::LogReg { data, .. } => {
                data.sample_batch(worker, self.batch_size(), rng, &mut scratch.x, &mut scratch.yf);
            }
            Workload::Mlp { data, .. } => {
                data.sample_batch(worker, self.batch_size(), rng, &mut scratch.x, &mut scratch.yi);
            }
            Workload::Lm { corpus, seq_plus_one, .. } => {
                corpus.sample_batch(self.batch_size(), *seq_plus_one, rng, &mut scratch.yi);
            }
        }
    }

    /// Build the XLA batch literals from a filled `scratch`.
    fn literals(&self, scratch: &BatchScratch) -> Result<Vec<xla::Literal>> {
        match self {
            Workload::LogReg { grad, .. } => Ok(vec![
                lit_f32(&scratch.x, &grad.spec.inputs[1].shape)?,
                lit_f32(&scratch.yf, &grad.spec.inputs[2].shape)?,
            ]),
            Workload::Mlp { grad, .. } => Ok(vec![
                lit_f32(&scratch.x, &grad.spec.inputs[1].shape)?,
                lit_i32(&scratch.yi, &grad.spec.inputs[2].shape)?,
            ]),
            Workload::Lm { grad, .. } => {
                Ok(vec![lit_i32(&scratch.yi, &grad.spec.inputs[1].shape)?])
            }
        }
    }

    /// Sample + build literals in one call (the eval path).
    fn sample(
        &self,
        worker: usize,
        rng: &mut Rng,
        scratch: &mut BatchScratch,
    ) -> Result<Vec<xla::Literal>> {
        self.sample_scratch(worker, rng, scratch);
        self.literals(scratch)
    }
}

#[derive(Default)]
struct BatchScratch {
    x: Vec<f32>,
    yf: Vec<f32>,
    yi: Vec<i32>,
}

/// Everything the trainer needs beyond the workload.
#[derive(Clone)]
pub struct TrainerOptions {
    pub algorithm: AlgorithmKind,
    pub topology: Topology,
    pub period: usize,
    pub aga_init_period: usize,
    pub aga_warmup: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub nesterov: bool,
    pub seed: u64,
    pub slowmo: SlowMoParams,
    /// Cost model for the simulated clock; `cost_dim` lets a small stand-in
    /// model emulate the paper's full-size model in the time columns
    /// (e.g. the MLP suite bills communication at ResNet-50's d = 25.5e6).
    pub cost: CostModel,
    pub cost_dim: usize,
    /// Per-node cost overrides (heterogeneous clusters / stragglers).
    /// `None` = every node carries `cost` — the homogeneous case whose
    /// critical path reproduces the pre-virtual-time `sim_seconds`
    /// bit-exact. A `Some` table REPLACES `cost` for billing wholesale:
    /// if you change `cost` after [`TrainerOptions::from_config`] resolved
    /// a table, rebuild the table against the new base too.
    pub node_costs: Option<NodeCosts>,
    /// Record a metrics row every `log_every` steps (consensus distance is
    /// O(n d), so dense logging of big models costs time).
    pub log_every: usize,
    /// Size of the persistent worker pool that phases 1-2, the mix and the
    /// eval pass shard across. 1 = sequential (the default); results are
    /// bit-identical at any value.
    pub threads: usize,
    /// Work-stealing dynamic chunking: the pool over-splits every parallel
    /// region so idle threads pull extra chunks (heterogeneous-cost
    /// workers). Bit-identical to static sharding; off by default.
    pub stealing: bool,
    /// Pin the pool's worker threads to cores (`train.pin` / `--pin`):
    /// worker i to core `i % available_parallelism`, so each thread's
    /// static row shard stays cache-local across rounds. Best-effort —
    /// warns once and runs unpinned where affinity calls fail. Bits are
    /// identical pinned or not.
    pub pin: bool,
    /// Max gossip rounds in flight on the async gossip pipeline of ANY
    /// backend (`train.pipeline_depth` / `--pipeline-depth`; default 1 =
    /// the classic double buffer). The shared mixer and the message-passing
    /// cores (bus, tcp) each keep a depth-k ring of receive planes and
    /// chain rounds through completion latches, drained FIFO and
    /// bit-identical to BSP at every drained boundary. The step loop itself
    /// drains before each gradient phase (gradients need the mixed
    /// iterate), so training keeps at most one round in flight per step;
    /// depth > 1 pipelines back-to-back comm-only round sequences — the
    /// mixer/backend benches and the pipeline/overlap test suites drive it
    /// directly.
    pub pipeline_depth: usize,
    /// Execution regime (`train.regime` / `--regime`):
    /// * [`Regime::Bsp`] — synchronous rounds (the default);
    /// * [`Regime::Overlap`] — double-buffered async gossip: the round-t
    ///   mix overlaps round t+1's sampling phase, bit-identical to BSP at
    ///   every drained boundary (and trivially so at every k·H global
    ///   average);
    /// * [`Regime::Async`] — event-driven AD-PSGD mixing on the
    ///   [`crate::eventsim`] per-link time plane: per-node iteration
    ///   counters, bounded-stale mixing, per-link billing. Reproduces BSP
    ///   bit-exactly only at `max_staleness = 0` with the lockstep billing
    ///   (the regression anchor).
    pub regime: Regime,
    /// Async regime only: how many versions behind BSP-fresh a mix input
    /// may be. 0 = strict (lockstep, bit-identical to BSP); >= 1 lets
    /// compute overlap in-flight transfers (drops the BSP equivalence).
    pub max_staleness: usize,
    /// Which communication plane to run on: the shared-memory mixer
    /// (default), the message-passing bus, or the loopback socket plane
    /// (`tcp` — the same bus core over real framed `TcpStream`s).
    /// Uncompressed trajectories are bit-identical across all three; only
    /// the bytes' journey and the accounting model differ.
    pub backend: BackendKind,
    /// Gossip-message compression on the transmit path (either backend).
    pub compression: Compression,
    /// Per-receive deadline in seconds for the fault-tolerant round state
    /// machine ([`rounds::RoundMachine`]): a peer that stays silent past
    /// this budget is dropped from the round — its mixing weight folds back
    /// onto the senders' own rows — and the round retries over the degraded
    /// membership. `0.0` (the default) disables the machine: a stalled
    /// peer blocks forever, the pre-PR-7 semantics. Needs a
    /// deadline-capable backend (bus | tcp) and the BSP regime.
    pub round_timeout: f64,
    /// TCP backend only: the `host:port` every rank's listener binds
    /// (`--listen` / `comm.listen`). Port 0 (the default) asks the OS for
    /// a free port per rank; a fixed port P pins rank r to P + r.
    pub listen: String,
}

impl TrainerOptions {
    pub fn from_config(cfg: &ExperimentConfig, cost_dim: usize) -> TrainerOptions {
        let base_cost = CostModel::calibrated_resnet50();
        TrainerOptions {
            algorithm: cfg.algorithm,
            topology: cfg.topology(),
            period: cfg.period,
            aga_init_period: cfg.aga_init_period,
            aga_warmup: cfg.aga_warmup,
            lr: LrSchedule::StepDecay {
                lr: cfg.lr,
                every: cfg.lr_decay_every,
                factor: cfg.lr_decay_factor,
            },
            momentum: cfg.momentum,
            nesterov: cfg.nesterov,
            seed: cfg.seed,
            slowmo: SlowMoParams::default(),
            // One calibration feeds BOTH the base model and the resolved
            // per-node table, so straggler factors always scale the same
            // alpha/compute the homogeneous path bills.
            cost: base_cost,
            cost_dim,
            node_costs: cfg.node_costs(base_cost).expect("validated"),
            log_every: cfg.log_every,
            threads: cfg.threads,
            stealing: cfg.stealing,
            pin: cfg.pin,
            pipeline_depth: cfg.pipeline_depth,
            regime: cfg.regime_kind().expect("validated"),
            max_staleness: cfg.max_staleness,
            backend: cfg.backend_kind().expect("validated"),
            compression: cfg.compression_kind().expect("validated"),
            round_timeout: cfg.round_timeout,
            listen: cfg.listen.clone(),
        }
    }
}

/// Per-worker state (everything but the parameter row, which lives in the
/// trainer's [`ParamMatrix`]). Each worker owns its batch scratch so
/// phase 1-2 can run one worker per pool job.
struct Worker {
    opt: Optimizer,
    rng: Rng,
    grad: Vec<f32>,
    loss: f32,
    scratch: BatchScratch,
}

/// The coordinator.
pub struct Trainer {
    pub workload: Workload,
    opts: TrainerOptions,
    workers: Vec<Worker>,
    /// In-flight overlap mixes, oldest first (the backend's pipeline is
    /// drained strictly FIFO). Declared BEFORE `params`/`backend`: on drop
    /// each Ticket blocks until the background jobs release their raw
    /// views of those buffers.
    pending: VecDeque<PendingComm>,
    /// n x d worker parameters (worker i = row i).
    params: ParamMatrix,
    /// The pluggable communication plane (shared-memory mixer or
    /// message-passing bus; [`TrainerOptions::backend`]).
    backend: Box<dyn CommBackend>,
    /// The persistent execution engine every parallel phase shards across.
    pool: WorkerPool,
    schedule: Box<dyn Schedule>,
    /// The event-driven async-gossip engine (`Some` iff
    /// [`TrainerOptions::regime`] is [`Regime::Async`]); owns the per-link
    /// payload plane and the staleness accounting.
    eventsim: Option<AsyncGossip>,
    /// Gossip rounds requested asynchronous (overlap regime) but executed
    /// synchronously because the backend has no async path — surfaced in
    /// [`CommStats::fallback_rounds`] instead of silently downgrading.
    fallback_rounds: u64,
    /// The fault-tolerant round state machine (`Some` iff
    /// [`TrainerOptions::round_timeout`] > 0): every comm action runs
    /// announce → gossip → collect → commit with a per-receive deadline;
    /// stalled peers are dropped by mixing-row renormalization, never by
    /// poisoning the trainer.
    rounds: Option<RoundMachine>,
    /// One simulated clock per node (critical-path time plane); advanced
    /// per action with the resolved per-node `node_costs`.
    clocks: VirtualClocks,
    /// The resolved per-node cost table (homogeneous from `opts.cost`
    /// unless `opts.node_costs` overrides it).
    node_costs: NodeCosts,
    /// Zero comm charge for `CommAction::None` steps (no per-step alloc).
    no_comm: Vec<f64>,
    /// SlowMo outer state (parameters at last sync + slow momentum buffer).
    slowmo_prev: Vec<f32>,
    slowmo_u: Vec<f32>,
    step: usize,
    /// Scratch for [`Trainer::global_loss`] mean-parameter evaluation.
    mean_buf: Vec<f32>,
}

impl Trainer {
    pub fn new(workload: Workload, init_params: Vec<f32>, opts: TrainerOptions) -> Result<Trainer> {
        let n = opts.topology.n;
        let d = workload.flat_dim();
        anyhow::ensure!(init_params.len() == d, "init params must match flat_dim");
        let root = Rng::new(opts.seed ^ 0x7EA1);
        let workers = (0..n)
            .map(|i| Worker {
                opt: if opts.momentum > 0.0 {
                    Optimizer::momentum_sgd(opts.momentum, opts.nesterov)
                } else {
                    Optimizer::sgd()
                },
                rng: root.split(i as u64),
                grad: vec![0.0; d],
                loss: 0.0,
                scratch: BatchScratch::default(),
            })
            .collect();
        let params = ParamMatrix::broadcast(n, &init_params);
        let schedule = schedule_for(opts.algorithm, opts.period, opts.aga_init_period, opts.aga_warmup)?;
        let node_costs = match &opts.node_costs {
            Some(c) => {
                anyhow::ensure!(
                    c.n() == n,
                    "cost table covers {} nodes, topology has {n}",
                    c.n()
                );
                c.validate()?;
                c.clone()
            }
            None => NodeCosts::homogeneous(opts.cost, n),
        };
        let backend: Box<dyn CommBackend> = match opts.backend {
            BackendKind::Shared => Box::new(SharedBackend::with_depth(
                &opts.topology,
                d,
                &node_costs,
                opts.cost_dim,
                opts.compression,
                opts.pipeline_depth.max(1),
            )),
            // The schedule itself says whether it can ever global-average
            // (pure-gossip schedules skip the all-to-all edge setup).
            BackendKind::Bus => Box::new(BusBackend::with_depth(
                &opts.topology,
                d,
                &node_costs,
                opts.cost_dim,
                opts.compression,
                schedule.uses_global_average(),
                opts.pipeline_depth.max(1),
            )),
            // Same core, real sockets: loopback listeners at `opts.listen`,
            // one stream per gossip edge, all-to-all streams dialed lazily
            // on the first global average.
            BackendKind::Tcp => Box::new(TcpBackend::new_loopback_with_depth(
                &opts.topology,
                d,
                &node_costs,
                opts.cost_dim,
                opts.compression,
                schedule.uses_global_average(),
                &opts.listen,
                opts.pipeline_depth.max(1),
            )?),
        };
        let rounds = if opts.round_timeout > 0.0 {
            require_deadline_support(backend.as_ref())?;
            anyhow::ensure!(
                opts.regime == Regime::Bsp,
                "--round-timeout drives the synchronous round protocol — the {:?} regime \
                 reorders rounds around it (run --regime bsp, or drop the timeout)",
                opts.regime
            );
            Some(RoundMachine::new(n, opts.round_timeout)?)
        } else {
            None
        };
        let pool = WorkerPool::with_options(opts.threads, opts.stealing, opts.pin);
        // Every backend overlaps uncompressed gossip now (the bus/tcp core
        // issues epoch-tagged rounds through the same pipeline contract as
        // the shared mixer). The only remaining downgrade is compressed
        // transmit — error-feedback residuals must update in transmit
        // order — so surface that once at startup and count every fallback
        // in CommStats::fallback_rounds.
        if opts.regime == Regime::Overlap && !backend.supports_overlap() {
            crate::obs::warn_once!(
                "coordinator.compressed-overlap-fallback",
                "compressed transmit cannot overlap (error-feedback state is \
                 ordered) — overlap rounds on the {} backend will run synchronously \
                 (counted in comm fallback_rounds)",
                opts.backend.name()
            );
        }
        let eventsim = if opts.regime == Regime::Async {
            anyhow::ensure!(
                opts.compression == Compression::None,
                "the async regime transmits raw iterates — per-receiver error-feedback \
                 state is undefined under bounded-stale mixing (disable comm.compression)"
            );
            Some(AsyncGossip::new(
                &opts.topology,
                &node_costs,
                d,
                opts.cost_dim,
                opts.max_staleness,
                opts.algorithm,
                opts.period,
                &params,
            )?)
        } else {
            None
        };
        let clocks = VirtualClocks::new(&opts.topology);
        let slowmo_prev = if opts.algorithm == AlgorithmKind::SlowMo { init_params } else { Vec::new() };
        let slowmo_u = if opts.algorithm == AlgorithmKind::SlowMo { vec![0.0; d] } else { Vec::new() };
        Ok(Trainer {
            workload,
            opts,
            workers,
            pending: VecDeque::new(),
            params,
            backend,
            pool,
            schedule,
            eventsim,
            fallback_rounds: 0,
            rounds,
            clocks,
            node_costs,
            no_comm: vec![0.0; n],
            slowmo_prev,
            slowmo_u,
            step: 0,
            mean_buf: vec![0.0; d],
        })
    }

    pub fn n(&self) -> usize {
        self.workers.len()
    }

    /// The persistent worker pool (sharding policy, poison state). Exposed
    /// so harnesses can inspect — or deliberately poison — the engine.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Mean worker loss at the last executed step.
    pub fn mean_loss(&self) -> f64 {
        self.workers.iter().map(|w| w.loss as f64).sum::<f64>() / self.workers.len() as f64
    }

    /// Average parameters across workers (x-bar), e.g. for evaluation.
    /// Overlap note: reflects the last DRAINED state; see [`Trainer::drain`].
    pub fn mean_params(&self) -> Vec<f32> {
        self.params.mean_row()
    }

    /// Worker i's parameter row (overlap note: last drained state).
    pub fn worker_params(&self, i: usize) -> &[f32] {
        self.params.row(i)
    }

    /// The live parameter matrix (read-only view; overlap note: last
    /// drained state).
    pub fn param_matrix(&self) -> &ParamMatrix {
        &self.params
    }

    /// Simulated wall-clock of the run: the critical path through the
    /// per-node virtual clocks (== every node's clock in a homogeneous run
    /// — bit-identical to the pre-virtual-time scalar clock).
    pub fn sim_seconds(&self) -> f64 {
        self.clocks.max_seconds()
    }

    /// The fastest node's virtual clock.
    pub fn sim_seconds_min(&self) -> f64 {
        self.clocks.min_seconds()
    }

    /// Straggler slack: critical path minus the fastest node's clock
    /// (0 in a homogeneous run).
    pub fn straggler_slack(&self) -> f64 {
        self.clocks.slack()
    }

    /// Total seconds nodes have spent stalled at synchronization barriers
    /// behind slower peers, summed over nodes.
    pub fn barrier_wait_seconds(&self) -> f64 {
        self.clocks.total_wait()
    }

    /// Per-node virtual clock readings (worker i = entry i).
    pub fn node_sim_seconds(&self) -> &[f64] {
        self.clocks.seconds()
    }

    /// The resolved per-node cost table this run bills against.
    pub fn node_costs(&self) -> &NodeCosts {
        &self.node_costs
    }

    pub fn current_period(&self) -> usize {
        self.schedule.current_period()
    }

    /// The backend's gossip-round clock (drives time-varying topologies;
    /// checkpointed).
    pub fn gossip_clock(&self) -> usize {
        self.backend.gossip_clock()
    }

    /// Overwrite the gossip clock (resume plumbing / test hook; normal
    /// restores go through [`Trainer::restore`]).
    pub fn set_gossip_clock(&mut self, rounds: usize) {
        self.backend.set_gossip_clock(rounds);
    }

    /// Which communication backend this trainer runs on.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Async gossip rounds issued but not yet drained (0 in BSP mode and at
    /// every drained boundary — eval, checkpoint, global average).
    pub fn pending_rounds(&self) -> usize {
        self.pending.len()
    }

    /// Cumulative measured communication (wire scalars, messages,
    /// alpha-beta seconds) over all completed actions — the same
    /// accounting on either backend — plus the clocks' cumulative
    /// barrier-wait breakdown. Overlap note: an in-flight async round is
    /// counted once drained.
    pub fn comm_stats(&self) -> CommStats {
        let mut total = self.backend.total();
        total.barrier_wait = self.clocks.total_wait();
        total.fallback_rounds = self.fallback_rounds;
        total
    }

    /// The run's unified counter registry ([`obs::Counters`]): every
    /// scattered tally — wire drops, round-machine repairs, overlap
    /// fallbacks, trace evictions, pool panics — under its stable name.
    /// This is THE source the CSV columns, the JSON arrays and the
    /// `# traffic:` line all render from.
    pub fn counters(&self) -> obs::Counters {
        obs::Counters {
            stale_frames: self.backend.total().stale_frames_dropped,
            peer_drops: self.peer_drops(),
            row_renorms: self.row_renorms(),
            fallback_rounds: self.fallback_rounds,
            spans_dropped: obs::thread_spans_dropped(),
            pool_panics: self.pool.panic_count(),
        }
    }

    /// Which execution regime this trainer runs (bsp | overlap | async).
    pub fn regime(&self) -> Regime {
        self.opts.regime
    }

    /// Peers dropped by round deadline so far (0 without `--round-timeout`).
    pub fn peer_drops(&self) -> u64 {
        self.rounds.as_ref().map(|m| m.drops).unwrap_or(0)
    }

    /// Mixing rows renormalized by those drops (0 without `--round-timeout`).
    pub fn row_renorms(&self) -> u64 {
        self.rounds.as_ref().map(|m| m.renorms).unwrap_or(0)
    }

    /// The round machine's checkpointable snapshot (`None` without
    /// `--round-timeout`).
    pub fn round_state(&self) -> Option<RoundState> {
        self.rounds.as_ref().map(|m| m.state())
    }

    /// Re-admit a peer previously dropped by the round machine (its
    /// pristine mixing weight folds back in). Errors without
    /// `--round-timeout` or if the node is not dropped.
    pub fn rejoin_node(&mut self, node: usize) -> Result<()> {
        match self.rounds.as_mut() {
            Some(m) => m.rejoin(node, self.backend.as_mut()),
            None => anyhow::bail!("no round machine: rejoin needs --round-timeout > 0"),
        }
    }

    /// Fault injection for tests and scenarios: mute `node` on the wire —
    /// it stays connected but transmits nothing, the wedged-peer failure
    /// mode the round deadline exists for. Errors on backends without
    /// fault injection (shared has no wire to go silent on).
    pub fn mute_node(&mut self, node: usize, muted: bool) -> Result<()> {
        self.backend.set_muted(node, muted)
    }

    /// The async regime's staleness histogram — entry s counts mix inputs
    /// that were s versions behind BSP-fresh. `None` outside the async
    /// regime.
    pub fn staleness_histogram(&self) -> Option<&[u64]> {
        self.eventsim.as_ref().map(|e| e.histogram())
    }

    /// `(max, mean)` staleness over all async mix inputs so far
    /// ((0, 0.0) outside the async regime, and always in strict mode).
    pub fn staleness(&self) -> (u64, f64) {
        self.eventsim.as_ref().map(|e| e.staleness()).unwrap_or((0, 0.0))
    }

    /// Mean per-link utilization of the event plane at the current
    /// critical path (0 outside the async regime).
    pub fn link_utilization(&self) -> f64 {
        self.eventsim
            .as_ref()
            .map(|e| e.link_utilization(self.clocks.max_seconds()))
            .unwrap_or(0.0)
    }

    /// Complete every in-flight overlap mix, oldest first. After this the
    /// visible state is bit-identical to the BSP schedule at the same
    /// step. No-op when nothing is pending (always, in BSP mode).
    pub fn drain(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let mut sp = obs::span(Phase::Drain, obs::CLUSTER);
        let mut sim = 0.0;
        while let Some(pending) = self.pending.pop_front() {
            let charge = self.backend.finish(&mut self.params, pending)?;
            sim += charge.stats.sim_seconds;
        }
        sp.set_sim(sim);
        Ok(())
    }

    /// Execute one iteration of Algorithm 1; returns the action taken.
    ///
    /// BSP mode: phases 1-2, then the communication action, synchronously.
    /// Overlap mode: sample first (parameter-independent), drain the
    /// previous round's mix, run gradients + optimizer, then LAUNCH this
    /// round's gossip on the pool and return while it runs. Global
    /// averages stay synchronous — they are the schedule's barriers.
    pub fn step_once(&mut self) -> Result<CommAction> {
        if self.opts.regime == Regime::Async {
            return self.step_async();
        }
        let overlap = self.opts.regime == Regime::Overlap;
        let k = self.step;
        let lr = self.opts.lr.at(k);
        if overlap {
            {
                let _sp = obs::span(Phase::Sample, obs::CLUSTER);
                self.sample_phase()?;
            }
            self.drain()?;
            let _sp = obs::span(Phase::Grad, obs::CLUSTER);
            self.grad_phase(lr, true)?;
        } else {
            debug_assert!(self.pending.is_empty());
            let _sp = obs::span(Phase::Grad, obs::CLUSTER);
            self.grad_phase(lr, false)?;
        }
        let mean_loss = self.mean_loss();
        // 3: communication action (the pool caps its own shard counts —
        // gossip at n rows, the global-average mean at d columns; one
        // policy, `WorkerPool::shards`). Every action reports the
        // CommCharge it incurred; the backend accumulates the run total.
        //
        // 4 (fused with 3 below): the per-node clocks advance by one
        // `compute_i + comm_i` charge under the action's barrier scope.
        // The fused addition and the exact f64 barrier max make the
        // homogeneous case bit-identical to the old scalar
        // `advance(compute + sim_seconds)` sequence.
        let action = self.schedule.action(k, mean_loss);
        if let Some(machine) = self.rounds.as_mut() {
            // Fault-tolerant path (BSP-only, validated at construction):
            // the action runs announce → gossip → collect → commit under
            // the per-receive deadline; a stalled peer is dropped by
            // renormalizing its mixing row and the round retries. The
            // returned charge bills what the COMMITTED round moved.
            let charge =
                machine.run(action, self.backend.as_mut(), &mut self.params, &self.pool)?;
            if action == CommAction::GlobalAverage
                && self.opts.algorithm == AlgorithmKind::SlowMo
            {
                self.slowmo_outer_update(lr);
            }
            advance_clocks(
                &mut self.clocks,
                &self.node_costs.compute,
                &charge.node_seconds,
                charge.barrier,
            );
            self.step += 1;
            return Ok(action);
        }
        match action {
            CommAction::None => {
                advance_clocks(
                    &mut self.clocks,
                    &self.node_costs.compute,
                    &self.no_comm,
                    BarrierScope::None,
                );
            }
            CommAction::Gossip => {
                let mut issued = None;
                if overlap {
                    let mut sp = obs::span(Phase::GossipIssue, obs::CLUSTER);
                    // SAFETY: until drain() completes this round, the
                    // trainer never takes &mut to params (accessors are
                    // read-only, every mutating path drains first), never
                    // drops the backend before the pending mix (field
                    // order), and never leaks the PendingComm.
                    issued = unsafe { self.backend.gossip_async(&self.params, &self.pool) }?;
                    if let Some(pending) = &issued {
                        sp.set_sim(pending.charge().stats.sim_seconds);
                    }
                }
                match issued {
                    Some(pending) => {
                        // Clocks charge at issue time — the round WILL
                        // complete (or the run fails), same as BSP billing.
                        let charge = pending.charge();
                        advance_clocks(
                            &mut self.clocks,
                            &self.node_costs.compute,
                            &charge.node_seconds,
                            charge.barrier,
                        );
                        self.pending.push_back(pending);
                    }
                    // Compressed transmit is the one remaining path with
                    // no async support (error-feedback residuals update in
                    // transmit order): the schedule falls back to the
                    // synchronous round, bit-identical either way — but in
                    // overlap mode the lost overlap is COUNTED, not silent
                    // (warned once at startup, tallied in
                    // CommStats::fallback_rounds).
                    None => {
                        if overlap {
                            self.fallback_rounds += 1;
                        }
                        let charge = self.backend.gossip(&mut self.params, &self.pool)?;
                        advance_clocks(
                            &mut self.clocks,
                            &self.node_costs.compute,
                            &charge.node_seconds,
                            charge.barrier,
                        );
                    }
                }
            }
            CommAction::GlobalAverage => {
                let charge = self.backend.global_average(&mut self.params, &self.pool)?;
                if self.opts.algorithm == AlgorithmKind::SlowMo {
                    self.slowmo_outer_update(lr);
                }
                advance_clocks(
                    &mut self.clocks,
                    &self.node_costs.compute,
                    &charge.node_seconds,
                    charge.barrier,
                );
            }
        }
        self.step += 1;
        Ok(action)
    }

    /// One global step of the event-driven regime: raise the cluster's
    /// iteration horizon by one and process events until every node has
    /// completed it. Between horizon raises nodes run at their own virtual
    /// pace (bounded-stale mixing; the horizon is a simulation artifact
    /// and bills nothing), and `run_until` always returns at a drained
    /// step boundary, so logging / eval / checkpointing see the same
    /// invariants as BSP. With `max_staleness = 0` and homogeneous costs
    /// this reproduces the BSP trajectory and the barrier-billed clocks
    /// bit-exactly (the [`crate::eventsim`] anchor).
    fn step_async(&mut self) -> Result<CommAction> {
        let k = self.step;
        let target = k + 1;
        let engine = self.eventsim.as_mut().expect("async regime constructs its engine");
        let workload = &self.workload;
        let workers = &mut self.workers;
        let pool = &self.pool;
        let lr = &self.opts.lr;
        let mut step_fn = |params: &mut ParamMatrix, batch: &[(usize, usize)]| {
            async_step_batch(workload, workers, params, pool, lr, batch)
        };
        let slowmo_on = self.opts.algorithm == AlgorithmKind::SlowMo;
        let sp = self.opts.slowmo;
        let slowmo_prev = &mut self.slowmo_prev;
        let slowmo_u = &mut self.slowmo_u;
        let mut sync_fn = |kk: usize, params: &mut ParamMatrix| -> Result<()> {
            if slowmo_on {
                slowmo_outer(params, slowmo_prev, slowmo_u, lr.at(kk), sp);
            }
            Ok(())
        };
        engine.run_until(
            target,
            &mut self.params,
            self.backend.as_mut(),
            &self.pool,
            &mut self.clocks,
            &self.node_costs,
            &mut step_fn,
            &mut sync_fn,
        )?;
        self.step = target;
        Ok(engine.action_at(k))
    }

    /// Phase 0 (overlap mode): every worker draws its batch into its own
    /// scratch, sharded across the pool. Pure RNG work — runs while the
    /// previous round's mix is still in flight.
    fn sample_phase(&mut self) -> Result<()> {
        let n = self.workers.len();
        let t = self.pool.shards(n);
        let per = (n + t - 1) / t;
        let workload = &self.workload;
        self.pool.run(
            self.workers
                .chunks_mut(per)
                .enumerate()
                .map(|(ci, wchunk)| {
                    move || {
                        for (j, w) in wchunk.iter_mut().enumerate() {
                            workload.sample_scratch(ci * per + j, &mut w.rng, &mut w.scratch);
                        }
                        Ok(())
                    }
                })
                .collect(),
        )
    }

    /// Phases 1-2: local gradient + optimizer update, one parameter row per
    /// worker, sharded across the pool. With `presampled` the batch comes
    /// from the worker's scratch (overlap mode); otherwise each worker
    /// samples inline first — the exact same RNG draws in the same
    /// per-worker order either way.
    fn grad_phase(&mut self, lr: f64, presampled: bool) -> Result<()> {
        let d = self.params.d();
        let n = self.workers.len();
        let t = self.pool.shards(n);
        let per = (n + t - 1) / t;
        let workload = &self.workload;
        let workers = &mut self.workers;
        let rows = self.params.row_blocks_mut(per);
        self.pool.run(
            workers
                .chunks_mut(per)
                .zip(rows)
                .enumerate()
                .map(|(ci, (wchunk, rchunk))| {
                    move || {
                        for (j, (w, row)) in
                            wchunk.iter_mut().zip(rchunk.chunks_mut(d)).enumerate()
                        {
                            step_worker(workload, ci * per + j, w, row, lr, presampled)?;
                        }
                        Ok(())
                    }
                })
                .collect(),
        )
    }

    /// SlowMo (Wang et al. 2019) outer update at a sync point. All workers
    /// hold the same averaged x at this point.
    fn slowmo_outer_update(&mut self, lr: f64) {
        slowmo_outer(&mut self.params, &mut self.slowmo_prev, &mut self.slowmo_u, lr, self.opts.slowmo);
    }

    fn consensus(&self) -> f64 {
        consensus_distance_pooled(&self.params, &self.pool)
    }

    /// The paper's plotted quantity: the global objective
    /// f(x-bar) = (1/n) sum_i f_i(x-bar) evaluated at the AVERAGED
    /// parameters on a fixed per-node eval batch. (The mean of local
    /// losses at local params under-reports divergence: drifted workers
    /// look "better" on their own shards — Definition 1's heterogeneity.)
    ///
    /// Sharded across the pool, one slot per node; the node totals reduce
    /// in ascending order, so every pool size produces the same bits.
    /// Drains the in-flight mix first (the mean must see the post-mix
    /// iterate, like the BSP schedule would). Eval is a synchronization
    /// point: gathering x-bar needs every row, so the virtual clocks
    /// advance to the barrier max (a no-op in homogeneous runs).
    pub fn global_loss(&mut self) -> Result<f64> {
        self.drain()?;
        self.clocks.sync();
        self.params.mean_into(&mut self.mean_buf);
        let n = self.workers.len();
        let d = self.mean_buf.len();
        // 4 fixed batches per node: low-noise eval (the transient-stage
        // gaps live in the 3rd decimal of the convex objective).
        const EVAL_BATCHES: usize = 4;
        let base = Rng::new(self.opts.seed ^ 0xE7A1_0055);
        let workload = &self.workload;
        let mean = &self.mean_buf;
        let mut node_totals = vec![0.0f64; n];
        let t = self.pool.shards(n);
        let per = (n + t - 1) / t;
        self.pool.run(
            node_totals
                .chunks_mut(per)
                .enumerate()
                .map(|(ci, chunk)| {
                    let base = &base;
                    move || {
                        let mut scratch = BatchScratch::default();
                        let mut grad_sink = vec![0.0f32; d];
                        for (j, slot) in chunk.iter_mut().enumerate() {
                            let i = ci * per + j;
                            let mut rng = base.split(i as u64); // FIXED eval stream per node
                            let mut total = 0.0f64;
                            for _ in 0..EVAL_BATCHES {
                                let batch = workload.sample(i, &mut rng, &mut scratch)?;
                                total += workload.grad_fn().call_into(mean, batch, &mut grad_sink)?
                                    as f64;
                            }
                            *slot = total;
                        }
                        Ok(())
                    }
                })
                .collect(),
        )?;
        Ok(node_totals.iter().sum::<f64>() / (n * EVAL_BATCHES) as f64)
    }

    /// Snapshot the full training state (see [`checkpoint`]): parameters,
    /// velocities, counters, the gossip clock, adaptive-schedule state,
    /// SlowMo outer buffers, the backend's cumulative traffic counters,
    /// any compressor residuals, and — since v4 — the per-node virtual
    /// clocks (so resumed heterogeneous runs keep their time axis). DRAINS
    /// the in-flight overlap mix first — the snapshot must be a BSP step
    /// boundary, never a half-mixed state — and, like eval, acts as a
    /// synchronization point for the virtual clocks (a no-op in
    /// homogeneous runs). Errors if only a strict subset of workers has
    /// velocity state (a partial snapshot could not resume exactly).
    pub fn checkpoint(&mut self) -> Result<checkpoint::Checkpoint> {
        self.drain()?;
        self.clocks.sync();
        let n = self.workers.len();
        let d = self.params.d();
        let with_vel = self.workers.iter().filter(|w| w.opt.velocity_buf().is_some()).count();
        let velocities = if with_vel == 0 {
            None
        } else if with_vel == n {
            let mut vels = ParamMatrix::zeros(n, d);
            for (i, w) in self.workers.iter().enumerate() {
                let v = w.opt.velocity_buf().expect("counted above");
                anyhow::ensure!(
                    v.len() == d,
                    "worker {i} velocity has {} entries, params have {d}",
                    v.len()
                );
                vels.copy_row_from(i, v);
            }
            Some(vels)
        } else {
            anyhow::bail!(
                "velocity state present on {with_vel}/{n} workers — refusing to write a partial checkpoint"
            );
        };
        let slowmo = (self.opts.algorithm == AlgorithmKind::SlowMo).then(|| {
            checkpoint::SlowMoState { prev: self.slowmo_prev.clone(), u: self.slowmo_u.clone() }
        });
        let ef_residuals = self.backend.export_compressor_state();
        let ef_compression = ef_residuals.as_ref().map(|_| self.opts.compression);
        Ok(checkpoint::Checkpoint {
            step: self.step as u64,
            sim_seconds: self.clocks.max_seconds(),
            params: self.params.clone(),
            velocities,
            gossip_clock: self.backend.gossip_clock() as u64,
            schedule: self.schedule.export_state(),
            slowmo,
            rng_states: self.workers.iter().map(|w| w.rng.state()).collect(),
            comm: Some(self.comm_stats()),
            ef_residuals,
            ef_compression,
            clocks: Some(checkpoint::ClockState {
                seconds: self.clocks.seconds().to_vec(),
                waited: self.clocks.waited().to_vec(),
            }),
            eventsim: self.eventsim.as_ref().map(|e| e.export_state()),
            rounds: self.rounds.as_ref().map(|m| m.state()),
        })
    }

    /// Restore a snapshot (params, velocities, counters, gossip clock,
    /// schedule + SlowMo state, worker RNG streams). A v2 checkpoint makes
    /// a fresh trainer replay bit-identically to the unbroken run; for v1
    /// files (no RNG block) the caller must replay the data streams itself.
    /// The workload/data/schedule config must match the one the snapshot
    /// came from; shapes are validated. Any in-flight mix is drained first
    /// (its result is then overwritten wholesale).
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        self.drain()?;
        let n = self.workers.len();
        let d = self.params.d();
        anyhow::ensure!(
            ck.params.n() == n && ck.params.d() == d,
            "checkpoint is {}x{}, trainer is {n}x{d}",
            ck.params.n(),
            ck.params.d()
        );
        self.params.as_mut_slice().copy_from_slice(ck.params.as_slice());
        match &ck.velocities {
            Some(v) => {
                anyhow::ensure!(
                    v.n() == n && v.d() == d,
                    "checkpoint velocities are {}x{}, trainer is {n}x{d}",
                    v.n(),
                    v.d()
                );
                for (w, row) in self.workers.iter_mut().zip(v.rows()) {
                    w.opt.set_velocity(row);
                }
            }
            None => {
                // Snapshot predates the first momentum step (or momentum is
                // off): clear any live velocity so the resumed trajectory
                // matches the original.
                for w in self.workers.iter_mut() {
                    w.opt.set_velocity(&[]);
                }
            }
        }
        self.backend.set_gossip_clock(ck.gossip_clock as usize);
        // Traffic counters continue from the snapshot (pre-v3 files carry
        // none — counters restart at zero, documented in `checkpoint`).
        // The barrier-wait breakdown lives in the clock state and the
        // fallback tally in the trainer, so the backend total carries
        // both zeroed.
        let mut comm = ck.comm.unwrap_or_default();
        self.fallback_rounds = comm.fallback_rounds;
        comm.barrier_wait = 0.0;
        comm.fallback_rounds = 0;
        self.backend.restore_total(comm);
        // Compressed runs: re-inject the exact error-feedback residuals the
        // interrupted run was carrying (None zeroes them). The codec that
        // produced them must match this run's — residuals are meaningless
        // under a different compression scheme.
        if let Some(c) = ck.ef_compression {
            anyhow::ensure!(
                c == self.opts.compression,
                "checkpoint residuals were written by {:?} compression, this run uses {:?}",
                c,
                self.opts.compression
            );
        }
        self.backend.import_compressor_state(ck.ef_residuals.as_ref())?;
        match &ck.schedule {
            Some(st) => self.schedule.import_state(st),
            None => {
                // v1 / fixed-schedule snapshot: rebuild the schedule from
                // config so no adapted state from *this* process leaks past
                // the restore point (mirrors the velocity reset above).
                self.schedule = schedule_for(
                    self.opts.algorithm,
                    self.opts.period,
                    self.opts.aga_init_period,
                    self.opts.aga_warmup,
                )?;
            }
        }
        if self.opts.algorithm == AlgorithmKind::SlowMo {
            match &ck.slowmo {
                Some(sm) => {
                    anyhow::ensure!(
                        sm.prev.len() == d && sm.u.len() == d,
                        "checkpoint slowmo buffers have {} / {} entries, want {d}",
                        sm.prev.len(),
                        sm.u.len()
                    );
                    self.slowmo_prev.clear();
                    self.slowmo_prev.extend_from_slice(&sm.prev);
                    self.slowmo_u.clear();
                    self.slowmo_u.extend_from_slice(&sm.u);
                }
                None => {
                    // v1 snapshot without outer state: re-anchor the outer
                    // loop at the restored ensemble mean with zero slow
                    // momentum (exact resume is impossible without it).
                    self.params.mean_into(&mut self.mean_buf);
                    self.slowmo_prev.clear();
                    self.slowmo_prev.extend_from_slice(&self.mean_buf);
                    self.slowmo_u.clear();
                    self.slowmo_u.resize(d, 0.0);
                }
            }
        }
        if !ck.rng_states.is_empty() {
            anyhow::ensure!(
                ck.rng_states.len() == n,
                "checkpoint has {} rng states for {n} workers",
                ck.rng_states.len()
            );
            for (w, st) in self.workers.iter_mut().zip(&ck.rng_states) {
                w.rng = Rng::from_state(*st);
            }
        }
        self.step = ck.step as usize;
        // Per-node time axis: a v4 checkpoint restores every node's clock
        // and wait account exactly; older files carry one scalar clock, so
        // every node resumes at it (lockstep) with zeroed waits.
        match &ck.clocks {
            Some(cs) => self.clocks.restore(&cs.seconds, &cs.waited)?,
            None => self.clocks.restore_uniform(ck.sim_seconds),
        }
        // Event plane: a v5 snapshot restores the per-edge in-flight /
        // stale state exactly (mid-flight payloads resume their virtual
        // deliveries); a pre-v5 or BSP-written snapshot re-seeds every
        // link cache from the restored rows at the boundary version.
        // Symmetric strictness with the engine's max_staleness check: an
        // async-written snapshot cannot resume losslessly under another
        // regime (its mid-flight payloads and staleness accounts would be
        // silently dropped), so that mismatch is an error, not a downgrade.
        match (self.eventsim.as_mut(), &ck.eventsim) {
            (Some(engine), Some(st)) => {
                engine.import_state(st, ck.step as usize, ck.gossip_clock as usize)?;
            }
            (Some(engine), None) => {
                engine.reset(&self.params, ck.step as usize, ck.gossip_clock as usize);
            }
            (None, Some(_)) => anyhow::bail!(
                "checkpoint was written by the async regime (it carries per-link in-flight \
                 state) — resume with --regime async and the same max_staleness"
            ),
            (None, None) => {}
        }
        // Round membership (v7): a machine-carrying snapshot re-applies
        // every recorded drop to the backend so the resumed run mixes over
        // the same degraded rows. A pre-v7 (or machine-less) snapshot
        // resets this run's machine to full membership; a degraded
        // snapshot restored WITHOUT a machine would silently un-drop its
        // dead peers, so that mismatch is an error.
        match (self.rounds.as_mut(), &ck.rounds) {
            (Some(machine), Some(st)) => machine.restore(st, self.backend.as_mut())?,
            (Some(machine), None) => {
                let pristine = RoundState {
                    round: 0,
                    drops: 0,
                    renorms: 0,
                    rejoins: 0,
                    alive: vec![true; n],
                };
                machine.restore(&pristine, self.backend.as_mut())?;
            }
            (None, Some(st)) => {
                anyhow::ensure!(
                    st.alive.iter().all(|&a| a),
                    "checkpoint carries a degraded round membership ({} of {} peers alive) — \
                     resume with --round-timeout > 0 so the drops stay in force",
                    st.alive.iter().filter(|&&a| a).count(),
                    st.alive.len()
                );
            }
            (None, None) => {}
        }
        Ok(())
    }

    /// Run `steps` iterations, recording metrics every `log_every` steps
    /// (plus the final step). Returns the history. Logged rows always
    /// observe DRAINED state, so BSP and overlap runs log identical
    /// histories.
    pub fn run(&mut self, steps: usize, label: &str) -> Result<History> {
        let mut hist = History::new(label);
        // Recording f(x-bar) costs one extra grad pass per node; for the
        // large LM workload the curve uses the (iid) mean train loss
        // instead.
        let cheap_eval = !matches!(self.workload, Workload::Lm { .. });
        for s in 0..steps {
            self.step_once()?;
            let last = s + 1 == steps;
            if s % self.opts.log_every.max(1) == 0 || last {
                self.drain()?;
                // Capture the clock spread BEFORE the eval barrier syncs
                // everyone up — the logged slack is the cluster's spread as
                // it ran, not post-gather.
                let sim_min = self.clocks.min_seconds();
                let slack = self.clocks.slack();
                let loss =
                    if cheap_eval { self.global_loss()? } else { self.mean_loss() };
                let comm = self.comm_stats();
                let counters = self.counters();
                let (stale_max, stale_mean) = self.staleness();
                hist.push(Record {
                    step: self.step - 1,
                    loss,
                    consensus: self.consensus(),
                    lr: self.opts.lr.at(self.step - 1),
                    sim_seconds: self.clocks.max_seconds(),
                    comm_scalars: comm.scalars_sent,
                    comm_msgs: comm.msgs,
                    sim_min_seconds: sim_min,
                    straggler_slack: slack,
                    barrier_wait: comm.barrier_wait,
                    stale_max,
                    stale_mean,
                    link_util: self.link_utilization(),
                    peer_drops: counters.peer_drops,
                    row_renorms: counters.row_renorms,
                    stale_frames: counters.stale_frames,
                    fallback_rounds: counters.fallback_rounds,
                    spans_dropped: counters.spans_dropped,
                    pool_panics: counters.pool_panics,
                });
            }
        }
        self.drain()?;
        Ok(hist)
    }
}

/// Advance the per-node clocks by one `compute + comm` charge under
/// `barrier` and — when tracing — emit the barrier stall the advance
/// opened as an instant probe. The clock arithmetic is identical traced
/// or untraced (the probe only reads the before/after wait totals).
fn advance_clocks(
    clocks: &mut VirtualClocks,
    compute: &[f64],
    comm: &[f64],
    barrier: BarrierScope,
) {
    if obs::enabled() {
        let before = clocks.total_wait();
        clocks.advance(compute, comm, barrier);
        let wait = clocks.total_wait() - before;
        if wait > 0.0 {
            obs::instant(Phase::Barrier, obs::CLUSTER, wait);
        }
    } else {
        clocks.advance(compute, comm, barrier);
    }
}

/// SlowMo (Wang et al. 2019) outer update over an already-averaged
/// parameter matrix. Free function so BOTH regimes — the BSP/overlap step
/// loop and the event engine's barrier hook — apply the identical
/// arithmetic:
///   u <- beta u + (x_prev_sync - x_avg) / gamma
///   x <- x_prev_sync - alpha gamma u
fn slowmo_outer(
    params: &mut ParamMatrix,
    prev: &mut [f32],
    u: &mut [f32],
    lr: f64,
    sp: SlowMoParams,
) {
    let gamma = lr.max(1e-12) as f32;
    let beta = sp.beta as f32;
    let alpha = sp.alpha as f32;
    {
        let avg = params.row(0);
        for ((u, prev), a) in u.iter_mut().zip(prev.iter_mut()).zip(avg) {
            *u = beta * *u + (*prev - *a) / gamma;
            *prev -= alpha * gamma * *u;
        }
    }
    params.fill_rows(prev);
}

/// Phases 1-2 for a batch of `(node, iteration)` pairs handed over by the
/// event engine, sharded across the pool. The engine guarantees the nodes
/// are pairwise distinct, so the raw worker/row views are disjoint — the
/// same soundness pattern as [`mixer::Mixer::gossip_async`]'s jobs. Each
/// node bills its own iteration's learning rate, so per-node schedules
/// stay exact even when iterations interleave.
fn async_step_batch(
    workload: &Workload,
    workers: &mut [Worker],
    params: &mut ParamMatrix,
    pool: &WorkerPool,
    lr: &LrSchedule,
    batch: &[(usize, usize)],
) -> Result<()> {
    debug_assert!(
        {
            let mut nodes: Vec<usize> = batch.iter().map(|&(n, _)| n).collect();
            nodes.sort_unstable();
            nodes.windows(2).all(|w| w[0] != w[1])
        },
        "event engine handed a batch with duplicate nodes"
    );
    if batch.is_empty() {
        return Ok(());
    }
    let d = params.d();
    if pool.shards(batch.len()) <= 1 {
        for &(node, iter) in batch {
            step_worker(workload, node, &mut workers[node], params.row_mut(node), lr.at(iter), false)?;
        }
        return Ok(());
    }
    let per = pool.chunk_len(batch.len());
    let wbase = workers.as_mut_ptr() as usize;
    let pbase = params.as_mut_slice().as_mut_ptr() as usize;
    pool.run(
        batch
            .chunks(per)
            .map(|chunk| {
                move || {
                    for &(node, iter) in chunk {
                        // SAFETY: nodes are pairwise distinct across the
                        // whole batch (asserted above), so each Worker and
                        // parameter row is reached by exactly one job;
                        // workload/lr are shared reads; both allocations
                        // outlive the batch because pool.run joins before
                        // returning.
                        let w = unsafe { &mut *(wbase as *mut Worker).add(node) };
                        let row = unsafe {
                            std::slice::from_raw_parts_mut((pbase as *mut f32).add(node * d), d)
                        };
                        step_worker(workload, node, w, row, lr.at(iter), false)?;
                    }
                    Ok(())
                }
            })
            .collect(),
    )
}

/// Phase 1-2 for one worker: sample its batch (unless presampled by the
/// overlap phase 0), run the AOT grad graph, apply the local optimizer step
/// to its parameter row. Free function so the pool jobs can call it without
/// touching the trainer.
fn step_worker(
    workload: &Workload,
    i: usize,
    w: &mut Worker,
    row: &mut [f32],
    lr: f64,
    presampled: bool,
) -> Result<()> {
    if !presampled {
        workload.sample_scratch(i, &mut w.rng, &mut w.scratch);
    }
    let batch = workload.literals(&w.scratch)?;
    w.loss = workload.grad_fn().call_into(row, batch, &mut w.grad)?;
    w.opt.step(row, &w.grad, lr);
    Ok(())
}

/// Build a logistic-regression workload from the default artifacts
/// (paper §5.1 experiments).
pub fn logreg_workload(
    rt: Arc<Runtime>,
    n: usize,
    samples_per_node: usize,
    non_iid: bool,
    seed: u64,
) -> Result<(Workload, Vec<f32>)> {
    let spec = rt.manifest.find("logreg", "grad", None)?.clone();
    let grad = GradFn::new(rt, &spec.name)?;
    let d = spec.flat_dim;
    let data = LogRegData::generate(n, d, samples_per_node, non_iid, seed);
    let init = model::logreg_layout(d).init(seed);
    Ok((Workload::LogReg { data, grad }, init))
}

/// Build the MLP classification workload (image-classification substitute).
pub fn mlp_workload(
    rt: Arc<Runtime>,
    n: usize,
    samples_per_node: usize,
    non_iid: bool,
    seed: u64,
) -> Result<(Workload, Vec<f32>)> {
    let spec = rt.manifest.find("mlp", "grad", None)?.clone();
    let in_dim = spec.meta_usize("in_dim").unwrap();
    let hidden = spec.meta_usize("hidden").unwrap();
    let classes = spec.meta_usize("classes").unwrap();
    let eval_spec = rt.manifest.find("mlp", "eval", None).ok().cloned();
    let grad = GradFn::new(rt.clone(), &spec.name)?;
    let eval = match eval_spec {
        Some(e) => Some(EvalFn::new(rt, &e.name)?),
        None => None,
    };
    let eval_batch = eval.as_ref().map(|e| e.spec.meta_usize("batch").unwrap_or(256)).unwrap_or(256);
    let data = ClusterData::generate(n, in_dim, classes, samples_per_node, eval_batch, non_iid, seed);
    let init = model::mlp_layout(in_dim, hidden, classes).init(seed);
    Ok((Workload::Mlp { data, grad, eval }, init))
}

/// Build the LM workload (BERT substitute) for a transformer config tag.
pub fn lm_workload(rt: Arc<Runtime>, tag: &str, seed: u64) -> Result<(Workload, Vec<f32>)> {
    let spec = rt.manifest.find("transformer", "grad", Some(tag))?.clone();
    let cfg = model::TransformerConfig {
        vocab: spec.meta_usize("vocab").unwrap(),
        d_model: spec.meta_usize("d_model").unwrap(),
        n_layers: spec.meta_usize("n_layers").unwrap(),
        n_heads: spec.meta_usize("n_heads").unwrap(),
        d_ff: spec.meta_usize("d_ff").unwrap(),
        seq_len: spec.meta_usize("seq_len").unwrap(),
    };
    let eval_spec = rt.manifest.find("transformer", "eval", Some(tag)).ok().cloned();
    let grad = GradFn::new(rt.clone(), &spec.name)?;
    let eval = match eval_spec {
        Some(e) => Some(EvalFn::new(rt, &e.name)?),
        None => None,
    };
    let corpus = TokenCorpus::new(cfg.vocab, 4, seed);
    let init = model::transformer_layout(&cfg).init(seed);
    Ok((Workload::Lm { corpus, grad, eval, seq_plus_one: cfg.seq_len + 1 }, init))
}

/// Evaluate the MLP workload's held-out accuracy at the mean parameters.
pub fn mlp_eval_accuracy(trainer: &Trainer) -> Result<Option<f32>> {
    if let Workload::Mlp { data, eval: Some(eval), .. } = &trainer.workload {
        let mean = trainer.mean_params();
        let batch = vec![
            lit_f32(&data.eval_x, &eval.spec.inputs[1].shape)?,
            lit_i32(&data.eval_y, &eval.spec.inputs[2].shape)?,
        ];
        return Ok(Some(eval.call(&mean, &batch)?));
    }
    Ok(None)
}

/// Evaluate the LM workload's held-out loss at the mean parameters.
pub fn lm_eval_loss(trainer: &Trainer, eval_batches: usize, seed: u64) -> Result<Option<f32>> {
    if let Workload::Lm { corpus, eval: Some(eval), seq_plus_one, .. } = &trainer.workload {
        let mean = trainer.mean_params();
        let b = eval.spec.meta_usize("batch").unwrap_or(8);
        let mut rng = Rng::new(seed ^ 0xE7A1);
        let mut toks = Vec::new();
        let mut total = 0.0f32;
        for _ in 0..eval_batches {
            corpus.sample_batch(b, *seq_plus_one, &mut rng, &mut toks);
            let batch = vec![lit_i32(&toks, &eval.spec.inputs[1].shape)?];
            total += eval.call(&mean, &batch)?;
        }
        return Ok(Some(total / eval_batches as f32));
    }
    Ok(None)
}
