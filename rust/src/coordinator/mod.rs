//! The training coordinator: Algorithm 1 (and its whole family) over n
//! workers, with the model compute executed through PJRT.
//!
//! Per iteration k (the paper's main recursion, eq. (10)):
//!   1. every worker samples a local minibatch and executes the AOT grad
//!      graph: `(loss, g_i) = grad(x_i, batch_i)`;
//!   2. local optimizer update `x_i <- x_i - gamma (momentum) g_i`;
//!   3. the [`Schedule`] decides the communication action:
//!      gossip mix, exact global average (ring all-reduce), or nothing;
//!   4. the [`SimClock`] advances by the alpha-beta cost of the action so a
//!      single-process run reports paper-style wall-clock columns.
//!
//! Workers are deterministic: worker i's batch stream is `seed.split(i)`,
//! so every experiment is replayable bit-for-bit.

pub mod checkpoint;
pub mod mixer;

use std::rc::Rc;

use anyhow::Result;

use crate::algorithms::{schedule_for, AlgorithmKind, CommAction, Schedule, SlowMoParams};
use crate::config::ExperimentConfig;
use crate::costmodel::{CostModel, SimClock};
use crate::data::{ClusterData, LogRegData, TokenCorpus};
use crate::metrics::{consensus_distance, History, Record};
use crate::model;
use crate::optim::{LrSchedule, Optimizer};
use crate::rng::Rng;
use crate::runtime::{lit_f32, lit_i32, EvalFn, GradFn, Runtime};
use crate::topology::Topology;

/// The workload: dataset + AOT executables + batch plumbing.
pub enum Workload {
    LogReg { data: LogRegData, grad: GradFn },
    Mlp { data: ClusterData, grad: GradFn, eval: Option<EvalFn> },
    Lm { corpus: TokenCorpus, grad: GradFn, eval: Option<EvalFn>, seq_plus_one: usize },
}

impl Workload {
    pub fn grad_fn(&self) -> &GradFn {
        match self {
            Workload::LogReg { grad, .. } => grad,
            Workload::Mlp { grad, .. } => grad,
            Workload::Lm { grad, .. } => grad,
        }
    }

    pub fn flat_dim(&self) -> usize {
        self.grad_fn().flat_dim()
    }

    fn batch_size(&self) -> usize {
        self.grad_fn().spec.meta_usize("batch").unwrap_or(32)
    }

    /// Build this step's batch literals for `worker`.
    fn sample(&self, worker: usize, rng: &mut Rng, scratch: &mut BatchScratch) -> Result<Vec<xla::Literal>> {
        match self {
            Workload::LogReg { data, grad } => {
                let m = self.batch_size();
                data.sample_batch(worker, m, rng, &mut scratch.x, &mut scratch.yf);
                Ok(vec![
                    lit_f32(&scratch.x, &grad.spec.inputs[1].shape)?,
                    lit_f32(&scratch.yf, &grad.spec.inputs[2].shape)?,
                ])
            }
            Workload::Mlp { data, grad, .. } => {
                let m = self.batch_size();
                data.sample_batch(worker, m, rng, &mut scratch.x, &mut scratch.yi);
                Ok(vec![
                    lit_f32(&scratch.x, &grad.spec.inputs[1].shape)?,
                    lit_i32(&scratch.yi, &grad.spec.inputs[2].shape)?,
                ])
            }
            Workload::Lm { corpus, grad, seq_plus_one, .. } => {
                let b = self.batch_size();
                corpus.sample_batch(b, *seq_plus_one, rng, &mut scratch.yi);
                Ok(vec![lit_i32(&scratch.yi, &grad.spec.inputs[1].shape)?])
            }
        }
    }
}

#[derive(Default)]
struct BatchScratch {
    x: Vec<f32>,
    yf: Vec<f32>,
    yi: Vec<i32>,
}

/// Everything the trainer needs beyond the workload.
pub struct TrainerOptions {
    pub algorithm: AlgorithmKind,
    pub topology: Topology,
    pub period: usize,
    pub aga_init_period: usize,
    pub aga_warmup: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub nesterov: bool,
    pub seed: u64,
    pub slowmo: SlowMoParams,
    /// Cost model for the simulated clock; `cost_dim` lets a small stand-in
    /// model emulate the paper's full-size model in the time columns
    /// (e.g. the MLP suite bills communication at ResNet-50's d = 25.5e6).
    pub cost: CostModel,
    pub cost_dim: usize,
    /// Record a metrics row every `log_every` steps (consensus distance is
    /// O(n d), so dense logging of big models costs time).
    pub log_every: usize,
}

impl TrainerOptions {
    pub fn from_config(cfg: &ExperimentConfig, cost_dim: usize) -> TrainerOptions {
        TrainerOptions {
            algorithm: cfg.algorithm,
            topology: cfg.topology(),
            period: cfg.period,
            aga_init_period: cfg.aga_init_period,
            aga_warmup: cfg.aga_warmup,
            lr: LrSchedule::StepDecay {
                lr: cfg.lr,
                every: cfg.lr_decay_every,
                factor: cfg.lr_decay_factor,
            },
            momentum: cfg.momentum,
            nesterov: cfg.nesterov,
            seed: cfg.seed,
            slowmo: SlowMoParams::default(),
            cost: CostModel::calibrated_resnet50(),
            cost_dim,
            log_every: cfg.log_every,
        }
    }
}

/// Per-worker state.
struct Worker {
    params: Vec<f32>,
    opt: Optimizer,
    rng: Rng,
    grad: Vec<f32>,
    loss: f32,
}

/// The coordinator.
pub struct Trainer {
    pub workload: Workload,
    opts: TrainerOptions,
    workers: Vec<Worker>,
    mixer: mixer::Mixer,
    schedule: Box<dyn Schedule>,
    clock: SimClock,
    /// SlowMo outer state (parameters at last sync + slow momentum buffer).
    slowmo_prev: Vec<f32>,
    slowmo_u: Vec<f32>,
    step: usize,
    scratch: BatchScratch,
    /// Parameter matrix view used by the mixer (moved in/out each action).
    params_buf: Vec<Vec<f32>>,
}

impl Trainer {
    pub fn new(workload: Workload, init_params: Vec<f32>, opts: TrainerOptions) -> Trainer {
        let n = opts.topology.n;
        let d = workload.flat_dim();
        assert_eq!(init_params.len(), d, "init params must match flat_dim");
        let root = Rng::new(opts.seed ^ 0x7EA1);
        let workers = (0..n)
            .map(|i| Worker {
                params: init_params.clone(),
                opt: if opts.momentum > 0.0 {
                    Optimizer::momentum_sgd(opts.momentum, opts.nesterov)
                } else {
                    Optimizer::sgd()
                },
                rng: root.split(i as u64),
                grad: vec![0.0; d],
                loss: 0.0,
            })
            .collect();
        let mixer = mixer::Mixer::new(&opts.topology, d);
        let schedule = schedule_for(opts.algorithm, opts.period, opts.aga_init_period, opts.aga_warmup);
        let slowmo_prev = if opts.algorithm == AlgorithmKind::SlowMo { init_params.clone() } else { Vec::new() };
        let slowmo_u = if opts.algorithm == AlgorithmKind::SlowMo { vec![0.0; d] } else { Vec::new() };
        Trainer {
            workload,
            opts,
            workers,
            mixer,
            schedule,
            clock: SimClock::default(),
            slowmo_prev,
            slowmo_u,
            step: 0,
            scratch: BatchScratch::default(),
            params_buf: (0..n).map(|_| vec![0.0; d]).collect(),
        }
    }

    pub fn n(&self) -> usize {
        self.workers.len()
    }

    /// Mean worker loss at the last executed step.
    pub fn mean_loss(&self) -> f64 {
        self.workers.iter().map(|w| w.loss as f64).sum::<f64>() / self.workers.len() as f64
    }

    /// Average parameters across workers (x-bar), e.g. for evaluation.
    pub fn mean_params(&self) -> Vec<f32> {
        let d = self.workers[0].params.len();
        let mut mean = vec![0.0f32; d];
        for w in &self.workers {
            for (m, v) in mean.iter_mut().zip(&w.params) {
                *m += v;
            }
        }
        let inv = 1.0 / self.workers.len() as f32;
        mean.iter_mut().for_each(|m| *m *= inv);
        mean
    }

    pub fn worker_params(&self, i: usize) -> &[f32] {
        &self.workers[i].params
    }

    pub fn sim_seconds(&self) -> f64 {
        self.clock.seconds
    }

    pub fn current_period(&self) -> usize {
        self.schedule.current_period()
    }

    /// Execute one iteration of Algorithm 1; returns the action taken.
    pub fn step_once(&mut self) -> Result<CommAction> {
        let k = self.step;
        let lr = self.opts.lr.at(k);
        // 1+2: local gradient + update per worker.
        for i in 0..self.workers.len() {
            let batch = {
                let w = &mut self.workers[i];
                self.workload.sample(i, &mut w.rng, &mut self.scratch)?
            };
            let w = &mut self.workers[i];
            w.loss = self.workload.grad_fn().call_into(&w.params, batch, &mut w.grad)?;
            w.opt.step(&mut w.params, &w.grad, lr);
        }
        let mean_loss = self.mean_loss();
        // 3: communication action.
        let action = self.schedule.action(k, mean_loss);
        match action {
            CommAction::None => {}
            CommAction::Gossip => {
                self.with_param_matrix(|mixer, params| mixer.gossip(params));
            }
            CommAction::GlobalAverage => {
                self.with_param_matrix(|mixer, params| mixer.global_average(params));
                if self.opts.algorithm == AlgorithmKind::SlowMo {
                    self.slowmo_outer_update(lr);
                }
            }
        }
        // 4: simulated clock.
        let dt = self.opts.cost.compute
            + match action {
                CommAction::None => 0.0,
                CommAction::Gossip => self.opts.cost.gossip(&self.opts.topology, self.opts.cost_dim),
                CommAction::GlobalAverage => {
                    self.opts.cost.all_reduce(self.opts.topology.n, self.opts.cost_dim)
                }
            };
        self.clock.advance(dt);
        self.step += 1;
        Ok(action)
    }

    /// Move worker params into the contiguous matrix, run `f`, move back.
    fn with_param_matrix<F: FnOnce(&mut mixer::Mixer, &mut [Vec<f32>])>(&mut self, f: F) {
        for (buf, w) in self.params_buf.iter_mut().zip(&mut self.workers) {
            std::mem::swap(buf, &mut w.params);
        }
        f(&mut self.mixer, &mut self.params_buf);
        for (buf, w) in self.params_buf.iter_mut().zip(&mut self.workers) {
            std::mem::swap(buf, &mut w.params);
        }
    }

    /// SlowMo (Wang et al. 2019) outer update at a sync point. All workers
    /// hold the same averaged x at this point.
    fn slowmo_outer_update(&mut self, lr: f64) {
        let gamma = lr.max(1e-12) as f32;
        let beta = self.opts.slowmo.beta as f32;
        let alpha = self.opts.slowmo.alpha as f32;
        let avg = self.workers[0].params.clone();
        for ((u, prev), a) in self.slowmo_u.iter_mut().zip(&mut self.slowmo_prev).zip(&avg) {
            *u = beta * *u + (*prev - *a) / gamma;
            *prev -= alpha * gamma * *u;
        }
        for w in &mut self.workers {
            w.params.copy_from_slice(&self.slowmo_prev);
        }
    }

    fn consensus(&self) -> f64 {
        // consensus_distance over a view of worker params.
        let params: Vec<Vec<f32>> = self.workers.iter().map(|w| w.params.clone()).collect();
        consensus_distance(&params)
    }

    /// The paper's plotted quantity: the global objective
    /// f(x-bar) = (1/n) sum_i f_i(x-bar) evaluated at the AVERAGED
    /// parameters on a fixed per-node eval batch. (The mean of local
    /// losses at local params under-reports divergence: drifted workers
    /// look "better" on their own shards — Definition 1's heterogeneity.)
    pub fn global_loss(&mut self) -> Result<f64> {
        let mean = self.mean_params();
        let d = mean.len();
        let mut grad_sink = vec![0.0f32; d];
        let mut total = 0.0f64;
        let n = self.workers.len();
        let base = Rng::new(self.opts.seed ^ 0xE7A1_0055);
        // 4 fixed batches per node: low-noise eval (the transient-stage
        // gaps live in the 3rd decimal of the convex objective).
        const EVAL_BATCHES: usize = 4;
        for i in 0..n {
            let mut rng = base.split(i as u64); // FIXED eval stream per node
            for _ in 0..EVAL_BATCHES {
                let batch = self.workload.sample(i, &mut rng, &mut self.scratch)?;
                total += self.workload.grad_fn().call_into(&mean, batch, &mut grad_sink)? as f64;
            }
        }
        Ok(total / (n * EVAL_BATCHES) as f64)
    }

    /// Snapshot the full training state (see [`checkpoint`]).
    pub fn checkpoint(&self) -> checkpoint::Checkpoint {
        let velocities: Vec<Vec<f32>> =
            self.workers.iter().filter_map(|w| w.opt.velocity_buf().map(|v| v.to_vec())).collect();
        checkpoint::Checkpoint {
            step: self.step as u64,
            sim_seconds: self.clock.seconds,
            params: self.workers.iter().map(|w| w.params.clone()).collect(),
            velocities: if velocities.len() == self.workers.len() { velocities } else { Vec::new() },
        }
    }

    /// Restore a snapshot (params, velocities, step counter, sim clock).
    /// The workload/data/schedule must match the one the snapshot came
    /// from; parameter shape is validated.
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        anyhow::ensure!(ck.params.len() == self.workers.len(), "checkpoint node count");
        let d = self.workload.flat_dim();
        anyhow::ensure!(ck.params.iter().all(|p| p.len() == d), "checkpoint flat_dim");
        for (w, p) in self.workers.iter_mut().zip(&ck.params) {
            w.params.copy_from_slice(p);
        }
        if !ck.velocities.is_empty() {
            for (w, v) in self.workers.iter_mut().zip(&ck.velocities) {
                w.opt.set_velocity(v);
            }
        }
        self.step = ck.step as usize;
        self.clock.seconds = ck.sim_seconds;
        Ok(())
    }

    /// Run `steps` iterations, recording metrics every `log_every` steps
    /// (plus the final step). Returns the history.
    pub fn run(&mut self, steps: usize, label: &str) -> Result<History> {
        let mut hist = History::new(label);
        // Recording f(x-bar) costs one extra grad pass per node; for the
        // large LM workload the curve uses the (iid) mean train loss
        // instead.
        let cheap_eval = !matches!(self.workload, Workload::Lm { .. });
        for s in 0..steps {
            self.step_once()?;
            let last = s + 1 == steps;
            if s % self.opts.log_every.max(1) == 0 || last {
                let loss =
                    if cheap_eval { self.global_loss()? } else { self.mean_loss() };
                hist.push(Record {
                    step: self.step - 1,
                    loss,
                    consensus: self.consensus(),
                    lr: self.opts.lr.at(self.step - 1),
                    sim_seconds: self.clock.seconds,
                });
            }
        }
        Ok(hist)
    }
}

/// Build a logistic-regression workload from the default artifacts
/// (paper §5.1 experiments).
pub fn logreg_workload(
    rt: Rc<Runtime>,
    n: usize,
    samples_per_node: usize,
    non_iid: bool,
    seed: u64,
) -> Result<(Workload, Vec<f32>)> {
    let spec = rt.manifest.find("logreg", "grad", None)?.clone();
    let grad = GradFn::new(rt, &spec.name)?;
    let d = spec.flat_dim;
    let data = LogRegData::generate(n, d, samples_per_node, non_iid, seed);
    let init = model::logreg_layout(d).init(seed);
    Ok((Workload::LogReg { data, grad }, init))
}

/// Build the MLP classification workload (image-classification substitute).
pub fn mlp_workload(
    rt: Rc<Runtime>,
    n: usize,
    samples_per_node: usize,
    non_iid: bool,
    seed: u64,
) -> Result<(Workload, Vec<f32>)> {
    let spec = rt.manifest.find("mlp", "grad", None)?.clone();
    let in_dim = spec.meta_usize("in_dim").unwrap();
    let hidden = spec.meta_usize("hidden").unwrap();
    let classes = spec.meta_usize("classes").unwrap();
    let eval_spec = rt.manifest.find("mlp", "eval", None).ok().cloned();
    let grad = GradFn::new(rt.clone(), &spec.name)?;
    let eval = match eval_spec {
        Some(e) => Some(EvalFn::new(rt, &e.name)?),
        None => None,
    };
    let eval_batch = eval.as_ref().map(|e| e.spec.meta_usize("batch").unwrap_or(256)).unwrap_or(256);
    let data = ClusterData::generate(n, in_dim, classes, samples_per_node, eval_batch, non_iid, seed);
    let init = model::mlp_layout(in_dim, hidden, classes).init(seed);
    Ok((Workload::Mlp { data, grad, eval }, init))
}

/// Build the LM workload (BERT substitute) for a transformer config tag.
pub fn lm_workload(rt: Rc<Runtime>, tag: &str, seed: u64) -> Result<(Workload, Vec<f32>)> {
    let spec = rt.manifest.find("transformer", "grad", Some(tag))?.clone();
    let cfg = model::TransformerConfig {
        vocab: spec.meta_usize("vocab").unwrap(),
        d_model: spec.meta_usize("d_model").unwrap(),
        n_layers: spec.meta_usize("n_layers").unwrap(),
        n_heads: spec.meta_usize("n_heads").unwrap(),
        d_ff: spec.meta_usize("d_ff").unwrap(),
        seq_len: spec.meta_usize("seq_len").unwrap(),
    };
    let eval_spec = rt.manifest.find("transformer", "eval", Some(tag)).ok().cloned();
    let grad = GradFn::new(rt.clone(), &spec.name)?;
    let eval = match eval_spec {
        Some(e) => Some(EvalFn::new(rt, &e.name)?),
        None => None,
    };
    let corpus = TokenCorpus::new(cfg.vocab, 4, seed);
    let init = model::transformer_layout(&cfg).init(seed);
    Ok((Workload::Lm { corpus, grad, eval, seq_plus_one: cfg.seq_len + 1 }, init))
}

/// Evaluate the MLP workload's held-out accuracy at the mean parameters.
pub fn mlp_eval_accuracy(trainer: &Trainer) -> Result<Option<f32>> {
    if let Workload::Mlp { data, eval: Some(eval), .. } = &trainer.workload {
        let mean = trainer.mean_params();
        let batch = vec![
            lit_f32(&data.eval_x, &eval.spec.inputs[1].shape)?,
            lit_i32(&data.eval_y, &eval.spec.inputs[2].shape)?,
        ];
        return Ok(Some(eval.call(&mean, &batch)?));
    }
    Ok(None)
}

/// Evaluate the LM workload's held-out loss at the mean parameters.
pub fn lm_eval_loss(trainer: &Trainer, eval_batches: usize, seed: u64) -> Result<Option<f32>> {
    if let Workload::Lm { corpus, eval: Some(eval), seq_plus_one, .. } = &trainer.workload {
        let mean = trainer.mean_params();
        let b = eval.spec.meta_usize("batch").unwrap_or(8);
        let mut rng = Rng::new(seed ^ 0xE7A1);
        let mut toks = Vec::new();
        let mut total = 0.0f32;
        for _ in 0..eval_batches {
            corpus.sample_batch(b, *seq_plus_one, &mut rng, &mut toks);
            let batch = vec![lit_i32(&toks, &eval.spec.inputs[1].shape)?];
            total += eval.call(&mean, &batch)?;
        }
        return Ok(Some(total / eval_batches as f32));
    }
    Ok(None)
}
